"""Slotted page layout invariants (paper §3.3, Fig. 7)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import pages


records = st.lists(
    st.tuples(
        st.integers(min_value=0, max_value=2**31 - 1),      # vid
        st.integers(min_value=0, max_value=255),            # color
        st.binary(min_size=1, max_size=300),                # payload
    ),
    min_size=1,
    max_size=30,
    unique_by=lambda t: t[0],
)


@given(records)
@settings(max_examples=100, deadline=None)
def test_pack_lookup_roundtrip(entries):
    b = pages.PageBuilder()
    added = []
    for vid, color, payload in entries:
        if b.add(vid, color, payload):
            added.append((vid, color, payload))
    page = b.finalize()
    assert len(page) == pages.PAGE_SIZE
    assert pages.page_count(page) == len(added)
    for vid, color, payload in added:
        hit = pages.page_lookup(page, vid)
        assert hit is not None
        slot, data = hit
        assert data == payload
        assert slot.color == color


@given(records)
@settings(max_examples=50, deadline=None)
def test_slots_sorted_by_vid(entries):
    b = pages.PageBuilder()
    for vid, color, payload in entries:
        b.add(vid, color, payload)
    page = b.finalize()
    slots = pages.page_slots(page)
    vids = [s.vid for s in slots]
    assert vids == sorted(vids)


def test_lookup_missing_returns_none():
    b = pages.PageBuilder()
    b.add(5, 0, b"hello")
    page = b.finalize()
    assert pages.page_lookup(page, 4) is None
    assert pages.page_lookup(page, 6) is None


def test_two_way_growth_dense_packing():
    """Header+slots grow forward, heap backward; a full page wastes < one record."""
    b = pages.PageBuilder()
    payload = b"x" * 100
    vid = 0
    while b.add(vid, 0, payload):
        vid += 1
    page = b.finalize()
    util = pages.page_utilization(page)
    # free space must be smaller than one record+slot
    assert (1 - util) * pages.PAGE_SIZE < len(payload) + pages.SLOT_SIZE


def test_fixed_layout_fragmentation_grows_with_dim():
    """Fig. 6: fragmentation upper bound rises with dimensionality."""
    # record = d*4 vector + 260 adjacency bytes, page 4096
    utils = [
        pages.fixed_layout_utilization(d * 4 + 260)
        for d in (128, 512, 768, 960)
    ]
    frags = [1 - u for u in utils]
    assert frags[0] < 0.10            # SIFT-class: low fragmentation
    assert max(frags[1:]) > 0.20      # high-dim: severe fragmentation
    # GIST-like d=960: 4100B record spans 2 pages -> ~50% waste (paper: 52%)
    assert frags[3] == pytest.approx(0.5, abs=0.05)
