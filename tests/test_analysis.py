"""Protocol verifier (repro.analysis): lint rules, dynamic checker, explorer.

Three layers of coverage:

  * static lint — one firing and one clean fixture per rule, driven through
    ``run_lint_text`` with synthetic filenames (the determinism and purity
    rules are path-scoped to ``repro/core``), plus the repo-wide clean gate:
    ``run_lint(["src"])`` must return nothing, which is exactly what CI runs.
  * dynamic checker — ``_Buggy*Pool`` subclasses that each reintroduce one
    historic bug class (lost wakeup, skipped LOCKED window, double publish,
    leaked slot, quota drift); the checker watching them must name the right
    detector.  A clean pool driven through the same motions must stay silent.
  * schedule explorer — seed-0 identity is bitwise the unscheduled engine;
    ``verify_protocol`` is bitwise inert end to end; and the two regression
    replays from the issue: pipeann's wait_any tie-break decisions replay
    identically per query across >= 50 permuted interleavings, and the velo
    HBM staged-scatter boundary is deterministic under a fixed seed while
    results stay schedule-invariant across >= 50 seeds.
"""

import pathlib
import textwrap

import numpy as np
import pytest

from repro.analysis import registry, run_lint, run_lint_text
from repro.analysis.explore import (
    SchedulePolicy,
    _smoke_fixture,
    explore,
    normalize_results,
    run_system_under,
    scatter_sizes,
    smoke,
    trace_by_query,
)
from repro.analysis.protocol import ProtocolChecker, ProtocolError
from repro.core import baselines
from repro.core import workload as workload_mod
from repro.core.bufferpool import RESIDENT_BIT, RecordBufferPool, SlotState
from repro.core.search import SearchParams
from repro.core.serving import ServingPlane, TenantSpec

ROOT = pathlib.Path(__file__).resolve().parents[1]

# path-scoped rules (purity + determinism) key on "repro/core" in the name
CORE = "src/repro/core/fake.py"
ELSEWHERE = "src/repro/velo/fake.py"


def lint(src: str, filename: str = CORE):
    return run_lint_text(textwrap.dedent(src), filename)


def rules(findings) -> set:
    return {f.rule for f in findings}


# ===================================================== static lint fixtures


class TestOpRegistry:
    def test_unknown_op_fires(self):
        fs = lint("""
            def co(q):
                yield ("read", 1)
                yield ("frobnicate", 2)
        """)
        assert rules(fs) == {"op-unknown"}
        assert "frobnicate" in fs[0].message

    def test_non_protocol_module_is_silent(self):
        # a generator yielding unrelated tagged tuples never speaks the
        # engine protocol — no known op, no findings
        fs = lint("""
            def rows():
                yield ("status", "ok")
                yield ("status", "done")
        """)
        assert fs == []

    def test_arity_mismatch_fires(self):
        fs = lint("""
            def co(q):
                yield ("compute", 1, 2)
                yield ("load_wait", 5)
        """)
        assert rules(fs) == {"op-arity"}
        assert len(fs) == 2

    def test_correct_arities_clean(self):
        fs = lint("""
            def co(q):
                yield ("compute", 1)
                yield ("load_wait", 5, "tok")
                yield ("submit_cb", 3, None)
                yield ("wait_any", ["a", "b"])
        """)
        assert fs == []


def _dispatcher(*names: str) -> str:
    lines = ["def dispatch(kind):"]
    kw = "if"
    for name in names:
        lines.append(f'    {kw} kind == "{name}":')
        lines.append("        pass")
        kw = "elif"
    return "\n".join(lines) + "\n"


ALL_OPS = tuple(registry.ENGINE_OPS)  # every registered op, no hand copy


class TestOpDispatch:
    def test_missing_ops_fire(self):
        fs = lint(_dispatcher("compute", "score"))
        assert rules(fs) == {"op-dispatch"}
        assert "wait_any" in fs[0].message  # one of the missing ops is named

    def test_unregistered_name_fires(self):
        fs = lint(_dispatcher(*ALL_OPS, "frobnicate"))
        assert rules(fs) == {"op-dispatch"}
        assert "frobnicate" in fs[0].message

    def test_full_dispatcher_with_event_kinds_clean(self):
        fs = lint(_dispatcher(*ALL_OPS, "callback", "resume"))
        assert fs == []

    def test_event_kind_switch_is_not_a_dispatcher(self):
        # fewer than two registered ops compared: not an op dispatcher
        fs = lint("""
            def pump(kind):
                if kind == "callback":
                    return 1
                elif kind == "resume":
                    return 2
        """)
        assert fs == []


class TestBeginLoadPairing:
    def test_unclosed_window_fires(self):
        fs = lint("""
            def loader(pool, vid):
                pool.begin_load(vid)
        """)
        assert rules(fs) == {"begin-load-pairing"}

    def test_one_armed_branch_fires(self):
        fs = lint("""
            def loader(pool, vid, rec, ok):
                pool.begin_load(vid)
                if ok:
                    pool.finish_load(vid, rec)
        """)
        assert rules(fs) == {"begin-load-pairing"}

    def test_both_branches_close_clean(self):
        fs = lint("""
            def loader(pool, vid, rec, ok):
                pool.begin_load(vid)
                if ok:
                    pool.finish_load(vid, rec)
                else:
                    pool.abort_load(vid)
        """)
        assert fs == []

    def test_leniency_nested_callback_closes(self):
        fs = lint("""
            def loader(pool, ssd, vid):
                pool.begin_load(vid)
                def on_complete(rec):
                    pool.finish_load(vid, rec)
                ssd.submit(on_complete)
        """)
        assert fs == []

    def test_leniency_loop_body_closes(self):
        fs = lint("""
            def loader(pool, vids, recs):
                for v in vids:
                    pool.begin_load(v)
                for v, r in zip(vids, recs):
                    pool.finish_load(v, r)
        """)
        assert fs == []

    def test_leniency_transitive_closer(self):
        fs = lint("""
            def _publish(pool, vid, rec):
                pool.finish_load(vid, rec)

            def loader(pool, vid, rec):
                pool.begin_load(vid)
                _publish(pool, vid, rec)
        """)
        assert fs == []

    def test_leniency_return_delegation(self):
        fs = lint("""
            def reserve(pool, vid):
                return pool.begin_load(vid)
        """)
        assert fs == []

    def test_leniency_raise_path(self):
        fs = lint("""
            def loader(pool, vid):
                pool.begin_load(vid)
                raise RuntimeError("load backend gone")
        """)
        assert fs == []


class TestPublishInLocked:
    def test_publish_under_locked_fires(self):
        fs = lint("""
            def publish(self, slot, vid, rec):
                self.state[slot] = SlotState.LOCKED
                self.on_publish(vid, rec)
        """)
        assert rules(fs) == {"publish-in-locked"}
        assert "LOCKED" in fs[0].message

    def test_publish_without_state_write_fires(self):
        fs = lint("""
            def publish(self, vid, rec):
                self.on_publish(vid, rec)
        """)
        assert rules(fs) == {"publish-in-locked"}

    def test_publish_after_occupied_clean(self):
        fs = lint("""
            def publish(self, slot, vid, rec):
                self.state[slot] = SlotState.OCCUPIED
                self.on_publish(vid, rec)
        """)
        assert fs == []


class TestCoroutinePurity:
    FIRING = """
        def search(ctx, q):
            rec = ctx.pool.lookup(0)
            yield ("read", 1)
    """

    def test_blocking_call_in_module_coroutine_fires(self):
        fs = lint(self.FIRING)
        assert "blocking-call-in-coroutine" in rules(fs)

    def test_accessor_method_is_the_allowed_layer(self):
        fs = lint("""
            class Accessor:
                def fetch(self, vid):
                    rec = self.pool.lookup(vid)
                    yield ("read", 1)
        """)
        assert fs == []

    def test_rule_is_scoped_to_core(self):
        assert lint(self.FIRING, ELSEWHERE) == []


class TestWallClock:
    FIRING = """
        import time

        def stamp():
            return time.perf_counter()
    """

    def test_host_clock_in_core_fires(self):
        fs = lint(self.FIRING)
        assert rules(fs) == {"wall-clock"}

    def test_rule_is_scoped_to_core(self):
        assert lint(self.FIRING, ELSEWHERE) == []


class TestUnseededRng:
    def test_unseeded_default_rng_fires(self):
        fs = lint("""
            import numpy as np
            rng = np.random.default_rng()
        """)
        assert rules(fs) == {"unseeded-rng"}

    def test_legacy_global_rng_fires(self):
        fs = lint("""
            import numpy as np
            x = np.random.rand(3)
        """)
        assert rules(fs) == {"unseeded-rng"}

    def test_stdlib_random_fires(self):
        fs = lint("""
            import random
            y = random.random()
        """)
        assert rules(fs) == {"unseeded-rng"}

    def test_seeded_generator_clean(self):
        fs = lint("""
            import numpy as np

            def draw(seed):
                rng = np.random.default_rng(seed)
                return rng.integers(0, 10)
        """)
        assert fs == []


class TestSetIteration:
    def test_iterating_named_set_fires(self):
        fs = lint("""
            pending = {1, 2, 3}
            for x in pending:
                print(x)
        """)
        assert rules(fs) == {"set-iteration"}

    def test_iterating_set_literal_fires(self):
        fs = lint("""
            for x in {1, 2}:
                print(x)
        """)
        assert rules(fs) == {"set-iteration"}

    def test_closure_over_enclosing_set_fires(self):
        # the historic hazard: a nested function iterating a set bound in
        # the enclosing scope
        fs = lint("""
            def outer():
                pending = set()
                def drain():
                    for x in pending:
                        print(x)
                return drain
        """)
        assert rules(fs) == {"set-iteration"}

    def test_rebound_to_sorted_clean(self):
        fs = lint("""
            s = {1, 2}
            s = sorted(s)
            for x in s:
                print(x)
        """)
        assert fs == []

    def test_dict_iteration_clean(self):
        fs = lint("""
            d = {}
            for k in d:
                print(k)
        """)
        assert fs == []


def test_repo_source_tree_is_lint_clean():
    """The gate CI runs: the whole src/ tree under every rule, zero findings."""
    assert run_lint([str(ROOT / "src")]) == []


def test_finding_format():
    fs = lint("""
        def loader(pool, vid):
            pool.begin_load(vid)
    """)
    assert fs[0].format().startswith(f"{CORE}:3: [begin-load-pairing]")


# ================================================ dynamic protocol checker


def _pool(n_slots=4, n_vids=16, cls=RecordBufferPool, **kw):
    pages = np.arange(n_vids, dtype=np.int64)
    return cls(n_slots, pages, **kw)


def _watched(pool):
    checker = ProtocolChecker()
    checker.watch_pool(pool)
    return checker


class _LostWakeupPool(RecordBufferPool):
    """finish_load publishes but silently drops the parked waiters."""

    def finish_load(self, vid, record):
        slot = self._slot_of(vid)
        self.slots[slot] = record
        self.state[slot] = SlotState.OCCUPIED
        self.waiters.pop(vid, None)  # BUG: no resumes queued
        return slot


class _SkipLockWindowPool(RecordBufferPool):
    """begin_load installs straight to OCCUPIED — no LOCKED window, so
    concurrent searchers can never coalesce on the in-flight load."""

    def begin_load(self, vid):
        if self.is_resident(vid):
            return self._slot_of(vid)
        slot = self._acquire_slot(vid)
        if slot < 0:
            return -1
        self.state[slot] = SlotState.OCCUPIED  # BUG: skips LOCKED
        self.slot_vid[slot] = vid
        self.slots[slot] = None
        self.record_map[vid] = RESIDENT_BIT | np.uint64(slot)
        self._claim(slot, vid)
        return slot


class _DoublePublishPool(RecordBufferPool):
    """Duplicate admit re-fires the publish hook instead of keep-first."""

    def admit(self, vid, record):
        if (self.is_resident(vid)
                and self.state[self._slot_of(vid)] != SlotState.LOCKED):
            if self.on_publish is not None:
                self.on_publish(vid, record)  # BUG: second fire while resident
            return self._slot_of(vid)
        return super().admit(vid, record)


class _SlotLeakPool(RecordBufferPool):
    """Eviction forgets to return the freed slot to the free list."""

    def _evict_slot(self, slot):
        vid = int(self.slot_vid[slot])
        self.record_map[vid] = np.uint64(self.disk_pages[vid])
        self.slot_vid[slot] = -1
        self.slots[slot] = None
        self.slot_group[slot] = 0
        self._release(slot)
        self.state[slot] = SlotState.FREE
        self.evictions += 1
        # BUG: free_list.append(slot) missing


class _QuotaDriftPool(RecordBufferPool):
    """Slot claims stop updating the per-tenant ownership counter."""

    def _claim(self, slot, vid):
        t = self._tenant(vid)
        self.slot_tenant[slot] = t
        self.tenant_slots[t].add(slot)
        # BUG: tenant_owned[t] never incremented


class TestProtocolChecker:
    def test_clean_pool_stays_silent(self):
        pool = _pool(n_slots=3)
        checker = _watched(pool)
        # async window with a coalescing waiter
        pool.begin_load(0)
        pool.add_waiter(0, "searcher")
        pool.finish_load(0, "rec0")
        assert pool.take_resumes() == [("searcher", "rec0")]
        # demand admits past capacity force clock evictions
        for vid in range(1, 8):
            pool.admit(vid, f"rec{vid}")
        pool.admit_group([8, 9], ["rec8", "rec9"])
        pool.lookup(9)
        pool.abort_load(10)  # no-op: not loading
        checker.at_flush()
        checker.at_end()
        checker.raise_if_violations()
        assert checker.ok()
        assert checker.calls["begin_load"] == 1
        assert checker.calls["finish_load"] == 1
        assert checker.calls["admit"] == 7
        assert checker.flushes == 1

    def test_lost_wakeup_detected(self):
        pool = _pool(cls=_LostWakeupPool)
        checker = _watched(pool)
        pool.begin_load(0)
        pool.add_waiter(0, "searcher")
        pool.finish_load(0, "rec")
        assert "lost-wakeup" in {v.rule for v in checker.violations}
        with pytest.raises(ProtocolError, match="lost-wakeup"):
            checker.raise_if_violations()

    def test_parked_waiter_surviving_the_run_is_a_lost_wakeup(self):
        pool = _pool()
        checker = _watched(pool)
        pool.begin_load(0)
        pool.add_waiter(0, "searcher")
        checker.at_end()  # the run "drained" with a waiter still parked
        assert "lost-wakeup" in {v.rule for v in checker.violations}

    def test_skipped_locked_window_is_a_bad_transition(self):
        pool = _pool(cls=_SkipLockWindowPool)
        checker = _watched(pool)
        pool.begin_load(0)
        bad = [v for v in checker.violations if v.rule == "bad-transition"]
        assert bad and "FREE -> OCCUPIED" in bad[0].detail

    def test_double_publish_detected(self):
        pool = _pool(cls=_DoublePublishPool)
        checker = _watched(pool)
        pool.admit(0, "rec")
        assert checker.ok()  # first publish is legitimate
        pool.admit(0, "rec")  # duplicate admit re-fires the hook
        assert "double-publish" in {v.rule for v in checker.violations}

    def test_evicted_vid_may_republish(self):
        pool = _pool(n_slots=2)
        checker = _watched(pool)
        for vid in range(6):  # wraps the 2-slot pool repeatedly
            pool.admit(vid, f"rec{vid}")
        pool.admit(0, "rec0-again")  # 0 was evicted: legitimate re-publish
        checker.at_end()
        assert checker.ok()

    def test_slot_leak_detected_at_flush(self):
        pool = _pool(cls=_SlotLeakPool, n_slots=3)
        checker = _watched(pool)
        for vid in range(3):
            pool.admit(vid, f"rec{vid}")
        pool.run_clock(target=1)  # buggy eviction drops the slot
        checker.at_flush()
        leaks = [v for v in checker.violations if v.rule == "slot-leak"]
        assert leaks and "free list" in leaks[0].detail

    def test_quota_accounting_drift_detected(self):
        pool = _pool(cls=_QuotaDriftPool)
        checker = _watched(pool)
        pool.admit(0, "rec")
        checker.at_flush()
        assert "quota-accounting" in {v.rule for v in checker.violations}

    def test_wrapping_is_observational(self):
        """A watched pool and a bare pool driven identically end in the same
        state — the checker must never perturb what it observes."""
        drive = lambda p: (
            p.begin_load(0), p.add_waiter(0, "w"), p.finish_load(0, "r0"),
            [p.admit(v, f"r{v}") for v in range(1, 7)],
            p.admit_group([8, 9], ["r8", "r9"]),
        )
        bare, watched = _pool(), _pool()
        _watched(watched)
        drive(bare)
        drive(watched)
        assert (bare.state == watched.state).all()
        assert (bare.slot_vid == watched.slot_vid).all()
        assert (bare.record_map == watched.record_map).all()
        assert bare.pressure_stats() == watched.pressure_stats()


# ======================================== end-to-end verify_protocol wiring


@pytest.fixture(scope="module")
def small():
    return _smoke_fixture()


def _norm(results):
    return normalize_results(results)


def _build_and_run(small, name, verify, hbm=False, **cfg_kw):
    ds, graph, qb = small
    cfg = baselines.SystemConfig(
        n_workers=2, batch_size=4, buffer_ratio=0.3,
        hbm_tier=hbm, verify_protocol=verify, **cfg_kw,
    )
    system = baselines.build_system(name, ds.base, graph, qb, config=cfg)
    results, stats = system.run(ds.queries)
    return system, results


@pytest.mark.parametrize("algo,hbm", [
    ("velo", False), ("velo", True), ("pipeann", False), ("diskann", False),
])
def test_verify_protocol_is_bitwise_inert(small, algo, hbm):
    """verify_protocol=True must observe, never perturb: results identical
    to the unverified run, zero violations, and the checker demonstrably saw
    traffic (calls + flush boundaries)."""
    _, ref = _build_and_run(small, algo, verify=False, hbm=hbm)
    system, got = _build_and_run(small, algo, verify=True, hbm=hbm)
    assert _norm(got) == _norm(ref)
    assert system.checker is not None
    system.checker.raise_if_violations()
    assert system.checker.flushes > 0
    if getattr(system.ctx.accessor, "pool", None) is not None:
        # record-pool systems: the checker saw real pool traffic
        assert sum(system.checker.calls.values()) > 0
    if hbm:
        assert any(k.startswith("hbm.") for k in system.checker.calls)


def test_verify_protocol_on_serving_plane(small):
    """The plane wires the checker across the shared pool + every tenant's
    HBM tier; a quota-enabled mixed workload must run violation-free and
    bitwise match the unverified plane."""
    ds, graph, qb = small
    specs = [
        TenantSpec.from_dataset(f"t{i}", ds, graph, qb, system="velo",
                                params=SearchParams(L=24, W=4, prefetch=False))
        for i in range(2)
    ]
    nq = len(ds.queries)
    wload = workload_mod.zipfian_mix([nq, nq], 40, s=1.5, seed=0)

    def run(verify):
        cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4,
                                     tenant_quota=0.6,
                                     verify_protocol=verify)
        plane = ServingPlane(specs, cfg, shared_pool=True)
        return plane, plane.run(wload)

    _, ref = run(False)
    plane, got = run(True)
    for t_ref, t_got in zip(ref.tenants, got.tenants):
        assert _norm(t_got.results) == _norm(t_ref.results)
    assert plane.checker is not None
    plane.checker.raise_if_violations()
    assert plane.checker.flushes > 0


# ============================================== schedule explorer contracts


def test_seed0_policy_is_identity():
    pol = SchedulePolicy(0)
    assert [pol.event_rank(s) for s in range(5)] == [0] * 5
    assert [pol.worker_rank(w) for w in range(8)] == list(range(8))
    pol.note(("wait_any", 3, 7))
    assert pol.trace == [("wait_any", 3, 7)]


def test_seeded_policy_permutes_and_is_reproducible():
    a, b = SchedulePolicy(11), SchedulePolicy(11)
    ranks_a = [a.event_rank(s) for s in range(64)]
    ranks_b = [b.event_rank(s) for s in range(64)]
    assert ranks_a == ranks_b  # same seed, same rank stream
    assert len(set(ranks_a)) > 1
    assert [a.worker_rank(w) for w in range(8)] != list(range(8)) or \
           [a.worker_rank(w) for w in range(8, 16)] != list(range(8, 16))


def test_seed0_schedule_is_bitwise_the_unscheduled_engine(small):
    _, ref = _build_and_run(small, "velo", verify=True)  # schedule=None
    got = run_system_under(SchedulePolicy(0), "velo", fixture=small)
    assert _norm(got) == _norm(ref)


def test_trace_helpers():
    trace = [("wait_any", 1, 5), ("scatter", 3), ("wait_any", 0, 2),
             ("wait_any", 1, 6), ("scatter", 8)]
    assert trace_by_query(trace) == {
        1: [("wait_any", 1, 5), ("wait_any", 1, 6)],
        0: [("wait_any", 0, 2)],
    }
    assert scatter_sizes(trace) == [3, 8]


def test_normalize_results_hops_flag():
    class R:
        ids = [np.int64(3)]
        dists = [np.float32(0.5)]
        hops = 7
    with_hops = normalize_results([R()])
    without = normalize_results([R()], include_hops=False)
    assert with_hops == (((3,), (0.5,), 7),)
    assert without == (((3,), (0.5,)),)


def test_smoke_reports_invariant_and_nonvacuous():
    reports = smoke(algorithms=("diskann",), n_schedules=2, hbm_for=())
    reps = reports["diskann"]
    assert len(reps) == 3  # baseline + 2 seeds
    assert all(r.equal for r in reps)
    assert sum(r.ties["event"] + r.ties["worker"] for r in reps[1:]) > 0


# ------------------------- issue regressions: >= 50 explored interleavings


N_SCHEDULES = 50


def test_pipeann_wait_any_replays_across_50_interleavings(small):
    """pipeann's multi-submit wait_any tie-break: across >= 50 permuted
    schedules the results are bitwise invariant AND each query's sequence of
    wait_any resolutions replays identically — the tie-break is a function
    of the query, not of the interleaving.  (The protocol checker's parity
    is pinned separately; these loops run unverified for speed.)"""
    def run_under(policy):
        return run_system_under(policy, "pipeann", verify=False,
                                fixture=small)

    reports = explore(run_under, range(1, N_SCHEDULES + 1))
    assert all(r.equal for r in reports), \
        [r.first_diff for r in reports if not r.equal]
    # non-vacuous: the permuted schedules genuinely had choices to make
    assert sum(r.ties["worker"] + r.ties["event"] for r in reports[1:]) > 0
    base = trace_by_query(reports[0].trace)
    assert base  # pipeann recorded wait_any decisions at all
    for r in reports[1:]:
        assert trace_by_query(r.trace) == base, f"seed {r.seed} diverged"


def test_velo_hbm_scatter_invariant_across_50_interleavings(small):
    """The HBM staged-scatter boundary: results bitwise invariant across
    >= 50 interleavings (cbs off — the cache-aware pivot is legitimately
    schedule-adaptive), and the scatter boundary sequence is deterministic
    under a FIXED seed.  Cross-seed the boundary sizes may legitimately
    shift (publish-vs-flush timing), which is exactly why the replay unit
    is same-seed."""
    def run_under(policy):
        return run_system_under(policy, "velo", hbm_tier=True, verify=False,
                                params=SearchParams(cbs=False), fixture=small)

    reports = explore(run_under, range(1, N_SCHEDULES + 1))
    assert all(r.equal for r in reports), \
        [r.first_diff for r in reports if not r.equal]
    assert sum(r.ties["worker"] + r.ties["event"] for r in reports[1:]) > 0
    assert sum(len(scatter_sizes(r.trace)) for r in reports) > 0
    for seed in (0, 7, 23):
        p1, p2 = SchedulePolicy(seed), SchedulePolicy(seed)
        run_under(p1)
        run_under(p2)
        assert p1.trace == p2.trace, f"seed {seed}: trace not deterministic"
        assert scatter_sizes(p1.trace) == scatter_sizes(p2.trace)


def test_registry_covers_sla_arrival_events():
    """The lint gate's push_event coverage: the SLA scheduler's "arrival"
    kind is registered, so the heap-kind lint rule keeps watching the
    scheduler loop instead of whitelisting it."""
    assert "arrival" in registry.EVENT_KINDS
    assert registry.EVENT_KINDS >= {"callback", "resume"}


def test_sla_edf_schedule_invariant_with_slack_ties(small):
    """The scheduler row of the explorer (satellite of the SLA PR): a
    pure-EDF serving plane (feedback off) under burst arrivals must be
    bitwise schedule-invariant, and the permuted schedules must have hit
    genuine equal-slack ties (equal deadlines from burst-clustered
    arrivals) — a zero tie count would make the pass vacuous.  The feedback
    controller is deliberately OFF here: its steering is input-adaptive
    with respect to completion timing, the same carve-out as velo's cbs
    pivot (see explore.run_sla_under)."""
    from repro.analysis.explore import run_sla_under

    def run_under(policy):
        return run_sla_under(policy, fixture=small)

    reports = explore(run_under, [7, 8])
    assert all(r.equal for r in reports), \
        [r.first_diff for r in reports if not r.equal]
    assert sum(r.ties["slack"] for r in reports[1:]) > 0
