"""Hypothesis property + stateful coverage of the Fig. 5 state machine.

The `RuleBasedStateMachine` drives arbitrary interleavings of the pool's full
public surface — lookup / begin_load / finish_load / abort_load / admit /
admit_group / run_clock — the way racing search coroutines (across all
workers sharing the one pool) would, and calls ``check_invariants()`` after
every rule.  A lightweight model mirrors only what the clock cannot disturb:
the set of open LOCKED windows (LOCKED slots are never evicted), the records
published for each vid (lookup must return the FIRST admitted record or
None — never a stale or foreign one), and waiter conservation
(parked == resumed + still-waiting).

Run in CI with a pinned seed and no deadline (see .github/workflows/ci.yml):

    PYTHONPATH=src python -m pytest -q tests/test_bufferpool_stateful.py \
        --hypothesis-seed=0
"""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st
from hypothesis.stateful import (
    RuleBasedStateMachine,
    initialize,
    invariant,
    rule,
)

from repro.core.bufferpool import RESIDENT_BIT, RecordBufferPool, SlotState

N_RECORDS = 64
VIDS = st.integers(min_value=0, max_value=N_RECORDS - 1)


def make_pool(n_slots=8, n_records=N_RECORDS, **kw):
    vid_to_page = np.arange(n_records) // 4
    return RecordBufferPool(n_slots, vid_to_page, **kw)


def make_tenant_pool(n_slots=8, n_records=N_RECORDS, n_tenants=3, quota=None,
                     **kw):
    vid_to_page = np.arange(n_records) // 4
    tenant_of = np.arange(n_records) % n_tenants
    return RecordBufferPool(n_slots, vid_to_page, tenant_of=tenant_of,
                            tenant_quota=quota, **kw)


# ------------------------------------------------------------ property tests


@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["lookup", "admit", "clock"]),
                  st.integers(min_value=0, max_value=63)),
        min_size=1, max_size=300,
    ),
    n_slots=st.integers(min_value=1, max_value=16),
)
@settings(max_examples=100, deadline=None)
def test_state_machine_invariants(ops, n_slots):
    """Arbitrary op sequences never violate the Fig. 5 state machine."""
    pool = make_pool(n_slots=n_slots)
    for op, vid in ops:
        if op == "lookup":
            rec = pool.lookup(vid)
            if rec is not None:
                assert rec == f"r{vid}"
        elif op == "admit":
            if not pool.is_resident(vid):
                pool.admit(vid, f"r{vid}")
            slot = int(pool.record_map[vid] & ~RESIDENT_BIT)
            assert pool.state[slot] in (SlotState.OCCUPIED, SlotState.MARKED)
        else:
            pool.run_clock(target=1 + vid % 3)
        pool.check_invariants()


@given(
    n_slots=st.integers(min_value=1, max_value=8),
    locked=st.lists(st.booleans(), min_size=8, max_size=8),
    vids=st.lists(st.integers(min_value=8, max_value=63), min_size=1, max_size=20),
)
@settings(max_examples=100, deadline=None)
def test_admit_under_locked_slots_never_crashes(n_slots, locked, vids):
    """Admissions into a pool with an arbitrary subset of LOCKED slots (all
    the way to fully locked) either succeed or return -1 — never crash, never
    corrupt the state machine, never evict a LOCKED slot."""
    pool = make_pool(n_slots=n_slots)
    for s in range(n_slots):
        if locked[s]:
            assert pool.begin_load(s) >= 0    # open LOCKED window for vid s
        else:
            pool.admit(s, f"r{s}")
    locked_vids = {s for s in range(n_slots) if locked[s]}
    for vid in vids:
        slot = pool.admit(vid, f"r{vid}")
        if slot == -1:
            assert all(pool.state == SlotState.LOCKED)
            assert not pool.is_resident(vid)
        else:
            assert pool.lookup(vid) == f"r{vid}"
        pool.check_invariants()
    for v in locked_vids:  # in-flight loads must never have been evicted
        assert pool.is_loading(v)


# ------------------------------------------------------------ stateful suite


class PoolMachine(RuleBasedStateMachine):
    """Arbitrary interleavings of the async pool API (Fig. 5 + waiter lists)."""

    @initialize(n_slots=st.integers(min_value=1, max_value=12),
                group_demote=st.booleans())
    def setup(self, n_slots, group_demote):
        self.pool = make_pool(n_slots=n_slots, group_demote=group_demote)
        self.loading: set[int] = set()       # open LOCKED windows, by vid
        self.published: dict[int, str] = {}  # vid -> FIRST record ever kept
        self.waiter_seq = 0
        self.parked: set[str] = set()        # waiters not yet resumed
        self.resumed: list[tuple[str, object]] = []

    # ---- rules -----------------------------------------------------------

    @rule(vid=VIDS)
    def lookup(self, vid):
        rec = self.pool.lookup(vid)
        if rec is not None:
            assert rec == self.published[vid], "lookup must serve FIRST record"
        else:
            # a miss is correct only if the record is absent, loading, or was
            # evicted (published set only tracks what was once admitted)
            assert self.pool.status(vid) in ("absent", "loading")

    @rule(vid=VIDS)
    def begin_load(self, vid):
        st_before = self.pool.status(vid)
        slot = self.pool.begin_load(vid)
        if st_before != "absent":
            assert slot == self.pool._slot_of(vid)  # no duplicate windows
        elif slot >= 0:
            self.loading.add(vid)
        else:
            assert self.pool.status(vid) == "absent"

    @rule(vid=VIDS)
    def finish_load(self, vid):
        before = self.pool.status(vid)
        rec = f"load-{vid}"
        self.pool.finish_load(vid, rec)
        if before == "loading":
            self.loading.discard(vid)
            self.published[vid] = rec
        elif before == "absent" and self.pool.status(vid) == "present":
            self.published[vid] = rec  # degraded to a plain admit
        # before == "present": keep-first — published stays unchanged

    @rule(vid=VIDS)
    def abort_load(self, vid):
        self.pool.abort_load(vid)
        if vid in self.loading:
            self.loading.discard(vid)
            assert self.pool.status(vid) == "absent"

    @rule(vid=VIDS)
    def add_waiter(self, vid):
        if not self.pool.is_loading(vid):
            return
        name = f"w{self.waiter_seq}"
        self.waiter_seq += 1
        self.pool.add_waiter(vid, name)
        self.parked.add(name)

    @rule(vid=VIDS)
    def admit(self, vid):
        before = self.pool.status(vid)
        rec = f"admit-{vid}"
        slot = self.pool.admit(vid, rec)
        if before == "loading":
            # demand admit publishes the open window (duplicate-admit race)
            self.loading.discard(vid)
            self.published[vid] = rec
            assert slot >= 0
        elif before == "absent" and slot >= 0:
            self.published[vid] = rec
        # before == "present": keep-first — published stays unchanged

    @rule(vids=st.lists(VIDS, min_size=1, max_size=6))
    def admit_group(self, vids):
        # duplicates allowed on purpose: in-batch dups must keep-first, not
        # double-allocate (regression: the mapping array corrupted otherwise)
        before = {v: self.pool.status(v) for v in vids}
        self.pool.admit_group(vids, [f"group-{v}" for v in vids])
        for v in vids:
            if before[v] == "loading":
                assert self.pool.is_loading(v), "groups must skip LOCKED vids"
            elif before[v] == "absent" and self.pool.status(v) == "present":
                self.published[v] = f"group-{v}"

    @rule(target_n=st.integers(min_value=0, max_value=6))
    def run_clock(self, target_n):
        self.pool.run_clock(target=target_n)

    @rule()
    def drain_resumes(self):
        for waiter, rec in self.pool.take_resumes():
            assert waiter in self.parked
            self.parked.discard(waiter)
            self.resumed.append((waiter, rec))

    # ---- invariants (checked after EVERY rule) ---------------------------

    @invariant()
    def structural(self):
        self.pool.check_invariants()

    @invariant()
    def locked_windows_match_model(self):
        pool_loading = {v for v in range(N_RECORDS) if self.pool.is_loading(v)}
        assert pool_loading == self.loading

    @invariant()
    def present_records_are_first_kept(self):
        for v in range(N_RECORDS):
            if self.pool.status(v) == "present":
                slot = self.pool._slot_of(v)
                assert self.pool.slots[slot] == self.published[v]

    @invariant()
    def waiters_conserved(self):
        in_lists = sum(len(ws) for ws in self.pool.waiters.values())
        in_queue = len(self.pool.pending_resumes)
        assert self.pool.lock_waits == len(self.resumed) + in_lists + in_queue
        # waiters only ever park on open windows
        assert set(self.pool.waiters) <= self.loading


TestPoolMachine = PoolMachine.TestCase
TestPoolMachine.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None
)


# ------------------------------------------------ multi-tenant quota machine


class TenantPoolMachine(RuleBasedStateMachine):
    """Arbitrary interleavings of the pool API over a MULTI-TENANT pool with
    soft quotas (the serving plane's shared pool): vids round-robin three
    tenants, and after every rule the quota accounting must match actual slot
    ownership exactly, with no tenant above its cap and no LOCKED slot ever
    reclaimed by quota pressure.  Deterministic replays of the same rules
    live in tests/test_bufferpool.py (the hypothesis-free pre-validation)."""

    @initialize(
        n_slots=st.integers(min_value=2, max_value=12),
        quota=st.sampled_from([None, 0.25, 0.4, 0.6, 1.0]),
        group_demote=st.booleans(),
    )
    def setup(self, n_slots, quota, group_demote):
        self.pool = make_tenant_pool(
            n_slots=n_slots, quota=quota, group_demote=group_demote
        )
        self.loading: set[int] = set()

    @rule(vid=VIDS)
    def lookup(self, vid):
        self.pool.lookup(vid)

    @rule(vid=VIDS)
    def begin_load(self, vid):
        absent = self.pool.status(vid) == "absent"
        if self.pool.begin_load(vid) >= 0 and absent:
            self.loading.add(vid)

    @rule(vid=VIDS)
    def finish_load(self, vid):
        self.pool.finish_load(vid, f"load-{vid}")
        self.loading.discard(vid)

    @rule(vid=VIDS)
    def abort_load(self, vid):
        self.pool.abort_load(vid)
        self.loading.discard(vid)

    @rule(vid=VIDS)
    def admit(self, vid):
        self.pool.admit(vid, f"admit-{vid}")
        self.loading.discard(vid)  # a demand admit publishes an open window

    @rule(base=VIDS, width=st.integers(min_value=1, max_value=4))
    def admit_group(self, base, width):
        # co-resident groups come from ONE tenant's page: stride by the
        # tenant count so every member maps to the same tenant
        vids = [(base + 3 * i) % N_RECORDS for i in range(width)]
        self.pool.admit_group(vids, [f"group-{v}" for v in vids])

    @rule(target_n=st.integers(min_value=0, max_value=6))
    def run_clock(self, target_n):
        self.pool.run_clock(target=target_n)

    @rule()
    def drain_resumes(self):
        self.pool.take_resumes()

    @invariant()
    def structural_and_quota_accounting(self):
        # check_invariants recounts slot ownership per tenant and asserts it
        # equals tenant_owned, and that no tenant exceeds its cap
        self.pool.check_invariants()

    @invariant()
    def locked_windows_survive_quota_pressure(self):
        for v in self.loading:
            assert self.pool.is_loading(v), (
                "an open LOCKED window was torn down by quota reclaim"
            )

    @invariant()
    def ownership_totals(self):
        assert int(self.pool.tenant_owned.sum()) == self.pool.occupancy()


TestTenantPoolMachine = TenantPoolMachine.TestCase
TestTenantPoolMachine.settings = settings(
    max_examples=60, stateful_step_count=50, deadline=None
)
