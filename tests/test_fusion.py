"""Cross-query fused score dispatch: parity + regression tests.

The contract: routing distance work through the engine's rendezvous buffer
(``EngineConfig.fuse``) must not change what any search returns.

  * With one coroutine per worker (B=1) a rendezvous holds a single request,
    which the distance plane executes on the exact per-query code path — so
    fused results are BYTE-IDENTICAL (ids, hops, page reads, and distances)
    to per-query dispatch for all five algorithms.  Velo's stride prefetch is
    the one schedule-sensitive piece (suspension points decide when prefetch
    completions land in the pool — the same reason tests/test_engine.py
    excludes it from async==sync equality), so velo runs here without it.
  * At B>1 fusion genuinely interleaves queries; cache-oblivious searches
    still return identical neighbors, and the schedule-sensitive velo
    configuration keeps recall parity.
  * The fused multi-query engine primitives (estimate_many / refine_many /
    refine_full_many) match the per-query calls row-for-row on every backend.

Also here: regression tests for the engine accounting fixes that rode along
with the fusion PR (token leaks, coalesced-read charging, nearest-rank p99).
"""

import numpy as np
import pytest

from repro.core import baselines, distance
from repro.core.engine import run_workload
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS
from repro.core.sim import SSD, CostModel, WorkloadStats

ALGOS = sorted(ALGORITHMS)  # diskann, inmemory, pipeann, starling, velo
N_QUERIES = 16


def _ids(results, k=10):
    out = np.full((len(results), k), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(k, len(r.ids))
        out[i, :m] = r.ids[:m]
    return out


def _run(name, ds, graph, qb, *, fuse, B=1, fuse_rows=256, params=None,
         n_queries=N_QUERIES):
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        batch_size=B,
        fuse=fuse,
        fuse_rows=fuse_rows,
        params=params or baselines.SearchParams(L=32, W=4, prefetch=False),
    )
    sys_ = baselines.build_system(name, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries[:n_queries])
    return sys_, results, stats


# ----------------------------------------------------- end-to-end parity


@pytest.mark.parametrize("algo", ALGOS)
def test_fused_byte_identical_all_algorithms(algo, small_ds, small_graph, small_qb):
    """B=1: fused dispatch == per-query dispatch, bit for bit."""
    _, ref, _ = _run(algo, small_ds, small_graph, small_qb, fuse=False)
    _, got, _ = _run(algo, small_ds, small_graph, small_qb, fuse=True)
    for i, (r0, r1) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(r0.ids, r1.ids, err_msg=f"{algo} q{i}: ids")
        assert r0.hops == r1.hops, f"{algo} q{i}: hops"
        assert r0.reads == r1.reads, f"{algo} q{i}: reads"
        np.testing.assert_array_equal(r0.dists, r1.dists, err_msg=f"{algo} q{i}: dists")


def test_fused_async_identical_ids(small_ds, small_graph, small_qb):
    """B=8 on the cache-oblivious config: fusing frontiers across the eight
    in-flight queries must not change any query's neighbors."""
    params = baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False)
    outs = {}
    for fuse in (False, True):
        cfg = baselines.SystemConfig(batch_size=8, buffer_ratio=0.2, fuse=fuse,
                                     params=params)
        sys_ = baselines.build_system("+record", small_ds.base, small_graph,
                                      small_qb, cfg)
        results, _ = sys_.run(small_ds.queries[:40])
        outs[fuse] = _ids(results)
    np.testing.assert_array_equal(outs[False], outs[True])


def test_fused_velo_recall_parity(small_ds, small_graph, small_qb):
    """Default velo (prefetch + cbs) is schedule-sensitive; fusion may change
    individual traversals but must keep recall."""
    from repro.core.dataset import recall_at_k

    recalls = {}
    for fuse in (False, True):
        cfg = baselines.SystemConfig(batch_size=8, buffer_ratio=0.2, fuse=fuse)
        sys_ = baselines.build_system("velo", small_ds.base, small_graph,
                                      small_qb, cfg)
        results, _ = sys_.run(small_ds.queries)
        recalls[fuse] = recall_at_k(_ids(results), small_ds.groundtruth, 10)
    assert abs(recalls[False] - recalls[True]) < 0.05, recalls


def test_fusion_reduces_dispatches(small_ds, small_graph, small_qb):
    """The whole point: B=8 fused must issue fewer kernel dispatches, fusing
    several queries' rows per flush."""
    params = baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False)
    sys_u, _, stats_u = _run("+record", small_ds, small_graph, small_qb,
                             fuse=False, B=8, params=params, n_queries=40)
    sys_f, _, stats_f = _run("+record", small_ds, small_graph, small_qb,
                             fuse=True, B=8, params=params, n_queries=40)
    assert sys_f.ctx.dist.stats.dispatches() < 0.7 * sys_u.ctx.dist.stats.dispatches()
    assert stats_f.requests_per_flush > 1.5
    assert stats_u.score_flushes == 0  # rendezvous counters are fusion-only
    assert sys_f.ctx.dist.stats.fused_queries >= sys_f.ctx.dist.stats.fused_calls


def test_fuse_rows_budget_caps_flush(small_ds, small_graph, small_qb):
    """A tiny row budget must force small rendezvous batches."""
    params = baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False)
    _, _, tight = _run("+record", small_ds, small_graph, small_qb, fuse=True,
                       B=8, fuse_rows=8, params=params)
    _, _, loose = _run("+record", small_ds, small_graph, small_qb, fuse=True,
                       B=8, fuse_rows=4096, params=params)
    assert tight.rows_per_flush <= loose.rows_per_flush + 1e-9


# ------------------------------------------ fused engine primitives


@pytest.fixture(scope="module")
def pqs(small_ds, small_qb):
    return [
        RabitQuantizer.prepare_query(small_qb, small_ds.queries[i])
        for i in range(3)
    ]


@pytest.mark.parametrize("backend", ["scalar", "batch", "pallas"])
def test_estimate_many_matches_per_query(backend, small_qb, pqs, rng):
    eng = distance.get_engine(backend)
    groups = [
        (pq, rng.integers(0, small_qb.norms.shape[0], m))
        for pq, m in zip(pqs, (5, 64, 17))
    ]
    fused = eng.estimate_many(small_qb, groups)
    for (pq, ids), out in zip(groups, fused):
        ref = distance.get_engine(backend).estimate(small_qb, pq, ids)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    assert eng.stats.fused_calls == 1 and eng.stats.fused_queries == 3
    assert eng.stats.level1_calls == 1  # one dispatch served three queries


@pytest.mark.parametrize("backend", ["scalar", "batch", "pallas"])
def test_refine_many_matches_per_query(backend, small_qb, pqs, rng):
    eng = distance.get_engine(backend)
    groups = []
    for pq, m in zip(pqs, (1, 63, 30)):
        ids = rng.integers(0, small_qb.norms.shape[0], m)
        groups.append((pq, small_qb.ext_codes[ids], small_qb.ext_lo[ids],
                       small_qb.ext_step[ids]))
    fused = eng.refine_many(small_qb, groups)
    for (pq, codes, lo, step), out in zip(groups, fused):
        ref = distance.get_engine(backend).refine(small_qb, pq, codes, lo, step)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    assert eng.stats.level2_calls == 1


@pytest.mark.parametrize("backend", ["scalar", "batch", "pallas"])
def test_refine_full_many_matches_per_query(backend, small_qb, rng):
    eng = distance.get_engine(backend)
    d = small_qb.dim
    groups = [
        (rng.standard_normal(d).astype(np.float32),
         rng.standard_normal((m, d)).astype(np.float32))
        for m in (2, 40, 9)
    ]
    fused = eng.refine_full_many(groups)
    for (q, vecs), out in zip(groups, fused):
        ref = distance.get_engine(backend).refine_full(q, vecs)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-3)
    assert eng.stats.full_calls == 1


def test_many_apis_handle_empty_and_single_groups(small_qb, pqs):
    eng = distance.get_engine("batch")
    outs = eng.estimate_many(
        small_qb,
        [(pqs[0], np.empty(0, np.int64)), (pqs[1], np.asarray([3, 7]))],
    )
    assert outs[0].shape == (0,) and outs[1].shape == (2,)
    # single live group delegates to the bitwise per-query path, one call
    ref = distance.get_engine("batch").estimate(small_qb, pqs[1], np.asarray([3, 7]))
    np.testing.assert_array_equal(outs[1], ref)
    assert eng.stats.fused_calls == 0 and eng.stats.level1_calls == 1
    outs = eng.estimate_many(small_qb, [(pqs[0], np.empty(0, np.int64))])
    assert outs[0].shape == (0,)


# ------------------------------------------ engine accounting regressions


class _DictStore:
    def __init__(self, n_pages=64):
        self.pages = {i: bytes([i % 256]) * 16 for i in range(n_pages)}

    def read_page(self, pid):
        return self.pages[pid]


def test_finished_query_tokens_are_reclaimed():
    """A coroutine finishing with outstanding submit tokens must not leak
    its token_info entries (unbounded growth over long runs)."""

    def leaky(qid, _q):
        toks = yield ("submit", [qid % 8, (qid + 1) % 8, (qid + 2) % 8])
        res = yield ("wait_any", set(toks))  # waits for ONE, abandons two
        return res[1]

    from repro.core.engine import Engine, EngineConfig

    engine = Engine(_DictStore(), SSD(), CostModel(), EngineConfig(batch_size=4))
    results, _ = engine.run(leaky, np.zeros((24, 2), np.float32))
    assert all(r is not None for r in results)
    assert engine._token_info == {}, "finished queries leaked submit tokens"
    assert engine._tokens_by_query == {}


def test_inflight_dedup_dict_is_pruned():
    """The page-dedup dict must not retain one entry per page ever read."""

    def scan(qid, _q):
        for pid in range(60):
            yield ("read", [pid])
        return qid

    from repro.core.engine import Engine, EngineConfig

    engine = Engine(_DictStore(), SSD(), CostModel(), EngineConfig(batch_size=1))
    engine.run(scan, np.zeros((2, 2), np.float32))
    # without pruning this would hold all 60 pages; completed windows are
    # dropped on the next submit, so only the tail survives
    assert len(engine._inflight) < 10


def test_inflight_pruning_survives_idle_worker():
    """A drained worker sitting at an early clock must not pin the prune
    horizon (it can issue no further reads, so its time is irrelevant)."""

    def scan(qid, _q):
        if qid > 0:
            return qid  # worker 2's only query finishes instantly
        for pid in range(60):
            yield ("read", [pid])
        return qid

    from repro.core.engine import Engine, EngineConfig

    engine = Engine(
        _DictStore(), SSD(), CostModel(),
        EngineConfig(n_workers=2, batch_size=1),
    )
    results, _ = engine.run(scan, np.zeros((2, 2), np.float32))
    assert results == [0, 1]
    assert len(engine._inflight) < 10


def test_coalesced_reads_not_charged_and_counted():
    """Two coroutines demanding one page: a single SQE is charged, the
    coalesced read is free and counted in WorkloadStats."""

    def demand(qid, _q):
        pages = yield ("read", [5])
        return pages[5]

    cost = CostModel()
    _, stats = run_workload(
        demand, np.zeros((2, 2), np.float32), store=_DictStore(),
        cost=cost, ssd=SSD(), n_workers=1, batch_size=2,
    )
    assert stats.io_count == 1
    assert stats.coalesced_reads == 1

    # makespan accounting: B reads of one page must charge ~one submit, not B
    def run_n(n):
        _, s = run_workload(
            demand, np.zeros((n, 2), np.float32), store=_DictStore(),
            cost=cost, ssd=SSD(), n_workers=1, batch_size=n,
        )
        return s

    s8 = run_n(8)
    assert s8.io_count == 1 and s8.coalesced_reads == 7


def test_p99_latency_nearest_rank():
    stats = WorkloadStats(n_queries=100)
    stats.latencies = [i / 1000.0 for i in range(1, 101)]  # 1ms .. 100ms
    # nearest-rank p99 of 100 samples is the 99th value, NOT the max
    assert stats.p99_latency_ms() == pytest.approx(99.0)
    stats.latencies = [0.005]
    assert stats.p99_latency_ms() == pytest.approx(5.0)
    stats.latencies = []
    assert stats.p99_latency_ms() == 0.0
