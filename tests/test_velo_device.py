"""Device plane: batched beam search + scan search vs host plane / ground truth."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.core.dataset import recall_at_k
from repro.velo import batch_search as bs
from repro.velo import scan_search as ss
from repro.velo.device_cache import (
    DeviceRecordCache,
    FREE,
    LOCKED,
    MARKED,
    OCCUPIED,
)
from repro.velo.index import from_host


@pytest.fixture(scope="module")
def dev_index(small_qb, small_graph):
    return from_host(small_qb, small_graph)


def test_batch_search_recall(small_ds, dev_index):
    q = jnp.asarray(small_ds.queries)
    ids, d2, steps = bs.batch_search(dev_index, q, L=48, k=10, max_steps=96)
    rec = recall_at_k(np.asarray(ids), small_ds.groundtruth, 10)
    assert rec > 0.6, f"device graph search recall {rec}"
    assert bool((np.asarray(steps) > 3).all())
    assert np.isfinite(np.asarray(d2)).all()


def test_batch_search_matches_larger_L(small_ds, dev_index):
    """More beam budget must never hurt recall (monotonicity sanity)."""
    q = jnp.asarray(small_ds.queries[:30])
    rs = {}
    for L in (16, 64):
        ids, _, _ = bs.batch_search(dev_index, q, L=L, k=10, max_steps=128)
        rs[L] = recall_at_k(np.asarray(ids), small_ds.groundtruth[:30], 10)
    assert rs[64] >= rs[16]


def test_scan_search_recall(small_ds, dev_index):
    """Two-stage compressed scan is near-exhaustive: recall limited only by
    4-bit refinement noise."""
    q = jnp.asarray(small_ds.queries)
    ids, d2 = ss.scan_search(dev_index, q, k=10, rerank=64)
    rec = recall_at_k(np.asarray(ids), small_ds.groundtruth, 10)
    assert rec > 0.8, f"scan recall {rec}"


def test_scan_beats_graph_recall(small_ds, dev_index):
    """On one shard the exhaustive level-1 scan upper-bounds graph traversal."""
    q = jnp.asarray(small_ds.queries[:40])
    ids_g, _, _ = bs.batch_search(dev_index, q, L=48, k=10, max_steps=96)
    ids_s, _ = ss.scan_search(dev_index, q, k=10, rerank=96)
    rg = recall_at_k(np.asarray(ids_g), small_ds.groundtruth[:40], 10)
    rs_ = recall_at_k(np.asarray(ids_s), small_ds.groundtruth[:40], 10)
    assert rs_ >= rg - 0.02


def test_device_matches_host_distance_semantics(small_ds, small_qb, small_graph, dev_index):
    """Refined distances from the device search equal the host quantizer's."""
    from repro.core.quant import RabitQuantizer

    q = jnp.asarray(small_ds.queries[:4])
    ids, d2, _ = bs.batch_search(dev_index, q, L=32, k=5, max_steps=64)
    ids, d2 = np.asarray(ids), np.asarray(d2)
    for i in range(4):
        pq = RabitQuantizer.prepare_query(small_qb, small_ds.queries[i])
        host = RabitQuantizer.refine_dist2(small_qb, pq, ids[i])
        np.testing.assert_allclose(d2[i], host, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------- device cache


def test_device_cache_admit_touch_evict():
    vid_to_page = np.arange(64) // 4
    c = DeviceRecordCache.create(8, vid_to_page, dim=16, R=4)
    vids = np.asarray([1, 2, 3])
    assert not c.resident_mask(vids).any()
    c.admit(
        vids,
        exts=np.zeros((3, 8), np.uint8),
        los=np.zeros(3), steps_=np.ones(3),
        adjs=[np.asarray([4, 5]), np.asarray([6]), np.asarray([7, 8, 9])],
        disk_pages=vid_to_page[vids],
    )
    assert c.resident_mask(vids).all()
    c.touch(vids)
    assert c.hits == 3
    # fill and force eviction
    more = np.arange(10, 20)
    c.admit(more, np.zeros((10, 8), np.uint8), np.zeros(10), np.ones(10),
            [np.asarray([0])] * 10, vid_to_page[more])
    assert (c.slot_state != FREE).sum() == 8
    assert c.evictions > 0
    # evicted records' hybrid pointers must point back at their disk pages
    evicted = [v for v in range(64) if c.record_map[v] < 0]
    for v in evicted:
        assert -(c.record_map[v] + 1) == vid_to_page[v]


def test_device_cache_second_chance():
    vid_to_page = np.arange(16)
    c = DeviceRecordCache.create(2, vid_to_page, dim=8, R=2)
    c.admit(np.asarray([0, 1]), np.zeros((2, 4), np.uint8), np.zeros(2),
            np.ones(2), [np.asarray([1]), np.asarray([0])], vid_to_page[:2])
    c.slot_state[:] = MARKED
    c.touch(np.asarray([0]))          # vid 0 gets its second chance
    slot0 = c.record_map[0]
    assert c.slot_state[slot0] == OCCUPIED
    c.admit(np.asarray([5]), np.zeros((1, 4), np.uint8), np.zeros(1),
            np.ones(1), [np.asarray([0])], vid_to_page[5:6])
    assert c.resident_mask(np.asarray([0]))[0], "hot record must survive"
    assert not c.resident_mask(np.asarray([1]))[0]


def _filled_cache(n_slots=4, n=32):
    vid_to_page = np.arange(n) // 4
    c = DeviceRecordCache.create(n_slots, vid_to_page, dim=16, R=4)
    vids = np.arange(n_slots)
    c.admit(vids, np.full((n_slots, 8), 7, np.uint8), np.zeros(n_slots),
            np.ones(n_slots), [np.asarray([0])] * n_slots, vid_to_page[vids])
    return c, vid_to_page


def test_device_cache_sweep_all_locked():
    """A sweep over a fully-LOCKED cache frees nothing and touches no state:
    LOCKED slots are mid-scatter and must never be reclaimed."""
    c, _ = _filled_cache()
    c.slot_state[:] = LOCKED
    before_map = c.record_map.copy()
    before_vid = c.slot_vid.copy()
    freed = c.sweep(3)
    assert len(freed) == 0
    assert (c.slot_state == LOCKED).all()
    np.testing.assert_array_equal(c.record_map, before_map)
    np.testing.assert_array_equal(c.slot_vid, before_vid)
    assert c.evictions == 0


def test_device_cache_sweep_need_exceeds_slots():
    """`need` far beyond the slot count is capped, not an infinite clock walk;
    an all-OCCUPIED cache yields every slot (demote pass, then evict pass)."""
    c, _ = _filled_cache(n_slots=4)
    freed = c.sweep(100)
    assert len(freed) == 4
    assert (c.slot_state == FREE).all()
    assert c.evictions == 4
    # freed slots' records point back at their disk pages
    for v in range(4):
        assert c.record_map[v] < 0


def test_device_cache_admit_already_resident():
    """Re-admitting a resident vid is a no-op: same slot, payload untouched,
    no second slot consumed."""
    c, vid_to_page = _filled_cache(n_slots=4)
    slot0 = int(c.record_map[0])
    before_ext = c.cache_ext[slot0].copy()
    used_before = int((c.slot_state != FREE).sum())
    c.admit(np.asarray([0]), np.full((1, 8), 99, np.uint8), np.full(1, 5.0),
            np.full(1, 5.0), [np.asarray([1, 2])], vid_to_page[:1])
    assert int(c.record_map[0]) == slot0
    np.testing.assert_array_equal(c.cache_ext[slot0], before_ext)
    assert int((c.slot_state != FREE).sum()) == used_before


def test_hbm_scatter_double_buffer_parity(small_qb):
    """The staged-scatter tier (records parked during step t, installed by
    one batched scatter at the t/t+1 boundary) must land in the SAME state a
    sequential per-record admit reaches, and the device mirror maintained by
    the jitted scatter must stay bit-identical to the host slot arrays."""
    from repro.core.hbm import HbmTier
    from repro.core.store import DecodedRecord

    n = len(small_qb.ext_codes)
    vid_to_page = np.arange(n) // 4

    def record(v):
        return DecodedRecord(
            vid=v, adjacency=np.asarray([(v + 1) % n, (v + 2) % n]),
            ext_payload=small_qb.record_payload(v),
        )

    tier = HbmTier(small_qb, vid_to_page, n_slots=8, R=4)
    ref = DeviceRecordCache.create(
        8, vid_to_page, dim=small_qb.dim, R=4,
        code_cols=small_qb.ext_codes.shape[1],
    )
    tier.device_arrays()  # force the mirror so every scatter updates it
    rng = np.random.default_rng(0)
    for _ in range(6):  # steps, each staging one admit group
        group = rng.choice(n, size=3, replace=False)
        staged = []
        for v in group:
            if tier._stage(int(v), record(int(v))):
                staged.append(int(v))
        assert tier.scatter_staged() == len(staged)
        if staged:  # sequential reference: plain admit of the same group
            recs = [record(v) for v in staged]
            ncode = small_qb.ext_codes.shape[1]
            ref.admit(
                np.asarray(staged),
                np.stack([np.frombuffer(r.ext_payload[:ncode], np.uint8)
                          for r in recs]),
                np.asarray([np.frombuffer(r.ext_payload[ncode:ncode + 4],
                                          np.float32)[0] for r in recs]),
                np.asarray([np.frombuffer(r.ext_payload[ncode + 4:ncode + 8],
                                          np.float32)[0] for r in recs]),
                [r.adjacency.astype(np.int32) for r in recs],
                vid_to_page[staged],
            )
        np.testing.assert_array_equal(tier.cache.record_map, ref.record_map)
        np.testing.assert_array_equal(tier.cache.slot_state, ref.slot_state)
        np.testing.assert_array_equal(tier.cache.slot_vid, ref.slot_vid)
        np.testing.assert_array_equal(tier.cache.cache_ext, ref.cache_ext)
        # the functionally-updated device mirror tracks the host arrays
        ext_d, lo_d, step_d = tier.device_arrays()
        np.testing.assert_array_equal(np.asarray(ext_d), tier.cache.cache_ext)
        np.testing.assert_array_equal(np.asarray(lo_d), tier.cache.cache_lo)
        np.testing.assert_array_equal(np.asarray(step_d),
                                      tier.cache.cache_step)
