"""Two-level quantization: estimator quality + refinement error (paper §3.3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import flat
from repro.core.quant import (
    RabitQuantizer,
    pack_bits,
    pack_nibbles,
    unpack_bits,
    unpack_nibbles,
)


@given(st.integers(min_value=1, max_value=40), st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_bit_packing_roundtrip(rows, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(rows, 64)).astype(np.uint8)
    np.testing.assert_array_equal(unpack_bits(pack_bits(bits), 64), bits)


@given(st.integers(min_value=1, max_value=40), st.integers(min_value=0, max_value=2**32 - 1))
@settings(max_examples=60, deadline=None)
def test_nibble_packing_roundtrip(rows, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 16, size=(rows, 32)).astype(np.uint8)
    np.testing.assert_array_equal(unpack_nibbles(pack_nibbles(codes), 32), codes)


def test_rotation_preserves_distances(small_ds, small_qb):
    """The random rotation must be orthonormal: rotated-space distances equal
    original-space distances."""
    qb = small_qb
    r = qb.rotation
    np.testing.assert_allclose(r @ r.T, np.eye(qb.dim), atol=1e-4)


def test_estimator_correlates(small_ds, small_qb):
    """Level-1 binary estimates must rank-correlate strongly with true dists."""
    qb = small_qb
    q = small_ds.queries[0]
    pq = RabitQuantizer.prepare_query(qb, q)
    ids = np.arange(400)
    est = RabitQuantizer.estimate_dist2(qb, pq, ids)
    ref = ((small_ds.base[ids] - q) ** 2).sum(1)
    corr = np.corrcoef(est, ref)[0, 1]
    assert corr > 0.75


def test_refinement_tighter_than_estimate(small_ds, small_qb):
    """Level-2 (4-bit) refinement must be much more accurate than level-1."""
    qb = small_qb
    q = small_ds.queries[1]
    pq = RabitQuantizer.prepare_query(qb, q)
    ids = np.arange(300)
    ref = ((small_ds.base[ids] - q) ** 2).sum(1)
    est1 = RabitQuantizer.estimate_dist2(qb, pq, ids)
    est2 = RabitQuantizer.refine_dist2(qb, pq, ids)
    err1 = np.abs(est1 - ref).mean()
    err2 = np.abs(est2 - ref).mean()
    assert err2 < 0.5 * err1
    assert err2 / ref.mean() < 0.15


def test_payload_refine_matches_array_refine(small_ds, small_qb):
    qb = small_qb
    pq = RabitQuantizer.prepare_query(qb, small_ds.queries[2])
    for vid in (0, 17, 1234):
        payload = qb.record_payload(vid)
        a = RabitQuantizer.refine_dist2_from_payload(qb, pq, payload)
        b = RabitQuantizer.refine_dist2(qb, pq, np.asarray([vid]))[0]
        assert a == pytest.approx(float(b), rel=1e-5)


def test_ext8_much_tighter_than_ext4(small_ds):
    qz8 = RabitQuantizer(small_ds.dim, seed=0, ext_bits=8)
    qb8 = qz8.fit_encode(small_ds.base)
    qz4 = RabitQuantizer(small_ds.dim, seed=0, ext_bits=4)
    qb4 = qz4.fit_encode(small_ds.base)
    q = small_ds.queries[0]
    ids = np.arange(200)
    ref = ((small_ds.base[ids] - q) ** 2).sum(1)
    e8 = np.abs(RabitQuantizer.refine_dist2(qb8, RabitQuantizer.prepare_query(qb8, q), ids) - ref).mean()
    e4 = np.abs(RabitQuantizer.refine_dist2(qb4, RabitQuantizer.prepare_query(qb4, q), ids) - ref).mean()
    assert e8 < 0.2 * e4


def test_resident_bytes_much_smaller_than_raw(small_ds, small_qb):
    raw = small_ds.base.nbytes
    resident = small_qb.resident_bytes() - small_qb.rotation.nbytes
    # 1 bit/dim + 8 B metadata vs 4 B/dim
    assert resident < 0.15 * raw
