"""The batched distance plane: backend parity + batch-primitive properties.

The contract under test: every search algorithm, run end-to-end through the
engine, must return the SAME neighbors (ids), hops, and I/O counts whichever
DistanceEngine backend computes its distances — scalar oracle, vectorized
NumPy, or the Pallas kernels in interpret mode — with distances matching to
float tolerance.  This is what makes the backends interchangeable by config.
"""

import numpy as np
import pytest

from repro.core import baselines, distance
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS

BACKENDS = ["scalar", "batch", "pallas"]
ALGOS = sorted(ALGORITHMS)  # diskann, inmemory, pipeann, starling, velo

N_QUERIES = 16


def _run_system(name, ds, graph, qb, backend):
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        batch_size=4,
        distance_backend=backend,
        params=baselines.SearchParams(L=32, W=4),
    )
    sys_ = baselines.build_system(name, ds.base, graph, qb, cfg)
    results, _ = sys_.run(ds.queries[:N_QUERIES])
    assert sys_.ctx.dist.name == backend, "requested backend must be active"
    return results


# -------------------------------------------------------- end-to-end parity


@pytest.mark.parametrize("algo", ALGOS)
def test_backend_parity_all_algorithms(algo, small_ds, small_graph, small_qb):
    """scalar == batch == pallas: same ids/hops/reads, dists to tolerance."""
    runs = {
        b: _run_system(algo, small_ds, small_graph, small_qb, b) for b in BACKENDS
    }
    ref = runs["scalar"]
    for backend in ("batch", "pallas"):
        got = runs[backend]
        for i, (r0, r1) in enumerate(zip(ref, got)):
            np.testing.assert_array_equal(
                r0.ids, r1.ids, err_msg=f"{algo}/{backend} query {i}: ids"
            )
            assert r0.hops == r1.hops, f"{algo}/{backend} query {i}: hops"
            assert r0.reads == r1.reads, f"{algo}/{backend} query {i}: reads"
            np.testing.assert_allclose(
                r0.dists, r1.dists, rtol=2e-3, atol=2e-3,
                err_msg=f"{algo}/{backend} query {i}: dists",
            )


def test_engine_counts_batches(small_ds, small_graph, small_qb):
    """The plane must be fed batches, not single rows: rows/call > 1."""
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, batch_size=4, distance_backend="batch"
    )
    sys_ = baselines.build_system("diskann", small_ds.base, small_graph, small_qb, cfg)
    sys_.run(small_ds.queries[:N_QUERIES])
    stats = sys_.ctx.dist.stats
    assert stats.level1_rows > 0 and stats.full_rows > 0
    assert stats.rows_per_call() > 2.0, stats


# ------------------------------------------------- batch primitive properties


@pytest.fixture(scope="module")
def prepared(small_ds, small_qb):
    return RabitQuantizer.prepare_query(small_qb, small_ds.queries[0])


@pytest.mark.parametrize("m", [1, 3, 64, 65, 200])
def test_estimate_batch_shape_dtype(m, small_qb, prepared, rng):
    ids = rng.integers(0, small_qb.norms.shape[0], m)
    out = RabitQuantizer.estimate_batch(
        small_qb, prepared,
        small_qb.binary_codes[ids], small_qb.norms[ids], small_qb.ip_bar[ids],
    )
    assert out.shape == (m,) and out.dtype == np.float32
    assert np.all(np.isfinite(out))


@pytest.mark.parametrize("m", [1, 3, 64, 65, 200])
def test_refine_batch_shape_dtype(m, small_qb, prepared, rng):
    ids = rng.integers(0, small_qb.norms.shape[0], m)
    out = RabitQuantizer.refine_batch(
        small_qb, prepared,
        small_qb.ext_codes[ids], small_qb.ext_lo[ids], small_qb.ext_step[ids],
    )
    assert out.shape == (m,) and out.dtype == np.float32
    assert np.all(out >= 0.0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_primitives_match_oracle(backend, small_qb, prepared, rng):
    """estimate/refine/refine_full agree with the scalar oracle row-for-row,
    at every row count a search frontier can produce (incl. bucket edges)."""
    oracle = distance.ScalarEngine()
    eng = distance.get_engine(backend)
    for m in (1, 7, 63, 64, 65, 128):
        ids = rng.integers(0, small_qb.norms.shape[0], m)
        np.testing.assert_allclose(
            eng.estimate(small_qb, prepared, ids),
            oracle.estimate(small_qb, prepared, ids),
            rtol=2e-3, atol=2e-3,
        )
        codes, lo, step = (
            small_qb.ext_codes[ids], small_qb.ext_lo[ids], small_qb.ext_step[ids]
        )
        np.testing.assert_allclose(
            eng.refine(small_qb, prepared, codes, lo, step),
            oracle.refine(small_qb, prepared, codes, lo, step),
            rtol=2e-3, atol=2e-3,
        )
        vecs = rng.standard_normal((m, small_qb.dim)).astype(np.float32)
        q = rng.standard_normal(small_qb.dim).astype(np.float32)
        np.testing.assert_allclose(
            eng.refine_full(q, vecs), oracle.refine_full(q, vecs),
            rtol=1e-4, atol=1e-3,
        )


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_empty_batches(backend, small_qb, prepared):
    eng = distance.get_engine(backend)
    assert eng.estimate(small_qb, prepared, np.empty(0, np.int64)).shape == (0,)
    ncode = small_qb.ext_codes.shape[1]
    out = eng.refine(
        small_qb, prepared,
        np.empty((0, ncode), np.uint8), np.empty(0, np.float32),
        np.empty(0, np.float32),
    )
    assert out.shape == (0,)
    assert eng.refine_full(
        np.zeros(small_qb.dim, np.float32), np.empty((0, small_qb.dim), np.float32)
    ).shape == (0,)
    # empty batches must not be charged as engine calls
    assert eng.stats.level1_calls == 0 and eng.stats.level2_calls == 0


def test_record_matrix_roundtrips_build_arrays(small_ds, small_graph, small_qb):
    """Payloads decoded from on-disk pages must reassemble into exactly the
    build-time code matrices (one index image, two access paths)."""
    from repro.core.store import VeloIndex

    index = VeloIndex(small_ds.base, small_graph, small_qb)
    vids = [0, 17, 555, 1234]
    recs = [
        index.decode_record(v, index.store.read_page(index.page_of(v)))
        for v in vids
    ]
    codes, lo, step = index.record_matrix(recs)
    np.testing.assert_array_equal(codes, small_qb.ext_codes[vids])
    np.testing.assert_allclose(lo, small_qb.ext_lo[vids])
    np.testing.assert_allclose(step, small_qb.ext_step[vids])


def test_record_matrix_ext8(small_ds, small_graph):
    """ext_bits=8 records decode and batch-refine through the same plane
    (the Pallas engine must route 8-bit refinement to the NumPy path)."""
    from repro.core.store import VeloIndex

    qb8 = RabitQuantizer(small_ds.dim, seed=0, ext_bits=8).fit_encode(small_ds.base)
    index = VeloIndex(small_ds.base, small_graph, qb8)
    vids = [0, 7, 321]
    recs = [
        index.decode_record(v, index.store.read_page(index.page_of(v)))
        for v in vids
    ]
    codes, lo, step = index.record_matrix(recs)
    assert codes.shape == (len(vids), small_ds.dim)
    np.testing.assert_array_equal(codes, qb8.ext_codes[vids])
    pq = RabitQuantizer.prepare_query(qb8, small_ds.queries[0])
    ref = RabitQuantizer.refine_dist2(qb8, pq, np.asarray(vids))
    for backend in BACKENDS:
        got = index.refine_records(distance.get_engine(backend), pq, recs)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------- selection rules


def test_get_engine_selection_rules():
    assert distance.get_engine("scalar").name == "scalar"
    assert distance.get_engine("batch").name == "batch"
    prev = distance.default_backend()
    try:
        distance.set_default_backend("scalar")
        assert distance.get_engine("default").name == "scalar"
        assert distance.get_engine(None).name == "scalar"
    finally:
        distance.set_default_backend(prev)
    with pytest.raises(ValueError):
        distance.get_engine("not-a-backend")
    with pytest.raises(ValueError):
        distance.set_default_backend("not-a-backend")
    # auto: pallas when jax is importable, batch otherwise — never an error
    assert distance.get_engine("auto").name in ("pallas", "batch")


def test_search_context_defaults_to_process_backend(small_ds, small_graph, small_qb):
    prev = distance.default_backend()
    try:
        distance.set_default_backend("scalar")
        cfg = baselines.SystemConfig(distance_backend="default")
        sys_ = baselines.build_system(
            "velo", small_ds.base, small_graph, small_qb, cfg
        )
        assert sys_.ctx.dist.name == "scalar"
    finally:
        distance.set_default_backend(prev)
