"""Resident code plane + shared rendezvous: parity and accounting.

The contracts under test (deterministic module — any hypothesis-based
additions belong in their own module, the dev container lacks hypothesis):

  * register-once tables: ``DistanceEngine.register_index`` uploads an
    index's code tables exactly once per engine; every id-based call after
    that gathers from the registered table (``DistanceStats.uploads`` is
    O(1) per index, where the legacy pallas path re-uploaded gathered rows
    per call).
  * resident == host-gather, bitwise: id-based estimates/refinements served
    from the registered tables equal the caller-gathered matrix path bit for
    bit, at the primitive level and end-to-end for all five algorithms on
    all three backends.
  * shared rendezvous == per-worker rendezvous, bitwise, on a one-worker
    system (any B): the flush points and charges coincide, so the topology
    flag cannot change results; at multiple workers it keeps recall while
    cutting dispatches (the system-wide fused batch).
  * the pallas pad-to-bucket helper handles row counts on a bucket multiple
    (pass-through) and m=0 (pads up to one full bucket).
"""

import numpy as np
import pytest

from repro.core import baselines, distance
from repro.core.dataset import recall_at_k
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS

BACKENDS = ["scalar", "batch", "pallas"]
ALGOS = sorted(ALGORITHMS)  # diskann, inmemory, pipeann, starling, velo
N_QUERIES = 16


def _run(name, ds, graph, qb, **kw):
    kw.setdefault("params", baselines.SearchParams(L=32, W=4))
    cfg = baselines.SystemConfig(buffer_ratio=0.2, **kw)
    sys_ = baselines.build_system(name, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries[:N_QUERIES])
    return sys_, results, stats


def _assert_bitwise(ref, got, label):
    for i, (r0, r1) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(r0.ids, r1.ids, err_msg=f"{label} q{i}: ids")
        assert r0.hops == r1.hops, f"{label} q{i}: hops"
        assert r0.reads == r1.reads, f"{label} q{i}: reads"
        np.testing.assert_array_equal(
            r0.dists, r1.dists, err_msg=f"{label} q{i}: dists"
        )


@pytest.fixture(scope="module")
def prepared(small_ds, small_qb):
    return RabitQuantizer.prepare_query(small_qb, small_ds.queries[0])


# ------------------------------------------------ register-once table uploads


@pytest.mark.parametrize("backend", BACKENDS)
def test_uploads_are_o1_per_index(backend, small_qb, prepared, rng):
    """Many id-based calls, one table upload (the resident-plane invariant)."""
    eng = distance.get_engine(backend)
    for _ in range(12):
        ids = rng.integers(0, small_qb.norms.shape[0], 33)
        eng.estimate(small_qb, prepared, ids)
        eng.refine_ids(small_qb, prepared, ids)
    assert eng.stats.uploads == 1, eng.stats
    assert eng.stats.resident_gathers == 12 * 2 * 33
    # re-registration is idempotent and free
    eng.register_index(small_qb)
    assert eng.stats.uploads == 1


def test_legacy_pallas_uploads_per_call(small_qb, prepared, rng):
    """resident=False keeps the PR-2 behavior the counter was built to expose:
    every kernel call re-uploads its gathered rows."""
    eng = distance.get_engine("pallas", resident=False)
    if eng.name != "pallas":  # pragma: no cover - jax missing
        pytest.skip("pallas unavailable")
    n_calls = 5
    for _ in range(n_calls):
        ids = rng.integers(0, small_qb.norms.shape[0], 17)
        eng.estimate(small_qb, prepared, ids)
    # one host-view registration + one row upload per kernel call
    assert eng.stats.uploads == 1 + n_calls, eng.stats


def test_distinct_indexes_register_separately(small_ds, small_qb, prepared):
    eng = distance.get_engine("batch")
    qb2 = RabitQuantizer(small_ds.dim, seed=7).fit_encode(small_ds.base)
    pq2 = RabitQuantizer.prepare_query(qb2, small_ds.queries[0])
    ids = np.arange(10)
    eng.estimate(small_qb, prepared, ids)
    eng.estimate(qb2, pq2, ids)
    eng.estimate(small_qb, prepared, ids)
    assert eng.stats.uploads == 2


# ------------------------------------- resident == host-gather (primitives)


@pytest.mark.parametrize("backend", BACKENDS)
def test_resident_gather_bitwise_equals_host_gather(
    backend, small_qb, prepared, rng
):
    """Id-based calls against the registered table must equal the
    caller-gathered matrix path BIT FOR BIT on every backend (the pallas
    on-device gather feeds the same kernel the same rows)."""
    eng = distance.get_engine(backend)
    for m in (1, 7, 64, 65, 200):
        ids = rng.integers(0, small_qb.norms.shape[0], m)
        est = eng.estimate(small_qb, prepared, ids)
        ref_est = eng._estimate(
            small_qb, prepared,
            small_qb.binary_codes[ids], small_qb.norms[ids],
            small_qb.ip_bar[ids],
        )
        np.testing.assert_array_equal(est, np.asarray(ref_est, np.float32))
        got = eng.refine_ids(small_qb, prepared, ids)
        ref = eng.refine(
            small_qb, prepared,
            small_qb.ext_codes[ids], small_qb.ext_lo[ids],
            small_qb.ext_step[ids],
        )
        np.testing.assert_array_equal(got, ref)


@pytest.mark.parametrize("backend", BACKENDS)
def test_refine_ids_matches_oracle(backend, small_qb, prepared, rng):
    oracle = distance.ScalarEngine()
    eng = distance.get_engine(backend)
    ids = rng.integers(0, small_qb.norms.shape[0], 50)
    np.testing.assert_allclose(
        eng.refine_ids(small_qb, prepared, ids),
        oracle.refine_ids(small_qb, prepared, ids),
        rtol=2e-3, atol=2e-3,
    )


@pytest.mark.parametrize("backend", BACKENDS)
def test_refine_ids_many_matches_per_query(backend, small_ds, small_qb, rng):
    eng = distance.get_engine(backend)
    pqs = [
        RabitQuantizer.prepare_query(small_qb, small_ds.queries[i])
        for i in range(3)
    ]
    groups = [
        (pq, rng.integers(0, small_qb.norms.shape[0], m))
        for pq, m in zip(pqs, (5, 64, 31))
    ]
    fused = eng.refine_ids_many(small_qb, groups)
    single = distance.get_engine(backend)
    for (pq, ids), got in zip(groups, fused):
        np.testing.assert_allclose(
            got, single.refine_ids(small_qb, pq, ids), rtol=2e-3, atol=2e-3
        )
    assert eng.stats.uploads == 1


def test_refine_ids_empty_and_ext8(small_ds, small_graph, prepared, small_qb):
    """Empty id sets are not charged; ext_bits=8 routes to the NumPy path on
    every backend (no int4 kernel) while staying id-addressable."""
    for backend in BACKENDS:
        eng = distance.get_engine(backend)
        out = eng.refine_ids(small_qb, prepared, np.empty(0, np.int64))
        assert out.shape == (0,) and eng.stats.level2_calls == 0
    qb8 = RabitQuantizer(small_ds.dim, seed=0, ext_bits=8).fit_encode(small_ds.base)
    pq8 = RabitQuantizer.prepare_query(qb8, small_ds.queries[0])
    ids = np.asarray([0, 7, 321])
    ref = RabitQuantizer.refine_dist2(qb8, pq8, ids)
    for backend in BACKENDS:
        got = distance.get_engine(backend).refine_ids(qb8, pq8, ids)
        np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


# ----------------------------------------- resident == host-gather (end-to-end)


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("backend", BACKENDS)
def test_resident_plane_parity_end_to_end(
    algo, backend, small_ds, small_graph, small_qb
):
    """All five algorithms, all three backends: the id-based resident wire
    format returns identical ids/hops/reads/dists to the materialized
    host-gather path (the on-disk payloads round-trip to the build tables)."""
    _, ref, _ = _run(
        algo, small_ds, small_graph, small_qb,
        batch_size=4, distance_backend=backend, resident_plane=False,
    )
    sys_, got, _ = _run(
        algo, small_ds, small_graph, small_qb,
        batch_size=4, distance_backend=backend, resident_plane=True,
    )
    _assert_bitwise(ref, got, f"{algo}/{backend}")
    # the resident run registered its index exactly once
    assert sys_.ctx.dist.stats.uploads <= 1


def test_end_to_end_uploads_o1_on_pallas(small_ds, small_graph, small_qb):
    """The acceptance criterion in one test: a whole velo workload on the
    pallas backend uploads tables once, where the host-gather path pays one
    row upload per kernel dispatch (O(hops))."""
    res, _, _ = _run(
        "velo", small_ds, small_graph, small_qb,
        batch_size=4, distance_backend="pallas", resident_plane=True,
    )
    leg, _, _ = _run(
        "velo", small_ds, small_graph, small_qb,
        batch_size=4, distance_backend="pallas", resident_plane=False,
    )
    if res.ctx.dist.name != "pallas":  # pragma: no cover - jax missing
        pytest.skip("pallas unavailable")
    assert res.ctx.dist.stats.uploads == 1
    assert leg.ctx.dist.stats.uploads > 100  # one per dispatch
    assert res.ctx.dist.stats.resident_gathers > 0


# --------------------------------------------------------- shared rendezvous


@pytest.mark.parametrize("algo", ALGOS)
@pytest.mark.parametrize("B", [1, 8])
def test_shared_rendezvous_bitwise_one_worker(
    algo, B, small_ds, small_graph, small_qb
):
    """One worker: the shared topology's flush points and charges coincide
    with the per-worker buffer, so results are bitwise identical at any B."""
    _, ref, _ = _run(
        algo, small_ds, small_graph, small_qb,
        batch_size=B, n_workers=1, fuse=True, shared_rendezvous=False,
    )
    _, got, _ = _run(
        algo, small_ds, small_graph, small_qb,
        batch_size=B, n_workers=1, fuse=True, shared_rendezvous=True,
    )
    _assert_bitwise(ref, got, f"{algo} B={B}")


def test_shared_rendezvous_fuses_across_workers(small_ds, small_graph, small_qb):
    """4 workers: the system-wide buffer produces fewer, wider dispatches
    than per-worker fusion at recall parity."""
    s_pw, r_pw, st_pw = _run(
        "velo", small_ds, small_graph, small_qb,
        batch_size=8, n_workers=4, fuse=True, shared_rendezvous=False,
    )
    s_sh, r_sh, st_sh = _run(
        "velo", small_ds, small_graph, small_qb,
        batch_size=8, n_workers=4, fuse=True, shared_rendezvous=True,
    )
    assert s_sh.ctx.dist.stats.dispatches() < s_pw.ctx.dist.stats.dispatches()
    assert st_sh.requests_per_flush > st_pw.requests_per_flush

    def rec(rs):
        ids = np.full((len(rs), 10), -1, np.int64)
        for i, r in enumerate(rs):
            ids[i, : min(10, len(r.ids))] = r.ids[:10]
        return recall_at_k(ids, small_ds.groundtruth[:N_QUERIES], 10)

    assert abs(rec(r_sh) - rec(r_pw)) < 0.1


@pytest.mark.parametrize("algo", ALGOS)
def test_shared_rendezvous_terminates_multi_worker(
    algo, small_ds, small_graph, small_qb
):
    """All five algorithms complete under the shared topology at 2 workers
    (the all-stalled flush is always reachable — no cross-worker deadlock)
    and return a full result set."""
    _, got, stats = _run(
        algo, small_ds, small_graph, small_qb,
        batch_size=4, n_workers=2, fuse=True, shared_rendezvous=True,
    )
    assert len(got) == N_QUERIES and all(r is not None for r in got)
    assert all(len(r.ids) > 0 for r in got)
    assert stats.score_requests > 0


def test_shared_rendezvous_off_is_default(small_ds, small_graph, small_qb):
    """SystemConfig.shared_rendezvous=None inherits the process default
    (False): PR-2 per-worker semantics unless explicitly enabled."""
    sys_, _, _ = _run("velo", small_ds, small_graph, small_qb, fuse=True)
    assert sys_.config.shared_rendezvous is False


# ------------------------------------------------------ pad-to-bucket helper


def _pallas_engine():
    eng = distance.get_engine("pallas")
    if eng.name != "pallas":  # pragma: no cover - jax missing
        pytest.skip("pallas unavailable")
    return eng


def test_pad_to_bucket_passthrough_on_multiple():
    """m exactly on a bucket multiple: arrays pass through unpadded."""
    eng = _pallas_engine()
    codes = np.arange(eng.bucket * 2 * 8, dtype=np.uint8).reshape(-1, 8)
    norms = np.ones(eng.bucket * 2, dtype=np.float32)
    m, (c, n) = eng._pad_to_bucket([codes, norms], [0, 0])
    assert m == eng.bucket * 2
    assert c is codes and n is norms  # no copy, no pad


def test_pad_to_bucket_pads_and_fills():
    eng = _pallas_engine()
    codes = np.full((5, 4), 9, dtype=np.uint8)
    step = np.full(5, 2.0, dtype=np.float32)
    m, (c, s) = eng._pad_to_bucket([codes, step], [0, 1])
    assert m == 5 and c.shape == (eng.bucket, 4) and s.shape == (eng.bucket,)
    np.testing.assert_array_equal(c[:5], codes)
    assert (c[5:] == 0).all() and (s[5:] == 1.0).all()
    np.testing.assert_array_equal(s[:5], step)


def test_pad_to_bucket_empty_rows():
    """m=0 pads up to one full bucket (a valid static kernel shape)."""
    eng = _pallas_engine()
    codes = np.empty((0, 8), dtype=np.uint8)
    lo = np.empty(0, dtype=np.float32)
    m, (c, lo_p) = eng._pad_to_bucket([codes, lo], [0, 0])
    assert m == 0 and c.shape == (eng.bucket, 8) and lo_p.shape == (eng.bucket,)
    assert (c == 0).all()
    # and the id variant
    m, idsp = eng._pad_ids(np.empty(0, dtype=np.int64))
    assert m == 0 and idsp.shape == (eng.bucket,) and idsp.dtype == np.int32


def test_pad_ids_on_bucket_multiple():
    eng = _pallas_engine()
    ids = np.arange(eng.bucket, dtype=np.int64)
    m, idsp = eng._pad_ids(ids)
    assert m == eng.bucket and idsp.shape == (eng.bucket,)
    np.testing.assert_array_equal(idsp, ids.astype(np.int32))


# ------------------------------------------------------------ cost plumbing


def test_table_upload_charged_once(small_ds, small_graph, small_qb):
    """The engine charges table_upload_s exactly once per run: zeroing it
    shortens the makespan by at most one upload, not one per hop."""
    from repro.core.sim import CostModel

    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, batch_size=4,
        params=baselines.SearchParams(L=32, W=4),
    )
    big = 1e-3
    sys_a = baselines.build_system(
        "velo", small_ds.base, small_graph, small_qb, cfg,
        cost=CostModel(table_upload_s=big),
    )
    _, st_a = sys_a.run(small_ds.queries[:N_QUERIES])
    sys_b = baselines.build_system(
        "velo", small_ds.base, small_graph, small_qb, cfg,
        cost=CostModel(table_upload_s=0.0),
    )
    _, st_b = sys_b.run(small_ds.queries[:N_QUERIES])
    delta = st_a.makespan_s - st_b.makespan_s
    assert 0.0 < delta <= big * 1.5, delta


def test_calibration_overrides_cost_model():
    from repro.core.sim import CostModel

    cost = CostModel()
    calib = {"batch": {"batch_dispatch_s": 1.5e-6, "table_upload_s": 9e-5,
                       "not_a_field": 1.0}}
    out = baselines.apply_calibration(cost, "batch", calib)
    assert out.batch_dispatch_s == 1.5e-6 and out.table_upload_s == 9e-5
    # untouched backend -> untouched model
    assert baselines.apply_calibration(cost, "pallas", calib) is cost
    assert baselines.load_calibration(None) is None
    assert baselines.load_calibration(calib) is calib
