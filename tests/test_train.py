"""Training substrate: loss falls; int8 optimizer tracks fp32; grad compression."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import configs
from repro.models import model as Mod
from repro.train import data as Data
from repro.train import optimizer as Opt
from repro.train import train_step as TS


@pytest.fixture(scope="module")
def tiny():
    cfg = configs.get("tinyllama-1.1b", reduced=True)
    model = Mod.build(cfg)
    return cfg, model


def _run(model, cfg, opt_name, steps=40, compress=False, seed=0):
    opt_cfg = Opt.OptConfig(lr=3e-3, total_steps=steps, warmup_steps=2)
    step_fn = jax.jit(TS.make_train_step(
        model, opt_name=opt_name, opt_cfg=opt_cfg, ce_chunk=32,
        compress_grads=compress,
    ))
    params, opt_state = TS.make_init(model, opt_name)(jax.random.key(seed))
    dcfg = Data.DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4,
                           seed=seed)
    losses = []
    for step in range(steps):
        batch = {k: jnp.asarray(v) for k, v in Data.batch_for_step(dcfg, step).items()
                 if not k.startswith("_")}
        params, opt_state, m = step_fn(params, opt_state, batch)
        losses.append(float(m["loss"]))
    return losses


def test_loss_decreases(tiny):
    cfg, model = tiny
    losses = _run(model, cfg, "adamw")
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::8]


def test_adamw8_tracks_adamw(tiny):
    """Blockwise-int8 moments must land within noise of fp32 Adam."""
    cfg, model = tiny
    l32 = _run(model, cfg, "adamw", steps=30)
    l8 = _run(model, cfg, "adamw8", steps=30)
    assert abs(np.mean(l8[-5:]) - np.mean(l32[-5:])) < 0.3, (l32[-5:], l8[-5:])


def test_grad_compression_trains(tiny):
    cfg, model = tiny
    losses = _run(model, cfg, "adamw", steps=30, compress=True)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_microbatch_equivalence(tiny):
    """Grad accumulation over k microbatches == one big batch (same loss path)."""
    cfg, model = tiny
    opt_cfg = Opt.OptConfig(lr=1e-3, total_steps=10, warmup_steps=1)
    params, opt_state = TS.make_init(model, "adamw")(jax.random.key(0))
    dcfg = Data.DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=0)
    batch = {k: jnp.asarray(v) for k, v in Data.batch_for_step(dcfg, 0).items()
             if not k.startswith("_")}

    outs = {}
    for mb in (1, 4):
        step_fn = jax.jit(TS.make_train_step(
            model, opt_name="adamw", opt_cfg=opt_cfg, microbatches=mb, ce_chunk=32))
        p2, _, m = step_fn(params, opt_state, batch)
        outs[mb] = (float(m["loss"]), p2)
    assert abs(outs[1][0] - outs[4][0]) < 2e-2
    # parameters after one step agree to accumulation tolerance
    for a, b in zip(jax.tree.leaves(outs[1][1]), jax.tree.leaves(outs[4][1])):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-2
        )


def test_int8_quantizer_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000) * 3.0, jnp.float32)
    q, s = Opt._q8(x)
    back = Opt._dq8(q, s, (1000,))
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    assert err < 3.0 / 127 * 3.5  # within a few quantization steps
