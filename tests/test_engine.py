"""Engine semantics: async == sync results; accounting sanity (paper §3.1)."""

import dataclasses

import numpy as np
import pytest

from repro.core import baselines
from repro.core.dataset import recall_at_k
from repro.core.sim import SSD, SSDConfig


def _ids(results, k=10):
    out = np.full((len(results), k), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(k, len(r.ids))
        out[i, :m] = r.ids[:m]
    return out


@pytest.fixture(scope="module")
def systems(small_ds, small_graph, small_qb):
    return small_ds, small_graph, small_qb


def test_async_equals_sync_results(systems):
    """A cache-OBLIVIOUS algorithm under B=1 and B=8 must return identical
    neighbors — execution overlap must never change its output.  (The
    cache-AWARE search is excluded by design: Alg. 2's pivot depends on pool
    state, which depends on query interleaving; its recall parity is checked
    separately below.)"""
    ds, g, qb = systems
    outs = {}
    for B in (1, 8):
        cfg = baselines.SystemConfig(
            batch_size=B, buffer_ratio=0.2,
            params=baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False),
        )
        sys_ = baselines.build_system("+record", ds.base, g, qb, cfg)
        results, _ = sys_.run(ds.queries[:40])
        outs[B] = _ids(results)
    np.testing.assert_array_equal(outs[1], outs[8])


def test_cache_aware_async_recall_parity(systems):
    """Alg. 2 results may differ between B=1 and B=8 (pivoting is
    cache-state-dependent) but recall must be equivalent."""
    ds, g, qb = systems
    recalls = {}
    for B in (1, 8):
        cfg = baselines.SystemConfig(batch_size=B, buffer_ratio=0.2)
        sys_ = baselines.build_system("velo", ds.base, g, qb, cfg)
        results, _ = sys_.run(ds.queries)
        recalls[B] = recall_at_k(_ids(results), ds.groundtruth, 10)
    assert abs(recalls[1] - recalls[8]) < 0.05, recalls


def test_async_improves_throughput(systems):
    ds, g, qb = systems
    qps = {}
    for B in (1, 8):
        cfg = baselines.SystemConfig(batch_size=B, buffer_ratio=0.1)
        sys_ = baselines.build_system("velo", ds.base, g, qb, cfg)
        _, stats = sys_.run(ds.queries)
        qps[B] = stats.qps
    assert qps[8] > 1.5 * qps[1], f"async must overlap I/O: {qps}"


def test_multi_worker_scales(systems):
    ds, g, qb = systems
    qps = {}
    for w in (1, 4):
        cfg = baselines.SystemConfig(n_workers=w, batch_size=4, buffer_ratio=0.2)
        sys_ = baselines.build_system("velo", ds.base, g, qb, cfg)
        _, stats = sys_.run(ds.queries)
        qps[w] = stats.qps
    assert qps[4] > 2.0 * qps[1]


def test_io_dedup_under_prefetch(systems):
    """Prefetch + demand read of the same page must cost one I/O."""
    ds, g, qb = systems
    cfg = baselines.SystemConfig(batch_size=4, buffer_ratio=0.15)
    sys_ = baselines.build_system("velo", ds.base, g, qb, cfg)
    _, stats = sys_.run(ds.queries)
    # every charged I/O is one page; with dedup, total I/O <= sum of per-query
    # demand reads + prefetches without double count. Loose sanity bound:
    assert stats.io_count < 3 * stats.n_queries * sys_.config.params.L


def test_slower_ssd_hurts_sync_more_than_async(systems):
    ds, g, qb = systems
    ratios = {}
    for B, name in ((1, "sync"), (8, "async")):
        cfg = baselines.SystemConfig(batch_size=B, buffer_ratio=0.1)
        sys_ = baselines.build_system("velo", ds.base, g, qb, cfg)
        _, fast = sys_.run(ds.queries, SSDConfig(read_latency_s=40e-6))
        sys2 = baselines.build_system("velo", ds.base, g, qb, cfg)
        _, slow = sys2.run(ds.queries, SSDConfig(read_latency_s=400e-6))
        ratios[name] = fast.qps / slow.qps
    assert ratios["sync"] > ratios["async"], (
        "async must hide I/O latency better than sync"
    )


def test_recall_all_systems(systems):
    """Every compared system must answer with reasonable recall on the same graph."""
    ds, g, qb = systems
    floor = {"velo": 0.60, "diskann": 0.75, "starling": 0.75, "pipeann": 0.75,
             "inmemory": 0.75}
    for name, lo in floor.items():
        cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4)
        sys_ = baselines.build_system(name, ds.base, g, qb, cfg)
        results, _ = sys_.run(ds.queries)
        rec = recall_at_k(_ids(results), ds.groundtruth, 10)
        assert rec >= lo, f"{name}: recall {rec} < {lo}"


def test_velo_fewer_ios_than_diskann(systems):
    """Compression + record cache + co-placement must cut I/O per query."""
    ds, g, qb = systems
    ios = {}
    for name in ("velo", "diskann"):
        cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4)
        sys_ = baselines.build_system(name, ds.base, g, qb, cfg)
        _, stats = sys_.run(ds.queries)
        ios[name] = stats.ios_per_query
    assert ios["velo"] < ios["diskann"]


def test_velo_disk_smaller_than_diskann(systems):
    ds, g, qb = systems
    cfg = baselines.SystemConfig()
    v = baselines.build_system("velo", ds.base, g, qb, cfg)
    d = baselines.build_system("diskann", ds.base, g, qb, cfg)
    assert v.disk_bytes() < 0.5 * d.disk_bytes()
