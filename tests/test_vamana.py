"""Vamana construction + affinity coloring invariants (Alg. 1)."""

import numpy as np

from repro.core import dataset as dataset_mod
from repro.core import vamana


def test_degree_bound(small_graph):
    g = small_graph
    assert (g.degrees <= g.R).all()
    assert (g.degrees > 0).all()


def test_no_self_loops_no_padding_leak(small_graph):
    g = small_graph
    for v in range(0, g.n, 97):
        nbrs = g.neighbors(v)
        assert (nbrs != v).all()
        assert (nbrs >= 0).all()
        assert (nbrs < g.n).all()
        assert len(set(nbrs.tolist())) == len(nbrs)


def test_graph_mostly_reachable(small_ds, small_graph):
    """Greedy search from the medoid must reach most of the graph (Vamana's
    long-range links keep it navigable)."""
    g = small_graph
    from collections import deque

    seen = {g.medoid}
    dq = deque([g.medoid])
    while dq:
        v = dq.popleft()
        for u in g.neighbors(v):
            u = int(u)
            if u not in seen:
                seen.add(u)
                dq.append(u)
    assert len(seen) > 0.99 * g.n


def test_affinity_within_tau(small_ds, small_graph):
    """Alg. 1 line 8: affine vertices collected within the (collection) radius."""
    g = small_graph
    base = small_ds.base
    lim = (2.0 * g.tau) ** 2 * (1 + 1e-5)
    checked = 0
    for p, cands in list(g.affinity.items())[:200]:
        for v, d2 in cands:
            true_d2 = float(((base[p] - base[v]) ** 2).sum())
            assert true_d2 <= lim
            assert abs(true_d2 - d2) / max(true_d2, 1e-9) < 1e-3
            checked += 1
    assert checked > 0


def test_affinity_ids_filter(small_graph):
    g = small_graph
    full = g.affinity_ids(tau_scale=2.0)
    tight = g.affinity_ids(tau_scale=0.5)
    none = g.affinity_ids(tau_scale=0.0)
    assert none == {}
    n_full = sum(len(v) for v in full.values())
    n_tight = sum(len(v) for v in tight.values())
    assert n_tight <= n_full


def test_search_quality_on_graph(small_ds, small_graph):
    """Greedy beam search over the built graph reaches high recall with exact
    distances — the graph itself is sound."""
    g = small_graph
    base = small_ds.base
    hits = 0
    for qi in range(len(small_ds.queries)):
        q = small_ds.queries[qi]
        # plain in-memory greedy search, beam 40
        from bisect import insort

        items = []
        seen = set()
        explored = set()

        def ins(v):
            if v in seen:
                return
            seen.add(v)
            d2 = float(((base[v] - q) ** 2).sum())
            insort(items, (d2, v))

        ins(g.medoid)
        while True:
            cand = [v for _, v in items[:40] if v not in explored]
            if not cand:
                break
            v = cand[0]
            explored.add(v)
            for u in g.neighbors(v):
                ins(int(u))
        got = {v for _, v in items[:10]}
        hits += len(got & set(small_ds.groundtruth[qi].tolist()))
    recall = hits / (len(small_ds.queries) * 10)
    assert recall > 0.85, f"graph quality too low: recall={recall}"
