"""Shared record buffer pool: async LOCKED-window loads, record-level
coalescing, and multi-worker determinism/parity (paper §3.2, Fig. 5).

Contracts:

  * ``SystemConfig.async_load=False`` is the legacy per-system pool (slots
    admitted synchronously after the read, per record).  The async shared
    pool must be *bitwise identical* to it at ``n_workers=1`` for every
    algorithm in its deterministic configuration — velo without prefetch at
    B=1 (stride prefetch and B>1 interleaving are schedule-sensitive for the
    cache-aware pivot, the same exclusions tests/test_engine.py and
    tests/test_fusion.py apply) — and recall-equivalent at
    ``n_workers in {2, 4}`` for all five algorithms.
  * A demand read arriving while a prefetch holds the record's slot LOCKED
    must coalesce: ONE I/O charged, the first record kept, the demand
    coroutine parked and resumed with the prefetcher's record.  (The page-
    granularity version of this race lives in tests/test_fusion.py; these
    tests pin the record-granularity LOCKED-window behavior.)
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core.bufferpool import RecordBufferPool
from repro.core.dataset import recall_at_k
from repro.core.engine import run_workload
from repro.core.search import ALGORITHMS, RecordAccessor, SearchParams
from repro.core.sim import SSD, CostModel
from repro.core.store import VeloIndex

ALGOS = sorted(ALGORITHMS)  # diskann, inmemory, pipeann, starling, velo


def _ids(results, k=10):
    out = np.full((len(results), k), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(k, len(r.ids))
        out[i, :m] = r.ids[:m]
    return out


def _run(algo, ds, graph, qb, *, async_load, n_workers=1, n_queries=40,
         params=None, batch_size=None):
    if params is None:
        # velo's stride prefetch is the one schedule-sensitive piece at B=1;
        # the bitwise contract therefore pins it off (cf. test_fusion.py)
        params = SearchParams(L=32, W=4, prefetch=False)
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        n_workers=n_workers,
        batch_size=batch_size or 1,
        async_load=async_load,
        params=params,
    )
    sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries[:n_queries])
    return sys_, results, stats


# --------------------------------------------------- determinism and parity


@pytest.mark.parametrize("algo", ALGOS)
def test_shared_pool_bitwise_identical_to_legacy(algo, small_ds, small_graph,
                                                 small_qb):
    """n_workers=1: the async shared pool returns bit-for-bit what the legacy
    per-system pool returned — ids, distances, hops, and page reads."""
    _, ref, _ = _run(algo, small_ds, small_graph, small_qb, async_load=False)
    _, got, _ = _run(algo, small_ds, small_graph, small_qb, async_load=True)
    for i, (r0, r1) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(r0.ids, r1.ids, err_msg=f"{algo} q{i}: ids")
        np.testing.assert_array_equal(r0.dists, r1.dists,
                                      err_msg=f"{algo} q{i}: dists")
        assert r0.hops == r1.hops, f"{algo} q{i}: hops"
        assert r0.reads == r1.reads, f"{algo} q{i}: reads"


@pytest.mark.parametrize("n_workers", [2, 4])
@pytest.mark.parametrize("algo", ALGOS)
def test_shared_pool_multiworker_recall_parity(algo, n_workers, small_ds,
                                               small_graph, small_qb):
    """All five algorithms keep recall when n_workers coroutines share one
    pool with LOCKED-window coalescing (vs the legacy admit path)."""
    recalls = {}
    for async_load in (False, True):
        _, results, _ = _run(
            algo, small_ds, small_graph, small_qb, async_load=async_load,
            n_workers=n_workers, n_queries=len(small_ds.queries),
            batch_size=4,
            params=SearchParams(L=48, W=4),
        )
        recalls[async_load] = recall_at_k(
            _ids(results), small_ds.groundtruth, 10
        )
    assert abs(recalls[True] - recalls[False]) < 0.05, (algo, recalls)


def test_legacy_mode_never_parks():
    """async_load=False must never touch the LOCKED-window machinery."""
    ds_args = dict(n_workers=4, n_queries=40, batch_size=8,
                   params=SearchParams(L=48, W=4))
    import repro.core.dataset as dm
    import repro.core.vamana as vam
    from repro.core.quant import RabitQuantizer
    ds = dm.make_dataset(n=800, d=32, n_queries=40, k=10, seed=3)
    graph = vam.build_vamana(ds.base, R=12, L=24, batch_size=256, seed=3)
    qb = RabitQuantizer(32, seed=3).fit_encode(ds.base)
    _, _, stats = _run("velo", ds, graph, qb, async_load=False, **ds_args)
    assert stats.lock_waits == 0
    assert stats.coalesced_record_loads == 0
    assert stats.group_admits == 0


# ------------------------------------------- record-level coalescing races


@pytest.fixture(scope="module")
def velo_index(small_ds, small_graph, small_qb):
    return VeloIndex(small_ds.base, small_graph, small_qb)


def _fresh_accessor(velo_index, n_slots=64):
    pool = RecordBufferPool(n_slots, velo_index.layout.vid_to_page)
    return RecordAccessor(velo_index, pool, CostModel(), co_admit=False,
                          async_load=True)


def test_demand_coalesces_on_prefetch_locked_slot(velo_index):
    """The duplicate-admit race at RECORD granularity: a demand get() racing
    an in-flight prefetch of the same vid parks on the LOCKED slot — one I/O
    charged, one decode, the prefetcher's (first) record kept and handed to
    the demand coroutine."""
    acc = _fresh_accessor(velo_index)
    vid = 5

    def co(qid, _q):
        op = acc.prefetch_op(vid)
        assert op is not None
        assert acc.pool.is_loading(vid), "prefetch must open the LOCKED window"
        assert acc.prefetch_op(vid) is None, "in-flight load must not resubmit"
        yield op
        rec = yield from acc.get(vid)  # LOCKED window still open: must park
        return rec

    results, stats = run_workload(
        co, np.zeros((1, 2), np.float32), store=velo_index.store,
        cost=CostModel(), ssd=SSD(), batch_size=1,
    )
    assert stats.io_count == 1, "demand must coalesce, not re-read the page"
    assert stats.lock_waits == 1
    assert stats.coalesced_record_loads == 1
    assert acc.pool.status(vid) == "present"
    # the record handed to the waiter IS the published (first) one
    assert results[0] is acc.pool.lookup(vid)
    assert results[0].vid == vid


def test_cross_worker_demand_coalesces(velo_index):
    """Coalescing spans workers: the pool is one instance, so a demand on
    worker 1 parks on a LOCKED window opened by worker 0's prefetch and is
    resumed by its completion."""
    acc = _fresh_accessor(velo_index)
    vid = 7

    def co(qid, _q):
        if qid == 0:  # worker 0: prefetch holds the window open
            op = acc.prefetch_op(vid)
            assert op is not None
            yield op
            yield ("compute", 500e-6)  # outlive the read
            return None
        # worker 1: demand read of the same record while it is in flight
        yield ("compute", 1e-6)  # let worker 0 submit first
        rec = yield from acc.get(vid)
        return rec

    results, stats = run_workload(
        co, np.zeros((2, 2), np.float32), store=velo_index.store,
        cost=CostModel(), ssd=SSD(), n_workers=2, batch_size=1,
    )
    assert stats.io_count == 1
    assert stats.coalesced_record_loads == 1
    assert results[1] is acc.pool.lookup(vid)


def test_get_many_parks_on_foreign_loads(velo_index):
    """get_many splits its vids into present/loading/missing and parks on the
    loading ones AFTER publishing its own — no deadlock, every record real.
    The holder keeps its LOCKED window open well past the reader's own page
    read, so the reader genuinely parks instead of resolving inline."""
    acc = _fresh_accessor(velo_index)
    locked_vid, fresh_vid = 11, 12

    def co(qid, _q):
        if qid == 0:  # worker 0: slow loader holds the window open across
            # three sequential (suspending) reads ~250us before publishing
            assert acc.pool.begin_load(locked_vid) >= 0
            page = None
            for v in (locked_vid, 30, 50):
                pages = yield ("read", [velo_index.page_of(v)])
                if page is None:
                    page = pages[velo_index.page_of(locked_vid)]
            acc.pool.finish_load(
                locked_vid, velo_index.decode_record(locked_vid, page)
            )
            return None
        yield ("compute", 1e-6)  # let worker 0 open the window first
        recs = yield from acc.get_many([locked_vid, fresh_vid])
        return recs

    results, stats = run_workload(
        co, np.zeros((2, 2), np.float32), store=velo_index.store,
        cost=CostModel(), ssd=SSD(), n_workers=2, batch_size=1,
    )
    recs = results[1]
    assert recs[locked_vid].vid == locked_vid
    assert recs[fresh_vid].vid == fresh_vid
    assert stats.lock_waits == 1
    assert stats.coalesced_record_loads == 1
    assert recs[locked_vid] is acc.pool.lookup(locked_vid)


def test_inline_load_wait_resolution_counts_one_miss(velo_index):
    """A load_wait whose window closes during the searcher's own page read
    resolves inline — it must NOT add a hit on top of the miss the searcher
    already counted (one logical access, one stat)."""
    acc = _fresh_accessor(velo_index)
    locked_vid, fresh_vid = 11, 12

    def co(qid, _q):
        if qid == 0:  # prefetch completes while q1 is suspended on its read
            op = acc.prefetch_op(locked_vid)
            assert op is not None
            yield op
            return None
        yield ("compute", 1e-6)
        recs = yield from acc.get_many([locked_vid, fresh_vid])
        return recs

    results, stats = run_workload(
        co, np.zeros((2, 2), np.float32), store=velo_index.store,
        cost=CostModel(), ssd=SSD(), n_workers=2, batch_size=1,
    )
    assert results[1][locked_vid].vid == locked_vid
    # q1's two classification lookups: both misses, and nothing else —
    # the inline resolution must stay stat-free
    assert acc.pool.misses == 2
    assert acc.pool.hits == 0


def test_exhausted_pool_still_serves_uncached(velo_index):
    """Every slot pinned by an in-flight load: demand reads fall back to the
    legacy uncached path (read + return, no admission) — never deadlock."""
    pool = RecordBufferPool(2, velo_index.layout.vid_to_page)
    acc = RecordAccessor(velo_index, pool, CostModel(), co_admit=False,
                         async_load=True)
    pool.begin_load(100)
    pool.begin_load(101)  # pool fully LOCKED

    def co(qid, _q):
        rec = yield from acc.get(3)
        return rec

    results, _ = run_workload(
        co, np.zeros((1, 2), np.float32), store=velo_index.store,
        cost=CostModel(), ssd=SSD(), batch_size=1,
    )
    assert results[0].vid == 3
    assert pool.status(3) == "absent"  # served, not cached
    assert pool.is_loading(100) and pool.is_loading(101)


# -------------------------------------------------- end-to-end pool pressure


def test_velo_prefetch_coalesces_records(small_ds, small_graph, small_qb):
    """The acceptance bar: a default velo run (prefetch + cbs) under a shared
    pool must actually exercise record-level coalescing and group admits."""
    cfg = baselines.SystemConfig(buffer_ratio=0.1, n_workers=4, batch_size=8)
    sys_ = baselines.build_system("velo", small_ds.base, small_graph,
                                  small_qb, cfg)
    _, stats = sys_.run(small_ds.queries)
    assert stats.coalesced_record_loads > 0, "prefetch+demand races must coalesce"
    assert stats.lock_waits >= stats.coalesced_record_loads
    assert stats.group_admits > 0, "co-resident groups must admit as groups"
    assert stats.clock_skips >= 0
    rec = recall_at_k(_ids(sys_.run(small_ds.queries)[0]),
                      small_ds.groundtruth, 10)
    assert rec > 0.6
