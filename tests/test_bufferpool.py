"""Record buffer pool state machine (paper §3.2, Fig. 5) — property tests."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.bufferpool import RESIDENT_BIT, RecordBufferPool, SlotState


def make_pool(n_slots=8, n_records=64):
    vid_to_page = np.arange(n_records) // 4
    return RecordBufferPool(n_slots, vid_to_page)


def test_admit_lookup_hit():
    pool = make_pool()
    assert pool.lookup(3) is None            # miss
    pool.admit(3, "rec3")
    assert pool.lookup(3) == "rec3"          # hit
    assert pool.hits == 1 and pool.misses == 1


def test_hybrid_pointer_encoding():
    pool = make_pool()
    assert not pool.is_resident(5)
    assert pool.page_of(5) == 1              # vid 5 -> page 5//4
    slot = pool.admit(5, "r")
    assert pool.is_resident(5)
    assert pool.record_map[5] == (RESIDENT_BIT | np.uint64(slot))
    # evict everything; pointer must revert to the disk page
    pool.run_clock(target=pool.n_slots)
    assert not pool.is_resident(5)
    assert pool.page_of(5) == 1


def test_eviction_when_full():
    pool = make_pool(n_slots=4)
    for vid in range(4):
        pool.admit(vid, f"r{vid}")
    assert pool.occupancy() == 4
    pool.admit(10, "r10")                    # forces a clock eviction
    assert pool.occupancy() == 4
    assert pool.is_resident(10)
    assert pool.evictions == 1


def test_second_chance_protects_hot_records():
    """A record accessed between clock sweeps survives; a cold one dies."""
    pool = make_pool(n_slots=2)
    pool.admit(0, "hot")
    pool.admit(1, "cold")
    pool.run_clock(target=0)                 # no-op
    # first full sweep marks both
    pool.state[:] = SlotState.MARKED
    pool.lookup(0)                           # second chance: hot -> OCCUPIED
    pool.admit(2, "new")                     # clock must evict the cold one
    assert pool.is_resident(0), "hot record must survive"
    assert not pool.is_resident(1), "cold record must be evicted"


def test_duplicate_admit_is_idempotent():
    pool = make_pool()
    s1 = pool.admit(7, "a")
    s2 = pool.admit(7, "b")                  # prefetch/demand race
    assert s1 == s2
    assert pool.lookup(7) == "a"


@given(
    ops=st.lists(
        st.tuples(st.sampled_from(["lookup", "admit", "clock"]),
                  st.integers(min_value=0, max_value=63)),
        min_size=1, max_size=300,
    ),
    n_slots=st.integers(min_value=1, max_value=16),
)
@settings(max_examples=100, deadline=None)
def test_state_machine_invariants(ops, n_slots):
    """Arbitrary op sequences never violate the Fig. 5 state machine."""
    pool = make_pool(n_slots=n_slots)
    for op, vid in ops:
        if op == "lookup":
            rec = pool.lookup(vid)
            if rec is not None:
                assert rec == f"r{vid}"
        elif op == "admit":
            if not pool.is_resident(vid):
                pool.admit(vid, f"r{vid}")
            slot = int(pool.record_map[vid] & ~RESIDENT_BIT)
            assert pool.state[slot] in (SlotState.OCCUPIED, SlotState.MARKED)
        else:
            pool.run_clock(target=1 + vid % 3)
        pool.check_invariants()


def test_admit_all_locked_pool_returns_sentinel():
    """Every slot LOCKED by an in-flight load (pool smaller than the prefetch
    window): admit must signal exhaustion gracefully, not assert-crash."""
    pool = make_pool(n_slots=4)
    for vid in range(4):
        pool.admit(vid, f"r{vid}")
    pool.state[:] = SlotState.LOCKED
    slot = pool.admit(40, "r40")
    assert slot == -1, "exhausted pool must return the -1 sentinel"
    assert not pool.is_resident(40)
    pool.check_invariants()
    # unlocking makes the pool admit again
    pool.state[:] = SlotState.OCCUPIED
    assert pool.admit(40, "r40") >= 0
    assert pool.lookup(40) == "r40"


@given(
    n_slots=st.integers(min_value=1, max_value=8),
    locked=st.lists(st.booleans(), min_size=8, max_size=8),
    vids=st.lists(st.integers(min_value=8, max_value=63), min_size=1, max_size=20),
)
@settings(max_examples=100, deadline=None)
def test_admit_under_locked_slots_never_crashes(n_slots, locked, vids):
    """Admissions into a pool with an arbitrary subset of LOCKED slots (all
    the way to fully locked) either succeed or return -1 — never crash, never
    corrupt the state machine, never evict a LOCKED slot."""
    pool = make_pool(n_slots=n_slots)
    for vid in range(n_slots):
        pool.admit(vid, f"r{vid}")
    for s in range(n_slots):
        if locked[s]:
            pool.state[s] = SlotState.LOCKED
    locked_vids = {int(pool.slot_vid[s]) for s in range(n_slots)
                   if pool.state[s] == SlotState.LOCKED}
    for vid in vids:
        slot = pool.admit(vid, f"r{vid}")
        if slot == -1:
            assert all(pool.state == SlotState.LOCKED)
            assert not pool.is_resident(vid)
        else:
            assert pool.lookup(vid) == f"r{vid}"
        pool.check_invariants()
    for v in locked_vids:  # in-flight loads must never have been evicted
        assert pool.is_resident(v)


def test_hit_rate_tracks_skew():
    """Skewed access over a small pool must yield a decent hit rate — the
    record-level pool's reason to exist (paper Fig. 4)."""
    rng = np.random.default_rng(0)
    pool = make_pool(n_slots=32, n_records=256)
    # zipf-ish: 80% of accesses to 16 hot records
    for _ in range(2000):
        if rng.random() < 0.8:
            vid = int(rng.integers(0, 16))
        else:
            vid = int(rng.integers(16, 256))
        if pool.lookup(vid) is None:
            pool.admit(vid, f"r{vid}")
    # second chance keeps the hot set pinned: most hot accesses hit
    assert pool.hit_rate() > 0.6
