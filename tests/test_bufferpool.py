"""Record buffer pool state machine (paper §3.2, Fig. 5) — deterministic unit
tests.  Randomized property/stateful coverage (hypothesis) lives in
tests/test_bufferpool_stateful.py."""

import numpy as np

from repro.core.bufferpool import RESIDENT_BIT, RecordBufferPool, SlotState


def make_pool(n_slots=8, n_records=64):
    vid_to_page = np.arange(n_records) // 4
    return RecordBufferPool(n_slots, vid_to_page)


def test_admit_lookup_hit():
    pool = make_pool()
    assert pool.lookup(3) is None            # miss
    pool.admit(3, "rec3")
    assert pool.lookup(3) == "rec3"          # hit
    assert pool.hits == 1 and pool.misses == 1


def test_hybrid_pointer_encoding():
    pool = make_pool()
    assert not pool.is_resident(5)
    assert pool.page_of(5) == 1              # vid 5 -> page 5//4
    slot = pool.admit(5, "r")
    assert pool.is_resident(5)
    assert pool.record_map[5] == (RESIDENT_BIT | np.uint64(slot))
    # evict everything; pointer must revert to the disk page
    pool.run_clock(target=pool.n_slots)
    assert not pool.is_resident(5)
    assert pool.page_of(5) == 1


def test_eviction_when_full():
    pool = make_pool(n_slots=4)
    for vid in range(4):
        pool.admit(vid, f"r{vid}")
    assert pool.occupancy() == 4
    pool.admit(10, "r10")                    # forces a clock eviction
    assert pool.occupancy() == 4
    assert pool.is_resident(10)
    assert pool.evictions == 1


def test_second_chance_protects_hot_records():
    """A record accessed between clock sweeps survives; a cold one dies."""
    pool = make_pool(n_slots=2)
    pool.admit(0, "hot")
    pool.admit(1, "cold")
    pool.run_clock(target=0)                 # no-op
    # first full sweep marks both
    pool.state[:] = SlotState.MARKED
    pool.lookup(0)                           # second chance: hot -> OCCUPIED
    pool.admit(2, "new")                     # clock must evict the cold one
    assert pool.is_resident(0), "hot record must survive"
    assert not pool.is_resident(1), "cold record must be evicted"


def test_duplicate_admit_is_idempotent():
    pool = make_pool()
    s1 = pool.admit(7, "a")
    s2 = pool.admit(7, "b")                  # prefetch/demand race
    assert s1 == s2
    assert pool.lookup(7) == "a"


def test_admit_all_locked_pool_returns_sentinel():
    """Every slot LOCKED by an in-flight load (pool smaller than the prefetch
    window): admit must signal exhaustion gracefully, not assert-crash."""
    pool = make_pool(n_slots=4)
    for vid in range(4):
        assert pool.begin_load(vid) >= 0   # four in-flight loads pin the pool
    slot = pool.admit(40, "r40")
    assert slot == -1, "exhausted pool must return the -1 sentinel"
    assert not pool.is_resident(40)
    pool.check_invariants()
    # publishing the loads makes the pool admit again
    for vid in range(4):
        pool.finish_load(vid, f"r{vid}")
    assert pool.admit(40, "r40") >= 0
    assert pool.lookup(40) == "r40"


# ------------------------------------------------- LOCKED windows + waiters


def test_begin_finish_load_window():
    """begin_load opens a LOCKED window (miss, not readable); finish_load
    publishes it (hit)."""
    pool = make_pool()
    slot = pool.begin_load(9)
    assert slot >= 0
    assert pool.status(9) == "loading"
    assert pool.is_loading(9)
    assert pool.peek_resident(9) and not pool.peek_present(9)
    assert pool.lookup(9) is None            # LOCKED is a miss, not a hit
    assert pool.misses == 1
    assert pool.finish_load(9, "r9") == slot
    assert pool.status(9) == "present"
    assert pool.lookup(9) == "r9"
    pool.check_invariants()


def test_waiters_coalesce_on_locked_slot():
    """Waiters parked during the LOCKED window are queued for resumption with
    the published record — one load serves the whole cohort."""
    pool = make_pool()
    pool.begin_load(3)
    pool.add_waiter(3, "coroutine-A")
    pool.add_waiter(3, "coroutine-B")
    assert pool.lock_waits == 2
    pool.check_invariants()
    pool.finish_load(3, "rec3")
    assert pool.coalesced_record_loads == 2
    assert pool.take_resumes() == [("coroutine-A", "rec3"), ("coroutine-B", "rec3")]
    assert pool.take_resumes() == []         # drained exactly once
    pool.check_invariants()


def test_duplicate_admit_during_locked_window_publishes_first():
    """The record-level duplicate-admit race: a demand admit arriving while a
    prefetch holds the slot LOCKED must publish that window and keep the
    FIRST record — never two slots for one vid."""
    pool = make_pool()
    slot = pool.begin_load(5)                # prefetch opened the window
    pool.add_waiter(5, "waiter")
    assert pool.admit(5, "demand-rec") == slot
    assert pool.lookup(5) == "demand-rec"    # demand arrived first: kept
    assert pool.finish_load(5, "prefetch-rec") == slot
    assert pool.lookup(5) == "demand-rec", "second publish must keep first"
    assert [w for w, _ in pool.take_resumes()] == ["waiter"]
    pool.check_invariants()


def test_abort_load_frees_slot_and_wakes_waiters_empty():
    pool = make_pool(n_slots=2)
    pool.begin_load(7)
    pool.add_waiter(7, "w0")
    pool.abort_load(7)
    assert pool.status(7) == "absent"
    assert pool.take_resumes() == [("w0", None)]  # waiter re-issues the load
    assert len(pool.free_list) == 2
    pool.check_invariants()


# ------------------------------------------------------------- group admits


def test_admit_group_one_clock_interaction():
    """A co-resident group lands in one call: all admitted, one group_admits
    tick, resident vids skipped (keep first)."""
    pool = make_pool(n_slots=8)
    pool.admit(0, "kept")
    n = pool.admit_group([0, 1, 2, 3], ["dup0", "g1", "g2", "g3"])
    assert n == 3
    assert pool.group_admits == 1
    assert pool.lookup(0) == "kept"          # duplicate skipped, first kept
    for vid in (1, 2, 3):
        assert pool.lookup(vid) == f"g{vid}"
    gids = {int(pool.slot_group[pool._slot_of(v)]) for v in (1, 2, 3)}
    assert len(gids) == 1 and gids != {0}    # one shared non-zero group id
    pool.check_invariants()


def test_admit_group_under_pressure_never_touches_locked():
    """A group larger than the evictable space behaves exactly like the
    sequential admits it replaces (later members displace earlier ones via
    the clock — the legacy-parity contract): no crash, LOCKED slots never
    evicted, survivors bounded by the unpinned capacity."""
    pool = make_pool(n_slots=4)
    pool.begin_load(60)                      # one slot pinned by a load
    pool.admit_group(list(range(6)), [f"g{v}" for v in range(6)])
    assert pool.is_loading(60), "the in-flight load must keep its slot"
    survivors = [v for v in range(6) if pool.status(v) == "present"]
    assert len(survivors) == 3               # 4 slots - 1 LOCKED
    pool.check_invariants()


def test_admit_group_duplicate_vids_keep_first():
    """In-batch duplicates must not double-allocate: one slot per vid, first
    record kept, mapping array consistent (regression: a stale second slot
    used to corrupt record_map when the clock evicted it)."""
    pool = make_pool(n_slots=8)
    n = pool.admit_group([5, 5, 6], ["first", "second", "g6"])
    assert n == 2
    assert pool.lookup(5) == "first"
    assert pool.occupancy() == 2
    pool.run_clock(target=pool.n_slots)      # evict everything
    assert pool.status(5) == "absent" and pool.lookup(5) is None
    pool.check_invariants()


def test_admit_group_fully_locked_pool_drops_group():
    pool = make_pool(n_slots=2)
    pool.begin_load(60)
    pool.begin_load(61)                      # pool fully pinned
    n = pool.admit_group([1, 2], ["g1", "g2"])
    assert n == 0
    assert pool.group_admits == 0
    assert pool.status(1) == "absent" and pool.status(2) == "absent"
    pool.check_invariants()


def test_admit_group_skips_locked_vids():
    pool = make_pool(n_slots=8)
    pool.begin_load(2)
    n = pool.admit_group([1, 2, 3], ["g1", "racing", "g3"])
    assert n == 2
    assert pool.is_loading(2), "in-flight load must keep its window"
    pool.check_invariants()


def test_group_demote_ages_groups_together():
    """With group_demote on, the clock hand demoting one member MARKs the
    whole group, so co-placed groups age (and free) as a unit."""
    vid_to_page = np.arange(64) // 4
    pool = RecordBufferPool(8, vid_to_page, group_demote=True)
    pool.admit_group([0, 1, 2], ["a", "b", "c"])
    pool.admit(10, "solo")
    pool.run_clock(target=0)                 # no-op
    # force a full demote sweep: nothing freed yet, everything OCCUPIED
    pool.run_clock(target=1)                 # demotes + evicts first MARKED
    # whichever group member the hand touched first dragged the others down:
    group_states = {int(pool.state[pool._slot_of(v)])
                    for v in (0, 1, 2) if pool.is_resident(v)}
    assert SlotState.OCCUPIED not in group_states


# ------------------------------------------------------- clock accounting


def test_clock_skips_counted_and_no_livelock():
    """A sweep over an all-LOCKED pool must terminate after ONE revolution
    (n_slots skips), not burn 3 * n_slots steps silently."""
    pool = make_pool(n_slots=4)
    for vid in range(4):
        pool.begin_load(vid)
    freed = pool.run_clock(target=1)
    assert freed == 0
    assert pool.clock_skips == 4, "each LOCKED step must be counted, once"
    pool.check_invariants()


def test_clock_skips_partial_locked():
    """LOCKED slots mid-sweep are skipped (and counted) but do not stop the
    hand from evicting the unlocked ones."""
    pool = make_pool(n_slots=4)
    pool.begin_load(50)
    for vid in range(3):
        pool.admit(vid, f"r{vid}")
    freed = pool.run_clock(target=3)
    assert freed == 3
    assert pool.clock_skips >= 1
    assert pool.is_loading(50)
    pool.check_invariants()


def test_hit_rate_tracks_skew():
    """Skewed access over a small pool must yield a decent hit rate — the
    record-level pool's reason to exist (paper Fig. 4)."""
    rng = np.random.default_rng(0)
    pool = make_pool(n_slots=32, n_records=256)
    # zipf-ish: 80% of accesses to 16 hot records
    for _ in range(2000):
        if rng.random() < 0.8:
            vid = int(rng.integers(0, 16))
        else:
            vid = int(rng.integers(16, 256))
        if pool.lookup(vid) is None:
            pool.admit(vid, f"r{vid}")
    # second chance keeps the hot set pinned: most hot accesses hit
    assert pool.hit_rate() > 0.6


# ------------------------------------------------- multi-tenant soft quotas
# Deterministic replays of the quota rules (the hypothesis state machine in
# tests/test_bufferpool_stateful.py drives the same surface randomly; these
# pin the semantics in an environment without hypothesis).


def make_tenant_pool(n_slots=8, n_records=64, n_tenants=2, quota=None, **kw):
    vid_to_page = np.arange(n_records) // 4
    tenant_of = np.arange(n_records) % n_tenants  # vids round-robin tenants
    return RecordBufferPool(n_slots, vid_to_page, tenant_of=tenant_of,
                            tenant_quota=quota, **kw)


def test_quota_off_accounting_matches_ownership():
    """With no quota the policy is the pure global clock, but the ownership
    bookkeeping still tracks every claim/release exactly."""
    pool = make_tenant_pool(n_slots=4, quota=None)
    for vid in (0, 2, 4, 1, 3, 6, 8):  # evens tenant 0, odds tenant 1
        pool.admit(vid, f"r{vid}")
        pool.check_invariants()
    assert pool.tenant_cap is None
    assert int(pool.tenant_owned.sum()) == pool.occupancy()
    # one tenant may own the whole pool: no cap binds
    pool2 = make_tenant_pool(n_slots=4, quota=None)
    for vid in (0, 2, 4, 6):
        pool2.admit(vid, f"r{vid}")
    assert pool2.tenant_owned[0] == 4 and pool2.tenant_owned[1] == 0
    pool2.check_invariants()


def test_quota_caps_tenant_and_reclaims_own_slots():
    """At its cap a tenant recycles its OWN slots (tenant-scoped second
    chance): the oldest own record leaves, the other tenant is untouched."""
    pool = make_tenant_pool(n_slots=4, quota=0.5)  # cap = 2 slots per tenant
    pool.admit(0, "r0")
    pool.admit(2, "r2")     # tenant 0 at cap
    pool.admit(1, "r1")     # tenant 1 under cap
    pool.check_invariants()
    assert pool.admit(4, "r4") >= 0   # tenant 0 over cap: reclaims own
    pool.check_invariants()
    assert pool.tenant_owned[0] == 2  # still at cap, not above
    assert pool.quota_reclaims == 1
    assert pool.lookup(1) == "r1"     # tenant 1 untouched
    assert pool.lookup(4) == "r4"     # the new record is cached
    # one of tenant 0's earlier records was the reclaim victim
    assert (pool.lookup(0) is None) or (pool.lookup(2) is None)


def test_quota_denial_when_own_slots_all_locked():
    """A tenant at cap whose every slot sits in a LOCKED window cannot
    reclaim: the admission is skipped (-1), never an eviction of a foreign
    or LOCKED slot."""
    pool = make_tenant_pool(n_slots=4, quota=0.5)
    assert pool.begin_load(0) >= 0
    assert pool.begin_load(2) >= 0    # tenant 0 at cap, both LOCKED
    denials0 = pool.quota_denials
    assert pool.admit(4, "r4") == -1
    assert pool.quota_denials == denials0 + 1
    assert pool.is_loading(0) and pool.is_loading(2)
    pool.check_invariants()
    # tenant 1 is unaffected by tenant 0's cap pressure
    assert pool.admit(1, "r1") >= 0
    pool.check_invariants()


def test_quota_under_cap_uses_free_list_and_global_clock():
    """Under its cap a tenant acquires slots exactly like the single-tenant
    pool: free list first, then the global clock (which may evict another
    tenant's cold slots — that is the sharing benefit)."""
    pool = make_tenant_pool(n_slots=4, quota=0.75)  # cap = 3
    for vid in (1, 3, 5):     # tenant 1 takes three slots
        pool.admit(vid, f"r{vid}")
    pool.admit(0, "r0")       # tenant 0: last free slot
    pool.check_invariants()
    assert pool.tenant_owned[0] == 1 and pool.tenant_owned[1] == 3
    # pool full; tenant 0 under cap admits via the GLOBAL clock: some
    # (cold) record of either tenant is evicted, ownership stays consistent
    assert pool.admit(2, "r2") >= 0
    pool.check_invariants()
    assert pool.lookup(2) == "r2"
    assert int(pool.tenant_owned.sum()) == pool.occupancy() == 4


def test_quota_release_paths_decrement_ownership():
    """abort_load and clock eviction both hand the slot back: ownership
    follows the slot through every release path."""
    pool = make_tenant_pool(n_slots=4, quota=0.5)
    assert pool.begin_load(0) >= 0
    assert pool.tenant_owned[0] == 1
    pool.abort_load(0)
    assert pool.tenant_owned[0] == 0
    pool.check_invariants()
    pool.admit(2, "r2")
    pool.run_clock(target=1)  # demote
    pool.run_clock(target=1)  # evict
    assert pool.tenant_owned[0] == 0
    pool.check_invariants()


def test_quota_replay_mixed_ops_accounting_invariant():
    """A fixed mixed-op replay (the deterministic pre-validation of the
    stateful rules): after EVERY op, quota accounting matches actual slot
    ownership and no cap is exceeded."""
    pool = make_tenant_pool(n_slots=6, n_tenants=3, quota=0.34)  # cap = 2
    ops = [
        ("admit", 0), ("admit", 1), ("admit", 2), ("begin", 3),
        ("admit", 6), ("finish", 3), ("admit", 9), ("clock", 2),
        ("admit", 12), ("admit", 4), ("begin", 7), ("abort", 7),
        ("admit", 5), ("group", (8, 11, 14)), ("clock", 3), ("admit", 15),
        ("begin", 10), ("admit", 10), ("admit", 13), ("clock", 1),
    ]
    for op, arg in ops:
        if op == "admit":
            pool.admit(arg, f"r{arg}")
        elif op == "begin":
            pool.begin_load(arg)
        elif op == "finish":
            pool.finish_load(arg, f"l{arg}")
        elif op == "abort":
            pool.abort_load(arg)
        elif op == "group":
            pool.admit_group(list(arg), [f"g{v}" for v in arg])
        else:
            pool.run_clock(target=arg)
        pool.check_invariants()
        assert (pool.tenant_owned <= pool.tenant_cap).all()
