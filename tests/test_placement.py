"""Affinity co-placement invariants (paper §3.4)."""

import numpy as np

from repro.core import placement
from repro.core.pages import page_lookup, page_records


def _payloads(n, rng, lo=40, hi=180):
    sizes = rng.integers(lo, hi, size=n)
    blobs = [bytes(rng.integers(0, 256, size=s, dtype=np.uint8)) for s in sizes]
    return lambda vid: blobs[vid]


def test_every_record_placed_exactly_once(rng):
    n = 500
    pf = _payloads(n, rng)
    affinity = {i: [i + 1, i + 2] for i in range(0, 300, 10)}
    layout = placement.layout_affinity(pf, n, affinity)
    seen = set()
    for page in layout.pages:
        for slot, payload in page_records(page):
            assert slot.vid not in seen
            seen.add(slot.vid)
            assert payload == pf(slot.vid)
    assert seen == set(range(n))


def test_vid_to_page_is_correct(rng):
    n = 400
    pf = _payloads(n, rng)
    affinity = {i: [i + 3, i + 7] for i in range(0, 200, 13)}
    layout = placement.layout_affinity(pf, n, affinity)
    for vid in range(n):
        page = layout.pages[layout.vid_to_page[vid]]
        assert page_lookup(page, vid) is not None


def test_affine_groups_share_page_and_color(rng):
    n = 600
    pf = _payloads(n, rng, lo=30, hi=60)  # small records: groups always fit
    affinity = {i: [i + 1, i + 2, i + 3] for i in range(0, 400, 20)}
    layout = placement.layout_affinity(pf, n, affinity)
    colocated = 0
    total = 0
    for p, group in affinity.items():
        members = [p] + group
        pids = {int(layout.vid_to_page[v]) for v in members}
        colors = {int(layout.colors[v]) for v in members}
        total += 1
        if len(pids) == 1:
            colocated += 1
            assert len(colors) == 1 and colors.pop() != 0
    assert colocated / total > 0.9  # splits only as a last resort


def test_non_affine_records_have_color_zero(rng):
    n = 300
    pf = _payloads(n, rng)
    affinity = {10: [11, 12]}
    layout = placement.layout_affinity(pf, n, affinity)
    members = {10, 11, 12}
    for vid in range(n):
        if vid not in members:
            assert layout.colors[vid] == 0


def test_sequential_and_shuffle_layouts_complete(rng):
    n = 250
    pf = _payloads(n, rng)
    layout = placement.layout_sequential(pf, n)
    assert sorted(
        s.vid for page in layout.pages for s, _ in page_records(page)
    ) == list(range(n))

    adjacency = np.arange(n * 4).reshape(n, 4) % n
    degrees = np.full(n, 4, dtype=np.int32)
    layout2 = placement.layout_block_shuffle(pf, n, adjacency.astype(np.int32), degrees)
    assert sorted(
        s.vid for page in layout2.pages for s, _ in page_records(page)
    ) == list(range(n))


def test_affinity_layout_improves_colocation(rng, small_ds, small_graph):
    """The point of §3.4: affine vertices co-located >> sequential layout."""
    g = small_graph
    pf = _payloads(g.n, rng, lo=60, hi=100)
    aff = g.affinity_ids(tau_scale=1.0, cap=8)
    lay_aff = placement.layout_affinity(pf, g.n, aff)
    lay_seq = placement.layout_sequential(pf, g.n)

    def coloc_fraction(layout):
        num = den = 0
        for p, group in aff.items():
            for v in group:
                den += 1
                if layout.vid_to_page[p] == layout.vid_to_page[v]:
                    num += 1
        return num / max(den, 1)

    assert coloc_fraction(lay_aff) > 2 * coloc_fraction(lay_seq)
