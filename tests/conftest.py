"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see 1 CPU device;
only launch/dryrun.py (and subprocesses spawned by distributed tests) set the
512-device flag."""

import numpy as np
import pytest

from repro.core import dataset as dataset_mod
from repro.core import vamana as vamana_mod
from repro.core.quant import RabitQuantizer


@pytest.fixture(scope="session")
def small_ds():
    return dataset_mod.make_dataset(n=1500, d=64, n_queries=60, k=10, seed=0)


@pytest.fixture(scope="session")
def small_graph(small_ds):
    return vamana_mod.build_vamana(
        small_ds.base, R=20, L=40, batch_size=256, seed=0
    )


@pytest.fixture(scope="session")
def small_qb(small_ds):
    return RabitQuantizer(small_ds.dim, seed=0).fit_encode(small_ds.base)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(1234)
