"""Property tests for the discrete-event engine (paper §3.1 semantics)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.engine import run_workload
from repro.core.sim import SSD, CostModel, SSDConfig


class DictStore:
    """Minimal page store for synthetic coroutines."""

    def __init__(self, n_pages=64):
        self.pages = {i: bytes([i % 256]) * 16 for i in range(n_pages)}

    def read_page(self, pid):
        return self.pages[pid]


def make_algo(schedule):
    """A coroutine following a (kind, arg) schedule; returns visited pages."""

    def algo(qid, _q):
        got = []
        for kind, arg in schedule:
            if kind == "compute":
                yield ("compute", arg * 1e-6)
            elif kind == "read":
                pages = yield ("read", [arg])
                got.append((arg, pages[arg]))
            elif kind == "submit":
                toks = yield ("submit", [arg])
                res = yield ("wait_any", set(toks))
                got.append((res[1], res[2]))
        return got

    return algo


ops = st.lists(
    st.tuples(
        st.sampled_from(["compute", "read", "submit"]),
        st.integers(min_value=0, max_value=63),
    ),
    min_size=1,
    max_size=12,
)


@given(schedule=ops, n_queries=st.integers(1, 12),
       batch=st.integers(1, 6), workers=st.integers(1, 3))
@settings(max_examples=60, deadline=None)
def test_engine_completes_and_time_is_sane(schedule, n_queries, batch, workers):
    """Every query completes with correct data; simulated time is positive and
    the makespan is bounded by the fully-serial execution."""
    store = DictStore()
    queries = np.zeros((n_queries, 2), np.float32)
    results, stats = run_workload(
        lambda qid, q: make_algo(schedule)(qid, q),
        queries, store=store, ssd=SSD(SSDConfig()),
        cost=CostModel(), n_workers=workers, batch_size=batch,
    )
    assert len(results) == n_queries
    for r in results:
        assert r is not None
        for pid, page in r:
            assert page == store.read_page(pid)
    n_reads = sum(1 for k, _ in schedule if k in ("read", "submit"))
    n_comp = sum(a for k, a in schedule if k == "compute")
    serial = n_queries * (n_reads * 100e-6 + n_comp * 1e-6 + 1e-3)
    assert 0 <= stats.makespan_s <= serial + 1e-3
    assert stats.io_count <= n_queries * n_reads  # dedup can only reduce


@given(batch=st.integers(2, 8))
@settings(max_examples=15, deadline=None)
def test_async_overlap_never_slower(batch):
    """B>1 must never yield a longer makespan than B=1 for an I/O-heavy mix."""
    store = DictStore()
    schedule = [("read", i) for i in range(6)] + [("compute", 5)]
    queries = np.zeros((8, 2), np.float32)

    def run(B):
        _, stats = run_workload(
            lambda qid, q: make_algo(schedule)(qid, q),
            queries, store=store, ssd=SSD(), cost=CostModel(),
            n_workers=1, batch_size=B,
        )
        return stats.makespan_s

    assert run(batch) <= run(1) * 1.05
