"""Sharded scatter-gather serving plane: parity, scaling, and the two bugfix
regressions that rode in with it.

The load-bearing contract (docs/sharding.md): with ONE shard the sharded
engine is bitwise identical to the unsharded engine — same ids, same dists,
same hops, same makespan, same per-query latencies — for all five algorithms
in both fuse modes.  Everything the router adds (per-shard SSDs, clocks,
rendezvous buffers, the merge collective) must degenerate exactly at S=1.

Across shard counts only recall flatness is asserted for velo (its async
read completion order is legitimately timing-dependent); diskann's blocking
reads make it bitwise-stable at ANY shard count on one worker, which is
pinned too.

Bugfix regressions carried by this PR:
  * workload generators report the REQUESTED tenant count even when skew
    leaves some tenants never sampled (n_tenants used to be derived from
    ``tenant_ids.max() + 1``);
  * dist_search's shard merge masks invalid local-top-k lanes BEFORE the
    global-id offset translation (a sentinel id plus an offset used to look
    like a valid neighbor of the previous shard).
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import placement as placement_mod
from repro.core import sharding as sharding_mod
from repro.core import vamana as vamana_mod
from repro.core import workload as workload_mod
from repro.core.distance import ScoreRequest
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams

ALGOS = sorted(ALGORITHMS)


@pytest.fixture(scope="module")
def tiny():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=12, k=10, seed=4)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=4)
    qb = RabitQuantizer(32, seed=4).fit_encode(ds.base)
    return ds, graph, qb


def _run(tiny, algo, n_shards, fuse, n_workers=1):
    ds, graph, qb = tiny
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, n_workers=n_workers, batch_size=4, fuse=fuse,
        n_shards=n_shards, params=SearchParams(L=24, W=4),
    )
    sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries)
    return sys_, results, stats


def _recall(results, ds):
    ids = np.full((len(results), 10), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(10, len(r.ids))
        ids[i, :m] = r.ids[:m]
    return dataset_mod.recall_at_k(ids, ds.groundtruth, 10)


# ------------------------------------------------------------ plan mechanics


def test_shard_pages_contiguous_and_balanced():
    for n_pages, n_shards in [(7, 2), (16, 4), (5, 5), (9, 1), (100, 3)]:
        ps = placement_mod.shard_pages(n_pages, n_shards)
        assert ps.shape == (n_pages,) and ps.dtype == np.int32
        # contiguous: shard id never decreases page-to-page
        assert (np.diff(ps) >= 0).all(), (n_pages, n_shards)
        counts = np.bincount(ps, minlength=n_shards)
        assert counts.sum() == n_pages
        # balanced within one page
        assert counts.max() - counts.min() <= 1, (n_pages, n_shards, counts)


def test_plan_for_index_routes_every_vid(tiny):
    ds, graph, qb = tiny
    sys_ = _run(tiny, "velo", 3, True)[0]
    plan = sys_.shard_plan
    assert plan is not None and plan.n_shards == 3
    n = ds.base.shape[0]
    shards = plan.shards_of(np.arange(n))
    assert shards.shape == (n,)
    assert set(np.unique(shards)) <= set(range(3))
    # vid ownership agrees with page ownership, and every shard owns bytes
    by = sys_.store.shard_bytes(plan.page_shard)
    assert by.shape == (3,) and (by > 0).all()
    assert by.sum() == plan.page_shard.size * sys_.store.page_size
    np.testing.assert_array_equal(
        plan.shard_page_counts(), np.bincount(plan.page_shard, minlength=3)
    )


# ------------------------------------------------------ split/join mechanics


def _req(rows, payload):
    return ScoreRequest(kind="estimate", rows=rows, flop_s=1.0,
                        payload=payload)


def _router(shard_of_vid):
    vid_shard = np.asarray(shard_of_vid, dtype=np.int32)
    plan = sharding_mod.ShardPlan(
        n_shards=int(vid_shard.max()) + 1,
        page_shard=vid_shard.copy(), vid_shard=vid_shard,
    )
    return sharding_mod.ShardRouter(plan)


def test_split_single_shard_passes_original_request_through():
    router = _router([0, 0, 1])
    req = _req(2, np.array([10, 11]))
    parts = router.split(sharding_mod.ShardScatter(req, np.array([1, 1])))
    assert len(parts) == 1
    s, sub, ridx = parts[0]
    assert s == 1 and ridx is None
    assert sub is req  # untouched: the S=1 bitwise parity lever


def test_split_uneven_rows_and_flops():
    router = _router([0, 1])
    req = _req(5, np.array([7, 8, 9, 10, 11]))
    shards = np.array([1, 0, 1, 1, 0])
    parts = router.split(sharding_mod.ShardScatter(req, shards))
    assert [p[0] for p in parts] == [0, 1]
    (_, sub0, r0), (_, sub1, r1) = parts
    np.testing.assert_array_equal(r0, [1, 4])
    np.testing.assert_array_equal(r1, [0, 2, 3])
    assert sub0.rows == 2 and sub1.rows == 3
    np.testing.assert_array_equal(sub0.payload, [8, 11])
    np.testing.assert_array_equal(sub1.payload, [7, 9, 10])
    # flop cost splits proportionally and conserves the total
    assert abs(sub0.flop_s + sub1.flop_s - req.flop_s) < 1e-12


def test_split_tuple_payload_slices_every_element():
    router = _router([0, 1])
    codes = np.arange(12).reshape(3, 4)
    lo = np.array([0.0, 1.0, 2.0])
    step = np.array([0.1, 0.2, 0.3])
    req = _req(3, (codes, lo, step))
    parts = router.split(
        sharding_mod.ShardScatter(req, np.array([1, 0, 1]))
    )
    (_, sub0, _), (_, sub1, _) = parts
    np.testing.assert_array_equal(sub0.payload[0], codes[[1]])
    np.testing.assert_array_equal(sub1.payload[1], lo[[0, 2]])
    np.testing.assert_array_equal(sub1.payload[2], step[[0, 2]])


def test_scatter_join_reassembles_rows_at_max_time():
    join = sharding_mod.ScatterJoin(None, None, 0, rows=4, n_parts=2)
    assert not join.put(np.array([1, 3]), np.array([10.0, 30.0]), t=5.0)
    assert join.put(np.array([0, 2]), np.array([0.0, 20.0]), t=3.0)
    np.testing.assert_array_equal(join.merge(), [0.0, 10.0, 20.0, 30.0])
    assert join.t_done == 5.0
    # single-part joins hand the result object back untouched
    direct = sharding_mod.ScatterJoin(None, None, 0, rows=2, n_parts=1)
    val = np.array([1.0, 2.0])
    assert direct.put(None, val, t=1.0)
    assert direct.merge() is val


# ------------------------------------------------- the S=1 parity contract


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("algo", ALGOS)
def test_s1_bitwise_parity_with_unsharded(algo, fuse, tiny):
    _, ref, ref_stats = _run(tiny, algo, None, fuse)
    sys_s, got, got_stats = _run(tiny, algo, 1, fuse)
    label = f"{algo}/fuse={fuse}"
    assert [
        (list(r.ids), list(r.dists), r.hops) for r in got
    ] == [
        (list(r.ids), list(r.dists), r.hops) for r in ref
    ], f"{label}: sharded S=1 diverged from unsharded"
    # the clocks agree to the last bit too: same makespan, same per-query
    # latencies — the router's charge/resume order IS the unsharded order
    assert got_stats.makespan_s == ref_stats.makespan_s, label
    assert got_stats.latencies == ref_stats.latencies, label
    assert got_stats.scatter_ops > 0, f"{label}: scatter path never taken"
    assert sys_s.shard_plan is not None


def test_diskann_bitwise_stable_across_shard_counts(tiny):
    """Blocking-read algorithms see identical distance values regardless of
    how the fused batches regroup per shard, so their RESULTS (not clocks)
    are bitwise stable at any S on one worker."""
    _, ref, _ = _run(tiny, "diskann", 1, True)
    for S in (2, 4):
        _, got, stats = _run(tiny, "diskann", S, True)
        assert [
            (list(r.ids), list(r.dists), r.hops) for r in got
        ] == [
            (list(r.ids), list(r.dists), r.hops) for r in ref
        ], f"S={S}"
        assert stats.shard_flushes > 0 and stats.shard_merges > 0


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
def test_velo_recall_flat_across_shard_counts(fuse, tiny):
    ds = tiny[0]
    base = _recall(_run(tiny, "velo", 1, fuse)[1], ds)
    for S in (2, 4):
        _, got, stats = _run(tiny, "velo", S, fuse)
        rec = _recall(got, ds)
        assert abs(rec - base) <= 0.05, f"S={S}: {rec:.3f} vs {base:.3f}"
        assert stats.scatter_ops > 0
        if fuse:
            assert stats.shard_flushes > 0
        assert stats.shard_merges > 0, f"S={S}: no multi-shard merges"


# ------------------------------------------------------- bugfix regressions


def test_workload_n_tenants_survives_never_sampled_tenants():
    """Heavy zipfian skew on few ops leaves cold tenants unsampled; the
    generator must still report the REQUESTED tenant count (the old
    ``tenant_ids.max() + 1`` derivation silently dropped the cold tail,
    desynchronizing counts()/positions() from the serving plane's roster)."""
    m = workload_mod.zipfian_mix([10] * 6, 12, s=3.0, seed=0)
    assert int(m.tenant_ids.max()) < 5  # the premise: a cold tail exists
    assert m.n_tenants == 6
    counts = m.counts()
    assert counts.shape == (6,)
    assert counts.sum() == 12
    # cold tenants are present with zero ops, not absent
    assert (counts[int(m.tenant_ids.max()) + 1:] == 0).all()
    # back-compat: a workload built without the count still self-derives
    legacy = workload_mod.MixedWorkload(
        name=m.name, tenant_ids=m.tenant_ids.copy(),
        query_ids=m.query_ids.copy(),
    )
    assert legacy.n_tenants == int(m.tenant_ids.max()) + 1


def test_dist_search_merge_masks_before_offset():
    """An under-filled shard pads its local top-k with id -1 lanes carrying
    garbage distances.  The merge must mask those lanes BEFORE adding the
    shard's global-id offset — offset + (-1) is a valid-looking id of the
    neighboring shard, and an unmasked garbage distance can win the top-k."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.velo import dist_search

    # shard 1 found only one real neighbor; its pad lanes carry tiny
    # (garbage) distances that would win an unmasked merge
    ids0 = jnp.array([[0, 1, 2]])
    d20 = jnp.array([[0.1, 0.2, 0.3]])
    ids1 = jnp.array([[4, -1, -1]])
    d21 = jnp.array([[0.05, 0.0, 0.0]])

    g0, m0 = dist_search.mask_local_topk(ids0, d20, jnp.int32(0))
    g1, m1 = dist_search.mask_local_topk(ids1, d21, jnp.int32(100))
    assert g1.tolist() == [[104, -1, -1]]
    assert m1[0, 1] == jnp.inf and m1[0, 2] == jnp.inf

    gids = jnp.concatenate([g0, g1], axis=1)
    d2 = jnp.concatenate([m0, m1], axis=1)
    out_ids, out_d2 = dist_search.merge_topk(gids, d2, k=3)
    assert out_ids.tolist() == [[104, 0, 1]]
    np.testing.assert_allclose(np.asarray(out_d2), [[0.05, 0.1, 0.2]])
    # k larger than the valid candidate pool: sentinels may fill the tail
    # but only at +inf — they can never displace a real neighbor
    out_ids6, out_d26 = dist_search.merge_topk(gids, d2, k=6)
    tail = np.asarray(out_d26)[0, 4:]
    assert np.isinf(tail).all()
    assert out_ids6.tolist()[0][:4] == [104, 0, 1, 2]
