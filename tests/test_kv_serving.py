"""Paged KV pool + cache-aware scheduler + paged_attention kernel integration."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.serving.kv_pool import PagedKVPool
from repro.serving.scheduler import CacheAwareScheduler, ServeRequest

RNG = np.random.default_rng(0)


def test_append_and_block_tables():
    pool = PagedKVPool(n_pages=8, page_size=4, kv_heads=2, head_dim=8)
    pool.add_request(0)
    for t in range(10):  # spans 3 pages
        pool.append_token(0, RNG.standard_normal((2, 8)), RNG.standard_normal((2, 8)))
    req = pool.requests[0]
    assert req.context_len == 10
    assert len(req.block_table) == 3
    bt = pool.block_table_array(0, max_pages=4)
    assert (bt[:3] >= 0).all()


def test_eviction_spills_and_reloads_exactly():
    pool = PagedKVPool(n_pages=4, page_size=2, kv_heads=1, head_dim=4)
    pool.add_request(0)
    kept = []
    for t in range(8):  # needs 4 pages — fills the pool
        k = RNG.standard_normal((1, 4)).astype(np.float32)
        kept.append(k.copy())
        pool.append_token(0, k, k)
    pool.add_request(1)
    pool.append_token(1, RNG.standard_normal((1, 4)), RNG.standard_normal((1, 4)))
    assert pool.evictions >= 1
    # some page of request 0 was swapped out; reload and verify bytes
    req0 = pool.requests[0]
    swapped = [lp for lp, pp in enumerate(req0.block_table) if pp < 0]
    assert swapped
    lp = swapped[0]
    pp = pool.ensure_resident(0, lp)
    np.testing.assert_array_equal(pool.k_pages[pp, 0], kept[lp * 2])
    assert pool.swap_ins >= 1


def test_second_chance_protects_hot_request():
    pool = PagedKVPool(n_pages=4, page_size=2, kv_heads=1, head_dim=4)
    pool.add_request(0)
    pool.add_request(1)
    for _ in range(4):
        pool.append_token(0, np.ones((1, 4)), np.ones((1, 4)))  # 2 pages
        pool.append_token(1, np.zeros((1, 4)), np.zeros((1, 4)))
    # touch request 0's pages (hot), then force an eviction via request 2
    for lp in range(len(pool.requests[0].block_table)):
        pool.ensure_resident(0, lp)
    pool.state[:] = 3  # MARK everything (one full sweep)
    for lp in range(len(pool.requests[0].block_table)):
        pool.ensure_resident(0, lp)  # second chance for request 0
    pool.add_request(2)
    pool.append_token(2, np.full((1, 4), 2.0), np.full((1, 4), 2.0))
    assert all(p >= 0 for p in pool.requests[0].block_table), "hot request evicted"
    assert any(p < 0 for p in pool.requests[1].block_table), "cold request kept"


def test_scheduler_prefers_resident_requests():
    pool = PagedKVPool(n_pages=6, page_size=2, kv_heads=1, head_dim=4)
    sched = CacheAwareScheduler(pool, max_batch=2, age_boost=3)
    for rid in range(3):
        sched.submit(ServeRequest(rid=rid, prompt_len=4, max_new_tokens=6))
    # admit and build contexts: rids 0,1 hot; rid 2 swapped out
    batch = sched.next_batch()
    for req in sched.running.values():
        for _ in range(4):
            pool.append_token(req.rid, np.ones((1, 4)), np.ones((1, 4)))
    # force rid 2's pages out
    for lp, pp in enumerate(pool.requests[2].block_table):
        if pp >= 0:
            pool.state[pp] = 3
    pool.add_request(99)
    pool.append_token(99, np.zeros((1, 4)), np.zeros((1, 4)))
    batch = sched.next_batch()
    rids = {r.rid for r in batch}
    assert 2 not in rids or pool.residency_fraction(2) == 1.0
    # starvation guard: within age_boost steps rid 2 must get scheduled
    seen_2 = False
    for _ in range(5):
        batch = sched.next_batch()
        seen_2 |= any(r.rid == 2 for r in batch)
    assert seen_2


def test_pool_drives_paged_attention_kernel():
    """End-to-end: tokens appended through the pool, attention through the
    Pallas kernel via the pool's block tables == dense reference."""
    P_, page, KVH, Dh, B, H = 8, 4, 2, 16, 2, 4
    pool = PagedKVPool(n_pages=P_, page_size=page, kv_heads=KVH, head_dim=Dh)
    ctx = [7, 5]
    dense_k = [np.zeros((c, KVH, Dh), np.float32) for c in ctx]
    dense_v = [np.zeros((c, KVH, Dh), np.float32) for c in ctx]
    for b in range(B):
        pool.add_request(b)
        for t in range(ctx[b]):
            k = RNG.standard_normal((KVH, Dh)).astype(np.float32)
            v = RNG.standard_normal((KVH, Dh)).astype(np.float32)
            dense_k[b][t], dense_v[b][t] = k, v
            pool.append_token(b, k, v)

    max_pages = 2
    bt = np.stack([pool.block_table_array(b, max_pages) for b in range(B)])
    q = RNG.standard_normal((B, H, Dh)).astype(np.float32)
    out = paged_attention(
        jnp.asarray(q),
        jnp.asarray(pool.k_pages), jnp.asarray(pool.v_pages),
        jnp.asarray(bt), jnp.asarray(ctx, np.int32),
    )
    ref = paged_attention_ref(
        jnp.asarray(q),
        jnp.asarray(pool.k_pages), jnp.asarray(pool.v_pages),
        jnp.asarray(bt, np.int32), jnp.asarray(ctx, np.int32),
    )
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_serving_loop_completes_all_requests():
    pool = PagedKVPool(n_pages=16, page_size=2, kv_heads=1, head_dim=4)
    sched = CacheAwareScheduler(pool, max_batch=3)
    for rid in range(7):
        sched.submit(ServeRequest(rid=rid, prompt_len=2, max_new_tokens=4))
    steps = 0
    while not sched.idle and steps < 200:
        batch = sched.next_batch()
        for req in batch:  # "decode": append one token per scheduled request
            pool.append_token(req.rid, np.ones((1, 4)), np.ones((1, 4)))
        sched.complete_step(batch)
        steps += 1
    assert sched.idle
    assert sorted(sched.completed) == list(range(7))
