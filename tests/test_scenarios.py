"""End-to-end scenario matrix: cross-feature regression net.

Every feature PR so far added its own parity tests, but nothing exercised the
CROSS PRODUCT — fused dispatch on top of the async pool on top of the shared
rendezvous, per backend, per algorithm.  This module sweeps

    {algorithm} x {backend} x {fuse/shared-rendezvous} x {async pool}

on a tiny dataset and asserts, for every cell:

  * a recall floor (the features must compose without wrecking accuracy);
  * zero stat-counter leaks when the run drains: no in-flight read tokens
    left in the engine (``_token_info`` / ``_tokens_by_query``), no LOCKED
    buffer-pool slots, no parked waiters, no undrained pending resumes, and
    latency accounting that adds up query-for-query.

The full algorithm sweep runs on the (default) batch backend; the scalar and
pallas backends run a reduced slice — their numerics are already pinned
bitwise by tests/test_distance.py and tests/test_resident.py, so one fused +
shared + async cell per algorithm family is enough to catch composition
regressions without interpret-mode runtime blowup.
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import vamana as vamana_mod
from repro.core.bufferpool import SlotState
from repro.core.engine import Engine, EngineConfig
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams
from repro.core.sim import SSD

ALGOS = sorted(ALGORITHMS)

# (fuse, shared_rendezvous) — shared requires fuse, so the off/on lattice has
# three valid points
FUSE_MODES = [(False, False), (True, False), (True, True)]

RECALL_FLOOR = {
    "diskann": 0.6,
    "inmemory": 0.8,
    "pipeann": 0.6,
    "starling": 0.6,
    "velo": 0.6,
}


@pytest.fixture(scope="module")
def tiny():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=12, k=10, seed=4)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=4)
    qb = RabitQuantizer(32, seed=4).fit_encode(ds.base)
    return ds, graph, qb


def _run_cell(tiny, algo, backend, fuse, shared, async_load):
    """Build the system and drive the engine DIRECTLY (not System.run) so the
    engine instance stays inspectable for leak checks."""
    ds, graph, qb = tiny
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        n_workers=2,
        batch_size=4,
        distance_backend=backend,
        fuse=fuse,
        shared_rendezvous=shared,
        async_load=async_load,
        params=SearchParams(L=24, W=4),
    )
    sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
    engine = Engine(
        store=sys_.store,
        ssd=SSD(),
        cost=sys_.cost,
        config=EngineConfig(
            n_workers=sys_.config.n_workers,
            batch_size=sys_.config.batch_size,
            page_size=sys_.config.page_size,
            fuse=bool(sys_.config.fuse),
            fuse_rows=sys_.config.fuse_rows,
            shared_rendezvous=bool(sys_.config.shared_rendezvous),
        ),
        dist=sys_.ctx.dist,
        qb=sys_.ctx.qb,
    )
    results, stats = engine.run(sys_.make_coroutine, ds.queries)
    return sys_, engine, results, stats


def _assert_no_leaks(sys_, engine, results, stats, label):
    # engine: every async read token was either consumed or dropped with its
    # finished query
    assert engine._token_info == {}, f"{label}: leaked read tokens"
    assert engine._tokens_by_query == {}, f"{label}: leaked token owner sets"
    # latency accounting adds up, one entry per query
    assert len(stats.latencies) == stats.n_queries == len(results)
    assert len(stats.latency_qids) == stats.n_queries
    assert sorted(stats.latency_qids) == list(range(stats.n_queries))
    assert abs(sum(stats.latencies) - stats.sum_latency_s) < 1e-9
    # buffer pool (record-pool systems): the run drained — no open LOCKED
    # windows, no parked waiters, no undrained resumes
    pool = getattr(sys_.ctx.accessor, "pool", None)
    if pool is not None:
        assert not (pool.state == SlotState.LOCKED).any(), (
            f"{label}: LOCKED slots leaked past the end of the run"
        )
        assert pool.waiters == {}, f"{label}: waiter lists leaked"
        assert pool.pending_resumes == [], f"{label}: undrained resumes"
        pool.check_invariants()


def _recall(results, ds):
    ids = np.full((len(results), 10), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(10, len(r.ids))
        ids[i, :m] = r.ids[:m]
    return dataset_mod.recall_at_k(ids, ds.groundtruth, 10)


@pytest.mark.parametrize("async_load", [True, False],
                         ids=["async", "syncpool"])
@pytest.mark.parametrize("fuse,shared", FUSE_MODES,
                         ids=["nofuse", "fuse", "fuse+shared"])
@pytest.mark.parametrize("algo", ALGOS)
def test_scenario_matrix_batch_backend(algo, fuse, shared, async_load, tiny):
    ds = tiny[0]
    sys_, engine, results, stats = _run_cell(
        tiny, algo, "batch", fuse, shared, async_load
    )
    label = f"{algo}/batch/fuse={fuse}/shared={shared}/async={async_load}"
    rec = _recall(results, ds)
    assert rec >= RECALL_FLOOR[algo], f"{label}: recall {rec:.3f}"
    _assert_no_leaks(sys_, engine, results, stats, label)
    if fuse:
        assert stats.score_flushes > 0, f"{label}: fusion never flushed"
    else:
        assert stats.score_flushes == 0


@pytest.mark.parametrize("backend", ["scalar", "pallas"])
@pytest.mark.parametrize("algo", ["velo", "diskann"])
def test_scenario_matrix_other_backends(algo, backend, tiny):
    """Reduced slice for the non-default backends: the most feature-loaded
    cell (fused + shared rendezvous + async pool)."""
    ds = tiny[0]
    sys_, engine, results, stats = _run_cell(
        tiny, algo, backend, fuse=True, shared=True, async_load=True
    )
    label = f"{algo}/{backend}/fuse+shared/async"
    rec = _recall(results, ds)
    assert rec >= RECALL_FLOOR[algo], f"{label}: recall {rec:.3f}"
    _assert_no_leaks(sys_, engine, results, stats, label)
    assert stats.score_flushes > 0


@pytest.mark.parametrize("fuse,shared", FUSE_MODES,
                         ids=["nofuse", "fuse", "fuse+shared"])
@pytest.mark.parametrize("algo", ALGOS)
def test_scenario_matrix_verify_protocol_inert(algo, fuse, shared, tiny):
    """The dynamic protocol checker (SystemConfig.verify_protocol) rides the
    same cross-feature lattice bitwise-inertly: per cell, the verified run's
    (ids, dists, hops) match the unverified run exactly, zero violations are
    recorded, and the flush-boundary invariant pass demonstrably ran."""
    ds, graph, qb = tiny

    def run(verify):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=2, batch_size=4,
            fuse=fuse, shared_rendezvous=shared, async_load=True,
            hbm_tier=(algo == "velo"),  # one cell also crosses the HBM tier
            verify_protocol=verify,
            params=SearchParams(L=24, W=4),
        )
        sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
        results, _stats = sys_.run(ds.queries)
        return sys_, results

    _, ref = run(False)
    sys_v, got = run(True)
    label = f"{algo}/fuse={fuse}/shared={shared}/verify"
    assert [
        (list(r.ids), list(r.dists), r.hops) for r in got
    ] == [
        (list(r.ids), list(r.dists), r.hops) for r in ref
    ], f"{label}: verified run diverged from unverified run"
    assert sys_v.checker is not None, f"{label}: checker never armed"
    sys_v.checker.raise_if_violations()
    assert sys_v.checker.flushes > 0, f"{label}: no flush boundary observed"


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("algo", ALGOS)
def test_scenario_matrix_sharded_verify_inert(algo, fuse, tiny):
    """The sharded row of the matrix: {n_shards=2} x {fuse} x
    {verify_protocol}.  The protocol checker observes flush boundaries PER
    SHARD (flush_sharded calls at_flush once per shard flush, the fuse-off
    scatter path once per inline dispatch) and stays bitwise inert."""
    ds, graph, qb = tiny

    def run(verify):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=2, batch_size=4,
            fuse=fuse, async_load=True, n_shards=2,
            verify_protocol=verify,
            params=SearchParams(L=24, W=4),
        )
        sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
        results, stats = sys_.run(ds.queries)
        return sys_, results, stats

    _, ref, ref_stats = run(False)
    sys_v, got, stats = run(True)
    label = f"{algo}/sharded/fuse={fuse}/verify"
    assert [
        (list(r.ids), list(r.dists), r.hops) for r in got
    ] == [
        (list(r.ids), list(r.dists), r.hops) for r in ref
    ], f"{label}: verified run diverged from unverified run"
    rec = _recall(got, ds)
    assert rec >= RECALL_FLOOR[algo], f"{label}: recall {rec:.3f}"
    assert stats.scatter_ops > 0, f"{label}: scatter path never taken"
    assert stats.scatter_ops == ref_stats.scatter_ops, label
    assert sys_v.checker is not None, f"{label}: checker never armed"
    sys_v.checker.raise_if_violations()
    assert sys_v.checker.flushes > 0, f"{label}: no flush boundary observed"


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("scheduler", ["rr", "sla"])
def test_scenario_matrix_scheduler_verify_inert(scheduler, fuse, tiny):
    """The scheduler row of the matrix: {rr, sla} x {fuse} x
    {verify_protocol}.  With staggered arrivals and deadlines attached the
    protocol checker must stay bitwise inert under EITHER scheduling policy
    (EDF reorders dispatches, which is exactly the traffic the checker's
    transition rules must not perturb), and deadline accounting must agree
    between the verified and unverified runs."""
    from repro.core.scheduling import SlaPlan

    ds, graph, qb = tiny
    n = len(ds.queries)
    arr = np.linspace(0.0, 5e-4, n)  # arrivals staggered inside the run

    def run(verify):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=2, batch_size=4,
            fuse=fuse, async_load=True,
            scheduler=scheduler, sla_ms=2.0,
            verify_protocol=verify,
            params=SearchParams(L=24, W=4),
        )
        sys_ = baselines.build_system("velo", ds.base, graph, qb, cfg)
        results, stats = sys_.run(
            ds.queries, sla=SlaPlan.build(n, arrivals=arr, sla_ms=2.0)
        )
        return sys_, results, stats

    _, ref, ref_stats = run(False)
    sys_v, got, stats = run(True)
    label = f"velo/{scheduler}/fuse={fuse}/verify"
    assert [
        (list(r.ids), list(r.dists), r.hops) for r in got
    ] == [
        (list(r.ids), list(r.dists), r.hops) for r in ref
    ], f"{label}: verified run diverged from unverified run"
    assert stats.deadline_hits == ref_stats.deadline_hits, label
    assert stats.deadline_misses == ref_stats.deadline_misses, label
    assert stats.coroutine_switches == ref_stats.coroutine_switches, label
    assert stats.latency_qids == ref_stats.latency_qids, label
    assert sys_v.checker is not None, f"{label}: checker never armed"
    sys_v.checker.raise_if_violations()
    assert sys_v.checker.flushes > 0, f"{label}: no flush boundary observed"
