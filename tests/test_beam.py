"""Fused on-device beam step: parity with the host plane, backend
equivalence, masked top-k merge properties, and the ghost-id regression.

The load-bearing contract (docs/beam_step.md): with ``device_beam=True`` the
engine-resident beam — score -> visited mask -> top-k merge -> frontier
selection in ONE engine call per hop — returns bitwise-identical results
(ids, dists, hops) to the host beam for all five algorithms at
B=1/n_workers=1, on every DistanceEngine backend, fuse on and off.

One scoped exception, measured not assumed: velo's cache-aware pivot
(``acc.resident``) reads the simulated clock, so its TRAJECTORY is
timing-dependent whenever charges change — fuse alone already shifts velo's
hops on the pure host plane (no device beam involved).  Under fuse velo's
bar is therefore bitwise ids/dists; hops are compared only on the
charge-identical fuse-off path.  The same scoping applies across shard
counts: S>=2 bitwise parity is asserted for the deterministic-trajectory
algorithms (diskann, inmemory, starling), recall-level for velo.

The ghost-id regression (repro.velo.batch_search._merge_and_trim): a killed
duplicate copy must forfeit its id to the sentinel, not just its distance —
on an underfull beam the (INF, visited) tail survives the trim, and a ghost
keeping a real id would pair with that id's live copy in a LATER merge,
falsely marking it visited via the OR aggregation (and a 3-long id run
would break the pairwise-dedupe assumption).
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core import beam as beam_mod
from repro.core import dataset as dataset_mod
from repro.core import distance as distance_mod
from repro.core import vamana as vamana_mod
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams

ALGOS = sorted(ALGORITHMS)
TIMING_DEPENDENT = {"velo"}
BACKENDS = ["scalar", "batch", "pallas"]


@pytest.fixture(scope="module")
def tiny():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=12, k=10, seed=4)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=4)
    qb = RabitQuantizer(32, seed=4).fit_encode(ds.base)
    return ds, graph, qb


def _run(tiny, algo, device_beam, fuse, backend="default", n_shards=None,
         batch_size=1, n_workers=1):
    ds, graph, qb = tiny
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, n_workers=n_workers, batch_size=batch_size,
        fuse=fuse, n_shards=n_shards, device_beam=device_beam,
        distance_backend=backend, params=SearchParams(L=24, W=4),
    )
    sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries)
    return sys_, results, stats


def _key(results, with_hops=True):
    return [
        (list(r.ids), list(r.dists), r.hops if with_hops else None)
        for r in results
    ]


def _recall(results, ds):
    ids = np.full((len(results), 10), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(10, len(r.ids))
        ids[i, :m] = r.ids[:m]
    return dataset_mod.recall_at_k(ids, ds.groundtruth, 10)


def _skip_unless_available(backend):
    if backend == "pallas" and not distance_mod.pallas_available():
        pytest.skip("pallas backend unavailable (no jax)")


# ------------------------------------------------- the host-parity contract


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("algo", ALGOS)
def test_device_beam_bitwise_parity_with_host(algo, backend, fuse, tiny):
    _skip_unless_available(backend)
    _, ref, _ = _run(tiny, algo, False, fuse, backend)
    _, got, stats = _run(tiny, algo, True, fuse, backend)
    with_hops = not (fuse and algo in TIMING_DEPENDENT)
    assert _key(got, with_hops) == _key(ref, with_hops), (
        f"{algo}/{backend}/fuse={fuse}: device beam diverged from host"
    )
    assert stats.beam_ops > 0, f"{algo}: beam path never taken"
    assert stats.dist_downloads < _run(
        tiny, algo, False, fuse, backend
    )[2].dist_downloads, f"{algo}: fused steps saved no downloads"


@pytest.mark.parametrize("algo", ALGOS)
def test_device_beam_recall_level_at_interleaved_batch(algo, tiny):
    """B>1 interleaves coroutines, so trajectories may shift; the result
    QUALITY must not (recall within 0.02 of the host plane)."""
    ds = tiny[0]
    _, ref, _ = _run(tiny, algo, False, True, batch_size=4)
    _, got, stats = _run(tiny, algo, True, True, batch_size=4)
    assert abs(_recall(got, ds) - _recall(ref, ds)) <= 0.02
    assert stats.beam_ops > 0


def test_device_beam_off_is_the_default(tiny):
    sys_, _, stats = _run(tiny, "velo", None, False)
    assert sys_.config.device_beam is False or not sys_.config.device_beam
    assert stats.beam_ops == 0


# -------------------------------------------------- sharded-plane parity


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("algo", ALGOS)
def test_s1_sharded_parity_with_device_beam(algo, fuse, tiny):
    """The degenerate serving plane must not perturb the device beam: S=1
    sharded == unsharded, bitwise, with device_beam on."""
    _, ref, _ = _run(tiny, algo, True, fuse)
    _, got, stats = _run(tiny, algo, True, fuse, n_shards=1)
    assert _key(got) == _key(ref), f"{algo}/fuse={fuse}"
    assert stats.beam_ops > 0 and stats.scatter_ops > 0


@pytest.mark.parametrize("algo", ["diskann", "inmemory", "starling"])
def test_s2_bitwise_for_deterministic_trajectories(algo, tiny):
    """Multi-shard split + local-top-L merge + beam_finalize must reproduce
    the single-shard results exactly for algorithms whose trajectory does
    not read the clock."""
    _, ref, _ = _run(tiny, algo, True, True, n_shards=1)
    for S in (2, 4):
        _, got, stats = _run(tiny, algo, True, True, n_shards=S)
        assert _key(got) == _key(ref), f"{algo} S={S}"
        assert stats.shard_merges > 0, f"{algo} S={S}: no multi-shard merges"


def test_s2_velo_recall_level(tiny):
    ds = tiny[0]
    base = _recall(_run(tiny, "velo", True, True, n_shards=1)[1], ds)
    _, got, stats = _run(tiny, "velo", True, True, n_shards=2)
    assert abs(_recall(got, ds) - base) <= 0.05
    assert stats.shard_merges > 0


# ------------------------------------- backend equivalence, engine level


def _mk_req(qb, pq, state, fresh, explored=(), insert_ids=(), insert_ds=(),
            topk=0):
    fresh = np.asarray(fresh, np.int64)
    return beam_mod.BeamRequest(
        kind="estimate", state=state, fresh=fresh,
        explored=np.asarray(explored, np.int64),
        insert_ids=np.asarray(insert_ids, np.int64),
        insert_ds=np.asarray(insert_ds, np.float32),
        rows=int(fresh.size), flop_s=0.0, pq=pq, qb=qb, topk=int(topk),
    )


@pytest.mark.parametrize("backend", ["batch", "pallas"])
def test_beam_step_backends_match_scalar_oracle(backend):
    """A hostile step sequence — duplicate frontiers, re-submitted visited
    ids, seed inserts, explored marks emptying the frontier — produces
    lane-for-lane identical SELECTIONS on every backend.  Distances agree
    to float32 rounding only (scalar vs vectorized accumulation order);
    the bitwise contract is host-vs-device WITHIN a backend, asserted by
    the system-level parity tests above."""
    _skip_unless_available(backend)
    rng = np.random.default_rng(3)
    n, d, L = 200, 16, 8
    base = rng.standard_normal((n, d)).astype(np.float32)
    qb = RabitQuantizer(d, seed=0).fit_encode(base)
    pq = RabitQuantizer.prepare_query(
        qb, rng.standard_normal(d).astype(np.float32)
    )
    ref_eng = distance_mod.get_engine("scalar")
    got_eng = distance_mod.get_engine(backend)

    steps = [
        # seed insert + first frontier
        dict(fresh=[0], insert_ids=[0], insert_ds=[0.0], topk=0),
        # duplicates inside one frontier: first-wins
        dict(fresh=[5, 9, 5, 14, 9, 9], topk=0),
        # every id already visited: the step may only apply marks
        dict(fresh=[5, 9, 14], explored=[5], topk=0),
        # a fat frontier (wider than the beam) + a heap readout
        dict(fresh=list(range(20, 60)), explored=[9, 14], topk=L),
    ]
    st_ref = ref_eng.beam_new(L, n)
    st_got = got_eng.beam_new(L, n)
    for i, kw in enumerate(steps):
        (r,) = ref_eng.beam_step_many(qb, [_mk_req(qb, pq, st_ref, **kw)])
        (g,) = got_eng.beam_step_many(qb, [_mk_req(qb, pq, st_got, **kw)])
        np.testing.assert_array_equal(
            np.asarray(g.frontier), np.asarray(r.frontier), f"step {i}"
        )
        assert g.window_len == r.window_len, f"step {i}"
        np.testing.assert_allclose(
            np.float32(g.tail), np.float32(r.tail), rtol=1e-5, atol=1e-6,
            err_msg=f"step {i}",
        )
        if kw["topk"]:
            np.testing.assert_array_equal(
                np.asarray(g.topk_ids), np.asarray(r.topk_ids), f"step {i}"
            )
            np.testing.assert_allclose(
                np.asarray(g.topk_ds, np.float32),
                np.asarray(r.topk_ds, np.float32), rtol=1e-5, atol=1e-6,
                err_msg=f"step {i}",
            )


# ----------------------------------------- masked top-k merge properties


def _oracle_merge(cand, new, L):
    """The host _Beam's insort semantics: sort (d, v) ascending, keep L."""
    merged = sorted(cand + new)[:L]
    pad = [(float(beam_mod.INF), int(beam_mod.PAD_VID))] * (L - len(merged))
    return merged + pad


def test_merge_topk_matches_insort_oracle():
    rng = np.random.default_rng(7)
    for L in (1, 4, 16):
        for trial in range(20):
            n_c = int(rng.integers(0, L + 1))
            n_n = int(rng.integers(0, 2 * L))
            cand = [(float(np.float32(rng.random())), int(v))
                    for v in rng.integers(0, 50, n_c)]
            cand = sorted(cand) + [(float(beam_mod.INF),
                                    int(beam_mod.PAD_VID))] * (L - n_c)
            new = [(float(np.float32(rng.random())), int(v))
                   for v in rng.integers(0, 50, n_n)]
            d, v = beam_mod.merge_topk(
                np.array([c[0] for c in cand], np.float32),
                np.array([c[1] for c in cand], np.int64),
                np.array([x[0] for x in new], np.float32),
                np.array([x[1] for x in new], np.int64), L,
            )
            want = _oracle_merge(
                [c for c in cand if c[1] != beam_mod.PAD_VID], new, L
            )
            got = list(zip([float(x) for x in d], [int(x) for x in v]))
            assert got == want, (L, trial)


def test_merge_topk_padding_never_wins():
    """Pad lanes (INF, PAD_VID) sort strictly after every real candidate —
    even one carrying a genuinely infinite distance."""
    d, v = beam_mod.merge_topk(
        np.full(4, beam_mod.INF, np.float32),
        np.full(4, beam_mod.PAD_VID, np.int64),
        np.array([np.inf, 0.5], np.float32), np.array([3, 9], np.int64), 4,
    )
    assert list(v[:2]) == [9, 3]          # real inf sorts before pads by id
    assert all(x == beam_mod.PAD_VID for x in v[2:])
    assert d[0] == np.float32(0.5) and np.isinf(d[1])


def test_select_frontier_all_explored_and_underfull():
    L, n = 4, 10
    explored = np.zeros(n + 1, dtype=bool)
    cand_d = np.array([0.1, 0.2, beam_mod.INF, beam_mod.INF], np.float32)
    cand_v = np.array([3, 7, beam_mod.PAD_VID, beam_mod.PAD_VID], np.int64)
    front, wlen, tail = beam_mod.select_frontier(cand_d, cand_v, explored)
    assert list(front) == [3, 7] and wlen == 2 and np.isinf(tail)
    explored[[3, 7]] = True
    front, wlen, tail = beam_mod.select_frontier(cand_d, cand_v, explored)
    assert front.size == 0 and wlen == 2   # exhausted, but the window stays


def test_dedupe_first_keeps_first_occurrence():
    keep = beam_mod.dedupe_first(np.array([4, 2, 4, 4, 9, 2]))
    assert list(keep) == [True, True, False, False, True, False]
    assert beam_mod.dedupe_first(np.zeros(0, np.int64)).size == 0


# ------------------------------------------------ the ghost-id regression


def test_merge_and_trim_killed_dup_forfeits_its_id():
    """A killed duplicate must become a sentinel lane, not a ghost keeping
    the real id at (INF, visited): on an underfull beam the ghost survives
    the trim and poisons a later merge's OR(visited) aggregation."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.velo import batch_search

    n, L = 100, 4
    ids = jnp.array([[5, n, n, n]], jnp.int32)
    dist = jnp.array([[0.5, batch_search.INF, batch_search.INF,
                       batch_search.INF]], jnp.float32)
    visited = jnp.array([[False, True, True, True]])
    new_ids = jnp.array([[5, 7]], jnp.int32)       # 5 duplicates the beam
    new_dist = jnp.array([[0.4, 0.6]], jnp.float32)

    out_ids, out_dist, out_vis = batch_search._merge_and_trim(
        ids, dist, visited, new_ids, new_dist, L, n
    )
    oi = np.asarray(out_ids)[0]
    od = np.asarray(out_dist)[0]
    ov = np.asarray(out_vis)[0]
    # id 5 appears ONCE, with the min distance, still unvisited
    assert int((oi == 5).sum()) == 1, f"ghost copy of id 5 survived: {oi}"
    lane = int(np.argmax(oi == 5))
    assert od[lane] == np.float32(0.4) and not ov[lane]
    # the second merge the ghost used to poison: bring in a fresh neighbor
    # and assert the live id-5 lane still is not falsely marked visited
    out2_ids, _, out2_vis = batch_search._merge_and_trim(
        out_ids, out_dist, out_vis,
        jnp.array([[8]], jnp.int32), jnp.array([[0.7]], jnp.float32), L, n,
    )
    oi2 = np.asarray(out2_ids)[0]
    ov2 = np.asarray(out2_vis)[0]
    assert int((oi2 == 5).sum()) == 1
    assert not ov2[int(np.argmax(oi2 == 5))], "live candidate poisoned"
