"""Multi-tenant serving plane: isolation contract, quotas, and workload mixes.

Contracts (deterministic module — hypothesis-based additions belong in their
own module, the dev container lacks hypothesis):

  * Isolation: a ServingPlane with quotas off and the static pool partition
    is *bitwise identical* (ids, dists, hops, reads, per-tenant cache stats)
    to N isolated single-tenant systems, for all five algorithms — a single
    tenant at B in {1, 8}, and two interleaved tenants at B=1 (the
    deterministic schedule; per-query latencies are excluded, the shared
    SSD's queue residue shifts timing without touching results).
  * Sharing pays under skew: the hot tenant's hit rate with one shared pool
    is at least its static-partition hit rate at the same total bytes.
  * Soft quotas cap slot ownership without breaking pool invariants, and
    quota accounting matches ownership exactly after a full run.
  * Flush/I-O overlap: ``overlap_flush`` is bitwise inert at one worker
    (the existing shared-rendezvous parity contract) and engages at
    multiple workers without moving recall.
  * Stats idempotence: ``evaluate``/``plane.run`` report per-run deltas —
    calling them twice must not double-count cache or dispatch counters.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import vamana as vamana_mod
from repro.core import workload as workload_mod
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams
from repro.core.serving import (
    ServingPlane,
    TenantSpec,
    combined_table,
    evaluate_plane,
)

ALGOS = sorted(ALGORITHMS)  # diskann, inmemory, pipeann, starling, velo

# the deterministic configuration the bitwise contracts pin (cf.
# tests/test_sharedpool.py): stride prefetch is the one schedule-sensitive
# piece, so the parity params turn it off
PARITY_PARAMS = SearchParams(L=32, W=4, prefetch=False)


@pytest.fixture(scope="module")
def tenant_data():
    out = []
    for i, n in enumerate((700, 600)):
        ds = dataset_mod.make_dataset(n=n, d=32, n_queries=30, k=10, seed=i)
        graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                        seed=i)
        qb = RabitQuantizer(32, seed=i).fit_encode(ds.base)
        out.append((ds, graph, qb))
    return out


def _spec(tenant_data, i, algo, params=PARITY_PARAMS, name=None):
    ds, graph, qb = tenant_data[i]
    return TenantSpec.from_dataset(name or f"t{i}", ds, graph, qb,
                                   system=algo, params=params)


def _isolated(tenant_data, i, algo, batch_size, n_queries,
              params=PARITY_PARAMS, **cfg_kw):
    ds, graph, qb = tenant_data[i]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=batch_size,
                                 params=params, **cfg_kw)
    sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
    results, stats = sys_.run(ds.queries[:n_queries])
    return results, stats


def _assert_bitwise(ref, got, label):
    assert len(ref) == len(got)
    for i, (r0, r1) in enumerate(zip(ref, got)):
        np.testing.assert_array_equal(r0.ids, r1.ids, err_msg=f"{label} q{i}: ids")
        np.testing.assert_array_equal(r0.dists, r1.dists,
                                      err_msg=f"{label} q{i}: dists")
        assert r0.hops == r1.hops, f"{label} q{i}: hops"
        assert r0.reads == r1.reads, f"{label} q{i}: reads"


# ------------------------------------------------------- isolation contract


@pytest.mark.parametrize("batch_size", [1, 8])
@pytest.mark.parametrize("algo", ALGOS)
def test_single_tenant_plane_bitwise_equals_isolated(algo, batch_size,
                                                     tenant_data):
    """All the plane machinery — combined store, global vid/page namespaces,
    the combined table's offset ids, per-tenant accounting — must add ZERO
    perturbation: a one-tenant plane is the isolated system, bit for bit."""
    spec = _spec(tenant_data, 0, algo)
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=batch_size,
                                 params=PARITY_PARAMS)
    plane = ServingPlane([spec], cfg, shared_pool=True)
    wload = workload_mod.uniform_mix([30], 30, seed=0)
    run = plane.run(wload)
    ref, ref_stats = _isolated(tenant_data, 0, algo, batch_size, 30)
    _assert_bitwise(ref, run.tenants[0].results, f"{algo} B={batch_size}")
    ts = run.tenants[0].stats
    assert (ts.cache_hits, ts.cache_misses) == (
        ref_stats.cache_hits, ref_stats.cache_misses
    )


@pytest.mark.parametrize("algo", ALGOS)
def test_two_tenant_partitioned_plane_bitwise_equals_isolated(algo,
                                                              tenant_data):
    """Quotas off + static partition + B=1: interleaving two tenants on one
    engine must not change what each tenant computes — ids, hops, reads and
    per-tenant cache stats all match the two isolated systems exactly."""
    specs = [_spec(tenant_data, 0, algo, name="a"),
             _spec(tenant_data, 1, algo, name="b")]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=1,
                                 params=PARITY_PARAMS)
    plane = ServingPlane(specs, cfg, shared_pool=False)
    # 40 arrivals keeps per-tenant counts under the 30-query sets (no wrap)
    wload = workload_mod.uniform_mix([30, 30], 40, seed=3)
    run = plane.run(wload)
    assert plane.pool is None  # static partition: no shared pool instance
    for tid in (0, 1):
        tr = run.tenants[tid]
        ref, ref_stats = _isolated(tenant_data, tid, algo, 1,
                                   tr.stats.n_queries)
        _assert_bitwise(ref, tr.results, f"{algo} tenant{tid}")
        assert (tr.stats.cache_hits, tr.stats.cache_misses) == (
            ref_stats.cache_hits, ref_stats.cache_misses
        )


def test_combined_table_requires_matching_shapes(tenant_data):
    ds, _, qb = tenant_data[0]
    qb8 = dataclasses.replace(qb, ext_bits=8)
    assert combined_table([qb, qb]) is not None
    assert combined_table([qb, qb8]) is None
    tbl = combined_table([qb, tenant_data[1][2]])
    n0 = qb.norms.shape[0]
    np.testing.assert_array_equal(tbl.norms[:n0], qb.norms)
    np.testing.assert_array_equal(tbl.norms[n0:], tenant_data[1][2].norms)


# -------------------------------------------------------- sharing under skew


def test_shared_pool_hot_tenant_hit_rate_beats_partition(tenant_data):
    """The point of sharing: under a zipfian hot-tenant mix the shared pool
    lends cold tenants' slots to the hot one — its hit rate must be at least
    the static-partition hit rate at the same total byte budget."""
    specs = [_spec(tenant_data, 0, "velo", params=SearchParams(L=32, W=4)),
             _spec(tenant_data, 1, "velo", params=SearchParams(L=32, W=4))]
    cfg = baselines.SystemConfig(buffer_ratio=0.12, n_workers=2, batch_size=4)
    wload = workload_mod.zipfian_mix([30, 30], 120, s=1.8, seed=0)
    hot = int(wload.counts().argmax())
    rates = {}
    for shared in (True, False):
        plane = ServingPlane(specs, cfg, shared_pool=shared)
        run = plane.run(wload)
        rates[shared] = run.tenants[hot].stats.hit_rate
        for tr in run.tenants:
            if tr.recall is not None:
                assert tr.recall > 0.6, (tr.name, tr.recall)
    assert rates[True] >= rates[False], rates


def test_cross_tenant_fusion_spans_tenants(tenant_data):
    """With the fused distance plane, one rendezvous flush serves requests
    from DIFFERENT tenants (the combined-table routing)."""
    specs = [_spec(tenant_data, 0, "velo"), _spec(tenant_data, 1, "velo")]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, n_workers=2, batch_size=8,
                                 fuse=True, fuse_rows=128,
                                 shared_rendezvous=True)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    assert plane.table is not None
    run = plane.run(workload_mod.uniform_mix([30, 30], 60, seed=1))
    assert run.stats.cross_tenant_flushes > 0
    # the combined table registers ONCE for the whole plane
    assert plane.dist.stats.uploads == 1


# ------------------------------------------------------------- soft quotas


def test_tenant_quota_caps_ownership_and_keeps_invariants(tenant_data):
    specs = [_spec(tenant_data, 0, "velo", params=SearchParams(L=32, W=4)),
             _spec(tenant_data, 1, "velo", params=SearchParams(L=32, W=4))]
    cfg = baselines.SystemConfig(buffer_ratio=0.12, n_workers=2, batch_size=4,
                                 tenant_quota=0.4)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    wload = workload_mod.zipfian_mix([30, 30], 120, s=1.8, seed=0)
    run = plane.run(wload)
    pool = plane.pool
    pool.check_invariants()
    assert pool.tenant_cap is not None
    assert (pool.tenant_owned <= pool.tenant_cap).all()
    assert run.stats.quota_reclaims > 0  # the cap genuinely bound
    for tr in run.tenants:
        assert tr.recall is None or tr.recall > 0.6


def test_quota_off_is_pure_global_clock(tenant_data):
    """tenant_quota=None must be bit-identical to a pool that never heard of
    tenants: same results, same evictions, zero quota traffic."""
    specs = [_spec(tenant_data, 0, "velo"), _spec(tenant_data, 1, "velo")]
    cfg = baselines.SystemConfig(buffer_ratio=0.12, batch_size=1,
                                 params=PARITY_PARAMS)
    wload = workload_mod.uniform_mix([30, 30], 40, seed=5)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    run = plane.run(wload)
    assert run.stats.quota_reclaims == 0
    assert run.stats.quota_denials == 0
    assert plane.pool.tenant_cap is None
    # ownership accounting still runs (it is bookkeeping, not policy)
    plane.pool.check_invariants()
    assert int(plane.pool.tenant_owned.sum()) == plane.pool.occupancy()


# -------------------------------------------------------- flush/I-O overlap


@pytest.mark.parametrize("algo", ALGOS)
def test_overlap_flush_bitwise_inert_at_one_worker(algo, tenant_data):
    """The ROADMAP follow-on's guard rail: at one worker every due completion
    belongs to the initiator, so the overlap path never engages and the flag
    cannot change results — for all five algorithms, B=8, fused shared
    rendezvous."""
    ds, graph, qb = tenant_data[0]
    outs = {}
    for overlap in (False, True):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=1, batch_size=8, fuse=True,
            shared_rendezvous=True, overlap_flush=overlap,
            params=PARITY_PARAMS,
        )
        sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
        results, stats = sys_.run(ds.queries)
        outs[overlap] = results
        assert stats.overlap_flushes == 0  # structurally unreachable at 1w
    _assert_bitwise(outs[False], outs[True], f"{algo} overlap@1w")


def test_overlap_flush_engages_at_multiple_workers(tenant_data):
    ds, graph, qb = tenant_data[0]
    recalls = {}
    for overlap in (False, True):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=4, batch_size=8, fuse=True,
            fuse_rows=512, shared_rendezvous=True, overlap_flush=overlap,
            params=SearchParams(L=48, W=4),
        )
        sys_ = baselines.build_system("velo", ds.base, graph, qb, cfg)
        results, stats = sys_.run(ds.queries)
        if overlap:
            assert stats.overlap_flushes > 0, "overlap never engaged"
        else:
            assert stats.overlap_flushes == 0
        ids = np.full((len(results), 10), -1, dtype=np.int64)
        for i, r in enumerate(results):
            m = min(10, len(r.ids))
            ids[i, :m] = r.ids[:m]
        recalls[overlap] = dataset_mod.recall_at_k(ids, ds.groundtruth, 10)
    assert abs(recalls[True] - recalls[False]) < 0.05, recalls


# -------------------------------------------------------- stats idempotence


def test_evaluate_stats_idempotent(tenant_data):
    """Regression: evaluate() twice on one system used to report CUMULATIVE
    accessor/dispatch counters the second time (double counting).  Counters
    must be per-run deltas."""
    ds, graph, qb = tenant_data[0]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4)
    sys_ = baselines.build_system("velo", ds.base, graph, qb, cfg)
    r1 = baselines.evaluate(sys_, ds)
    r2 = baselines.evaluate(sys_, ds)
    # the table registered during run 1; run 2 must report zero NEW uploads
    assert r1["dist_uploads"] == 1
    assert r2["dist_uploads"] == 0
    # dispatches are per-run, not cumulative (cumulative would be ~2x)
    assert r1["dist_dispatches"] > 0
    assert r2["dist_dispatches"] <= 1.5 * r1["dist_dispatches"]
    # cache counters are per-run deltas: a third run's reported hit rate must
    # equal the delta of the accessor's cumulative counters around that run
    h0, m0 = sys_.ctx.accessor.stats()
    r3 = baselines.evaluate(sys_, ds)
    h1, m1 = sys_.ctx.accessor.stats()
    run3_accesses = (h1 - h0) + (m1 - m0)
    assert run3_accesses > 0
    assert abs(r3["hit_rate"] - (h1 - h0) / run3_accesses) < 1e-12


def test_plane_pressure_counters_not_double_counted(tenant_data):
    """Regression: the engine counts lock_waits/coalesced_record_loads for
    the ops it schedules AND the pool counts them at the slot — the plane
    must report the pool's per-run delta, not the sum of both (2x)."""
    specs = [_spec(tenant_data, 0, "velo", params=SearchParams(L=32, W=4)),
             _spec(tenant_data, 1, "velo", params=SearchParams(L=32, W=4))]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, n_workers=4, batch_size=8)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    run = plane.run(workload_mod.zipfian_mix([30, 30], 80, s=1.4, seed=0))
    assert run.stats.lock_waits == plane.pool.lock_waits
    assert run.stats.coalesced_record_loads == plane.pool.coalesced_record_loads
    assert run.stats.lock_waits > 0  # the regression is observable


def test_plane_run_stats_idempotent(tenant_data):
    specs = [_spec(tenant_data, 0, "velo"), _spec(tenant_data, 1, "velo")]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    wload = workload_mod.uniform_mix([30, 30], 40, seed=2)
    r1 = plane.run(wload)
    r2 = plane.run(wload)
    for a, b in zip(r1.tenants, r2.tenants):
        tot1 = a.stats.cache_hits + a.stats.cache_misses
        tot2 = b.stats.cache_hits + b.stats.cache_misses
        # per-run deltas: the warmed second run counts only ITS accesses
        # (cumulative reporting — the old bug — would be ~2x tot1)
        assert tot2 < 1.5 * tot1, (tot1, tot2)
        assert b.stats.n_queries == a.stats.n_queries


# ------------------------------------------------------ workload generators


def test_workload_generators_deterministic_and_sequential():
    for fn, kw in [
        (workload_mod.uniform_mix, {}),
        (workload_mod.zipfian_mix, {"s": 1.5}),
        (workload_mod.bursty_mix, {"mean_burst": 6}),
    ]:
        w1 = fn([20, 20, 20], 90, seed=7, **kw)
        w2 = fn([20, 20, 20], 90, seed=7, **kw)
        np.testing.assert_array_equal(w1.tenant_ids, w2.tenant_ids)
        np.testing.assert_array_equal(w1.query_ids, w2.query_ids)
        assert len(w1) == 90
        # per-tenant query ids are sequential (wrapping): the isolation
        # contract's precondition
        for t in range(3):
            qs = w1.query_ids[w1.positions(t)]
            np.testing.assert_array_equal(
                qs, np.arange(len(qs), dtype=np.int64) % 20
            )


def test_zipfian_mix_is_skewed_and_bursty_mix_runs():
    counts = workload_mod.zipfian_mix([50] * 4, 400, s=1.6, seed=0).counts()
    assert counts[0] > 2 * counts[-1], counts
    runs = workload_mod.bursty_mix([50] * 4, 400, mean_burst=10, seed=0)
    lens = runs.run_lengths()
    assert float(np.mean(lens)) > 2.5, np.mean(lens)
    uni = workload_mod.uniform_mix([50] * 4, 400, seed=0).run_lengths()
    assert float(np.mean(lens)) > float(np.mean(uni))


def test_evaluate_plane_reports_per_tenant_metrics(tenant_data):
    specs = [_spec(tenant_data, 0, "velo"), _spec(tenant_data, 1, "diskann")]
    cfg = baselines.SystemConfig(buffer_ratio=0.2, batch_size=4)
    plane = ServingPlane(specs, cfg, shared_pool=True)
    res = evaluate_plane(plane, workload_mod.uniform_mix([30, 30], 40, seed=0))
    assert set(res["tenants"]) == {"t0", "t1"}
    for t in res["tenants"].values():
        assert t["recall@k"] > 0.5
        assert 0.0 <= t["hit_rate"] <= 1.0
        assert t["n_queries"] > 0
    # mixed algorithms: diskann forces the shared engine to B=1
    assert plane.batch_size == 1


def test_per_tenant_latency_split_survives_priority_reordering(tenant_data):
    """Under scheduler="sla" queries complete far out of submission order,
    so the per-tenant latency/p99/deadline split must bin by QUERY ID
    (``latency_qids``), never by completion position — a positional zip
    against ``positions()`` silently assigns tenant 0's latencies to
    whichever queries happened to finish first.  Regression for the
    evaluate_plane p99 split."""
    params = SearchParams(L=32, W=4)
    specs = [_spec(tenant_data, 0, "velo", params=params),
             _spec(tenant_data, 1, "velo", params=params)]
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, n_workers=2, batch_size=4, fuse=True, fuse_rows=64,
        scheduler="sla", sla_ms=[5.0, 1.0], sla_feedback=False,
    )
    plane = ServingPlane(specs, cfg, shared_pool=True)
    wl = workload_mod.bursty_mix([30, 30], 80, mean_burst=8, seed=1,
                                 qps=20000.0)
    run = plane.run(wl)
    stats = run.stats
    # EDF + bursts genuinely reordered completions
    assert stats.latency_qids != sorted(stats.latency_qids)
    assert len(stats.latencies) == len(wl)

    lat_by_qid = dict(zip(stats.latency_qids, stats.latencies))
    svc_by_qid = dict(zip(stats.latency_qids, stats.service_times))
    for tr, tid in zip(run.tenants, (0, 1)):
        pos = list(wl.positions(tid))
        assert list(tr.stats.latency_qids) == pos
        assert tr.stats.latencies == [lat_by_qid[i] for i in pos]
        assert tr.stats.service_times == [svc_by_qid[i] for i in pos]
        # the tenant's p99 comes from its OWN distribution
        lo = 1e3 * min(tr.stats.latencies)
        hi = 1e3 * max(tr.stats.latencies)
        assert lo <= tr.stats.p99_latency_ms() <= hi
        assert (
            tr.stats.deadline_hits + tr.stats.deadline_misses
            == tr.stats.n_queries
        )
    # per-tenant accounting sums back to the global stats
    assert sum(t.stats.deadline_hits for t in run.tenants) == stats.deadline_hits
    assert (
        sum(t.stats.deadline_misses for t in run.tenants)
        == stats.deadline_misses
    )
    assert sum(t.stats.queue_wait_s for t in run.tenants) == pytest.approx(
        stats.queue_wait_s
    )
