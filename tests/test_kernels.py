"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.kernels.binary_ip import binary_ip, estimate_dist2
from repro.kernels.binary_ip.ref import binary_ip_ref, estimate_dist2_ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.int4_dist import int4_dist2
from repro.kernels.int4_dist.ref import int4_dist2_ref
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref


RNG = np.random.default_rng(7)


# ------------------------------------------------------------------ binary_ip


@pytest.mark.parametrize("B,N,d", [(1, 1, 8), (4, 10, 64), (128, 256, 128),
                                   (33, 777, 256), (5, 64, 1024)])
def test_binary_ip_matches_ref(B, N, d):
    q = RNG.standard_normal((B, d)).astype(np.float32)
    codes = RNG.integers(0, 256, size=(N, d // 8)).astype(np.uint8)
    np.testing.assert_allclose(
        binary_ip(q, codes), binary_ip_ref(q, codes), rtol=1e-5, atol=1e-4
    )


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_binary_ip_dtypes(dtype):
    q = jnp.asarray(RNG.standard_normal((16, 128)), dtype=dtype)
    codes = RNG.integers(0, 256, size=(64, 16)).astype(np.uint8)
    out = binary_ip(q, codes)
    ref = binary_ip_ref(jnp.asarray(q, jnp.float32), codes)
    np.testing.assert_allclose(out, ref, rtol=2e-2, atol=2e-1)


def test_estimate_matches_host_quantizer(small_ds, small_qb):
    """The device kernel must agree with the numpy host-plane estimator —
    the two planes share one index format."""
    from repro.core.quant import RabitQuantizer

    qb = small_qb
    q = small_ds.queries[:8]
    qr = (q - qb.centroid) @ qb.rotation.T
    dev = estimate_dist2(
        jnp.asarray(qr), jnp.asarray(qb.binary_codes),
        jnp.asarray(qb.norms), jnp.asarray(qb.ip_bar),
    )
    for i in range(8):
        pq = RabitQuantizer.prepare_query(qb, q[i])
        host = RabitQuantizer.estimate_dist2(qb, pq, np.arange(qb.norms.shape[0]))
        np.testing.assert_allclose(np.asarray(dev)[i], host, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------ int4_dist


@pytest.mark.parametrize("B,N,d", [(1, 1, 8), (3, 7, 64), (64, 200, 128), (16, 512, 960)])
def test_int4_dist_matches_ref(B, N, d):
    d = d + (d % 2)
    q = RNG.standard_normal((B, d)).astype(np.float32)
    codes = RNG.integers(0, 256, (N, d // 2)).astype(np.uint8)
    lo = RNG.uniform(-2, -1, N).astype(np.float32)
    step = RNG.uniform(0.1, 0.3, N).astype(np.float32)
    np.testing.assert_allclose(
        int4_dist2(q, codes, lo, step), int4_dist2_ref(q, codes, lo, step),
        rtol=1e-4, atol=1e-3,
    )


def test_int4_matches_host_refine(small_ds, small_qb):
    from repro.core.quant import RabitQuantizer

    qb = small_qb
    q = small_ds.queries[:4]
    qr = (q - qb.centroid) @ qb.rotation.T
    ids = np.arange(256)
    dev = int4_dist2(
        jnp.asarray(qr), jnp.asarray(qb.ext_codes[ids]),
        jnp.asarray(qb.ext_lo[ids]), jnp.asarray(qb.ext_step[ids]),
    )
    for i in range(4):
        pq = RabitQuantizer.prepare_query(qb, q[i])
        host = RabitQuantizer.refine_dist2(qb, pq, ids)
        np.testing.assert_allclose(np.asarray(dev)[i], host, rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ flash_attention


@pytest.mark.parametrize(
    "B,H,KVH,Sq,Skv,Dh,causal,window",
    [
        (1, 4, 2, 128, 128, 64, True, None),
        (2, 4, 1, 64, 192, 32, True, None),     # GQA + cross lengths + padding
        (1, 2, 2, 100, 100, 64, True, 37),      # sliding window, ragged tiles
        (1, 2, 2, 96, 96, 64, False, None),     # bidirectional (whisper encoder)
        (1, 8, 8, 256, 256, 128, True, None),
        (1, 4, 4, 128, 384, 64, True, 128),     # window + long KV (gemma3 local)
    ],
)
def test_flash_matches_ref(B, H, KVH, Sq, Skv, Dh, causal, window):
    q = RNG.standard_normal((B, H, Sq, Dh)).astype(np.float32)
    k = RNG.standard_normal((B, KVH, Skv, Dh)).astype(np.float32)
    v = RNG.standard_normal((B, KVH, Skv, Dh)).astype(np.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_bf16():
    q = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(RNG.standard_normal((1, 2, 128, 64)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True)
    ref = attention_ref(
        q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32), causal=True
    )
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref), rtol=5e-2, atol=5e-2
    )


# ------------------------------------------------------------ paged_attention


@pytest.mark.parametrize(
    "B,H,KVH,Dh,P,page,max_pages",
    [
        (2, 4, 2, 64, 16, 16, 4),
        (3, 8, 8, 32, 32, 8, 6),
        (1, 4, 1, 128, 8, 32, 3),
        (4, 2, 2, 64, 64, 16, 8),
    ],
)
def test_paged_matches_ref(B, H, KVH, Dh, P, page, max_pages):
    q = RNG.standard_normal((B, H, Dh)).astype(np.float32)
    kp = RNG.standard_normal((P, page, KVH, Dh)).astype(np.float32)
    vp = RNG.standard_normal((P, page, KVH, Dh)).astype(np.float32)
    bt = RNG.integers(0, P, (B, max_pages)).astype(np.int32)
    cl = RNG.integers(1, max_pages * page + 1, (B,)).astype(np.int32)
    out = paged_attention(q, kp, vp, bt, cl)
    ref = paged_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_paged_short_context():
    """context_len smaller than one page: only valid slots contribute."""
    B, H, KVH, Dh, P, page, max_pages = 1, 2, 2, 32, 4, 16, 2
    q = RNG.standard_normal((B, H, Dh)).astype(np.float32)
    kp = RNG.standard_normal((P, page, KVH, Dh)).astype(np.float32)
    vp = RNG.standard_normal((P, page, KVH, Dh)).astype(np.float32)
    bt = np.asarray([[2, 0]], np.int32)
    cl = np.asarray([3], np.int32)
    out = paged_attention(q, kp, vp, bt, cl)
    ref = paged_attention_ref(q, kp, vp, bt, cl)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
