"""Round-trip + property tests for the adjacency codecs (paper §3.3)."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import codec


sorted_ids = st.lists(
    st.integers(min_value=0, max_value=2**31 - 1), min_size=0, max_size=300, unique=True
).map(sorted)


@given(sorted_ids)
@settings(max_examples=150, deadline=None)
def test_delta_roundtrip(ids):
    arr = np.asarray(ids, dtype=np.uint32)
    out = codec.delta_decode(codec.delta_encode(arr))
    np.testing.assert_array_equal(out, arr)


@given(sorted_ids)
@settings(max_examples=150, deadline=None)
def test_pef_roundtrip(ids):
    arr = np.asarray(ids, dtype=np.uint32)
    out = codec.pef_decode(codec.pef_encode(arr))
    np.testing.assert_array_equal(out, arr)


@given(sorted_ids)
@settings(max_examples=50, deadline=None)
def test_dispatcher_roundtrip(ids):
    arr = np.asarray(ids, dtype=np.uint32)
    for name in codec.CODECS:
        out = codec.decode_adjacency(codec.encode_adjacency(arr, name), name)
        np.testing.assert_array_equal(out, np.sort(arr))


def test_compression_beats_raw_on_clustered_ids():
    """Clustered id runs (what affinity placement produces) must compress well."""
    rng = np.random.default_rng(0)
    starts = rng.integers(0, 1_000_000, size=8)
    ids = np.unique(np.concatenate([s + np.arange(8) for s in starts])).astype(np.uint32)
    raw = 4 * len(ids)
    assert len(codec.pef_encode(ids)) < raw
    assert len(codec.delta_encode(ids)) < raw


def test_pef_blocks_span():
    ids = np.arange(0, 5000, 7, dtype=np.uint32)  # > _BLOCK values
    out = codec.pef_decode(codec.pef_encode(ids))
    np.testing.assert_array_equal(out, ids)
