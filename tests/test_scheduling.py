"""SLA-aware scheduling (core.scheduling + the engine's EDF mode).

Four claim families:

  * latency accounting — with an ``SlaPlan`` attached, ``latencies`` run
    from ARRIVAL (queue wait behind a full batch reaches the tail), the old
    dispatch-relative number survives as ``service_times``, and a plan-free
    run stays bitwise the pre-SLA engine (latency == service, wait == 0);
  * rr parity — ``scheduler="rr"`` with a plan attached changes only the
    latency semantics: results, makespan and the charged coroutine-switch
    count are bitwise the plan-free run, for all five algorithms;
  * switch charging under reordering — EDF resumes out of submission order,
    but a preempted-then-resumed coroutine is still charged exactly one
    switch: with equal deadlines sla matches rr bitwise, and with reversed
    deadlines (inmemory: no I/O, so the dispatch multiset is order-free)
    the total charge count is identical while completion order inverts;
  * the feedback controller — steering outputs are pure functions of the
    completion windows (equal-time updates commute), beam width never drops
    below k, the fuse budget floors, and quota boosts respect the pool's
    ``tenant_owned <= tenant_cap`` invariant.
"""

import numpy as np
import pytest

from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import vamana as vamana_mod
from repro.core.quant import RabitQuantizer
from repro.core.scheduling import SlaController, SlaPlan, sla_seconds
from repro.core.search import ALGORITHMS, SearchParams

ALGOS = sorted(ALGORITHMS)


@pytest.fixture(scope="module")
def tiny():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=16, k=10, seed=5)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=5)
    qb = RabitQuantizer(32, seed=5).fit_encode(ds.base)
    return ds, graph, qb


def _system(tiny, algo="diskann", **kw):
    ds, graph, qb = tiny
    kw.setdefault("buffer_ratio", 0.2)
    kw.setdefault("n_workers", 2)
    kw.setdefault("batch_size", 4)
    kw.setdefault("params", SearchParams(L=24, W=4))
    cfg = baselines.SystemConfig(**kw)
    return baselines.build_system(algo, ds.base, graph, qb, cfg)


def _proj(results):
    return [(list(r.ids), list(r.dists), r.hops) for r in results]


# ------------------------------------------------- latency accounting bugfix


def test_latency_includes_queue_wait(tiny):
    """The PR's headline bugfix: behind a full batch, a query's p99 must
    include the time it sat admitted-but-undispatched.  An all-arrive-at-t0
    plan changes ONLY the latency semantics — answers, makespan and switch
    charges stay bitwise the plan-free run, and the old dispatch-relative
    numbers survive as ``service_times``."""
    ds = tiny[0]
    ref_res, ref = _system(tiny).run(ds.queries)
    res, stats = _system(tiny).run(
        ds.queries, sla=SlaPlan.build(len(ds.queries))
    )
    assert _proj(res) == _proj(ref_res)
    assert stats.makespan_s == ref.makespan_s
    assert stats.coroutine_switches == ref.coroutine_switches
    # the old latency distribution IS the service-time distribution
    assert stats.service_times == ref.latencies
    assert stats.sum_service_s == ref.sum_latency_s
    # 16 queries, 2 workers x batch 4: most of them queued behind the batch
    assert stats.queue_wait_s > 0.0
    assert max(stats.latencies) > max(stats.service_times)
    for lat, svc in zip(stats.latencies, stats.service_times):
        assert lat >= svc - 1e-12


@pytest.mark.parametrize("workers,batch", [(1, 1), (2, 4)],
                         ids=["serial", "batched"])
def test_no_plan_latency_equals_service(tiny, workers, batch):
    """Plan-free runs are bitwise the pre-SLA engine: latency == service
    per query, zero queue wait, no deadline accounting — including the
    degenerate B=1 / n_workers=1 topology."""
    ds = tiny[0]
    _, stats = _system(tiny, n_workers=workers, batch_size=batch).run(
        ds.queries
    )
    assert stats.latencies == stats.service_times
    assert stats.queue_wait_s == 0.0
    assert stats.deadline_hits == 0 and stats.deadline_misses == 0


def test_arrival_gates_dispatch(tiny):
    """Arrivals gate admission: with inter-arrival gaps far above the
    service time the plane drains between arrivals, so queue wait is exactly
    zero and the makespan stretches past the last arrival."""
    ds = tiny[0]
    n = len(ds.queries)
    arr = np.arange(n) * 0.05  # 50 ms apart >> per-query service time
    _, stats = _system(tiny).run(ds.queries, sla=SlaPlan.build(n, arrivals=arr))
    assert stats.queue_wait_s == 0.0
    assert stats.latencies == stats.service_times
    assert stats.makespan_s > float(arr[-1])


# -------------------------------------------- rr parity and switch charging


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
@pytest.mark.parametrize("algo", ALGOS)
def test_rr_parity_with_plan(tiny, algo, fuse):
    """scheduler="rr" + a deadline plan is bitwise the plan-free engine for
    every algorithm: same answers, same makespan, same charged switch count
    (the per-entry switch flags are untouched in rr)."""
    ds = tiny[0]
    ref_res, ref = _system(tiny, algo=algo, fuse=fuse).run(ds.queries)
    res, stats = _system(tiny, algo=algo, fuse=fuse, scheduler="rr").run(
        ds.queries, sla=SlaPlan.build(len(ds.queries), sla_ms=5.0)
    )
    assert _proj(res) == _proj(ref_res)
    assert stats.makespan_s == ref.makespan_s
    assert stats.coroutine_switches == ref.coroutine_switches
    assert stats.service_times == ref.latencies
    assert stats.deadline_hits + stats.deadline_misses == len(ds.queries)


@pytest.mark.parametrize("fuse", [False, True], ids=["nofuse", "fuse"])
def test_sla_equal_deadlines_matches_rr_bitwise(tiny, fuse):
    """With every deadline equal, EDF ordering degenerates to submission
    order — and the flush's switch-free credit must land exactly where rr's
    first-pop rule puts it, so the two schedulers are bitwise identical."""
    ds = tiny[0]
    n = len(ds.queries)
    rr_res, rr = _system(tiny, fuse=fuse, scheduler="rr").run(
        ds.queries, sla=SlaPlan.build(n, sla_ms=5.0)
    )
    sla_res, sla = _system(tiny, fuse=fuse, scheduler="sla").run(
        ds.queries, sla=SlaPlan.build(n, sla_ms=5.0)
    )
    assert _proj(sla_res) == _proj(rr_res)
    assert sla.makespan_s == rr.makespan_s
    assert sla.coroutine_switches == rr.coroutine_switches
    assert sla.latency_qids == rr.latency_qids


def test_sla_edf_reorders_with_exactly_one_switch_per_resume(tiny):
    """Reversed deadlines on one worker: EDF admits and completes back to
    front while rr runs front to back.  inmemory never suspends on I/O, so
    every dispatch is an admission or a rendezvous resume — and the
    exactly-one-switch law is directly checkable: charged switches ==
    admissions + resumes - one free credit per flush, under EITHER pop
    order.  (A resume that skipped its charge, or a preempted coroutine
    charged twice, breaks the identity.)"""
    ds = tiny[0]
    n = len(ds.queries)

    def plan():
        return SlaPlan(
            arrivals=np.zeros(n), deadlines=np.arange(n, 0, -1) * 1e-3
        )

    kw = dict(algo="inmemory", n_workers=1, batch_size=4, fuse=True,
              fuse_rows=64)
    rr_res, rr = _system(tiny, scheduler="rr", **kw).run(ds.queries,
                                                         sla=plan())
    sla_res, sla = _system(tiny, scheduler="sla", **kw).run(ds.queries,
                                                            sla=plan())
    assert _proj(sla_res) == _proj(rr_res)
    # the rendezvous genuinely preempted and resumed coroutines
    assert sla.score_flushes > 0
    for stats in (rr, sla):
        assert stats.coroutine_switches == (
            n + stats.score_requests - stats.score_flushes
        )
    # completion order inverted: the tightest deadline (last qid) finishes
    # first, and the whole order differs from rr's FIFO
    assert sla.latency_qids != rr.latency_qids
    assert sla.latency_qids[0] >= n - kw["batch_size"]
    assert (
        float(np.mean(sla.latency_qids[: n // 2]))
        > float(np.mean(sla.latency_qids[n // 2:]))
    )


# ----------------------------------------------------- starvation regression


def test_sla_holds_cold_tenant_floor_rr_violates(tiny):
    """Starvation under skew: a zipfian 4-tenant mix where the cold tenant
    carries a premium (tight) SLA.  Under rr its sparse queries queue behind
    the hot tenant's backlog and the 1.5 ms deadline is hopeless; EDF jumps
    them over the backlog and holds the floor — without starving the hot
    tenant in return (its hit-rate must not degrade vs rr)."""
    from repro.core import workload as workload_mod
    from repro.core.serving import ServingPlane, TenantSpec

    ds, graph, qb = tiny
    specs = [
        TenantSpec.from_dataset(f"t{i}", ds, graph, qb,
                                params=SearchParams(L=24, W=4))
        for i in range(4)
    ]
    wl = workload_mod.zipfian_mix([16] * 4, 200, s=1.6, seed=2, qps=30000.0)
    assert wl.counts()[3] == min(wl.counts())  # tenant 3 IS the cold one

    rates = {}
    for sched in ("rr", "sla"):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=2, batch_size=4,
            fuse=True, fuse_rows=64,
            scheduler=sched, sla_ms=[6.0, 6.0, 6.0, 1.5],
        )
        run = ServingPlane(specs, cfg).run(wl)
        rates[sched] = {
            "cold": run.tenants[3].stats.deadline_hit_rate,
            "hot": run.tenants[0].stats.deadline_hit_rate,
            "global": run.stats.deadline_hit_rate,
        }
    assert rates["sla"]["cold"] >= 0.8, rates
    assert rates["rr"]["cold"] < 0.3, rates
    assert rates["sla"]["hot"] >= rates["rr"]["hot"] - 0.05, rates
    assert rates["sla"]["global"] >= rates["rr"]["global"], rates


# --------------------------------------------------------- plan construction


def test_sla_plan_build_per_tenant_deadlines():
    tof = np.array([0, 1, 0, 2, 1], dtype=np.int64)
    arr = np.array([0.0, 1.0, 2.0, 3.0, 4.0])
    plan = SlaPlan.build(5, arrivals=arr, sla_ms=[2.0, 4.0, 8.0],
                         tenant_of=tof, n_tenants=3)
    np.testing.assert_allclose(
        plan.deadlines - plan.arrivals,
        np.array([2e-3, 4e-3, 2e-3, 8e-3, 4e-3]),
    )
    assert plan.deadline(3) == pytest.approx(3.0 + 8e-3)


def test_sla_plan_build_keeps_cold_tenants():
    """n_tenants carries the TRUE count: a cold tenant that drew no queries
    must not shift the per-tenant sla_ms mapping."""
    tof = np.zeros(4, dtype=np.int64)  # tenant 1 drew nothing
    plan = SlaPlan.build(4, sla_ms=[1.0, 99.0], tenant_of=tof, n_tenants=2)
    np.testing.assert_allclose(plan.deadlines, np.full(4, 1e-3))


def test_sla_plan_no_deadlines():
    plan = SlaPlan.build(3)
    assert plan.deadlines is None
    assert plan.deadline(0) == float("inf")
    plan.on_complete(0, 1.0, 0.5)  # no controller: a no-op


def test_sla_seconds_scalar_and_sequence():
    np.testing.assert_allclose(sla_seconds(2.0, 3), np.full(3, 2e-3))
    np.testing.assert_allclose(sla_seconds([1.0, 10.0], 2),
                               np.array([1e-3, 1e-2]))
    with pytest.raises(AssertionError):
        sla_seconds([1.0, 2.0, 3.0], 2)


# ------------------------------------------------------- feedback controller


def test_controller_order_insensitive():
    """Equal-time completions commute: folding the same multiset in two
    opposite orders lands in identical steering state — the property that
    keeps pure-EDF sla runs schedule-invariant under the explorer."""
    events = [
        (0, 1.0, 0.004), (1, 1.0, 0.001), (0, 1.0, 0.003), (1, 1.0, 0.0005),
        (0, 1.0, 0.005), (1, 1.0, 0.0008), (0, 1.0, 0.0045), (1, 1.0, 0.0002),
    ]
    sla = np.array([0.002, 0.002])
    fwd = SlaController(2, sla)
    rev = SlaController(2, sla)
    for t, td, lat in events:
        fwd.on_complete(t, td, lat)
    for t, td, lat in reversed(events):
        rev.on_complete(t, td, lat)
    assert fwd.beam_scale(0) == rev.beam_scale(0)
    assert fwd.beam_scale(1) == rev.beam_scale(1)
    assert fwd.fuse_rows(256) == rev.fuse_rows(256)


def test_controller_beam_and_fuse_bounds():
    c = SlaController(1, np.array([0.001]), min_samples=1)
    # the tail at 10x the SLA: beam clamps at min_scale, fuse budget floors
    c.on_complete(0, 0.0, 0.010)
    assert c.beam_scale(0) == pytest.approx(c.min_scale)
    assert c.fuse_rows(256) == max(c.min_fuse_rows, 25)
    assert c.fuse_rows(16) == 16  # the floor never raises a smaller base
    p = SearchParams(k=10, L=12)
    assert c.params_for(0, p).L >= p.k  # steering never cuts below k
    # recovery: later fast completions prune the old window (horizon) and
    # the beam widens back up to the cap
    for _ in range(4):
        c.on_complete(0, 1.0, 0.0001)
    assert c.beam_scale(0) == pytest.approx(c.max_scale)
    assert c.fuse_rows(256) == 256


def test_controller_identity_when_on_target():
    """A tenant whose tail sits at its SLA steers nothing: params_for
    returns the SAME object (no allocation on the steady-state hot path)."""
    c = SlaController(1, np.array([0.002]), min_samples=1)
    c.on_complete(0, 0.0, 0.002)
    assert c.beam_scale(0) == 1.0
    p = SearchParams(L=24)
    assert c.params_for(0, p) is p
    assert c.fuse_rows(128) == 128


def test_controller_quota_invariant():
    class _Pool:
        n_slots = 100
        tenant_cap = np.array([40, 40], dtype=np.int64)
        tenant_owned = np.array([35, 10], dtype=np.int64)

    pool = _Pool()
    c = SlaController(2, np.array([0.001, 0.001]), pool=pool, min_samples=1)
    # tenant 0 misses at 3x: cap boosted (clamped at quota_boost), tenant 1
    # untouched at base
    c.on_complete(0, 0.0, 0.003)
    assert pool.tenant_cap[0] == 80
    assert pool.tenant_cap[1] == 40
    # relaxing back can never strand ownership above the cap
    pool.tenant_owned[0] = 95
    c.on_complete(0, 1.0, 0.0001)  # recovered: boost would drop to base...
    assert pool.tenant_cap[0] == 95  # ...but the cap floors at ownership
    assert pool.tenant_cap[0] >= pool.tenant_owned[0]
