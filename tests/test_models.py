"""Model-block correctness: chunked forms vs recurrences, decode vs prefill,
MoE dispatch exactness."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro import configs
from repro.models import blocks as B
from repro.models import mamba as M
from repro.models import model as Mod
from repro.models import moe as MoE
from repro.models import rwkv as R

RNG = np.random.default_rng(0)


# ----------------------------------------------------------------- rwkv


def test_chunked_rwkv_matches_recurrence():
    B_, S, D, H = 2, 50, 64, 4
    p = R.init_rwkv(jax.random.key(0), D, 128, H, jnp.float32)
    p["u_bonus"] = jnp.asarray(RNG.standard_normal((H, D // H)) * 0.3, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B_, S, D)) * 0.5, jnp.float32)
    ref = R.time_mix_seq_recurrent(p, x, H)
    for c in (8, 16, 64):
        out = R.time_mix_seq_chunked(p, x, H, chunk=c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_rwkv_decode_matches_seq():
    """Step-by-step decode must reproduce the sequence path's last outputs."""
    B_, S, D, H = 1, 12, 32, 2
    p = R.init_rwkv(jax.random.key(1), D, 64, H, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B_, S, D)) * 0.5, jnp.float32)
    y_seq = R.time_mix_seq_recurrent(p, x, H)

    ts = jnp.zeros((B_, D), jnp.float32)
    wkv = jnp.zeros((B_, H, D // H, D // H), jnp.float32)
    outs = []
    for t in range(S):
        ts, wkv, y = R.time_mix_decode(p, ts, wkv, x[:, t], H)
        outs.append(y)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------- mamba


def test_chunked_mamba_matches_recurrence():
    B_, S, D, di, N, dtr, K = 2, 50, 32, 64, 8, 4, 4
    p = M.init_mamba(jax.random.key(2), D, di, N, dtr, K, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B_, S, D)) * 0.5, jnp.float32)
    ref = M.mamba_seq_recurrent(p, x)
    for c in (8, 16, 64):
        out = M.mamba_seq_chunked(p, x, chunk=c)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)


def test_mamba_decode_matches_seq():
    B_, S, D = 1, 10, 32
    di, N, dtr, K = 64, 4, 4, 4
    p = M.init_mamba(jax.random.key(2), D, di, N, dtr, K, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((B_, S, D)) * 0.5, jnp.float32)
    y_seq = M.mamba_seq_recurrent(p, x)

    state = M.init_mamba_state(B_, di, N, K, jnp.float32)
    outs = []
    for t in range(S):
        state, y = M.mamba_decode(p, state, x[:, t])
        outs.append(y)
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_seq),
                               rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------- moe


def test_moe_matches_dense_reference():
    """Capacity dispatch with cf=huge (no drops) == per-token dense expert mix."""
    T, d, F, E, k = 16, 8, 16, 4, 2
    p = MoE.init_moe(jax.random.key(3), d, F, E, 0, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((T, d)), jnp.float32)
    out, aux = MoE.moe_ffn(p, x, top_k=k, capacity_factor=float(E))

    # dense reference
    logits = x @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    vals, idx = jax.lax.top_k(probs, k)
    vals = vals / vals.sum(axis=-1, keepdims=True)
    ref = np.zeros((T, d), np.float32)
    for t in range(T):
        for j in range(k):
            e = int(idx[t, j])
            h = np.asarray(jax.nn.silu(x[t] @ p["w_gate"][e]) * (x[t] @ p["w_up"][e]))
            ref[t] += float(vals[t, j]) * (h @ np.asarray(p["w_down"][e]))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-3, atol=2e-3)
    assert float(aux) > 0


def test_moe_capacity_drops_overflow():
    """With tiny capacity most pairs drop; output stays finite and bounded."""
    T, d, F, E, k = 32, 8, 2, 4, 2
    p = MoE.init_moe(jax.random.key(4), d, F, E, 0, jnp.float32)
    x = jnp.asarray(RNG.standard_normal((T, d)), jnp.float32)
    out, _ = MoE.moe_ffn(p, x, top_k=k, capacity_factor=0.25)
    assert np.isfinite(np.asarray(out)).all()


# ------------------------------------------------ decode/prefill consistency


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma3-1b"])
def test_decode_continues_prefill(arch):
    """Greedy decode from prefill caches == teacher-forced forward logits."""
    cfg = configs.get(arch, reduced=True)
    model = Mod.build(cfg)
    params = Mod.init_params(model, jax.random.key(0))
    Bsz, S = 2, 12
    toks = jnp.asarray(RNG.integers(0, cfg.vocab_size, (Bsz, S + 1)), jnp.int32)

    # full forward over S+1 tokens: logits at position S-1 predict token S
    batch_full = {"tokens": toks, "labels": toks}
    logits_full, _ = Mod.prefill(model, params, batch_full)

    # prefill S tokens, then decode token S
    batch = {"tokens": toks[:, :S], "labels": toks[:, :S]}
    _, caches = Mod.prefill(model, params, batch)
    # rebuild fixed-size caches for decode: pad prefill caches to S+1
    dec_caches = Mod.init_decode_caches(model, Bsz, cache_len=S + 1)

    def inject(pref, dec):
        # copy prefill K/V into the decode cache's first S slots (shapes match
        # everywhere except the sequence axis at -2)
        def leaf(pc, dc):
            if pc.shape == dc.shape:
                return pc.astype(dc.dtype)
            if (
                pc.ndim == dc.ndim
                and pc.shape[:-2] == dc.shape[:-2]
                and pc.shape[-1] == dc.shape[-1]
                and pc.shape[-2] <= dc.shape[-2]
            ):
                return dc.at[..., : pc.shape[-2], :].set(pc.astype(dc.dtype))
            return dc
        return jax.tree.map(leaf, pref, dec,
                            is_leaf=lambda x: hasattr(x, "shape"))

    dec_caches = inject(caches, dec_caches)
    logits_dec, _ = Mod.decode_step(model, params, dec_caches, toks[:, S], jnp.int32(S))
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full), rtol=3e-2, atol=3e-2
    )
