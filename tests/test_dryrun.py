"""Dry-run machinery: HLO analysis unit tests + one real subprocess cell.

The subprocess is required because the 512-virtual-device flag must be set
before jax initializes (the main test process keeps 1 device)."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.launch import hlo_analysis as H

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_trip_count_correction():
    """A 64-iteration scan must be counted 64x (XLA's cost analysis counts 1x)."""

    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=64)
        return h.sum()

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 128), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cs = H.cost_stats(c.as_text(), 1)
    expect = 2 * 8 * 128 * 128 * 64
    assert abs(cs["flops_per_device"] - expect) / expect < 0.05


def test_nested_scan_trip_counts():
    def f(w, x):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ w), None
            h2, _ = jax.lax.scan(inner, h, None, length=4)
            return h2, None
        h, _ = jax.lax.scan(outer, x, None, length=8)
        return h.sum()

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    c = jax.jit(f).lower(w, x).compile()
    cs = H.cost_stats(c.as_text(), 1)
    expect = 2 * 4 * 64 * 64 * 4 * 8
    assert abs(cs["flops_per_device"] - expect) / expect < 0.05


def test_shape_bytes():
    assert H._shape_bytes("f32[4,4]") == 64
    assert H._shape_bytes("bf16[2,3]{1,0}") == 12
    assert H._shape_bytes("(f32[2], s8[8])") == 16
    assert H._shape_bytes("pred[10]") == 10


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """Lower+compile one real production cell at 512 virtual devices."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "rwkv6-7b", "--shape", "long_500k"],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=420,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    path = os.path.join(
        REPO, "src", "repro", "launch", "out", "dryrun",
        "rwkv6-7b__long_500k__pod1.json",
    )
    rec = json.load(open(path))
    assert rec["status"] == "ok"
    assert rec["n_devices"] == 256
    assert rec["cost"]["flops_per_device"] > 0
    assert rec["memory"]["peak_estimate_bytes"] < 16 * 2**30
