"""Checkpointing: round-trip (incl. bf16), atomicity, resume determinism."""

import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402

from repro.train import checkpoint as Ckpt
from repro.train import data as Data


def _state(seed=0):
    k = jax.random.key(seed)
    return {
        "params": {
            "w": jax.random.normal(k, (8, 16), jnp.float32),
            "b16": jax.random.normal(k, (4, 4)).astype(jnp.bfloat16),
            "nested": ({"a": jnp.arange(5)},),
        },
        "opt": {"m": jnp.zeros((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    st = _state()
    Ckpt.save(str(tmp_path), 3, st)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), st)
    restored, step = Ckpt.restore(str(tmp_path), like)
    assert step == 3
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_latest_pointer_advances(tmp_path):
    st = _state()
    Ckpt.save(str(tmp_path), 1, st)
    Ckpt.save(str(tmp_path), 5, st)
    assert Ckpt.latest_step(str(tmp_path)) == 5


def test_no_partial_checkpoint_on_failure(tmp_path):
    """A save interrupted before rename must leave LATEST intact."""
    st = _state()
    Ckpt.save(str(tmp_path), 1, st)

    class Boom(RuntimeError):
        pass

    import numpy as _np
    orig = _np.savez

    def bomb(*a, **kw):
        raise Boom()

    _np.savez = bomb
    try:
        with pytest.raises(Boom):
            Ckpt.save(str(tmp_path), 2, st)
    finally:
        _np.savez = orig
    assert Ckpt.latest_step(str(tmp_path)) == 1
    # no stray temp dirs
    assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp_")]


def test_data_replay_deterministic():
    cfg = Data.DataConfig(vocab_size=97, seq_len=16, global_batch=4, seed=3)
    a = Data.batch_for_step(cfg, 11)
    b = Data.batch_for_step(cfg, 11)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = Data.batch_for_step(cfg, 12)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_data_host_sharding_disjoint():
    full = Data.DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1)
    h0 = Data.DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1,
                         n_hosts=2, host_id=0)
    h1 = Data.DataConfig(vocab_size=97, seq_len=8, global_batch=8, seed=1,
                         n_hosts=2, host_id=1)
    b0 = Data.batch_for_step(h0, 5)
    b1 = Data.batch_for_step(h1, 5)
    assert b0["tokens"].shape[0] == 4 and b1["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_loader_prefetch_and_straggler(tmp_path):
    cfg = Data.DataConfig(vocab_size=97, seq_len=8, global_batch=4, seed=0)
    loader = Data.DataLoader(cfg, prefetch=2)
    try:
        b = loader.next_batch(timeout=5.0)
        assert b["tokens"].shape == (4, 8)
    finally:
        loader.close()
