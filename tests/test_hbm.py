"""HBM record-cache tier: residency protocol, zero-upload gathers, parity.

Contracts pinned here:

  * Roundtrip bit-identity: a record served from an HBM slot is
    byte-identical to the on-disk form (`QuantizedBase.record_payload`),
    adjacency included — the tier is a cache, not a re-encoder.
  * Slot gathers score exactly like id gathers on every backend, and the
    pallas slot path never re-uploads payloads (dist_uploads stays O(1)).
  * Tier-off is bitwise inert: `hbm_tier=False` builds no tier and the new
    stats stay zero; tier-on at the deterministic schedule (B=1, cbs off,
    prefetch off) returns identical ids/hops — residency never changes
    *what* is scored, only where the bytes come from.
  * Admission: the pool's publish hook stages only genuine installs; a full
    tier promotes only proven-hot records (promote_after pool hits);
    `peek_split` is non-counting and skips LOCKED slots.
  * Accounting: `evaluate` and `ServingPlane.run` report per-run DELTAS of
    the hbm_* counters (the PR-5 idempotence rule), and the serving plane's
    per-tenant tier split sums to the system-wide count.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import distance as distance_mod
from repro.core import vamana as vamana_mod
from repro.core import workload as workload_mod
from repro.core.bufferpool import RecordBufferPool
from repro.core.hbm import HbmTier
from repro.core.quant import RabitQuantizer
from repro.core.search import SearchParams
from repro.core.sim import CostModel
from repro.core.store import DecodedRecord
from repro.core.serving import ServingPlane, TenantSpec, evaluate_plane

pytest.importorskip("jax")


def _record(qb, v, n):
    return DecodedRecord(
        vid=v, adjacency=np.asarray([(v + 1) % n, (v + 3) % n]),
        ext_payload=qb.record_payload(v),
    )


def _tier_with(qb, vids, n_slots=16):
    n = len(qb.ext_codes)
    tier = HbmTier(qb, np.arange(n) // 4, n_slots=n_slots, R=4)
    for v in vids:
        assert tier._stage(int(v), _record(qb, int(v), n))
    assert tier.scatter_staged() == len(vids)
    return tier


# ---------------------------------------------------------------- roundtrip


def test_lookup_roundtrip_bit_identity(small_qb):
    n = len(small_qb.ext_codes)
    tier = _tier_with(small_qb, [3, 7, 11])
    for v in (3, 7, 11):
        rec = tier.lookup(v)
        assert rec is not None and rec.vid == v
        assert rec.ext_payload == small_qb.record_payload(v)
        np.testing.assert_array_equal(
            rec.adjacency, np.asarray([(v + 1) % n, (v + 3) % n])
        )
    assert tier.lookup(5) is None  # not resident
    assert tier.counters()["hits"] == 3
    assert tier.counters()["misses"] == 1


# -------------------------------------------------------------- slot gathers


@pytest.mark.parametrize("backend", ["scalar", "batch", "pallas"])
def test_refine_slots_matches_refine_ids(small_ds, small_qb, backend):
    if backend == "pallas" and not distance_mod.pallas_available():
        pytest.skip("pallas backend unavailable")
    eng = distance_mod.get_engine(backend)
    vids = np.asarray([2, 9, 17, 30, 41], dtype=np.int64)
    tier = _tier_with(small_qb, vids)
    slots = tier.cache.record_map[vids].astype(np.int64)
    pq = RabitQuantizer.prepare_query(small_qb, small_ds.queries[0])
    ref = eng.refine_ids(small_qb, pq, vids)
    got = eng.refine_slots(tier, pq, slots)
    np.testing.assert_allclose(got, ref, rtol=1e-6, atol=1e-6)

    pq2 = RabitQuantizer.prepare_query(small_qb, small_ds.queries[1])
    many_ref = eng.refine_ids_many(
        small_qb, [(pq, vids), (pq2, vids[:3])]
    )
    many_got = eng.refine_slots_many(tier, [(pq, slots), (pq2, slots[:3])])
    for r, g in zip(many_ref, many_got):
        np.testing.assert_allclose(g, r, rtol=1e-6, atol=1e-6)
    assert eng.stats.slot_gathers > 0


def test_pallas_slot_gather_zero_upload(small_ds, small_qb):
    if not distance_mod.pallas_available():
        pytest.skip("pallas backend unavailable")
    eng = distance_mod.get_engine("pallas")
    if eng.name != "pallas" or not eng.resident:
        pytest.skip("pallas resident plane unavailable")
    vids = np.asarray([1, 5, 9], dtype=np.int64)
    tier = _tier_with(small_qb, vids)
    slots = tier.cache.record_map[vids].astype(np.int64)
    pq = RabitQuantizer.prepare_query(small_qb, small_ds.queries[0])
    eng.register_index(small_qb)
    eng.refine_slots(tier, pq, slots)  # compile + mirror upload
    u0 = eng.stats.uploads
    for qi in range(1, 4):
        pqi = RabitQuantizer.prepare_query(small_qb, small_ds.queries[qi])
        eng.refine_slots(tier, pqi, slots)
    assert eng.stats.uploads == u0, "slot gathers must not re-upload payloads"


# ---------------------------------------------------------------- admission


def test_peek_split_noncounting_and_locked(small_qb):
    from repro.velo.device_cache import LOCKED

    vids = np.asarray([4, 8, 12], dtype=np.int64)
    tier = _tier_with(small_qb, vids)
    c0 = tier.counters()
    ids = np.asarray([4, 6, 8, 12], dtype=np.int64)
    mask, slots = tier.peek_split(ids)
    np.testing.assert_array_equal(mask, [True, False, True, True])
    assert tier.counters() == c0, "peek_split must not count hits/misses"
    # a LOCKED slot (mid-scatter) is excluded from the gather
    tier.cache.slot_state[tier.cache.record_map[8]] = LOCKED
    mask2, slots2 = tier.peek_split(ids)
    np.testing.assert_array_equal(mask2, [True, False, False, True])
    assert len(slots2) == 2
    assert tier.peek_split(np.asarray([6], dtype=np.int64)) is None


def test_on_publish_fires_on_genuine_installs_only(small_qb):
    n = len(small_qb.ext_codes)
    seen = []
    pool = RecordBufferPool(8, np.arange(n) // 4,
                            on_publish=lambda v, r: seen.append(v))
    pool.admit(1, _record(small_qb, 1, n))
    assert seen == [1]
    pool.admit(1, _record(small_qb, 1, n))  # duplicate: keep-first, no hook
    assert seen == [1]
    slot = pool.begin_load(2)
    assert slot >= 0
    pool.finish_load(2, _record(small_qb, 2, n))
    assert seen == [1, 2]


def test_note_hit_promotion_threshold(small_qb):
    n = len(small_qb.ext_codes)
    tier = _tier_with(small_qb, list(range(8)), n_slots=8)  # full
    cold = _record(small_qb, 20, n)
    for _ in range(tier.promote_after - 1):
        tier.note_hit(20, cold)
        assert not tier._staged, "a not-yet-proven record must not stage"
    tier.note_hit(20, cold)
    assert [s[0] for s in tier._staged] == [20], (
        "the promote_after-th pool hit stages the record"
    )
    # cold-tail publications never evict from a full tier
    tier.scatter_staged()
    tier.note_publish(30, _record(small_qb, 30, n))
    assert not tier._staged


# ------------------------------------------------------------ engine parity


def _small_system(ds, graph, qb, hbm, **kw):
    cfg = baselines.SystemConfig(
        buffer_ratio=0.15, distance_backend="batch", hbm_tier=hbm, **kw
    )
    return baselines.build_system("velo", ds.base, graph, qb, cfg)


def test_tier_off_builds_nothing(small_ds, small_graph, small_qb):
    sys_ = _small_system(small_ds, small_graph, small_qb, hbm=False)
    assert sys_.hbm is None
    assert sys_.ctx.accessor.hbm is None
    assert sys_.ctx.accessor.pool.on_publish is None
    res = baselines.evaluate(sys_, small_ds)
    assert res["hbm_tier"] is False
    assert res["hbm_hits"] == res["hbm_scatters"] == res["hbm_evictions"] == 0
    assert res["combined_hit_rate"] == res["hit_rate"]


def test_tier_on_search_parity_deterministic(small_ds, small_graph, small_qb):
    """At the deterministic schedule (B=1, cbs/prefetch off) the tier moves
    bytes, not decisions: ids and hops are identical with the tier on."""
    params = SearchParams(L=32, W=4, cbs=False, prefetch=False)
    off = _small_system(small_ds, small_graph, small_qb, hbm=False,
                        batch_size=1, params=params)
    on = _small_system(small_ds, small_graph, small_qb, hbm=True,
                       batch_size=1, params=params)
    res_off, st_off = off.run(small_ds.queries)
    res_on, st_on = on.run(small_ds.queries)
    for i, (a, b) in enumerate(zip(res_off, res_on)):
        np.testing.assert_array_equal(a.ids, b.ids, err_msg=f"q{i} ids")
        assert a.hops == b.hops, f"q{i} hops"
    assert st_on.hbm_hits > 0


def test_engine_tier_counters_and_uploads(small_ds, small_graph, small_qb):
    sys_ = _small_system(small_ds, small_graph, small_qb, hbm=True)
    res = baselines.evaluate(sys_, small_ds)
    assert res["hbm_tier"] is True
    assert res["hbm_hits"] > 0
    assert res["hbm_scatters"] > 0
    assert res["dist_uploads"] <= 2
    assert sys_.ctx.dist.stats.slot_gathers > 0
    assert res["combined_hit_rate"] >= res["hit_rate"]
    assert res["memory_bytes"] > sys_.index.resident_bytes()


def test_evaluate_reports_per_run_deltas(small_ds, small_graph, small_qb):
    """Satellite regression: hbm_* counters are snapshotted per run — a
    second evaluate reports that run's own tier traffic, not the cumulative
    totals (and a no-traffic run would report zeros)."""
    sys_ = _small_system(small_ds, small_graph, small_qb, hbm=True)
    baselines.evaluate(sys_, small_ds)
    c1 = sys_.hbm.counters()
    assert c1["hits"] > 0
    res2 = baselines.evaluate(sys_, small_ds)
    c2 = sys_.hbm.counters()
    assert res2["hbm_hits"] == c2["hits"] - c1["hits"]
    assert res2["hbm_misses"] == c2["misses"] - c1["misses"]
    assert res2["hbm_scatters"] == c2["scatters"] - c1["scatters"]
    assert res2["hbm_evictions"] == c2["evictions"] - c1["evictions"]
    assert res2["hbm_hits"] < c2["hits"], "delta, not the cumulative total"


# ---------------------------------------------------------------- cost model


def test_fused_batch_s_kind_routing():
    cost = CostModel(batch_dispatch_s=1e-6, full_dispatch_s=9e-6)
    assert cost.fused_batch_s(2e-6, kind="full") == pytest.approx(11e-6)
    assert cost.fused_batch_s(2e-6, kind="quant") == pytest.approx(3e-6)
    assert cost.fused_batch_s(2e-6) == pytest.approx(3e-6)
    # parity default: uncalibrated full dispatch equals the batch dispatch,
    # so pre-existing full-path charges are bitwise unchanged
    d = CostModel()
    assert d.full_dispatch_s == d.batch_dispatch_s


def test_apply_calibration_consumes_full_dispatch():
    cost = baselines.apply_calibration(
        CostModel(), "batch",
        {"batch": {"full_dispatch_s": 7e-6, "hbm_scatter_s": 2e-6,
                   "not_a_field": 1.0}},
    )
    assert cost.full_dispatch_s == pytest.approx(7e-6)
    assert cost.hbm_scatter_s == pytest.approx(2e-6)


# -------------------------------------------------------------- serving plane


@pytest.fixture(scope="module")
def hbm_tenants():
    out = []
    for i, n in enumerate((700, 600)):
        ds = dataset_mod.make_dataset(n=n, d=32, n_queries=30, k=10, seed=i)
        graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                        seed=i)
        qb = RabitQuantizer(32, seed=i).fit_encode(ds.base)
        out.append(TenantSpec.from_dataset(f"t{i}", ds, graph, qb,
                                           system="velo"))
    return out


def test_serving_plane_tier_split(hbm_tenants):
    cfg = baselines.SystemConfig(buffer_ratio=0.15, hbm_tier=True,
                                 distance_backend="batch")
    plane = ServingPlane(hbm_tenants, config=cfg, shared_pool=True)
    assert plane.hbm is not None
    wl = workload_mod.zipfian_mix([30, 30], n_ops=60, seed=0)
    out = evaluate_plane(plane, wl)
    assert out["hbm_tier"] is True
    assert out["hbm_hits"] > 0
    per_tenant = sum(t["hbm_hits"] for t in out["tenants"].values())
    assert per_tenant == out["hbm_hits"], "tenant tier split must sum exactly"
    # per-run delta idempotence on the plane (PR-5 counter rule)
    c1 = plane.hbm.counters()
    out2 = evaluate_plane(plane, wl)
    c2 = plane.hbm.counters()
    assert out2["hbm_hits"] == c2["hits"] - c1["hits"]
    per_tenant2 = sum(t["hbm_hits"] for t in out2["tenants"].values())
    assert per_tenant2 == out2["hbm_hits"]


def test_serving_static_partition_gets_no_tier(hbm_tenants):
    cfg = baselines.SystemConfig(buffer_ratio=0.15, hbm_tier=True,
                                 distance_backend="batch")
    plane = ServingPlane(hbm_tenants, config=cfg, shared_pool=False)
    assert plane.hbm is None
    wl = workload_mod.uniform_mix([30, 30], n_ops=40, seed=1)
    out = evaluate_plane(plane, wl)
    assert out["hbm_tier"] is False
    assert out["hbm_hits"] == 0
