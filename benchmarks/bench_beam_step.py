"""Fused on-device beam step: one dispatch per hop, no distance download.

The host beam loop round-trips every hop twice: upload ids, download raw
distances, insert into the beam on the host, pick the next frontier, repeat.
With ``SystemConfig.device_beam`` the per-query beam state lives on the
engine and one fused call per hop executes score -> visited mask -> top-k
merge -> frontier selection, returning only the (tiny) next frontier
(docs/beam_step.md).

Claims checked (the PR's acceptance bar):

  * PARITY — at B=1 / n_workers=1 the device plane returns bitwise-identical
    results (ids, dists, hops) to the host plane for ALL FIVE algorithms,
    fuse on and off (velo's hop count is excluded under fuse: its
    cache-aware pivot reads the simulated clock, so fuse alone already
    shifts the trajectory on the pure host plane — ids/dists stay bitwise);
  * EXCHANGE — distance downloads per query collapse to ~the refine stream
    (<= ~1.15x mean hops) with device_beam, and to <= ~0.6x the host
    plane's total (the estimate stream no longer ships raw distances);
  * THROUGHPUT — QPS with device_beam is no worse than the host plane at
    equal recall (recall drift <= 0.02);
  * a ``compiled_vs_interpret`` timing record for the fused step itself,
    so results.json separates real-accelerator runs from CPU interpret mode.

Standalone:  python -m benchmarks.bench_beam_step [--full] [--strict]
(--strict exits non-zero when any claim check fails, same contract as
benchmarks/run.py --strict.)
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks import common
from repro.core import baselines
from repro.core import beam as beam_mod
from repro.core import dataset as dataset_mod
from repro.core import distance as distance_mod
from repro.core import vamana as vamana_mod
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams

ALGOS = sorted(ALGORITHMS)
# velo's cache-aware pivot (acc.resident) reads the simulated clock, so its
# TRAJECTORY (hops) is timing-dependent whenever charges change — fuse alone
# already shifts it on the pure host plane.  Under fuse its parity bar is
# ids/dists; hops are bitwise only on the charge-identical fuse-off path.
TIMING_DEPENDENT = {"velo"}
RECALL_DRIFT = 0.02
QPS_FLOOR = 0.98
DOWNLOAD_CEIL = 1.15   # device: downloads/query <= ceil * mean hops
DOWNLOAD_HALVING = 0.6  # device downloads <= this fraction of host's


def _parity_fixture():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=12, k=10, seed=4)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=4)
    qb = RabitQuantizer(32, seed=4).fit_encode(ds.base)
    return ds, graph, qb


def _parity_sweep() -> dict[str, bool]:
    """device_beam vs host, bitwise, per algorithm (both fuse modes)."""
    ds, graph, qb = _parity_fixture()

    def run(algo, device_beam, fuse):
        # batch_size=1: the bitwise contract holds for SERIAL queries —
        # interleaved coroutines shift velo's timing-dependent cache pivot
        # (docs/beam_step.md), where parity is recall-level, not bitwise
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=1, batch_size=1, fuse=fuse,
            device_beam=device_beam, params=SearchParams(L=24, W=4),
        )
        sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
        results, stats = sys_.run(ds.queries)
        return results, stats

    out = {}
    for algo in ALGOS:
        ok = True
        for fuse in (False, True):
            ref, _ = run(algo, False, fuse)
            got, got_stats = run(algo, True, fuse)
            with_hops = not (fuse and algo in TIMING_DEPENDENT)
            ok &= [
                (list(r.ids), list(r.dists), r.hops if with_hops else None)
                for r in got
            ] == [
                (list(r.ids), list(r.dists), r.hops if with_hops else None)
                for r in ref
            ]
            ok &= got_stats.beam_ops > 0
        out[algo] = ok
    return out


def _exchange_sweep(quick: bool) -> dict:
    """Downloads/query and QPS, host vs device plane, per algorithm."""
    if quick:
        w = common.Workload("beamq", n=3000, d=64, n_queries=96, R=16,
                            L=32, seed=7)
        params = SearchParams(L=32, W=4)
    else:
        w = common.Workload("beam", n=8000, d=96, n_queries=192, R=24,
                            L=48, seed=7)
        params = SearchParams(L=48, W=4)

    rows = {}
    for algo in ALGOS:
        per = {}
        for device_beam in (False, True):
            cfg = baselines.SystemConfig(
                buffer_ratio=0.2, n_workers=2, batch_size=4,
                device_beam=device_beam, params=params,
            )
            sys_ = baselines.build_system(algo, w.ds.base, w.graph, w.qb,
                                          cfg)
            m = baselines.evaluate(sys_, w.ds)
            m["downloads_per_hop"] = (
                m["downloads_per_query"] / max(m["mean_hops"], 1e-9)
            )
            per["device" if device_beam else "host"] = m
        per["qps_ratio"] = per["device"]["qps"] / per["host"]["qps"]
        per["recall_drift"] = abs(
            per["device"]["recall@k"] - per["host"]["recall@k"]
        )
        rows[algo] = per
    return rows


def _fused_step_timing() -> dict | None:
    """compiled-vs-interpret wall clock of ONE fused beam step on the
    pallas engine (None when pallas is unavailable)."""
    if not distance_mod.pallas_available():
        return None
    rng = np.random.default_rng(0)
    n, d, rows = 2048, 64, 256
    base = rng.standard_normal((n, d)).astype(np.float32)
    qb = RabitQuantizer(d, seed=0).fit_encode(base)
    pq = RabitQuantizer.prepare_query(
        qb, rng.standard_normal(d).astype(np.float32)
    )
    eng = distance_mod.get_engine("pallas")
    state = eng.beam_new(64, n)
    req = beam_mod.BeamRequest(
        kind="estimate", state=state,
        fresh=rng.integers(0, n, rows).astype(np.int64),
        explored=np.zeros(0, np.int64),
        insert_ids=np.zeros(0, np.int64),
        insert_ds=np.zeros(0, np.float32),
        rows=rows, flop_s=0.0, pq=pq, qb=qb,
    )
    native = eng.interpret

    def make_fn(interpret):
        def fn():
            eng.interpret = interpret
            try:
                eng.beam_step_many(qb, [req])
            finally:
                eng.interpret = native
        return fn

    rec = common.compiled_vs_interpret(make_fn, reps=3, mode=native)
    rec["rows"] = rows
    return rec


def run(quick: bool = True) -> dict:
    parity = _parity_sweep()
    exchange = _exchange_sweep(quick)
    timing = _fused_step_timing()

    rows = []
    for algo, per in exchange.items():
        h, d = per["host"], per["device"]
        rows.append([
            algo, f"{h['downloads_per_hop']:.2f}",
            f"{d['downloads_per_hop']:.2f}",
            f"{h['qps']:.0f}", f"{d['qps']:.0f}",
            f"{per['qps_ratio']:.2f}", f"{d['recall@k']:.3f}",
            d["beam_ops"],
        ])
    text = common.fmt_table(
        ["algo", "dl/hop host", "dl/hop dev", "QPS host", "QPS dev",
         "ratio", "recall", "beam ops"],
        rows,
    )
    text += "\nB=1 bitwise parity: " + "  ".join(
        f"{a}={'ok' if ok else 'FAIL'}" for a, ok in parity.items()
    )
    if timing:
        text += (
            f"\nfused step ({timing['rows']} rows): compiled "
            f"{timing['compiled_s'] * 1e6:.1f}us"
            + (f"  interpret {timing['interpret_s'] * 1e6:.1f}us"
               if timing["interpret_s"] is not None else "")
            + f"  (pallas_interpret={timing['pallas_interpret']})"
        )

    checks = {
        # device plane returns the host plane's exact results
        **{f"parity_{a}": ok for a, ok in parity.items()},
        # the estimate stream stops shipping raw distances to the host
        "downloads_collapse_with_device_beam": all(
            per["device"]["downloads_per_hop"] <= DOWNLOAD_CEIL
            for per in exchange.values()
        ),
        "downloads_halved_vs_host": all(
            per["device"]["downloads_per_query"]
            <= DOWNLOAD_HALVING * per["host"]["downloads_per_query"]
            for per in exchange.values()
        ),
        # no-regression bar: at equal recall, the fused plane is no slower
        "qps_no_worse": all(
            per["qps_ratio"] >= QPS_FLOOR for per in exchange.values()
        ),
        "recall_flat": all(
            per["recall_drift"] <= RECALL_DRIFT for per in exchange.values()
        ),
        "beam_path_active": all(
            per["device"]["beam_ops"] > 0 for per in exchange.values()
        ),
    }
    return {
        "name": "device_beam_step",
        "results": {
            "parity": parity,
            "exchange": exchange,
            "fused_step_timing": timing,
        },
        "text": text,
        "checks": checks,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick profile (the default; kept explicit for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any claim check fails")
    args = ap.parse_args()
    res = run(quick=not args.full)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
