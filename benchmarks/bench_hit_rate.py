"""Paper Table 1: page-level cache hit rates (LRU/FIFO/Random) vs buffer ratio,
and the record-level clock pool at the same budgets.

Claims checked: page-policy hit rate is low and ~linear in ratio; policy
choice barely matters; the record pool far exceeds it per byte."""

from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    ratios = [0.1, 0.2, 0.3, 0.4, 0.5]
    policies = ["lru", "fifo", "random"]
    table: dict[str, list[float]] = {p: [] for p in policies}
    table["record-clock"] = []

    for ratio in ratios:
        for policy in policies:
            cfg = baselines.SystemConfig(
                buffer_ratio=ratio, page_policy=policy, batch_size=1,
                params=baselines.SearchParams(L=48, W=4),
            )
            sys_ = baselines.build_system("diskann", w.ds.base, w.graph, w.qb, cfg)
            _, stats = sys_.run(w.ds.queries)
            table[policy].append(stats.hit_rate)
        # record-level pool at the SAME byte budget (velo system, CBS off so
        # the access stream matches the beam-search pattern)
        cfg = baselines.SystemConfig(
            buffer_ratio=ratio, batch_size=1,
            params=baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False),
        )
        sys_ = baselines.build_system("+record", w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        table["record-clock"].append(stats.hit_rate)

    rows = [
        [name] + [f"{v:.1%}" for v in vals] for name, vals in table.items()
    ]
    text = common.fmt_table(["policy \\ ratio"] + [f"{r:.0%}" for r in ratios], rows)

    # paper claims.  The policy-choice claim ("LRU/FIFO offer only marginal
    # improvements over Random") is checked in the low-budget regime the
    # paper's argument targets (<= 20%); at generous budgets our skewed
    # synthetic workload lets LRU pull ahead somewhat.
    lru = table["lru"]
    spread_low = max(
        abs(table[a][i] - table[b][i])
        for i in range(2)
        for a in policies for b in policies
    )
    checks = {
        "hit_rate_~linear_in_ratio": lru[-1] < 4.0 * lru[0] + 0.15,
        "policies_within_6pts_at_low_budget": spread_low < 0.06,
        "record_pool_beats_pages_at_10%": table["record-clock"][0] > lru[0],
    }
    return {"name": "T1_hit_rate", "table": table, "ratios": ratios,
            "text": text, "checks": checks}
