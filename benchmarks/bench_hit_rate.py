"""Paper Table 1: page-level cache hit rates (LRU/FIFO/Random) vs buffer ratio,
and the record-level clock pool at the same budgets.

Claims checked: page-policy hit rate is low and ~linear in ratio; policy
choice barely matters; the record pool far exceeds it per byte.

Also here: the shared-pool scaling claim (§3.2) — ONE pool shared by all
n_workers (with LOCKED-window record coalescing) must beat the same byte
budget split into n independent per-worker pools, and a prefetching run must
actually exercise record-level coalescing (`coalesced_record_loads > 0`).
CI runs this module with `--strict`, so these checks failing fails the build.

And the HBM record-tier claim: at ONE total slot budget, splitting it into a
host pool plus a device record-cache tier must beat the host-only pool on
combined (either-tier) hit rate AND on QPS under the zipfian query mix, with
table uploads staying O(1) per index — the tier feeds the refine kernel by
slot gather, never by re-uploading payloads.  Runnable standalone:

  python -m benchmarks.bench_hit_rate [--quick | --full] [--strict]
"""

from __future__ import annotations

import argparse
import dataclasses

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    ratios = [0.1, 0.2, 0.3, 0.4, 0.5]
    policies = ["lru", "fifo", "random"]
    table: dict[str, list[float]] = {p: [] for p in policies}
    table["record-clock"] = []

    for ratio in ratios:
        for policy in policies:
            cfg = baselines.SystemConfig(
                buffer_ratio=ratio, page_policy=policy, batch_size=1,
                params=baselines.SearchParams(L=48, W=4),
            )
            sys_ = baselines.build_system("diskann", w.ds.base, w.graph, w.qb, cfg)
            _, stats = sys_.run(w.ds.queries)
            table[policy].append(stats.hit_rate)
        # record-level pool at the SAME byte budget (velo system, CBS off so
        # the access stream matches the beam-search pattern)
        cfg = baselines.SystemConfig(
            buffer_ratio=ratio, batch_size=1,
            params=baselines.SearchParams(L=48, W=4, cbs=False, prefetch=False),
        )
        sys_ = baselines.build_system("+record", w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        table["record-clock"].append(stats.hit_rate)

    rows = [
        [name] + [f"{v:.1%}" for v in vals] for name, vals in table.items()
    ]
    text = common.fmt_table(["policy \\ ratio"] + [f"{r:.0%}" for r in ratios], rows)

    # ---- shared pool across workers vs independent per-worker pools --------
    n_workers = 4
    shared_ratio = 0.2
    cfg = baselines.SystemConfig(
        buffer_ratio=shared_ratio, n_workers=n_workers, batch_size=8,
        params=baselines.SearchParams(L=48, W=4),
    )
    sys_shared = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
    _, shared_stats = sys_shared.run(w.ds.queries)

    # the same byte budget split into n_workers independent quarter-size
    # pools, each worker searching its own quarter of the query stream
    hits = misses = 0
    for i in range(n_workers):
        cfg_q = baselines.SystemConfig(
            buffer_ratio=shared_ratio / n_workers, n_workers=1, batch_size=8,
            params=baselines.SearchParams(L=48, W=4),
        )
        sys_q = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg_q)
        _, stats_q = sys_q.run(w.ds.queries[i::n_workers])
        hits += stats_q.cache_hits
        misses += stats_q.cache_misses
    sharded_hit = hits / max(1, hits + misses)

    shared = {
        "n_workers": n_workers,
        "buffer_ratio": shared_ratio,
        "shared_hit_rate": shared_stats.hit_rate,
        "sharded_hit_rate": sharded_hit,
        "lock_waits": shared_stats.lock_waits,
        "coalesced_record_loads": shared_stats.coalesced_record_loads,
        "group_admits": shared_stats.group_admits,
        "clock_skips": shared_stats.clock_skips,
    }
    text += "\n\n" + common.fmt_table(
        ["pool @ 20% budget, 4 workers", "hit rate", "coalesced", "group admits"],
        [
            ["shared (1 pool)", f"{shared['shared_hit_rate']:.1%}",
             shared["coalesced_record_loads"], shared["group_admits"]],
            ["sharded (4 quarter pools)", f"{sharded_hit:.1%}", "-", "-"],
        ],
    )

    # ---- HBM tier vs host-only pool at equal total slot budget -------------
    # the host-only pool gets the full budget; the tiered run splits it in
    # half — the device slots hold FULL records (codes + adjacency), so a
    # tier hit avoids both the upload and the SSD read
    hbm_ratio = 0.2
    params = baselines.SearchParams(L=48, W=4)
    sys_host = baselines.build_system(
        "velo", w.ds.base, w.graph, w.qb,
        baselines.SystemConfig(buffer_ratio=hbm_ratio, params=params,
                               hbm_tier=False),
    )
    n_host = sys_host.ctx.accessor.pool.n_slots
    sys_half = baselines.build_system(
        "velo", w.ds.base, w.graph, w.qb,
        baselines.SystemConfig(buffer_ratio=hbm_ratio / 2, params=params,
                               hbm_tier=False),
    )
    sys_tiered = baselines.build_system(
        "velo", w.ds.base, w.graph, w.qb,
        baselines.SystemConfig(
            buffer_ratio=hbm_ratio / 2, params=params, hbm_tier=True,
            hbm_slots=n_host - sys_half.ctx.accessor.pool.n_slots,
        ),
    )
    host_res = baselines.evaluate(sys_host, w.ds)
    tiered_res = baselines.evaluate(sys_tiered, w.ds)
    hbm = {
        "budget_slots": n_host,
        "tiered_host_slots": sys_tiered.ctx.accessor.pool.n_slots,
        "tiered_hbm_slots": sys_tiered.hbm.cache.n_slots,
        "host_only_hit_rate": host_res["hit_rate"],
        "host_only_qps": host_res["qps"],
        "host_only_ios_per_query": host_res["ios_per_query"],
        "combined_hit_rate": tiered_res["combined_hit_rate"],
        "tiered_qps": tiered_res["qps"],
        "tiered_ios_per_query": tiered_res["ios_per_query"],
        "hbm_hits": tiered_res["hbm_hits"],
        "hbm_hit_rate": tiered_res["hbm_hit_rate"],
        "hbm_scatters": tiered_res["hbm_scatters"],
        "hbm_evictions": tiered_res["hbm_evictions"],
        "dist_uploads": tiered_res["dist_uploads"],
    }
    text += "\n\n" + common.fmt_table(
        [f"pool @ {n_host} slots", "hit rate", "qps", "ios/q", "uploads"],
        [
            ["host-only", f"{host_res['hit_rate']:.1%}",
             f"{host_res['qps']:.0f}", f"{host_res['ios_per_query']:.1f}",
             host_res["dist_uploads"]],
            ["host+hbm (50/50)", f"{tiered_res['combined_hit_rate']:.1%}",
             f"{tiered_res['qps']:.0f}",
             f"{tiered_res['ios_per_query']:.1f}",
             tiered_res["dist_uploads"]],
        ],
    )

    # paper claims.  The policy-choice claim ("LRU/FIFO offer only marginal
    # improvements over Random") is checked in the low-budget regime the
    # paper's argument targets (<= 20%); at generous budgets our skewed
    # synthetic workload lets LRU pull ahead somewhat.
    lru = table["lru"]
    spread_low = max(
        abs(table[a][i] - table[b][i])
        for i in range(2)
        for a in policies for b in policies
    )
    checks = {
        "hit_rate_~linear_in_ratio": lru[-1] < 4.0 * lru[0] + 0.15,
        "policies_within_6pts_at_low_budget": spread_low < 0.06,
        "record_pool_beats_pages_at_10%": table["record-clock"][0] > lru[0],
        # shared-pool acceptance bar: one pool across workers >= the same
        # bytes split into independent per-worker pools, and prefetch+demand
        # races must coalesce at record granularity
        "shared_pool_beats_quarter_pools":
            shared["shared_hit_rate"] >= shared["sharded_hit_rate"],
        "record_coalescing_active_under_prefetch":
            shared["coalesced_record_loads"] > 0,
        # HBM-tier acceptance bar: the tier actually serves records, uploads
        # stay O(1) per index (slot gathers, not payload re-uploads), and at
        # equal total slots host+device beats host-only on combined hit rate,
        # QPS, and an absolute hit-rate floor
        "hbm_tier_serves_hits": hbm["hbm_hits"] > 0,
        "hbm_uploads_O1_per_index": hbm["dist_uploads"] <= 2,
        "hbm_combined_beats_host_only":
            hbm["combined_hit_rate"] > hbm["host_only_hit_rate"],
        "hbm_qps_beats_host_only": hbm["tiered_qps"] > hbm["host_only_qps"],
        "hbm_combined_hit_floor": hbm["combined_hit_rate"] >= 0.5,
    }
    return {"name": "T1_hit_rate", "table": table, "ratios": ratios,
            "shared_pool": shared, "hbm_tier": hbm, "text": text,
            "checks": checks}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workload (the default)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any claim check fails")
    args = ap.parse_args()
    res = run(quick=not args.full)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
