"""Shared benchmark context: datasets + graphs + quantizers, built once and
cached on disk (Vamana construction is the expensive step)."""

from __future__ import annotations

import os
import pickle
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.core import baselines as baselines_mod  # noqa: E402
from repro.core import dataset as dataset_mod  # noqa: E402
from repro.core import distance as distance_mod  # noqa: E402
from repro.core import vamana  # noqa: E402
from repro.core.quant import RabitQuantizer  # noqa: E402

CACHE_DIR = os.path.join(os.path.dirname(__file__), ".cache")
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def set_backend(name: str) -> None:
    """Select the DistanceEngine backend for every system the benchmarks
    build (threads run.py's --backend flag through SystemConfig's default)."""
    distance_mod.set_default_backend(name)


def active_backend() -> str:
    """The engine name systems will actually get — 'auto'/'default' resolved,
    pallas-without-jax degradation applied — so results.json records reality."""
    return distance_mod.resolved_backend()


def set_fuse(on: bool, rows: int | None = None,
             shared: bool | None = None, overlap: bool | None = None) -> None:
    """Enable cross-query fused score dispatch for every system the
    benchmarks build (threads run.py's --fuse / --shared-rendezvous /
    --overlap-flush flags through SystemConfig)."""
    baselines_mod.set_default_fuse(on, rows, shared, overlap)


def fuse_active() -> dict:
    """The fuse settings systems will actually get, for results.json."""
    on, rows = baselines_mod.default_fuse()
    return {"enabled": on, "rows": rows,
            "shared_rendezvous": baselines_mod.default_shared_rendezvous(),
            "overlap_flush": baselines_mod.default_overlap_flush()}


def set_hbm(on: bool, slots: int | None = None) -> None:
    """Enable the device-resident HBM record-cache tier for every
    record-pool system the benchmarks build (threads run.py's --hbm-tier /
    --hbm-slots flags through SystemConfig)."""
    baselines_mod.set_default_hbm(on, slots)


def hbm_active() -> dict:
    """The HBM-tier settings systems will actually get, for results.json."""
    on, slots = baselines_mod.default_hbm()
    return {"enabled": on, "slots": slots}


def set_calibration(path: str) -> None:
    """Load calibrate.py's per-backend CostModel overrides and make every
    system the benchmarks build inherit them (run.py's --calibration flag)."""
    baselines_mod.set_default_calibration(baselines_mod.load_calibration(path))


def set_device_beam(on: bool) -> None:
    """Enable the fused on-device beam step for every system the benchmarks
    build (threads run.py's --device-beam flag through SystemConfig)."""
    baselines_mod.set_default_device_beam(on)


def device_beam_active() -> bool:
    """The device-beam setting systems will actually get, for results.json."""
    return baselines_mod.default_device_beam()


def set_scheduler(scheduler: str, sla_ms: float | None = None) -> None:
    """Select the engine's scheduling policy (and optional per-query SLA in
    milliseconds) for every system the benchmarks build (threads run.py's
    --scheduler / --sla-ms flags through SystemConfig)."""
    baselines_mod.set_default_scheduler(scheduler, sla_ms)


def scheduler_active() -> dict:
    """The scheduler settings systems will actually get, for results.json."""
    scheduler, sla_ms = baselines_mod.default_scheduler()
    return {"policy": scheduler, "sla_ms": sla_ms}


def set_platform(platform: str = "cpu") -> None:
    """Pin the JAX platform (and its XLA tuning flags) BEFORE any kernel
    traces — only takes effect at the beginning of the program.  No-op when
    jax is absent (the host backends need no platform pin)."""
    try:
        import jax
    except ImportError:
        return
    jax.config.update("jax_platform_name", platform)
    if platform == "gpu":
        # https://jax.readthedocs.io/en/latest/gpu_performance_tips.html
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_gpu_triton_gemm_any=True"
            + " --xla_gpu_enable_latency_hiding_scheduler=true"
        ).strip()


_PALLAS_MODE_CACHE: dict[str, bool] = {}


def pallas_mode() -> bool | None:
    """Whether the pallas backend would run the kernels in interpret mode
    (True) or compiled (False); None when the active backend isn't pallas.
    Recorded in results.json so runs on real accelerators are
    distinguishable from CPU interpret-mode runs.  Cached: the probe builds
    an engine, and the answer cannot change within a process."""
    if active_backend() != "pallas":
        return None
    if "interpret" not in _PALLAS_MODE_CACHE:
        _PALLAS_MODE_CACHE["interpret"] = bool(
            distance_mod.get_engine("pallas").interpret
        )
    return _PALLAS_MODE_CACHE["interpret"]


def best_of(fn, reps: int = 5) -> float:
    """Min wall-clock of ``fn()`` over ``reps`` runs (micro-timing floor)."""
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def compiled_vs_interpret(make_fn, reps: int = 5,
                          mode: bool | None = None) -> dict:
    """Time one device operation in compiled and pallas-interpret modes.

    ``make_fn(interpret: bool)`` returns a zero-arg callable executing ONE
    invocation (it must block on the result); the harness warms each mode
    before timing so trace/compile time never lands in the measurement.
    ``compiled_s`` times the engine's NATIVE mode — ``pallas_interpret``
    records which mode that actually was, so results.json from a CPU
    interpret-mode run is distinguishable from a real accelerator run.
    ``interpret_s`` is measured only when the engine compiled for real (an
    interpret-mode process has no faster mode to compare against — and
    force-compiling its kernels would fail, which is why it interprets).
    ``mode`` overrides the native-mode probe: pass the timed engine's own
    ``interpret`` flag when it isn't the session default backend (the
    module-level ``pallas_mode()`` reflects the DEFAULT engine only)."""
    if mode is None:
        mode = pallas_mode()
    fn = make_fn(bool(mode))  # the engine's NATIVE interpret flag
    fn()  # warm: compile outside the timed region
    rec = {
        "compiled_s": best_of(fn, reps),
        "interpret_s": None,
        "pallas_interpret": mode,
    }
    if mode is False:
        fi = make_fn(True)
        fi()
        rec["interpret_s"] = best_of(fi, reps)
    return rec


class Workload:
    """dataset + graph + quantized base, disk-cached by key."""

    def __init__(self, name, n, d, n_queries, R, L, seed=0, query_skew=1.2):
        self.key = f"{name}-n{n}-d{d}-q{n_queries}-R{R}-L{L}-s{seed}"
        os.makedirs(CACHE_DIR, exist_ok=True)
        path = os.path.join(CACHE_DIR, self.key + ".pkl")
        if os.path.exists(path):
            with open(path, "rb") as f:
                self.ds, self.graph, self.qb = pickle.load(f)
            return
        self.ds = dataset_mod.make_dataset(
            n=n, d=d, n_queries=n_queries, k=10, seed=seed,
            query_skew=query_skew, name=name,
        )
        self.graph = vamana.build_vamana(self.ds.base, R=R, L=L, seed=seed)
        self.qb = RabitQuantizer(d, seed=seed).fit_encode(self.ds.base)
        with open(path, "wb") as f:
            pickle.dump((self.ds, self.graph, self.qb), f)


_WORKLOADS: dict[str, Workload] = {}


def sift_like(quick: bool = True) -> Workload:
    key = f"sift-{quick}"
    if key not in _WORKLOADS:
        if quick:
            _WORKLOADS[key] = Workload("siftq", n=6000, d=64, n_queries=300, R=24, L=48)
        else:
            _WORKLOADS[key] = Workload("sift", n=20000, d=128, n_queries=800, R=32, L=64)
    return _WORKLOADS[key]


def gist_like(quick: bool = True) -> Workload:
    key = f"gist-{quick}"
    if key not in _WORKLOADS:
        if quick:
            _WORKLOADS[key] = Workload("gistq", n=3000, d=480, n_queries=150, R=24, L=48)
        else:
            _WORKLOADS[key] = Workload("gist", n=6000, d=960, n_queries=300, R=32, L=64)
    return _WORKLOADS[key]


def result_ids(results, k: int = 10) -> np.ndarray:
    """Stack per-query QueryResult ids into an (n, k) matrix for recall_at_k,
    -1-padded when a query returned fewer than k neighbors."""
    out = np.full((len(results), k), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(k, len(r.ids))
        out[i, :m] = r.ids[:m]
    return out


def fmt_table(headers: list[str], rows: list[list]) -> str:
    widths = [
        max(len(str(h)), max((len(str(r[i])) for r in rows), default=0))
        for i, h in enumerate(headers)
    ]
    def line(vals):
        return "  ".join(str(v).ljust(w) for v, w in zip(vals, widths))
    sep = "  ".join("-" * w for w in widths)
    return "\n".join([line(headers), sep] + [line(r) for r in rows])
