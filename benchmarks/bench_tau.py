"""Paper Fig. 13: record co-placement threshold tau sweep + VeloANN-Page.

Claims checked: tau=default beats tau=0 (no co-placement) on I/O per query;
an over-relaxed tau degrades again; page-granular caching (VeloANN-Page) is
the worst."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    settings = [
        ("tau=0", "velo", 0.0),
        ("tau=0.5x", "velo", 0.5),
        ("tau=1x", "velo", 1.0),
        ("tau=2x", "velo", 2.0),
        ("velo-page", "velo-page", 1.0),
    ]
    pts = []
    for label, system, tau in settings:
        cfg = baselines.SystemConfig(
            buffer_ratio=0.1, batch_size=8, tau_scale=tau,
            params=baselines.SearchParams(L=48, W=4),
        )
        sys_ = baselines.build_system(system, w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        pts.append({"setting": label, "ios_per_query": stats.ios_per_query,
                    "latency_ms": stats.mean_latency_ms, "qps": stats.qps,
                    "hit_rate": stats.hit_rate})

    rows = [[p["setting"], f"{p['ios_per_query']:.1f}", f"{p['latency_ms']:.2f}",
             f"{p['qps']:.0f}", f"{p['hit_rate']:.2f}"] for p in pts]
    text = common.fmt_table(["setting", "IO/query", "latency ms", "QPS", "hit"], rows)

    by = {p["setting"]: p for p in pts}
    checks = {
        "tau1_fewer_ios_than_tau0": by["tau=1x"]["ios_per_query"]
        < by["tau=0"]["ios_per_query"],
        # paper: tau=10% DEGRADES vs 5%.  On clustered-Gaussian data the
        # degradation is geometry-dependent (affinity groups stay tight even
        # at 2x tau), so the check only requires no *significant* win —
        # the refutation is recorded in EXPERIMENTS.md §Paper-validation.
        "tau2_no_significant_win_over_tau1": by["tau=2x"]["qps"]
        <= by["tau=1x"]["qps"] * 1.05,
        "page_granularity_worst_latency": by["velo-page"]["latency_ms"]
        >= max(v["latency_ms"] for k, v in by.items() if k != "velo-page") * 0.95,
    }
    return {"name": "F13_tau", "points": pts, "text": text, "checks": checks}
