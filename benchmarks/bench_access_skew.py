"""Paper Fig. 4: access-frequency skew at vertex vs page granularity.

Claim checked: a large fraction of vertices is never touched while almost
every page is touched (the locality mismatch that motivates record-level
caching: paper reports 47.3% vertices unaccessed vs 0.1% pages untouched on
Sift1M)."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2, batch_size=1, track_access=True,
        params=baselines.SearchParams(L=48, W=4),
    )
    sys_ = baselines.build_system("diskann", w.ds.base, w.graph, w.qb, cfg)
    sys_.run(w.ds.queries)

    acc = sys_.ctx.accessor
    v = acc.vertex_counts
    p = acc.page_counts
    vertex_untouched = float((v == 0).mean())
    page_untouched = float((p == 0).mean())
    # skew: fraction of accesses landing on the hottest 10%
    def top10_share(c):
        c = np.sort(c)[::-1]
        return float(c[: max(1, len(c) // 10)].sum() / max(c.sum(), 1))

    res = {
        "vertex_untouched_frac": vertex_untouched,
        "page_untouched_frac": page_untouched,
        "vertex_top10_share": top10_share(v),
        "page_top10_share": top10_share(p),
    }
    text = common.fmt_table(
        ["granularity", "untouched", "top-10% share"],
        [
            ["vertex", f"{vertex_untouched:.1%}", f"{res['vertex_top10_share']:.1%}"],
            ["page", f"{page_untouched:.1%}", f"{res['page_top10_share']:.1%}"],
        ],
    )
    checks = {
        "many_vertices_untouched": vertex_untouched > 0.10,
        "far_fewer_pages_untouched": page_untouched < 0.5 * vertex_untouched,
        "vertex_skew_exceeds_page_skew": res["vertex_top10_share"] > res["page_top10_share"],
    }
    return {"name": "F4_access_skew", **res, "text": text, "checks": checks}
