"""Paper Table 3: disk index size + memory footprint, VeloANN vs DiskANN.

Claims checked: velo's disk index is several times smaller than DiskANN's
(paper: up to 10x, and ~4.5x smaller than the raw vectors); velo's memory
footprint is a fraction of DiskANN's at the same buffer ratio."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    out = {}
    rows = []
    for wl_name, wl in (("sift-like", common.sift_like(quick)),
                        ("gist-like", common.gist_like(quick))):
        origin = wl.ds.base.nbytes
        cfg = baselines.SystemConfig(buffer_ratio=0.2)
        velo = baselines.build_system("velo", wl.ds.base, wl.graph, wl.qb, cfg)
        disk = baselines.build_system("diskann", wl.ds.base, wl.graph, wl.qb, cfg)
        rec = {
            "origin_mb": origin / 1e6,
            "velo_disk_mb": velo.disk_bytes() / 1e6,
            "diskann_disk_mb": disk.disk_bytes() / 1e6,
            "velo_mem_mb": velo.memory_bytes() / 1e6,
            "diskann_mem_mb": disk.memory_bytes() / 1e6,
        }
        out[wl_name] = rec
        rows.append([wl_name, f"{rec['origin_mb']:.2f}",
                     f"{rec['velo_disk_mb']:.2f}", f"{rec['diskann_disk_mb']:.2f}",
                     f"{rec['velo_mem_mb']:.2f}", f"{rec['diskann_mem_mb']:.2f}"])
    text = common.fmt_table(
        ["dataset", "origin MB", "velo disk", "diskann disk", "velo mem", "diskann mem"],
        rows,
    )
    g = out["gist-like"]
    checks = {
        "velo_disk_much_smaller_than_diskann": g["velo_disk_mb"] < 0.25 * g["diskann_disk_mb"],
        "velo_disk_smaller_than_origin": g["velo_disk_mb"] < 0.5 * g["origin_mb"],
        "diskann_disk_amplifies_origin": g["diskann_disk_mb"] > g["origin_mb"],
        "velo_mem_smaller": g["velo_mem_mb"] < 0.5 * g["diskann_mem_mb"],
    }
    return {"name": "T3_index_size", "by_dataset": out, "text": text, "checks": checks}
