"""Paper Fig. 6: internal page fragmentation of the fixed-size-record layout
across dimensionalities, vs VeloANN's compressed slotted layout.

Claims checked: fragmentation rises with d (GIST-like d=960 ~ 50%), the
slotted layout keeps pages nearly full at every d."""

from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.core import vamana
from repro.core.dataset import make_dataset
from repro.core.pages import fixed_layout_utilization, page_utilization
from repro.core.quant import RabitQuantizer
from repro.core.store import VeloIndex


def run(quick: bool = True) -> dict:
    R = 64  # DiskANN's default graph degree (the paper's Fig. 6 regime: a
    # GIST record = 3840B vector + 256B adjacency spans two 4 KB pages)
    dims = [128, 256, 512, 768, 960] if not quick else [128, 512, 960]
    n = 800 if quick else 2000
    rows = []
    out = {}
    for d in dims:
        fixed_util = fixed_layout_utilization(d * 4 + 4 + R * 4)
        ds = make_dataset(n=n, d=d, n_queries=10, k=5, seed=d)
        g = vamana.build_vamana(ds.base, R=16, L=24, two_pass=False, seed=0)
        qb = RabitQuantizer(d, seed=0).fit_encode(ds.base)
        index = VeloIndex(ds.base, g, qb)
        utils = [page_utilization(p) for p in index.store.pages[:-1]]  # skip tail
        velo_util = float(np.mean(utils)) if utils else 1.0
        rows.append([d, f"{1-fixed_util:.1%}", f"{1-velo_util:.1%}"])
        out[d] = {"fixed_frag": 1 - fixed_util, "velo_frag": 1 - velo_util}

    text = common.fmt_table(["dim", "fixed-layout frag", "velo slotted frag"], rows)
    d_hi = dims[-1]
    checks = {
        "frag_grows_with_dim": out[d_hi]["fixed_frag"] > out[dims[0]]["fixed_frag"],
        # paper: "Gist1M reaches up to 52%"
        "gist_like_frag_~50%": abs(out[960]["fixed_frag"] - 0.5) < 0.08 if 960 in out else True,
        "velo_frag_small_everywhere": all(v["velo_frag"] < 0.12 for v in out.values()),
    }
    return {"name": "F6_fragmentation", "by_dim": out, "text": text, "checks": checks}
