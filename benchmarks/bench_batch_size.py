"""Paper Fig. 9: scheduler batch size B — throughput/latency trade-off.

Claims checked: QPS grows with B then saturates; average latency grows
with B (roughly linearly at large B)."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    Bs = [1, 2, 4, 8, 16, 32]
    pts = []
    for B in Bs:
        cfg = baselines.SystemConfig(
            buffer_ratio=0.1, batch_size=B, n_workers=1,
            params=baselines.SearchParams(L=48, W=4),
        )
        sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        pts.append({"B": B, "qps": stats.qps, "latency_ms": stats.mean_latency_ms})

    rows = [[p["B"], f"{p['qps']:.0f}", f"{p['latency_ms']:.2f}"] for p in pts]
    text = common.fmt_table(["B", "QPS", "latency ms"], rows)
    qps = [p["qps"] for p in pts]
    lat = [p["latency_ms"] for p in pts]
    checks = {
        "qps_grows_then_saturates": qps[2] > 1.5 * qps[0]
        and qps[-1] < 1.5 * qps[-2],
        "latency_grows_with_B": lat[-1] > lat[0],
    }
    return {"name": "F9_batch_size", "points": pts, "text": text, "checks": checks}
