"""Sharded scatter-gather serving: one index image across N engine shards.

The serving plane splits PageStore pages (the affinity-placement atomic
unit) across N engine shards; each query's frontier scatters to its owning
shards, fuses through per-shard rendezvous buffers, and merges back through
one small collective per flush — the all_gather + top_k idiom of
repro.velo.dist_search lifted into the coroutine engine.

Claims checked (the PR's acceptance bar):

  * S=1 PARITY — the sharded engine with one shard is bitwise identical to
    the unsharded engine (ids, dists, hops, makespan, per-query latencies)
    for ALL FIVE algorithms;
  * SCALING — velo QPS at 4 shards / 4 workers reaches >= 0.7 of linear
    over 1 shard / 1 worker, with recall flat and shard bytes balanced;
  * the two bugfix regressions that rode in with the plane: workload
    generators keep never-sampled cold tenants in n_tenants, and the
    distributed merge masks invalid top-k lanes before offset translation.

Standalone:  python -m benchmarks.bench_sharded [--full] [--strict]
(--strict exits non-zero when any claim check fails, same contract as
benchmarks/run.py --strict.)
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks import common
from repro.core import baselines
from repro.core import dataset as dataset_mod
from repro.core import vamana as vamana_mod
from repro.core import workload as workload_mod
from repro.core.quant import RabitQuantizer
from repro.core.search import ALGORITHMS, SearchParams

ALGOS = sorted(ALGORITHMS)
EFFICIENCY_FLOOR = 0.7
RECALL_DRIFT = 0.05


def _parity_fixture():
    ds = dataset_mod.make_dataset(n=600, d=32, n_queries=12, k=10, seed=4)
    graph = vamana_mod.build_vamana(ds.base, R=12, L=24, batch_size=256,
                                    seed=4)
    qb = RabitQuantizer(32, seed=4).fit_encode(ds.base)
    return ds, graph, qb


def _parity_sweep() -> dict[str, bool]:
    """S=1 sharded vs unsharded, bitwise, per algorithm (both fuse modes)."""
    ds, graph, qb = _parity_fixture()

    def run(algo, n_shards, fuse):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=1, batch_size=4, fuse=fuse,
            n_shards=n_shards, params=SearchParams(L=24, W=4),
        )
        sys_ = baselines.build_system(algo, ds.base, graph, qb, cfg)
        return sys_.run(ds.queries)

    out = {}
    for algo in ALGOS:
        ok = True
        for fuse in (False, True):
            ref, ref_stats = run(algo, None, fuse)
            got, got_stats = run(algo, 1, fuse)
            ok &= [
                (list(r.ids), list(r.dists), r.hops) for r in got
            ] == [
                (list(r.ids), list(r.dists), r.hops) for r in ref
            ]
            ok &= got_stats.makespan_s == ref_stats.makespan_s
            ok &= got_stats.latencies == ref_stats.latencies
            ok &= got_stats.scatter_ops > 0
        out[algo] = ok
    return out


def _scaling(quick: bool) -> dict:
    """Velo QPS across shard counts; one worker per shard (the fleet grows
    with the plane).  The profile pins fuse_rows/batch at the measured
    sweet spot so the efficiency check has headroom over its floor."""
    if quick:
        w = common.Workload("shardq", n=3000, d=64, n_queries=96, R=16,
                            L=32, seed=7)
        fuse_rows, params = 48, SearchParams(L=32, W=4)
    else:
        w = common.Workload("shard", n=8000, d=96, n_queries=192, R=24,
                            L=48, seed=7)
        fuse_rows, params = 48, SearchParams(L=48, W=4)

    rows = {}
    for S in (1, 2, 4):
        cfg = baselines.SystemConfig(
            buffer_ratio=0.2, n_workers=S, batch_size=8, fuse=True,
            fuse_rows=fuse_rows, n_shards=S, params=params,
        )
        sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
        m = baselines.evaluate(sys_, w.ds)
        by = sys_.store.shard_bytes(sys_.shard_plan.page_shard)
        m["shard_mb"] = [round(b / 2**20, 2) for b in by]
        m["balance"] = float(by.min() / by.max())
        rows[S] = m
    base = rows[1]["qps"]
    for S, m in rows.items():
        m["efficiency"] = m["qps"] / (S * base)
    return rows


def _regression_tenant_count() -> bool:
    """Cold tenants never sampled by a skewed mix must stay in n_tenants."""
    m = workload_mod.zipfian_mix([10] * 6, 12, s=3.0, seed=0)
    return (
        int(m.tenant_ids.max()) < 5        # premise: a cold tail exists
        and m.n_tenants == 6
        and m.counts().shape == (6,)
        and int(m.counts().sum()) == 12
    )


def _regression_masked_merge() -> bool:
    """dist_search masks invalid local lanes BEFORE the offset translation:
    a pad lane (id -1, garbage distance) from an under-filled shard must
    never win the merged top-k."""
    import jax.numpy as jnp

    from repro.velo import dist_search

    g0, m0 = dist_search.mask_local_topk(
        jnp.array([[0, 1, 2]]), jnp.array([[0.1, 0.2, 0.3]]), jnp.int32(0)
    )
    g1, m1 = dist_search.mask_local_topk(
        jnp.array([[4, -1, -1]]), jnp.array([[0.05, 0.0, 0.0]]),
        jnp.int32(100),
    )
    ids, d2 = dist_search.merge_topk(
        jnp.concatenate([g0, g1], axis=1),
        jnp.concatenate([m0, m1], axis=1), k=3
    )
    return (
        g1.tolist() == [[104, -1, -1]]
        and bool(jnp.isinf(m1[0, 1]))
        and ids.tolist() == [[104, 0, 1]]
        and bool(abs(d2[0, 0] - 0.05) < 1e-6)
    )


def run(quick: bool = True) -> dict:
    parity = _parity_sweep()
    scaling = _scaling(quick)

    rows = []
    for S, m in scaling.items():
        rows.append([
            f"S={S}", f"{m['qps']:.0f}", f"{m['efficiency']:.2f}",
            f"{m['recall@k']:.3f}", m["scatter_ops"], m["shard_flushes"],
            m["shard_merges"], f"{m['balance']:.2f}",
        ])
    text = common.fmt_table(
        ["shards", "QPS", "eff", "recall", "scatter", "flushes", "merges",
         "balance"],
        rows,
    )
    text += "\nS=1 bitwise parity: " + "  ".join(
        f"{a}={'ok' if ok else 'FAIL'}" for a, ok in parity.items()
    )

    recall_drift = abs(scaling[4]["recall@k"] - scaling[1]["recall@k"])
    checks = {
        # every algorithm runs bitwise-identically on the degenerate plane
        **{f"s1_parity_{a}": ok for a, ok in parity.items()},
        # near-linear scaling at flat recall, work spread across the shards
        "scaling_efficiency_4shards":
            scaling[4]["efficiency"] >= EFFICIENCY_FLOOR,
        "recall_flat_across_shards": recall_drift <= RECALL_DRIFT,
        "shard_bytes_balanced": scaling[4]["balance"] >= 0.9,
        "merge_collective_active": scaling[4]["shard_merges"] > 0,
        # the two bugfixes that rode in with the plane stay fixed
        "regression_workload_tenant_count": _regression_tenant_count(),
        "regression_dist_search_masked_merge": _regression_masked_merge(),
    }
    return {
        "name": "sharded_serving",
        "results": {"parity": parity,
                    "scaling": {str(k): v for k, v in scaling.items()}},
        "text": text,
        "checks": checks,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick profile (the default; kept explicit for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any claim check fails")
    args = ap.parse_args()
    res = run(quick=not args.full)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
