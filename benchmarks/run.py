"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [--quick | --full] [--only NAME] [--backend NAME]
                           [--fuse] [--fuse-rows N] [--shared-rendezvous]
                           [--overlap-flush] [--hbm-tier] [--hbm-slots N]
                           [--device-beam] [--scheduler NAME] [--sla-ms MS]
                           [--calibration PATH] [--strict]

Writes benchmarks/out/results.json and prints each table with the paper
claims it validates.  --strict exits non-zero when any module errors or any
paper-claim check fails, so CI smoke steps turn regressions into build
failures.  --full uses the larger workloads (slower, tighter
match to the paper's regimes); default is the quick profile (--quick makes
that explicit).  --backend selects the DistanceEngine for every system
(scalar | batch | pallas); --fuse turns on cross-query fused score dispatch
(one kernel dispatch serving the frontiers of all coroutines in flight on a
worker), with --fuse-rows setting the rendezvous flush budget.  Each module's
record carries the active backend, the fuse settings, and its wall-clock
seconds so runs can be compared side by side.
"""

from __future__ import annotations

import argparse
import importlib
import json
import os
import time
import traceback

from benchmarks import common

MODULES = [
    "bench_hit_rate",        # Table 1
    "bench_access_skew",     # Fig 4
    "bench_fragmentation",   # Fig 6
    "bench_throughput",      # Fig 8 (+ Fig 1)
    "bench_batch_size",      # Fig 9
    "bench_beam_width",      # Fig 10
    "bench_thread_scaling",  # Fig 11
    "bench_buffer_ratio",    # Fig 12
    "bench_tau",             # Fig 13
    "bench_breakdown",       # Fig 14
    "bench_index_size",      # Table 3
    "bench_fusion",          # cross-query fused dispatch: B x fuse-budget sweep
    "bench_multitenant",     # serving plane: shared pool vs partition under skew
    "bench_sharded",         # sharded scatter-gather: S=1 parity + QPS scaling
    "bench_beam_step",       # fused on-device beam step: parity + exchange
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick profile (the default; kept explicit for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument(
        "--backend", default=None, choices=["scalar", "batch", "pallas", "auto"],
        help="DistanceEngine backend for all systems (default: batch)",
    )
    ap.add_argument("--fuse", action="store_true",
                    help="cross-query fused score dispatch for all systems")
    ap.add_argument("--fuse-rows", type=int, default=None,
                    help="rendezvous flush row budget (default 256)")
    ap.add_argument("--shared-rendezvous", action="store_true",
                    help="one system-wide rendezvous buffer spanning all "
                         "workers (implies --fuse)")
    ap.add_argument("--overlap-flush", action="store_true",
                    help="overlap the shared-rendezvous stall flush with "
                         "other workers' in-flight completions (implies "
                         "--shared-rendezvous)")
    ap.add_argument("--hbm-tier", action="store_true",
                    help="device-resident HBM record-cache tier above the "
                         "host pool for every record-pool system")
    ap.add_argument("--hbm-slots", type=int, default=None,
                    help="HBM tier slot count (default: match the host "
                         "pool's slot count)")
    ap.add_argument("--device-beam", action="store_true",
                    help="fused on-device beam step (score + visited mask + "
                         "top-k merge + frontier selection in one engine "
                         "call) for every system")
    ap.add_argument("--scheduler", default=None, choices=["rr", "sla"],
                    help="engine scheduling policy for every system "
                         "(rr: FIFO round-robin, the default; sla: "
                         "earliest-deadline-first + feedback steering)")
    ap.add_argument("--sla-ms", type=float, default=None,
                    help="per-query SLA in milliseconds (enables deadline "
                         "accounting; with --scheduler sla also the EDF "
                         "deadline and the feedback target)")
    ap.add_argument("--calibration", default=None, metavar="PATH",
                    help="per-backend CostModel overrides from "
                         "benchmarks/calibrate.py (benchmarks/out/"
                         "calibration.json)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any module errors or any check fails")
    args = ap.parse_args()
    if args.quick and args.full:
        ap.error("--quick and --full are mutually exclusive")
    quick = not args.full
    if args.backend:
        common.set_backend(args.backend)
    if (args.fuse or args.fuse_rows is not None or args.shared_rendezvous
            or args.overlap_flush):
        common.set_fuse(
            args.fuse or args.shared_rendezvous or args.overlap_flush,
            args.fuse_rows,
            shared=(args.shared_rendezvous or args.overlap_flush) or None,
            overlap=args.overlap_flush or None,
        )
    if args.hbm_tier or args.hbm_slots is not None:
        common.set_hbm(args.hbm_tier or args.hbm_slots is not None,
                       args.hbm_slots)
    if args.device_beam:
        common.set_device_beam(True)
    if args.scheduler or args.sla_ms is not None:
        common.set_scheduler(args.scheduler or "rr", args.sla_ms)
    if args.calibration:
        common.set_calibration(args.calibration)
    print(f"distance backend: {common.active_backend()}  fuse: {common.fuse_active()}"
          f"  hbm: {common.hbm_active()}"
          f"  device_beam: {common.device_beam_active()}"
          f"  scheduler: {common.scheduler_active()}")

    os.makedirs(common.OUT_DIR, exist_ok=True)
    results = {}
    n_checks = n_pass = n_errors = 0
    for modname in MODULES:
        if args.only and args.only not in modname:
            continue
        mod = importlib.import_module(f"benchmarks.{modname}")
        t0 = time.time()
        try:
            res = mod.run(quick=quick)
        except Exception as e:  # noqa: BLE001
            res = {"name": modname, "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:], "checks": {}}
        dt = time.time() - t0
        res["wall_clock_s"] = dt
        res["distance_backend"] = common.active_backend()
        # interpret vs compiled matters for pallas wall-clock comparisons
        res["pallas_interpret"] = common.pallas_mode()
        res["fuse"] = common.fuse_active()
        res["hbm"] = common.hbm_active()
        res["device_beam"] = common.device_beam_active()
        res["scheduler"] = common.scheduler_active()
        res["calibration"] = args.calibration
        results[modname] = res
        print(f"\n=== {res.get('name', modname)}  ({dt:.1f}s) ===")
        if "error" in res:
            print("ERROR:", res["error"])
            n_errors += 1
            continue
        print(res["text"])
        for check, ok in res.get("checks", {}).items():
            n_checks += 1
            n_pass += bool(ok)
            print(f"  [{'PASS' if ok else 'FAIL'}] {check}")

    path = os.path.join(common.OUT_DIR, "results.json")
    with open(path, "w") as f:
        json.dump(
            {k: {kk: vv for kk, vv in v.items() if kk != "text"}
             for k, v in results.items()},
            f, indent=1, default=float,
        )
    print(f"\n==== paper-claim checks: {n_pass}/{n_checks} pass ====")
    print(f"results -> {path}")
    if args.strict and (n_errors or n_pass < n_checks):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
