"""Paper Fig. 11: throughput scaling with worker threads (1..32).

Claims checked: velo scales near-linearly and stays above every baseline at
every thread count (shared-SSD contention eventually binds everyone)."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    threads = [1, 4, 16] if quick else [1, 2, 4, 8, 16, 32]
    systems = ["velo", "diskann", "pipeann"] if quick else [
        "velo", "diskann", "starling", "pipeann"
    ]
    curves: dict[str, list[dict]] = {s: [] for s in systems}
    for name in systems:
        for t in threads:
            cfg = baselines.SystemConfig(
                buffer_ratio=0.2, n_workers=t,
                batch_size=8 if name == "velo" else 1,
                params=baselines.SearchParams(L=48, W=4),
            )
            sys_ = baselines.build_system(name, w.ds.base, w.graph, w.qb, cfg)
            _, stats = sys_.run(w.ds.queries)
            curves[name].append({"threads": t, "qps": stats.qps})

    rows = []
    for name, pts in curves.items():
        for p in pts:
            rows.append([name, p["threads"], f"{p['qps']:.0f}"])
    text = common.fmt_table(["system", "threads", "QPS"], rows)

    v = curves["velo"]
    checks = {
        "velo_scales_with_threads": v[-1]["qps"] > 2.0 * v[0]["qps"],
        "velo_leads_at_max_threads": v[-1]["qps"]
        > max(curves[s][-1]["qps"] for s in systems if s != "velo"),
    }
    return {"name": "F11_thread_scaling", "curves": curves, "text": text,
            "checks": checks}
