"""Paper Fig. 10: beam width W in cache-aware beam search.

Claims checked: an intermediate W is optimal; W=1 is WORSE than plain
best-first (W=0) — prefetching exactly one candidate stalls the pipeline
(paper's observation); large W over-fetches."""

from __future__ import annotations

import dataclasses

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    Ws = [0, 1, 2, 4, 8, 16]
    pts = []
    for W in Ws:
        if W == 0:
            params = baselines.SearchParams(L=48, W=1, cbs=False, prefetch=False)
        else:
            params = baselines.SearchParams(L=48, W=W, cbs=True, prefetch=True,
                                            prefetch_depth=W)
        cfg = baselines.SystemConfig(buffer_ratio=0.1, batch_size=8, params=params)
        sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        pts.append({"W": W, "qps": stats.qps, "latency_ms": stats.mean_latency_ms,
                    "ios_per_query": stats.ios_per_query, "hit_rate": stats.hit_rate})

    rows = [[p["W"], f"{p['qps']:.0f}", f"{p['latency_ms']:.2f}",
             f"{p['ios_per_query']:.1f}", f"{p['hit_rate']:.2f}"] for p in pts]
    text = common.fmt_table(["W", "QPS", "latency ms", "IO/query", "hit rate"], rows)

    qps = {p["W"]: p["qps"] for p in pts}
    best_W = max(qps, key=qps.get)
    checks = {
        "intermediate_W_optimal": best_W not in (0, Ws[-1]),
        "large_W_declines": qps[Ws[-1]] < qps[best_W],
        "hit_rate_grows_with_W": pts[-1]["hit_rate"] > pts[0]["hit_rate"],
    }
    return {"name": "F10_beam_width", "points": pts, "best_W": best_W,
            "text": text, "checks": checks}
