"""Cross-query fused dispatch: B x fuse-budget sweep.

The engine's rendezvous buffer collects the ("score", ...) ops of all
coroutines in flight on a worker and flushes them as one fused DistanceEngine
call.  This module measures how the fused-batch size and the total number of
distance dispatches scale with the coroutine batch B and the flush row budget,
against the per-query dispatch baseline (fuse off).

Claims checked: fusion cuts total dispatches (the launch-bound -> dispatch-
bound argument); the fused batch grows with B; recall is unaffected.
"""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines
from repro.core.dataset import recall_at_k


def _run(w, B, fuse, fuse_rows=256):
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        batch_size=B,
        n_workers=2,
        fuse=fuse,
        fuse_rows=fuse_rows,
        params=baselines.SearchParams(L=48, W=4),
    )
    sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
    results, stats = sys_.run(w.ds.queries)
    return {
        "B": B,
        "fuse": fuse,
        "fuse_rows": fuse_rows if fuse else 0,
        "recall": recall_at_k(common.result_ids(results), w.ds.groundtruth, 10),
        "qps": stats.qps,
        "dist_dispatches": sys_.ctx.dist.stats.dispatches(),
        "fused_dispatches": sys_.ctx.dist.stats.fused_calls,
        "requests_per_flush": stats.requests_per_flush,
        "rows_per_flush": stats.rows_per_flush,
    }


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    Bs = [1, 4, 16] if quick else [1, 4, 16, 32]
    budgets = [64, 512] if quick else [32, 128, 512, 2048]

    points: list[dict] = []
    for B in Bs:
        points.append(_run(w, B, fuse=False))
        for rows in budgets:
            points.append(_run(w, B, fuse=True, fuse_rows=rows))

    table_rows = [
        [p["B"], "on" if p["fuse"] else "off", p["fuse_rows"] or "-",
         f"{p['recall']:.3f}", f"{p['qps']:.0f}", p["dist_dispatches"],
         f"{p['requests_per_flush']:.2f}", f"{p['rows_per_flush']:.1f}"]
        for p in points
    ]
    text = common.fmt_table(
        ["B", "fuse", "budget", "recall@10", "QPS", "dispatches",
         "req/flush", "rows/flush"],
        table_rows,
    )

    def pick(B, fuse, rows=None):
        for p in points:
            if p["B"] == B and p["fuse"] == fuse and (
                rows is None or p["fuse_rows"] == rows
            ):
                return p
        raise KeyError((B, fuse, rows))

    bmax = Bs[-1]
    base = pick(bmax, False)
    fused = pick(bmax, True, budgets[-1])
    small = pick(bmax, True, budgets[0])
    checks = {
        # the point of the plane: fewer kernel dispatches at the same work
        "fused_cuts_dispatches": fused["dist_dispatches"] < 0.7 * base["dist_dispatches"],
        # the rendezvous actually fuses across queries once B > 1
        "fused_batch_grows_with_B": (
            fused["requests_per_flush"] > 1.2 * pick(1, True, budgets[-1])["requests_per_flush"]
        ),
        # a tighter budget flushes smaller batches
        "budget_bounds_batch": small["rows_per_flush"] <= fused["rows_per_flush"] + 1e-9,
        # fusion must not cost recall
        "recall_parity": abs(fused["recall"] - base["recall"]) < 0.05,
        # amortized dispatches must not cost simulated throughput
        "qps_no_worse": fused["qps"] > 0.95 * base["qps"],
    }
    dispatch_cut = base["dist_dispatches"] / max(fused["dist_dispatches"], 1)
    return {
        "name": "fusion_sweep",
        "points": points,
        "dispatch_cut_at_max_B": dispatch_cut,
        "text": text,
        "checks": checks,
    }
