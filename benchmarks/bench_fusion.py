"""Cross-query fused dispatch: B x fuse-budget sweep + rendezvous topology.

The engine's rendezvous buffer collects the ("score", ...) ops of in-flight
coroutines and flushes them as one fused DistanceEngine call.  This module
measures how the fused-batch size and the total number of distance dispatches
scale with the coroutine batch B and the flush row budget, against the
per-query dispatch baseline (fuse off) — and compares the two rendezvous
topologies at multiple workers: per-worker buffers (each flushes when ITS
worker stalls) versus the system-wide shared rendezvous (one buffer, flushed
at the row budget or when EVERY worker is stalled, so the fused batch spans
the whole system).

Claims checked: fusion cuts total dispatches (the launch-bound -> dispatch-
bound argument); the fused batch grows with B; recall is unaffected; the
shared rendezvous at 4 workers issues fewer dispatches than per-worker
fusion at equal recall.

Standalone:  python -m benchmarks.bench_fusion [--full] [--strict]
(--strict exits non-zero when any claim check fails, same contract as
benchmarks/run.py --strict.)
"""

from __future__ import annotations

import argparse

from benchmarks import common
from repro.core import baselines
from repro.core.dataset import recall_at_k


def _run(w, B, fuse, fuse_rows=256, n_workers=2, shared=False):
    cfg = baselines.SystemConfig(
        buffer_ratio=0.2,
        batch_size=B,
        n_workers=n_workers,
        fuse=fuse,
        fuse_rows=fuse_rows,
        shared_rendezvous=shared,
        params=baselines.SearchParams(L=48, W=4),
    )
    sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
    results, stats = sys_.run(w.ds.queries)
    return {
        "B": B,
        "fuse": fuse,
        "fuse_rows": fuse_rows if fuse else 0,
        "n_workers": n_workers,
        "shared": shared,
        "recall": recall_at_k(common.result_ids(results), w.ds.groundtruth, 10),
        "qps": stats.qps,
        "dist_dispatches": sys_.ctx.dist.stats.dispatches(),
        "fused_dispatches": sys_.ctx.dist.stats.fused_calls,
        "dist_uploads": sys_.ctx.dist.stats.uploads,
        "requests_per_flush": stats.requests_per_flush,
        "rows_per_flush": stats.rows_per_flush,
    }


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    Bs = [1, 4, 16] if quick else [1, 4, 16, 32]
    budgets = [64, 512] if quick else [32, 128, 512, 2048]

    points: list[dict] = []
    for B in Bs:
        points.append(_run(w, B, fuse=False))
        for rows in budgets:
            points.append(_run(w, B, fuse=True, fuse_rows=rows))

    # rendezvous topology at 4 workers: per-worker vs system-wide shared
    bmax = Bs[-1]
    topo = {
        "per_worker": _run(w, bmax, fuse=True, fuse_rows=budgets[-1],
                           n_workers=4),
        "shared": _run(w, bmax, fuse=True, fuse_rows=budgets[-1],
                       n_workers=4, shared=True),
    }

    table_rows = [
        [p["B"], "on" if p["fuse"] else "off", p["fuse_rows"] or "-",
         p["n_workers"], "shared" if p["shared"] else "worker",
         f"{p['recall']:.3f}", f"{p['qps']:.0f}", p["dist_dispatches"],
         f"{p['requests_per_flush']:.2f}", f"{p['rows_per_flush']:.1f}"]
        for p in points + list(topo.values())
    ]
    text = common.fmt_table(
        ["B", "fuse", "budget", "workers", "rendezvous", "recall@10", "QPS",
         "dispatches", "req/flush", "rows/flush"],
        table_rows,
    )

    def pick(B, fuse, rows=None):
        for p in points:
            if p["B"] == B and p["fuse"] == fuse and (
                rows is None or p["fuse_rows"] == rows
            ):
                return p
        raise KeyError((B, fuse, rows))

    base = pick(bmax, False)
    fused = pick(bmax, True, budgets[-1])
    small = pick(bmax, True, budgets[0])
    pw, sh = topo["per_worker"], topo["shared"]
    checks = {
        # the point of the plane: fewer kernel dispatches at the same work
        "fused_cuts_dispatches": fused["dist_dispatches"] < 0.7 * base["dist_dispatches"],
        # the rendezvous actually fuses across queries once B > 1
        "fused_batch_grows_with_B": (
            fused["requests_per_flush"] > 1.2 * pick(1, True, budgets[-1])["requests_per_flush"]
        ),
        # a tighter budget flushes smaller batches
        "budget_bounds_batch": small["rows_per_flush"] <= fused["rows_per_flush"] + 1e-9,
        # fusion must not cost recall
        "recall_parity": abs(fused["recall"] - base["recall"]) < 0.05,
        # amortized dispatches must not cost simulated throughput
        "qps_no_worse": fused["qps"] > 0.95 * base["qps"],
        # the shared rendezvous spans workers: fewer, wider dispatches at
        # 4 workers than per-worker buffers, at equal recall
        "shared_fewer_dispatches": sh["dist_dispatches"] < pw["dist_dispatches"],
        "shared_wider_flushes": sh["requests_per_flush"] > pw["requests_per_flush"],
        "shared_recall_parity": abs(sh["recall"] - pw["recall"]) < 0.05,
        # register-once tables: a whole run uploads O(1) tables, not O(hops)
        "uploads_o1": sh["dist_uploads"] <= 2,
    }
    dispatch_cut = base["dist_dispatches"] / max(fused["dist_dispatches"], 1)
    return {
        "name": "fusion_sweep",
        "points": points,
        "topology_4workers": topo,
        "dispatch_cut_at_max_B": dispatch_cut,
        "shared_dispatch_cut": pw["dist_dispatches"] / max(sh["dist_dispatches"], 1),
        "text": text,
        "checks": checks,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick profile (the default; kept explicit for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any claim check fails")
    args = ap.parse_args()
    res = run(quick=not args.full)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    print(f"dispatch cut at max B: {res['dispatch_cut_at_max_B']:.2f}x; "
          f"shared vs per-worker: {res['shared_dispatch_cut']:.2f}x")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
