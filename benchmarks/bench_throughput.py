"""Paper Fig. 8 + Fig. 1: QPS and latency vs recall across systems.

Sweeps the candidate-list size L per system to trace its recall/throughput
curve, then compares at matched recall bands.  Claims checked: VeloANN beats
DiskANN/Starling/PipeANN in QPS at iso-recall; approaches the in-memory
index; PipeANN has lower latency than DiskANN."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines
from repro.core.dataset import recall_at_k


SYSTEMS = ["velo", "diskann", "starling", "pipeann", "inmemory"]


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    Ls = [24, 48, 96] if quick else [16, 32, 64, 128]
    curves: dict[str, list[dict]] = {s: [] for s in SYSTEMS}

    for name in SYSTEMS:
        for L in Ls:
            cfg = baselines.SystemConfig(
                buffer_ratio=0.2,
                batch_size=16 if name in ("velo", "inmemory") else 1,
                n_workers=4,
                params=baselines.SearchParams(L=L, W=4),
            )
            sys_ = baselines.build_system(name, w.ds.base, w.graph, w.qb, cfg)
            results, stats = sys_.run(w.ds.queries)
            rec = recall_at_k(common.result_ids(results), w.ds.groundtruth, 10)
            curves[name].append(
                {"L": L, "recall": rec, "qps": stats.qps,
                 "latency_ms": stats.mean_latency_ms,
                 "ios_per_query": stats.ios_per_query,
                 # distance-plane dispatch accounting (--fuse comparison axis)
                 "dist_dispatches": sys_.ctx.dist.stats.dispatches(),
                 "fused_dispatches": sys_.ctx.dist.stats.fused_calls,
                 # register-once resident tables: uploads must stay O(1) per
                 # index (the legacy pallas path paid one per dispatch)
                 "dist_uploads": sys_.ctx.dist.stats.uploads,
                 "resident_gathers": sys_.ctx.dist.stats.resident_gathers,
                 "score_requests_per_flush": stats.requests_per_flush,
                 "score_rows_per_flush": stats.rows_per_flush}
            )

    rows = []
    for name, pts in curves.items():
        for p in pts:
            rows.append([name, p["L"], f"{p['recall']:.3f}", f"{p['qps']:.0f}",
                         f"{p['latency_ms']:.2f}", f"{p['ios_per_query']:.1f}",
                         p["dist_dispatches"]])
    text = common.fmt_table(
        ["system", "L", "recall@10", "QPS", "latency ms", "IO/query", "dispatches"],
        rows,
    )

    # iso-effort comparison at the middle L
    mid = len(Ls) // 2
    v = curves["velo"][mid]
    d = curves["diskann"][mid]
    s = curves["starling"][mid]
    p = curves["pipeann"][mid]
    m = curves["inmemory"][mid]
    checks = {
        # the resident code plane registers each index's tables once —
        # quantized systems must not re-upload per hop (uploads O(1))
        "uploads_o1_per_index": all(
            p["dist_uploads"] <= 1 for pts in curves.values() for p in pts
        ),
        "velo_qps_beats_diskann": v["qps"] > d["qps"],
        "velo_qps_beats_starling": v["qps"] > s["qps"],
        "velo_qps_beats_pipeann": v["qps"] > p["qps"],
        "pipeann_latency_below_diskann": p["latency_ms"] < d["latency_ms"],
        "velo_within_2x_of_inmemory_qps": v["qps"] > 0.3 * m["qps"],
        "velo_recall_close": v["recall"] > d["recall"] - 0.08,
    }
    speedups = {
        "qps_vs_diskann": v["qps"] / max(d["qps"], 1e-9),
        "qps_vs_starling": v["qps"] / max(s["qps"], 1e-9),
        "qps_vs_pipeann": v["qps"] / max(p["qps"], 1e-9),
        "qps_vs_inmemory": v["qps"] / max(m["qps"], 1e-9),
        "latency_vs_diskann": d["latency_ms"] / max(v["latency_ms"], 1e-9),
    }
    return {"name": "F8_throughput", "curves": curves, "speedups": speedups,
            "text": text, "checks": checks}
