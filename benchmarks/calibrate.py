"""Backend-aware cost calibration: micro-time per-backend constants.

The simulator charges every batched distance evaluation one amortized
``CostModel.batch_dispatch_s`` and every registered index one
``table_upload_s`` — but a scalar loop, a BLAS ufunc dispatch, and a Pallas
kernel launch (let alone an interpret-mode one) have wildly different real
overheads.  This module measures them:

  * dispatch  — per-call overhead of an id-based level-1 estimate, extracted
    by timing a 1-row call against a large call and subtracting the per-row
    slope (classic y = a + b*m fit at two points, min-of-reps);
  * full dispatch — the same two-point fit over the exact fp32 path
    (``refine_full``, the BLAS GEMV the DiskANN-style systems refine with):
    a ufunc/GEMV launch is not priced like the int4 table kernel, so
    ``CostModel.full_dispatch_s`` is calibrated apart from
    ``batch_dispatch_s``;
  * row cost  — the slope itself (diagnostic: it should track the CostModel
    per-dim constants);
  * upload    — wall-clock of ``register_index`` on a fresh engine (the
    register-once table pin; device_put for pallas, view construction for
    the host backends).

Results are written to ``benchmarks/out/calibration.json`` as
``{backend: {cost_field: seconds, ...}}`` — exactly the override format
``SystemConfig.calibration`` (or ``baselines.set_default_calibration``, the
hook behind ``run.py --calibration``) consumes, so simulated seconds track
the measured wall-clock ratios recorded in ``benchmarks/out/results.json``.

  python -m benchmarks.calibrate [--quick | --full] [--backends a,b,...]
"""

from __future__ import annotations

import argparse
import json
import os
import time

from benchmarks import common

import numpy as np  # noqa: E402

from repro.core import beam as beam_mod  # noqa: E402
from repro.core import distance as distance_mod  # noqa: E402
from repro.core.quant import RabitQuantizer  # noqa: E402

# CostModel fields the emitted overrides may set; everything else in the
# record is diagnostic and ignored by baselines.apply_calibration.
COST_FIELDS = (
    "batch_dispatch_s", "full_dispatch_s", "table_upload_s", "beam_step_s",
)


def _beam_req(qb, pq, state, ids):
    """A minimal level-1 BeamRequest for micro-timing (flop_s is cost-model
    input only — the engine never reads it)."""
    return beam_mod.BeamRequest(
        kind="estimate", state=state, fresh=np.asarray(ids, np.int64),
        explored=np.zeros(0, np.int64),
        insert_ids=np.zeros(0, np.int64),
        insert_ds=np.zeros(0, np.float32),
        rows=int(np.asarray(ids).size), flop_s=0.0, pq=pq, qb=qb,
    )


def _best_of(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def calibrate_backend(
    name: str, n: int = 8192, d: int = 64, big: int = 2048, reps: int = 5,
    seed: int = 0,
) -> dict:
    """Measured constants for one backend over a synthetic (n, d) index."""
    rng = np.random.default_rng(seed)
    base = rng.standard_normal((n, d)).astype(np.float32)
    qb = RabitQuantizer(d, seed=seed).fit_encode(base)
    pq = RabitQuantizer.prepare_query(qb, rng.standard_normal(d).astype(np.float32))
    ids_small = rng.integers(0, n, 1).astype(np.int64)
    ids_big = rng.integers(0, n, big).astype(np.int64)

    eng = distance_mod.get_engine(name)
    resolved = eng.name  # pallas may have degraded to batch
    # warm up: registers the table and compiles/jits the kernel wrappers so
    # the timed calls see the steady-state dispatch cost, not compile time
    eng.estimate(qb, pq, ids_small)
    eng.estimate(qb, pq, ids_big)
    eng.refine_ids(qb, pq, ids_big)

    t_small = _best_of(lambda: eng.estimate(qb, pq, ids_small), reps)
    t_big = _best_of(lambda: eng.estimate(qb, pq, ids_big), reps)
    row_s = max(t_big - t_small, 0.0) / max(big - 1, 1)
    dispatch_s = max(t_small - row_s, 1e-9)

    # same two-point fit over the exact fp32 path: refine_full is a dense
    # GEMV over a materialized vector matrix, dispatched differently from
    # the int4 table kernels (BLAS vs kernel launch)
    q = rng.standard_normal(d).astype(np.float32)
    vec_small = base[ids_small]
    vec_big = base[ids_big]
    eng.refine_full(q, vec_small)
    eng.refine_full(q, vec_big)
    tf_small = _best_of(lambda: eng.refine_full(q, vec_small), reps)
    tf_big = _best_of(lambda: eng.refine_full(q, vec_big), reps)
    full_row_s = max(tf_big - tf_small, 0.0) / max(big - 1, 1)
    full_dispatch_s = max(tf_small - full_row_s, 1e-9)

    # fused beam step: the same two-point fit over beam_step_many — the
    # single launch that scores, masks, merges, and selects the frontier.
    # The states/requests are prebuilt OUTSIDE the timed region (repeat
    # steps re-score the same rows against an already-visited mask: the
    # kernel work per row is identical, which is all the fit needs).
    st_small = eng.beam_new(64, n)
    st_big = eng.beam_new(64, n)
    rq_small = _beam_req(qb, pq, st_small, ids_small)
    rq_big = _beam_req(qb, pq, st_big, ids_big)
    eng.beam_step_many(qb, [rq_small])
    eng.beam_step_many(qb, [rq_big])
    tb_small = _best_of(lambda: eng.beam_step_many(qb, [rq_small]), reps)
    tb_big = _best_of(lambda: eng.beam_step_many(qb, [rq_big]), reps)
    beam_row_s = max(tb_big - tb_small, 0.0) / max(big - 1, 1)
    beam_step_s = max(tb_small - beam_row_s, 1e-9)

    # time ONLY register_index (the table pin), not engine construction:
    # registration is idempotent per engine, so each rep needs a fresh engine
    # — built outside the timed region
    upload_s = float("inf")
    for e in [distance_mod.get_engine(name) for _ in range(reps)]:
        t0 = time.perf_counter()
        e.register_index(qb)
        upload_s = min(upload_s, time.perf_counter() - t0)
    upload_s = max(upload_s, 1e-9)

    rec = {
        "backend": resolved,
        "batch_dispatch_s": dispatch_s,
        "full_dispatch_s": full_dispatch_s,
        "table_upload_s": upload_s,
        "beam_step_s": beam_step_s,
        "estimate_row_s": row_s,
        "full_row_s": full_row_s,
        "beam_row_s": beam_row_s,
        "n": n,
        "d": d,
        "big": big,
    }
    if resolved == "pallas":
        rec["pallas_interpret"] = bool(eng.interpret)
    return rec


def run(quick: bool = True, backends: list[str] | None = None) -> dict:
    if backends is None:
        backends = ["scalar", "batch"]
        if distance_mod.pallas_available():
            backends.append("pallas")
    n, big, reps = (4096, 1024, 3) if quick else (16384, 4096, 7)

    records = {}
    for name in backends:
        # keyed by requested name; apply_calibration looks up the RESOLVED
        # backend, so a pallas-degraded-to-batch run reads the "batch" row
        # (each record also carries the resolved name it measured)
        records[name] = calibrate_backend(name, n=n, big=big, reps=reps)

    rows = [
        [name, rec["backend"], f"{rec['batch_dispatch_s'] * 1e6:.2f}",
         f"{rec['full_dispatch_s'] * 1e6:.2f}",
         f"{rec['beam_step_s'] * 1e6:.2f}",
         f"{rec['estimate_row_s'] * 1e9:.1f}",
         f"{rec['table_upload_s'] * 1e6:.1f}"]
        for name, rec in records.items()
    ]
    text = common.fmt_table(
        ["backend", "resolved", "dispatch us", "full us", "beam us",
         "row ns", "upload us"], rows
    )

    # sanity: the ordering argument of the paper — a kernel-launch dispatch
    # costs more than a ufunc dispatch, and pinning tables on the device
    # (device_put) costs more than aliasing host views — the one-time price
    # register-once pays so the per-hop path never re-uploads
    checks = {
        "dispatch_positive": all(
            r["batch_dispatch_s"] > 0 for r in records.values()
        ),
        "upload_positive": all(
            r["table_upload_s"] > 0 for r in records.values()
        ),
        "full_dispatch_positive": all(
            r["full_dispatch_s"] > 0 for r in records.values()
        ),
        "beam_step_positive": all(
            r["beam_step_s"] > 0 for r in records.values()
        ),
    }
    if "pallas" in records and records["pallas"]["backend"] == "pallas":
        checks["pallas_dispatch_heavier_than_batch"] = (
            records["pallas"]["batch_dispatch_s"]
            > records["batch"]["batch_dispatch_s"]
        )
        checks["pallas_upload_heavier_than_host_view"] = (
            records["pallas"]["table_upload_s"]
            > records["batch"]["table_upload_s"]
        )

    out = {"name": "calibration", "records": records, "text": text,
           "checks": checks}
    os.makedirs(common.OUT_DIR, exist_ok=True)
    path = os.path.join(common.OUT_DIR, "calibration.json")
    with open(path, "w") as f:
        json.dump(records, f, indent=1, default=float)
    out["path"] = path
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small index, few reps (the default)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--backends", default=None,
                    help="comma-separated subset (default: all available)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any sanity check fails")
    args = ap.parse_args()
    backends = args.backends.split(",") if args.backends else None
    res = run(quick=not args.full, backends=backends)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    print(f"overrides -> {res['path']}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
