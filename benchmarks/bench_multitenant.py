"""Multi-tenant serving plane: shared pool vs static partition under skew.

N tenants (independent indexes) are hosted on ONE engine (core.serving).  The
experiment drives a zipfian hot-tenant arrival mix and a bursty mix through
two pool planes at the same total byte budget:

  * shared    — one RecordBufferPool spanning all tenants (global clock);
  * partition — each tenant statically owns its isolated-system pool size.

Claims checked: under skew the shared pool serves the HOT tenant strictly
better than its static share (idle tenants' cold slots are lent to the busy
one) and no tenant's recall moves; per-tenant soft quotas cap the hot
tenant's slot ownership while staying eviction-safe; with the fused distance
plane one rendezvous flush spans tenants (cross-tenant fusion); the shared-
rendezvous flush/I-O overlap engages at multiple workers without disturbing
recall.

``--sla`` runs the scheduling experiment instead: a bursty OVERLOAD arrival
mix (open-loop qps above plane capacity, per-query deadlines) through the
same plane under ``scheduler="rr"`` (static beam width, FIFO — the
baseline) and ``scheduler="sla"`` (EDF admission/ready ordering + the
feedback controller steering beam width, fuse budget and tenant quota).
Claim checked: sla strictly beats rr on deadline hit-rate at equal recall,
with p99 measured from ARRIVAL (queue wait included).

Standalone:  python -m benchmarks.bench_multitenant [--full] [--strict] [--sla]
(--strict exits non-zero when any claim check fails, same contract as
benchmarks/run.py --strict.)
"""

from __future__ import annotations

import argparse

from benchmarks import common
from repro.core import baselines
from repro.core import workload as workload_mod
from repro.core.serving import ServingPlane, TenantSpec, evaluate_plane


def _tenants(quick: bool) -> list[TenantSpec]:
    if quick:
        dims = dict(n=2500, d=64, n_queries=200, R=20, L=40)
    else:
        dims = dict(n=8000, d=96, n_queries=400, R=24, L=48)
    specs = []
    for i in range(3):
        w = common.Workload(f"mt{i}", seed=i, **dims)
        specs.append(TenantSpec.from_dataset(f"tenant{i}", w.ds, w.graph, w.qb))
    return specs


def _plane_cfg(quick: bool, **kw) -> baselines.SystemConfig:
    kw.setdefault("buffer_ratio", 0.15)
    kw.setdefault("n_workers", 2 if quick else 4)
    kw.setdefault("batch_size", 8)
    return baselines.SystemConfig(**kw)


def run(quick: bool = True) -> dict:
    specs = _tenants(quick)
    n_q = [len(s.queries) for s in specs]
    n_ops = 300 if quick else 900
    zipf = workload_mod.zipfian_mix(n_q, n_ops, s=1.6, seed=0)
    bursty = workload_mod.bursty_mix(n_q, n_ops, mean_burst=12, s=1.2, seed=0)

    results: dict[str, dict] = {}
    for wname, wload in [("zipf", zipf), ("bursty", bursty)]:
        for mode, shared in [("shared", True), ("partition", False)]:
            plane = ServingPlane(specs, _plane_cfg(quick), shared_pool=shared)
            results[f"{wname}/{mode}"] = evaluate_plane(plane, wload)

    # per-tenant soft quota: cap every tenant at 40% of the shared pool
    quota_plane = ServingPlane(
        specs, _plane_cfg(quick, tenant_quota=0.4), shared_pool=True
    )
    results["zipf/quota40"] = evaluate_plane(quota_plane, zipf)
    quota_plane.pool.check_invariants()  # accounting == ownership, post-run
    quota_owned = [int(x) for x in quota_plane.pool.tenant_owned]
    quota_cap = int(quota_plane.pool.tenant_cap[0])

    # fused distance plane across tenants + flush/I-O overlap
    for name, extra in [
        ("fused", dict(fuse=True, fuse_rows=128, shared_rendezvous=True)),
        ("fused+overlap", dict(fuse=True, fuse_rows=128,
                               shared_rendezvous=True, overlap_flush=True)),
    ]:
        plane = ServingPlane(specs, _plane_cfg(quick, **extra), shared_pool=True)
        results[f"zipf/{name}"] = evaluate_plane(plane, zipf)

    tenant_names = [s.name for s in specs]
    hot = tenant_names[int(zipf.counts().argmax())]

    rows = []
    for key, res in results.items():
        t = res["tenants"]
        rows.append([
            key, res["workload"],
            f"{res['qps']:.0f}",
            f"{res['hit_rate']:.1%}",
            "  ".join(f"{t[n]['hit_rate']:.1%}" for n in tenant_names),
            "  ".join(f"{t[n]['recall@k']:.3f}" for n in tenant_names),
            res["cross_tenant_flushes"], res["overlap_flushes"],
            res["quota_reclaims"],
        ])
    text = common.fmt_table(
        ["config", "mix", "QPS", "hit", "hit/tenant", "recall/tenant",
         "xten", "ovlp", "reclaim"],
        rows,
    )
    text += (
        f"\n\nhot tenant: {hot}; quota40 slot ownership {quota_owned}"
        f" (cap {quota_cap}, pool {quota_plane.pool.n_slots})"
    )

    def hit(key, name):
        return results[key]["tenants"][name]["hit_rate"]

    def recalls(key):
        return [v["recall@k"] for v in results[key]["tenants"].values()]

    checks = {
        # the acceptance bar: under zipfian skew the shared pool serves the
        # hot tenant STRICTLY better than its static partition share
        "shared_hot_hit_beats_partition":
            hit("zipf/shared", hot) > hit("zipf/partition", hot),
        "shared_global_hit_no_worse":
            results["zipf/shared"]["hit_rate"]
            >= results["zipf/partition"]["hit_rate"],
        # sharing the pool must not cost anyone recall
        "recall_floor_all_modes": all(
            r > 0.6 for key in results for r in recalls(key)
        ),
        # soft quotas: the cap binds (reclaims happened), ownership respects
        # it, and admissions degrade to uncached instead of erroring
        "quota_cap_respected": all(o <= quota_cap for o in quota_owned),
        "quota_reclaims_active":
            results["zipf/quota40"]["quota_reclaims"] > 0,
        # one rendezvous flush spans tenants (combined-table routing)
        "cross_tenant_fusion_active":
            results["zipf/fused"]["cross_tenant_flushes"] > 0,
        # the flush/I-O overlap engages at multiple workers, recall unmoved
        "overlap_engages":
            results["zipf/fused+overlap"]["overlap_flushes"] > 0,
        "overlap_recall_parity": all(
            abs(a - b) < 0.05 for a, b in
            zip(recalls("zipf/fused"), recalls("zipf/fused+overlap"))
        ),
    }
    return {
        "name": "multitenant_serving",
        "hot_tenant": hot,
        "results": results,
        "quota": {"owned": quota_owned, "cap": quota_cap},
        "text": text,
        "checks": checks,
    }


def run_sla(quick: bool = True) -> dict:
    """rr vs sla under bursty overload: same plane, same arrival schedule,
    same deadlines — only the scheduling policy differs.  The rr baseline
    keeps the static beam width (feedback off) but still gets deadline
    accounting, so the hit-rate comparison is apples-to-apples."""
    specs = _tenants(quick)
    n_q = [len(s.queries) for s in specs]
    n_ops = 240 if quick else 720
    # Open-loop overload: service time is ~0.9ms/query on the quick plane
    # (two workers -> ~2.2k qps capacity), so 4k qps builds a real backlog
    # and queue wait dominates the tail — the regime EDF + steering targets.
    qps = 4000.0 if quick else 6000.0
    sla_ms = 2.0
    wload = workload_mod.bursty_mix(
        n_q, n_ops, mean_burst=12, s=1.2, seed=0, qps=qps
    )

    common_kw = dict(fuse=True, fuse_rows=64, sla_ms=sla_ms)
    results: dict[str, dict] = {}
    for mode, extra in [
        ("rr", dict(scheduler="rr", sla_feedback=False)),
        ("sla", dict(scheduler="sla", sla_feedback=True)),
    ]:
        plane = ServingPlane(
            specs, _plane_cfg(quick, **common_kw, **extra), shared_pool=True
        )
        results[mode] = evaluate_plane(plane, wload)

    tenant_names = [s.name for s in specs]
    rows = []
    for mode, res in results.items():
        t = res["tenants"]
        rows.append([
            mode, res["workload"],
            f"{res['deadline_hit_rate']:.1%}",
            f"{res['p99_latency_ms']:.2f}",
            f"{res['mean_service_ms']:.2f}",
            f"{res['queue_wait_s'] * 1e3 / max(res['n_ops'], 1):.2f}",
            "  ".join(f"{t[n]['deadline_hit_rate']:.1%}" for n in tenant_names),
            "  ".join(f"{t[n]['recall@k']:.3f}" for n in tenant_names),
        ])
    text = common.fmt_table(
        ["scheduler", "mix", "ddl-hit", "p99ms", "svc-ms", "qwait-ms/q",
         "ddl-hit/tenant", "recall/tenant"],
        rows,
    )
    text += (
        f"\n\nopen-loop {qps:.0f} qps, sla {sla_ms:g} ms;"
        " p99 measured from arrival (queue wait included)"
    )

    def recalls(mode):
        return [v["recall@k"] for v in results[mode]["tenants"].values()]

    checks = {
        # the acceptance bar: EDF + feedback strictly beats static-B FIFO
        # on deadline hit-rate under the identical overload schedule
        "sla_beats_rr_deadline_hits":
            results["sla"]["deadline_hit_rate"]
            > results["rr"]["deadline_hit_rate"],
        # ...at equal recall: beam steering may not buy its hit-rate win by
        # giving up answer quality
        "sla_recall_parity": all(
            abs(a - b) < 0.05 for a, b in zip(recalls("rr"), recalls("sla"))
        ),
        "recall_floor": all(r > 0.6 for m in results for r in recalls(m)),
        # the latency bugfix: under overload the p99 must be dominated by
        # queue wait, i.e. visibly above the dispatch-relative service time
        "p99_includes_queue_wait":
            results["rr"]["queue_wait_s"] > 0.0
            and results["rr"]["p99_latency_ms"]
            > 2.0 * results["rr"]["mean_service_ms"],
        # sla must also not trade the tail away wholesale
        "sla_queue_wait_no_worse":
            results["sla"]["queue_wait_s"] <= results["rr"]["queue_wait_s"],
    }
    return {
        "name": "multitenant_sla",
        "results": results,
        "text": text,
        "checks": checks,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="quick profile (the default; kept explicit for CI)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any claim check fails")
    ap.add_argument("--sla", action="store_true",
                    help="run the rr-vs-sla scheduling experiment instead")
    args = ap.parse_args()
    if args.sla:
        res = run_sla(quick=not args.full)
    else:
        res = run(quick=not args.full)
    print(res["text"])
    ok = True
    for check, passed in res["checks"].items():
        ok &= bool(passed)
        print(f"  [{'PASS' if passed else 'FAIL'}] {check}")
    if args.strict and not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
