"""Paper Fig. 12: VeloANN vs fully in-memory Vamana at varying buffer ratios.

Claims checked: QPS approaches the in-memory index as the ratio grows
(paper: 0.73x/0.78x/0.92x at 10/30/50%); latency stays within a small
multiple."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    ratios = [0.1, 0.3, 0.5]
    pts = []

    mem_cfg = baselines.SystemConfig(
        batch_size=16, n_workers=2, params=baselines.SearchParams(L=48)
    )
    mem = baselines.build_system("inmemory", w.ds.base, w.graph, w.qb, mem_cfg)
    _, mem_stats = mem.run(w.ds.queries)

    for ratio in ratios:
        cfg = baselines.SystemConfig(
            buffer_ratio=ratio, batch_size=16, n_workers=2,
            params=baselines.SearchParams(L=48, W=4),
        )
        sys_ = baselines.build_system("velo", w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        pts.append({
            "ratio": ratio,
            "qps": stats.qps,
            "qps_frac_of_inmemory": stats.qps / max(mem_stats.qps, 1e-9),
            "latency_x_inmemory": stats.mean_latency_ms
            / max(mem_stats.mean_latency_ms, 1e-9),
            # shared-pool pressure: how hard the LOCKED-window machinery and
            # the clock work at this budget (tighter budget -> more churn)
            "lock_waits": stats.lock_waits,
            "coalesced_record_loads": stats.coalesced_record_loads,
            "group_admits": stats.group_admits,
            "clock_skips": stats.clock_skips,
        })

    rows = [[f"{p['ratio']:.0%}", f"{p['qps']:.0f}",
             f"{p['qps_frac_of_inmemory']:.2f}x",
             f"{p['latency_x_inmemory']:.2f}x",
             p["coalesced_record_loads"], p["group_admits"],
             p["clock_skips"]] for p in pts]
    rows.append(["in-memory", f"{mem_stats.qps:.0f}", "1.00x", "1.00x",
                 "-", "-", "-"])
    text = common.fmt_table(
        ["buffer ratio", "QPS", "QPS vs mem", "lat vs mem",
         "coalesced", "group admits", "clock skips"], rows)

    checks = {
        "qps_improves_with_ratio": pts[-1]["qps"] >= pts[0]["qps"],
        "approaches_inmemory": pts[-1]["qps_frac_of_inmemory"] > 0.4,
    }
    return {"name": "F12_buffer_ratio", "points": pts,
            "inmemory_qps": mem_stats.qps, "text": text, "checks": checks}
