"""Paper Fig. 14 (§5.5): breakdown — incrementally enable each technique.

Baseline -> +Async -> +Record -> +Prefetch -> +CBS, all on the co-placed
compressed layout, memory ratio 10% (paper's setting).  Claims checked:
async lifts throughput; record pool lifts it further and cuts I/O; CBS gets
the lowest latency of the async variants."""

from __future__ import annotations

from benchmarks import common
from repro.core import baselines


VARIANTS = ["baseline", "+async", "+record", "+prefetch", "+cbs"]


def run(quick: bool = True) -> dict:
    w = common.sift_like(quick)
    pts = []
    for name in VARIANTS:
        cfg = baselines.SystemConfig(
            buffer_ratio=0.1, batch_size=8,
            params=baselines.SearchParams(L=48, W=4),
        )
        sys_ = baselines.build_system(name, w.ds.base, w.graph, w.qb, cfg)
        _, stats = sys_.run(w.ds.queries)
        pts.append({"variant": name, "qps": stats.qps,
                    "latency_ms": stats.mean_latency_ms,
                    "ios_per_query": stats.ios_per_query,
                    "hit_rate": stats.hit_rate})

    rows = [[p["variant"], f"{p['qps']:.0f}", f"{p['latency_ms']:.2f}",
             f"{p['ios_per_query']:.1f}", f"{p['hit_rate']:.2f}"] for p in pts]
    text = common.fmt_table(["variant", "QPS", "latency ms", "IO/query", "hit"], rows)

    by = {p["variant"]: p for p in pts}
    checks = {
        "async_lifts_qps": by["+async"]["qps"] > 1.3 * by["baseline"]["qps"],
        "record_lifts_qps_further": by["+record"]["qps"] > by["+async"]["qps"],
        "record_cuts_io": by["+record"]["ios_per_query"]
        < by["+async"]["ios_per_query"],
        "cbs_lowest_latency_among_async": by["+cbs"]["latency_ms"]
        <= min(by[v]["latency_ms"] for v in ("+async", "+prefetch")) * 1.02,
        "full_velo_beats_baseline_qps": by["+cbs"]["qps"] > 2.0 * by["baseline"]["qps"],
    }
    return {"name": "F14_breakdown", "points": pts, "text": text, "checks": checks}
