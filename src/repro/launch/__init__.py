"""Launch layer: production meshes, dry-run driver, roofline, train/serve CLIs."""
