"""Serving CLI: the end-to-end VeloANN driver (the paper is a serving system).

Builds the compressed index over a synthetic corpus, then pushes a batched
query stream through the asynchronous engine and reports the paper's
metrics (QPS / latency / recall / IO / hit rate).

  PYTHONPATH=src python -m repro.launch.serve --n 20000 --d 128 --queries 500
"""

from __future__ import annotations

import argparse
import time

from repro.core import baselines, dataset, vamana
from repro.core.quant import RabitQuantizer


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=10000)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--queries", type=int, default=400)
    ap.add_argument("--system", default="velo",
                    choices=["velo", "diskann", "starling", "pipeann", "inmemory"])
    ap.add_argument("--buffer-ratio", type=float, default=0.2)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--L", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    t0 = time.time()
    print(f"[serve] generating corpus n={args.n} d={args.d} ...", flush=True)
    ds = dataset.make_dataset(n=args.n, d=args.d, n_queries=args.queries,
                              k=10, seed=args.seed)
    print(f"[serve] building Vamana graph ... ({time.time()-t0:.1f}s)", flush=True)
    graph = vamana.build_vamana(ds.base, R=32, L=64, seed=args.seed)
    qb = RabitQuantizer(args.d, seed=args.seed).fit_encode(ds.base)
    print(f"[serve] index built ({time.time()-t0:.1f}s); running {args.system} ...",
          flush=True)

    cfg = baselines.SystemConfig(
        buffer_ratio=args.buffer_ratio, batch_size=args.batch,
        n_workers=args.workers,
        params=baselines.SearchParams(L=args.L, W=4),
    )
    system = baselines.build_system(args.system, ds.base, graph, qb, cfg)
    out = baselines.evaluate(system, ds)
    print(f"[serve] system={out['system']} recall@10={out['recall@k']:.3f} "
          f"QPS={out['qps']:.0f} mean_lat={out['mean_latency_ms']:.2f}ms "
          f"p99={out['p99_latency_ms']:.2f}ms io/q={out['ios_per_query']:.1f} "
          f"hit={out['hit_rate']:.2f}")
    print(f"[serve] disk={out['disk_bytes']/1e6:.1f}MB "
          f"memory={out['memory_bytes']/1e6:.1f}MB "
          f"(origin {ds.base.nbytes/1e6:.1f}MB)")
    return out


if __name__ == "__main__":
    main()
