"""Roofline analysis over the dry-run manifests (EXPERIMENTS.md §Roofline).

Three terms per (arch x shape x mesh) cell, all in seconds per step:

  compute    = HLO_dot_FLOPs_per_device / 197e12        (v5e bf16 peak)
  memory     = HLO_bytes_per_device     / 819e9         (HBM bandwidth)
  collective = collective_bytes_per_device / 50e9       (ICI per link)

FLOPs and collective bytes are trip-count-corrected (hlo_analysis).  The
memory term uses fusion-boundary traffic of the CPU-backend HLO — an UPPER
bound on TPU HBM traffic (a TPU backend fuses more, and Pallas kernels keep
attention working sets in VMEM), flagged as such in the report.  The
roofline fraction reported for compute-dominated cells is
compute / max(terms); for bound cells the dominant term itself is the
optimization target of §Perf.

  python -m repro.launch.roofline [--markdown] [--multi-pod]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12       # bf16 per chip (TPU v5e)
HBM_BW = 819e9            # bytes/s per chip
ICI_BW = 50e9             # bytes/s per link

OUT_DIR = os.path.join(os.path.dirname(__file__), "out", "dryrun")


def model_flops_per_device(rec: dict) -> float:
    """6*N_active*D (train) or 2*N_active*D (inference fwd), per device."""
    m = rec.get("model")
    if not m:
        return 0.0
    n_act = m["active_params"]
    kind = rec.get("kind", "train")
    B = rec.get("global_batch", 0)
    S = rec.get("seq_len", 0)
    ndev = rec["n_devices"]
    if kind == "train":
        return 6.0 * n_act * B * S / ndev
    if kind == "prefill":
        return 2.0 * n_act * B * S / ndev
    if kind == "decode":
        return 2.0 * n_act * B / ndev
    return 0.0


def load_cells(multi_pod: bool | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(OUT_DIR, "*.json"))):
        rec = json.load(open(path))
        if multi_pod is not None and rec.get("multi_pod") != multi_pod:
            continue
        cells.append(rec)
    return cells


def analyze(rec: dict) -> dict:
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "multi_pod": rec["multi_pod"], "status": rec["status"],
                "reason": rec.get("reason", rec.get("error", ""))[:90]}
    c = rec["cost"]
    coll = rec["collectives"]
    t_compute = c["flops_per_device"] / PEAK_FLOPS
    t_memory = c["bytes_accessed_per_device"] / HBM_BW
    t_coll = coll["collective_bytes_per_device"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    useful = mf / max(c["flops_per_device"], 1e-9)
    frac = t_compute / max(terms[dominant], 1e-12)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "multi_pod": rec["multi_pod"],
        "status": "ok",
        "t_compute_s": t_compute, "t_memory_s": t_memory, "t_collective_s": t_coll,
        "dominant": dominant,
        "roofline_fraction": frac,
        "model_flops_per_device": mf,
        "useful_flops_ratio": useful,
        "mem_per_device_gib": rec["memory"]["peak_estimate_bytes"] / 2**30,
        "fits_hbm_16g": rec["memory"]["peak_estimate_bytes"] < 16 * 2**30,
    }


def bottleneck_note(row: dict) -> str:
    d = row["dominant"]
    if d == "memory":
        return "fuse/rematerialize: cut fusion-boundary traffic (attention mask + scan carries)"
    if d == "collective":
        return "reshard or overlap: reduce per-layer TP reductions / FSDP gathers"
    return "compute-bound: raise MFU via larger per-device tiles"


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | collective s | "
           "dominant | roofline frac | useful FLOP ratio | mem GiB | fits 16G |")
    sep = "|" + "---|" * 11
    out = [hdr, sep]
    for r in rows:
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | {'2pod' if r['multi_pod'] else '1pod'} | "
                f"— | — | — | skipped | — | — | — | {r.get('reason','')[:60]} |"
            )
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {'2pod' if r['multi_pod'] else '1pod'} | "
            f"{r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['dominant']} | {r['roofline_fraction']:.2f} | "
            f"{r['useful_flops_ratio']:.2f} | {r['mem_per_device_gib']:.2f} | "
            f"{'yes' if r['fits_hbm_16g'] else 'NO'} |"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--markdown", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all-meshes", action="store_true")
    args = ap.parse_args()

    mp = None if args.all_meshes else args.multi_pod
    rows = [analyze(r) for r in load_cells(mp)]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["multi_pod"]))
    if args.markdown:
        print(markdown_table(rows))
    else:
        for r in rows:
            if r["status"] != "ok":
                print(f"{r['arch']:24s} {r['shape']:12s} skipped: {r.get('reason','')[:60]}")
                continue
            print(
                f"{r['arch']:24s} {r['shape']:12s} {'2pod' if r['multi_pod'] else '1pod'} "
                f"C={r['t_compute_s']:.3g}s M={r['t_memory_s']:.3g}s X={r['t_collective_s']:.3g}s "
                f"dom={r['dominant']:10s} frac={r['roofline_fraction']:.2f} "
                f"useful={r['useful_flops_ratio']:.2f} mem={r['mem_per_device_gib']:.1f}GiB"
            )
    path = os.path.join(os.path.dirname(OUT_DIR), "roofline.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"\n-> {path}", flush=True)


if __name__ == "__main__":
    main()
