import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry run: lower + compile every (arch x shape x mesh) cell.

For each cell:
  * build the production mesh (16x16 single-pod / 2x16x16 multi-pod),
  * build the model + parameter/optimizer/cache partition specs,
  * jax.jit(step).lower(**ShapeDtypeStructs).compile()   (no allocation),
  * record memory_analysis(), cost_analysis(), and the collective schedule
    parsed from the partitioned HLO -> launch/out/dryrun/<cell>.json.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k [--multi-pod]
  python -m repro.launch.dryrun --all [--multi-pod] [--resume]
  python -m repro.launch.dryrun --arch veloann --shape serve_batch
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import hlo_analysis, mesh as mesh_mod, shapes as shapes_mod
from repro.models import model as Mod
from repro.models import sharding as Sh
from repro.train import optimizer as Opt
from repro.train import train_step as TS

OUT_DIR = os.path.join(os.path.dirname(__file__), "out", "dryrun")


# ----------------------------------------------------------- cache shardings


def cache_pspecs(model, caches_shape, dp, seq_len):
    """Partition specs for decode caches: batch over dp when divisible, else
    the KV sequence axis (long_500k), else the head/channel axis."""
    dp_size = 1
    mesh = Sh._ACTIVE["mesh"]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in dp:
        dp_size *= sizes[a]

    def spec(path, leaf):
        shape = leaf.shape
        names = [str(getattr(e, "key", getattr(e, "idx", e))) for e in path]
        stacked = "groups" in names
        off = 1 if stacked else 0
        field = names[-1]
        B = shape[off]
        out = [None] * len(shape)
        if field in ("k", "v", "ck", "cv"):
            S = shape[off + 2]
            if B % dp_size == 0 and B >= dp_size:
                out[off] = dp
            elif S % dp_size == 0:
                out[off + 2] = dp           # long-context: shard the sequence
            # KV heads never divide the 16-way model axis (kv in {1,4,8,12}),
            # so the model axis shards the SEQUENCE instead: decode attention
            # is a seq-reduction, XLA inserts the softmax partials' psum, and
            # per-device cache drops 16x (yi decode_32k 48 GiB -> ~3 GiB).
            if S % sizes.get("model", 1) == 0 and out[off + 2] is None:
                out[off + 2] = "model"
        elif field in ("conv", "ssm"):
            if B % dp_size == 0 and B >= dp_size:
                out[off] = dp
            elif shape[off + (2 if field == "conv" else 1)] % sizes.get("model", 1) == 0:
                out[off + (2 if field == "conv" else 1)] = "model"
        elif field in ("tshift", "wkv", "cshift"):
            if B % dp_size == 0 and B >= dp_size:
                out[off] = dp
            elif field == "wkv" and shape[off + 1] % sizes.get("model", 1) == 0:
                out[off + 1] = "model"
        return P(*out)

    return jax.tree_util.tree_map_with_path(spec, caches_shape)


# ------------------------------------------------------------------ the cell


def run_lm_cell(arch: str, shape: str, multi_pod: bool, microbatches: int | None,
                opt_name: str = "adamw", ce_chunk: int = 256) -> dict:
    cfg = configs.get(arch)
    reason = shapes_mod.skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "status": "skipped", "reason": reason}

    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    dp = mesh_mod.dp_axes(mesh)
    ndev = mesh_mod.n_devices(mesh)
    Sh.set_active_mesh(mesh, dp_axes=dp)

    model = Mod.build(cfg)
    cell = shapes_mod.input_specs(cfg, model, shape)

    params_shape = Mod.params_specs(model)
    pspecs = Sh.param_pspecs(params_shape)
    pspecs, degraded = Sh.check_divisible(params_shape, pspecs, mesh)
    psh = Sh.named(mesh, pspecs)

    t0 = time.time()
    if cell.kind == "train":
        opt_init, _ = Opt.OPTIMIZERS[opt_name]
        opt_shape = jax.eval_shape(opt_init, params_shape)
        ospecs = jax.tree.map(
            lambda leaf: P(), opt_shape
        )
        # moments mirror their parameter's sharding
        ospecs = {
            "m": pspecs, "v": pspecs,
            "step": P(),
        } if opt_name == "adamw" else ospecs
        osh = Sh.named(mesh, ospecs)

        batch_sh = {
            k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
            for k, v in cell.batch.items()
        }
        mb = microbatches or max(1, cell.global_batch // (ndev // dict(zip(mesh.axis_names, mesh.devices.shape))["model"]))

        def batch_shardings(ndim):
            return NamedSharding(mesh, P(None, dp, *([None] * (ndim - 2))))

        step_fn = TS.make_train_step(
            model, opt_name=opt_name, microbatches=mb, ce_chunk=ce_chunk,
            grad_pspecs=psh, batch_shardings=batch_shardings,
        )
        jitted = jax.jit(
            step_fn,
            in_shardings=(psh, osh, batch_sh),
            out_shardings=(psh, osh, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_shape, opt_shape, cell.batch)
    elif cell.kind == "prefill":
        batch_sh = {
            k: NamedSharding(mesh, P(dp, *([None] * (len(v.shape) - 1))))
            for k, v in cell.batch.items()
        }

        def prefill_fn(params, batch):
            return Mod.prefill(model, params, batch)

        jitted = jax.jit(
            prefill_fn,
            in_shardings=(psh, batch_sh),
            out_shardings=None,
        )
        lowered = jitted.lower(params_shape, cell.batch)
    else:  # decode
        cspecs = cache_pspecs(model, cell.caches, dp, cell.seq_len)
        csh = Sh.named(mesh, cspecs)
        B = cell.tokens.shape[0]
        tok_sh = NamedSharding(mesh, P(dp) if B % ndev == 0 or B >= 16 else P())

        def decode_fn(params, caches, tokens, pos):
            return Mod.decode_step(model, params, caches, tokens, pos)

        jitted = jax.jit(
            decode_fn,
            in_shardings=(psh, csh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, csh),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(
            params_shape, cell.caches, cell.tokens,
            jax.ShapeDtypeStruct((), jnp.int32),
        )
    lower_s = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0

    out = _collect(compiled, arch, shape, multi_pod, ndev, cfg)
    out.update(lower_s=round(lower_s, 1), compile_s=round(compile_s, 1),
               degraded_shardings=degraded[:20], kind=cell.kind,
               seq_len=cell.seq_len, global_batch=cell.global_batch)
    Sh.clear_active_mesh()
    return out


def run_veloann_cell(multi_pod: bool) -> dict:
    from repro.velo import dist_search
    from repro.velo.index import synthetic_specs

    vcfg = configs.get("veloann")
    mesh = mesh_mod.make_production_mesh(multi_pod=multi_pod)
    ndev = mesh_mod.n_devices(mesh)
    axes = mesh.axis_names

    per_shard = vcfg.corpus_size // ndev
    # sharded DeviceIndex: arrays carry a +1 sentinel row PER SHARD, so the
    # global array has ndev sentinel rows: n_global = ndev * (per_shard + 1)
    n_global = ndev * (per_shard + 1) - 1  # synthetic_specs adds the last +1
    idx = synthetic_specs(n_global, vcfg.dim, vcfg.R)
    offsets = jax.ShapeDtypeStruct((ndev,), jnp.int32)
    queries = jax.ShapeDtypeStruct((vcfg.query_batch, vcfg.dim), jnp.float32)

    search = dist_search.make_distributed_search(
        mesh, axes, mode=vcfg.mode, L=vcfg.rerank, k=vcfg.k, interpret=False,
    )
    # scan mode has no Pallas on CPU target: route through the jnp path by
    # monkey-free flag — dist_search(mode="scan") calls binary_ip with
    # interpret flag; interpret=False would build a TPU kernel. For the CPU
    # dry-run we lower the jnp reference path instead:
    search = dist_search.make_distributed_search(
        mesh, axes, mode="scan_ref", L=vcfg.rerank, k=vcfg.k,
    )

    t0 = time.time()
    lowered = jax.jit(search).lower(idx, offsets, queries)
    lower_s = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    compile_s = time.time() - t0
    out = _collect(compiled, "veloann", "serve_batch", multi_pod, ndev, None)
    out.update(lower_s=round(lower_s, 1), compile_s=round(compile_s, 1),
               kind="serve", seq_len=0, global_batch=vcfg.query_batch)
    return out


def _collect(compiled, arch, shape, multi_pod, ndev, cfg) -> dict:
    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis()
    xla_flops = float(ca.get("flops", 0.0)) if isinstance(ca, dict) else 0.0
    xla_bytes = float(ca.get("bytes accessed", 0.0)) if isinstance(ca, dict) else 0.0
    hlo = compiled.as_text()
    coll = hlo_analysis.collective_stats(hlo, ndev)
    cost = hlo_analysis.cost_stats(hlo, ndev)

    rec = {
        "arch": arch,
        "shape": shape,
        "multi_pod": multi_pod,
        "status": "ok",
        "n_devices": ndev,
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_estimate_bytes": ma.argument_size_in_bytes
            + ma.output_size_in_bytes
            + ma.temp_size_in_bytes
            - ma.alias_size_in_bytes,
        },
        "cost": {
            # trip-count-corrected (hlo_analysis); XLA's raw numbers kept for
            # reference (they count while bodies once — see hlo_analysis doc)
            "flops_per_device": cost["flops_per_device"],
            "bytes_accessed_per_device": cost["bytes_per_device"],
            "xla_flops_per_device_raw": xla_flops,
            "xla_bytes_per_device_raw": xla_bytes,
        },
        "collectives": coll,
        "hlo_chars": len(hlo),
    }
    if cfg is not None:
        rec["model"] = {
            "params": cfg.params_count(),
            "active_params": cfg.active_params_count(),
        }
    return rec


def cell_path(arch, shape, multi_pod):
    pod = "pod2" if multi_pod else "pod1"
    return os.path.join(OUT_DIR, f"{arch}__{shape}__{pod}.json")


def run_and_save(arch, shape, multi_pod, **kw):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = cell_path(arch, shape, multi_pod)
    try:
        if arch == "veloann":
            rec = run_veloann_cell(multi_pod)
        else:
            rec = run_lm_cell(arch, shape, multi_pod, kw.get("microbatches"))
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec = {
            "arch": arch, "shape": shape, "multi_pod": multi_pod,
            "status": "error", "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = rec["status"]
    extra = ""
    if status == "ok":
        mem = rec["memory"]["peak_estimate_bytes"] / 2**30
        extra = f" mem/dev={mem:.2f}GiB flops/dev={rec['cost']['flops_per_device']:.3g} compile={rec.get('compile_s')}s"
    print(f"[dryrun] {arch} {shape} {'pod2' if multi_pod else 'pod1'}: {status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--microbatches", type=int, default=None)
    args = ap.parse_args()

    cells: list[tuple[str, str, bool]] = []
    meshes = [False, True] if (args.both_meshes or args.all) else [args.multi_pod]
    if args.all:
        for arch in configs.all_archs():
            for shape in shapes_mod.SHAPES:
                for mp in meshes:
                    cells.append((arch, shape, mp))
        for mp in meshes:
            cells.append(("veloann", "serve_batch", mp))
    else:
        assert args.arch
        shapes = [args.shape] if args.shape else list(shapes_mod.SHAPES)
        if args.arch == "veloann":
            shapes = ["serve_batch"]
        for shape in shapes:
            for mp in meshes:
                cells.append((args.arch, shape, mp))

    for arch, shape, mp in cells:
        if args.resume and os.path.exists(cell_path(arch, shape, mp)):
            with open(cell_path(arch, shape, mp)) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    continue
        run_and_save(arch, shape, mp, microbatches=args.microbatches)


if __name__ == "__main__":
    main()
