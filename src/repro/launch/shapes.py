"""Assigned input shapes x per-arch input_specs (ShapeDtypeStruct stand-ins).

Shapes (assignment table):
  train_4k     seq_len=4096    global_batch=256   (training)
  prefill_32k  seq_len=32768   global_batch=32    (inference-prefill)
  decode_32k   seq_len=32768   global_batch=128   (decode: 1 new token, KV=32k)
  long_500k    seq_len=524288  global_batch=1     (long-context decode)

long_500k requires sub-quadratic attention: it RUNS for rwkv6 (SSM), jamba
(hybrid: Mamba + 32k-window attention) and gemma3 (5:1 local:global; the
global-layer KV shards over the data axis), and is SKIPPED for the pure
full-attention archs (yi, granite, tinyllama, kimi, dbrx, llava) and the
enc-dec whisper (30 s source bound) — per DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import model as Mod
from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

LONG_OK_FAMILIES = {"ssm", "hybrid"}
LONG_OK_ARCHS = {"gemma3-1b"}  # 5:1 local:global — dominated by O(w) layers


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    if shape == "long_500k":
        if cfg.family in LONG_OK_FAMILIES or cfg.name in LONG_OK_ARCHS:
            return None
        if cfg.family == "encdec":
            return "enc-dec (whisper): 30s source bound; no 500k decode"
        return "pure full-attention arch: 500k decode requires sub-quadratic attention"
    return None


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def cache_len_for(spec_window: int, seq_len: int) -> int:
    """KV slots for one layer: full layers hold seq_len; windowed layers hold a
    rolling buffer of window+1 rounded up to 128 for shardability."""
    if spec_window > 0:
        return min(_round_up(spec_window + 1, 128), _round_up(seq_len, 128))
    return seq_len


S = jax.ShapeDtypeStruct
I32 = jnp.int32
BF16 = jnp.bfloat16


def _batch_specs(cfg: ModelConfig, B: int, seq: int) -> dict:
    text = seq
    out = {}
    if cfg.frontend == "vision":
        text = max(16, seq - cfg.frontend_tokens)
        out["patches"] = S((B, cfg.frontend_tokens, cfg.d_model), BF16)
    if cfg.n_encoder_layers:
        out["frames"] = S((B, cfg.encoder_tokens, cfg.d_model), BF16)
    out["tokens"] = S((B, text), I32)
    out["labels"] = S((B, text), I32)
    return out


def decode_cache_specs(model: Mod.Model, B: int, seq_len: int):
    """ShapeDtypeStructs for decode caches at the given context length."""
    cfg = model.cfg

    def one(spec):
        if spec.kind == "attn":
            klen = cache_len_for(spec.window, seq_len)
            c = {
                "k": S((B, cfg.n_kv_heads, klen, cfg.d_head), BF16),
                "v": S((B, cfg.n_kv_heads, klen, cfg.d_head), BF16),
            }
            if spec.cross:
                c["ck"] = S((B, cfg.n_kv_heads, cfg.encoder_tokens, cfg.d_head), BF16)
                c["cv"] = S((B, cfg.n_kv_heads, cfg.encoder_tokens, cfg.d_head), BF16)
            return c
        if spec.kind == "mamba":
            return {
                "conv": S((B, cfg.ssm_conv - 1, cfg.d_inner), BF16),
                "ssm": S((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
            }
        if spec.kind == "rwkv":
            dh = cfg.d_model // cfg.n_heads
            return {
                "tshift": S((B, cfg.d_model), jnp.float32),
                "wkv": S((B, cfg.n_heads, dh, dh), jnp.float32),
                "cshift": S((B, cfg.d_model), jnp.float32),
            }
        raise ValueError(spec.kind)

    prefix = tuple(one(s) for s in model.prefix_specs)
    groups = 0
    if model.n_groups:
        per_group = tuple(one(s) for s in model.group_specs)
        groups = jax.tree.map(
            lambda x: S((model.n_groups,) + x.shape, x.dtype), per_group
        )
    return {"prefix": prefix, "groups": groups}


@dataclasses.dataclass
class CellSpec:
    kind: str                   # train | prefill | decode
    batch: dict                 # ShapeDtypeStructs of batch inputs
    caches: object = None       # decode only
    tokens: object = None       # decode only: (B,) int32
    pos: int = 0                # decode only: write index
    seq_len: int = 0
    global_batch: int = 0


def input_specs(cfg: ModelConfig, model: Mod.Model, shape: str) -> CellSpec:
    sh = SHAPES[shape]
    B, seq = sh["global_batch"], sh["seq_len"]
    if sh["kind"] in ("train", "prefill"):
        return CellSpec(
            kind=sh["kind"],
            batch=_batch_specs(cfg, B, seq),
            seq_len=seq,
            global_batch=B,
        )
    # decode: one new token against a KV cache of seq_len
    return CellSpec(
        kind="decode",
        batch={},
        caches=decode_cache_specs(model, B, seq),
        tokens=S((B,), I32),
        pos=seq - 1,
        seq_len=seq,
        global_batch=B,
    )
