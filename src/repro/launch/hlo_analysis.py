"""Parse compiled (SPMD-partitioned) HLO text for collective traffic and
trip-count-corrected FLOPs / HBM bytes.

Why this exists: `compiled.cost_analysis()` visits while bodies ONCE — a
64-iteration lax.scan reports 1/64th of the true FLOPs (verified
empirically) — and it reports no collective traffic at all.  Scan-over-layers
models (every model here) therefore need their loop bodies re-multiplied.

Method:
  * computations are segmented from the HLO text; instruction defs are
    indexed (name -> shape/bytes/operands);
  * while trip counts come from the `backend_config={"known_trip_count"...}`
    annotation (fallback: the largest s32 constant in the loop condition);
  * two execution-count maps are propagated from the entry:
      mult_exec — through while/call/conditional edges (memory-level
                  computations; fusion bodies excluded so HBM bytes are
                  counted once, at the fusion boundary)
      mult_all  — additionally through fusion `calls=` edges (dot ops live
                  inside wrapped fusion computations on the CPU backend)
  * per-device collective wire bytes use ring-algorithm accounting:
      all-gather        out_bytes * (n-1)/n
      all-reduce        2 * in_bytes * (n-1)/n
      reduce-scatter    in_bytes * (n-1)/n
      all-to-all        in_bytes * (n-1)/n
      collective-permute in_bytes
    with n = replica-group size parsed from the instruction.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_INSTR_HEAD = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*")
_OP_AFTER_SHAPE = re.compile(r"\s*([\w\-]+)\(")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)
_DOT_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_TRIP = re.compile(r'known_trip_count[^0-9]*(\d+)')


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(text: str) -> list[int]:
    m = _SHAPE_RE.search(text)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


def _group_size(line: str, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


@dataclasses.dataclass
class Instr:
    name: str
    shape_txt: str
    op: str
    operands: list[str]
    line: str

    @property
    def out_bytes(self) -> int:
        return _shape_bytes(self.shape_txt)


@dataclasses.dataclass
class Comp:
    name: str
    instrs: list[Instr]
    param_shapes: dict[str, str]  # param name -> shape text
    is_entry: bool = False


def _parse(hlo: str) -> dict[str, Comp]:
    comps: dict[str, Comp] = {}
    current: Comp | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        # computation header: "%name (args) -> result {" at column 0
        # (instructions are indented; args may nest parens and contain
        # /*index=N*/ comments)
        if (
            stripped.endswith("{")
            and "->" in stripped
            and not line.startswith(" ")
            and not stripped.startswith("HloModule")
            and (stripped.startswith("%") or stripped.startswith("ENTRY"))
        ):
            is_entry = stripped.startswith("ENTRY")
            body = stripped[len("ENTRY"):].strip() if is_entry else stripped
            name = body.split("(", 1)[0].strip().lstrip("%").strip()
            args_txt = body.split("(", 1)[1].rsplit(") ->", 1)[0]
            param_shapes: dict[str, str] = {}
            # split top-level commas (tuple shapes nest parens)
            depth = 0
            cur = ""
            parts = []
            for ch in args_txt:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                if ch == "," and depth == 0:
                    parts.append(cur)
                    cur = ""
                else:
                    cur += ch
            if cur.strip():
                parts.append(cur)
            for part in parts:
                if ":" in part:
                    nm, shp = part.split(":", 1)
                    param_shapes[nm.strip().lstrip("%")] = shp.strip()
            current = Comp(name, [], param_shapes, is_entry)
            comps[name] = current
            continue
        if current is None:
            continue
        mh = _INSTR_HEAD.match(line)
        if mh:
            name = mh.group(1)
            rest = line[mh.end():]
            # result shape: either a tuple "(...)" (may contain /*index=N*/
            # comments) or "dtype[dims]{layout}" — balanced-scan the tuple.
            if rest.startswith("("):
                depth = 0
                for pos, ch in enumerate(rest):
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            break
                shape_txt = rest[: pos + 1]
                rest = rest[pos + 1:]
            else:
                ms = re.match(r"[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?", rest)
                if not ms:
                    continue
                shape_txt = ms.group(0)
                rest = rest[ms.end():]
            mo = _OP_AFTER_SHAPE.match(rest)
            if not mo:
                continue
            op = mo.group(1)
            rest = rest[mo.end():]
            depth = 1
            args = ""
            for ch in rest:
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                args += ch
            operands = re.findall(r"%([\w.\-]+)", args)
            current.instrs.append(Instr(name, shape_txt, op, operands, line))
    return comps


def _shape_of(name: str, comp: Comp) -> str:
    for i in comp.instrs:
        if i.name == name:
            return i.shape_txt
    return comp.param_shapes.get(name, "")


def _bytes_of(name: str, comp: Comp) -> int:
    return _shape_bytes(_shape_of(name, comp))


def _trip_count(instr: Instr, comps: dict[str, Comp]) -> int:
    m = _TRIP.search(instr.line)
    if m:
        return int(m.group(1))
    mcond = re.search(r"condition=%?([\w.\-]+)", instr.line)
    best = 1
    if mcond and mcond.group(1) in comps:
        for i in comps[mcond.group(1)].instrs:
            if i.op == "constant":
                mc = re.search(r"constant\((\d+)\)", i.line)
                if mc:
                    best = max(best, int(mc.group(1)))
    return best


def _multipliers(comps: dict[str, Comp], include_fusions: bool) -> dict[str, float]:
    entry = None
    for c, comp in comps.items():
        if comp.is_entry:
            entry = c
    if entry is None and comps:
        entry = next(iter(comps))

    mult: dict[str, float] = defaultdict(float)
    stack = [(entry, 1.0)]
    visited = set()
    while stack:
        comp_name, m = stack.pop()
        if comp_name not in comps:
            continue
        mult[comp_name] += m
        key = (comp_name, m)
        if key in visited:
            continue
        visited.add(key)
        for i in comps[comp_name].instrs:
            if i.op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", i.line)
                if mb:
                    stack.append((mb.group(1), m * _trip_count(i, comps)))
                mc = re.search(r"condition=%?([\w.\-]+)", i.line)
                if mc:
                    stack.append((mc.group(1), m * _trip_count(i, comps)))
            elif i.op in ("call", "custom-call", "async-start"):
                mt = re.search(r"to_apply=%?([\w.\-]+)", i.line)
                if mt:
                    stack.append((mt.group(1), m))
            elif i.op == "conditional":
                for mt in re.finditer(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)", i.line
                ):
                    stack.append((mt.group(1), m))
            elif i.op == "fusion" and include_fusions:
                mt = re.search(r"calls=%?([\w.\-]+)", i.line)
                if mt:
                    stack.append((mt.group(1), m))
            elif i.op in ("reduce", "reduce-window", "sort", "scatter", "map") and include_fusions:
                mt = re.search(r"to_apply=%?([\w.\-]+)", i.line)
                if mt:
                    stack.append((mt.group(1), m))
    return mult


# ------------------------------------------------------------------- public


def cost_stats(hlo: str, total_devices: int) -> dict:
    """Trip-count-corrected per-device dot-FLOPs and fusion-boundary HBM bytes."""
    comps = _parse(hlo)
    mult_all = _multipliers(comps, include_fusions=True)
    mult_exec = _multipliers(comps, include_fusions=False)

    _SKIP_BYTES = {
        "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
        "while", "call", "conditional", "after-all", "partition-id",
        "get-dimension-size",
    }

    flops = 0.0
    bytes_hbm = 0.0
    dot_count = 0.0
    for cname, comp in comps.items():
        ma = mult_all.get(cname, 0.0)
        me = mult_exec.get(cname, 0.0)
        for i in comp.instrs:
            if i.op == "dot" and ma > 0:
                out_elems = 1
                for d in _first_shape_dims(i.shape_txt):
                    out_elems *= d
                k = 1
                mc = _DOT_CONTRACT.search(i.line)
                if mc and i.operands:
                    lhs_dims = _first_shape_dims(_shape_of(i.operands[0], comp))
                    for cd in mc.group(1).split(","):
                        if cd and int(cd) < len(lhs_dims):
                            k *= lhs_dims[int(cd)]
                flops += 2.0 * out_elems * k * ma
                dot_count += ma
            if me > 0 and i.op not in _SKIP_BYTES:
                op_bytes = [_bytes_of(o, comp) for o in i.operands]
                in_b = sum(op_bytes)
                out_b = i.out_bytes
                # slice-aware accounting: dynamic-(update-)slice touches only
                # the slice, not the aliased buffer — scan residual saves and
                # KV-cache writes were otherwise overcharged by the full
                # buffer size per step (measured 3 PiB of phantom traffic on
                # rwkv6 train_4k).  XLA names fusions by their ops.
                big = max(op_bytes, default=0)
                if "dynamic-update-slice" in i.name or i.op == "dynamic-update-slice":
                    in_b = in_b - big           # buffer aliased in place
                    out_b = max(out_b - big, 0)  # write = slice only
                elif "dynamic-slice" in i.name or i.op == "dynamic-slice":
                    in_b = in_b - big + out_b    # read = slice (+ indices)
                bytes_hbm += (in_b + out_b) * me

    return {
        "flops_per_device": flops,
        "bytes_per_device": bytes_hbm,
        "dot_instructions_executed": dot_count,
    }


def collective_stats(hlo: str, total_devices: int) -> dict:
    comps = _parse(hlo)
    mult = _multipliers(comps, include_fusions=False)

    per_op = defaultdict(float)
    counts = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for i in comp.instrs:
            base = i.op.replace("-start", "")
            if base not in _COLLECTIVES or i.op.endswith("-done"):
                continue
            n = _group_size(i.line, total_devices)
            in_bytes = sum(_bytes_of(o, comp) for o in i.operands)
            out_bytes = i.out_bytes
            frac = (n - 1) / max(n, 1)
            if base == "all-gather":
                wire = out_bytes * frac
            elif base == "all-reduce":
                wire = 2 * in_bytes * frac
            elif base == "reduce-scatter":
                wire = in_bytes * frac
            elif base == "all-to-all":
                wire = in_bytes * frac
            else:  # collective-permute
                wire = in_bytes
            per_op[base] += wire * m
            counts[base] += m

    return {
        "collective_bytes_per_device": sum(per_op.values()),
        "by_op": dict(per_op),
        "counts": dict(counts),
    }
