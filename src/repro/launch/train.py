"""Training CLI: real steps on synthetic data with checkpointing.

  PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt [--resume]

On this CPU container only reduced configs are practical; the same code path
drives the full configs on a real fleet (mesh via --mesh data,model sizes).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import model as Mod
from repro.train import checkpoint as Ckpt
from repro.train import data as Data
from repro.train import optimizer as Opt
from repro.train import train_step as TS


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--opt", default="adamw", choices=["adamw", "adamw8"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at-step", type=int, default=None,
                    help="inject a crash (fault-tolerance testing)")
    args = ap.parse_args(argv)

    cfg = configs.get(args.arch, reduced=args.reduced)
    model = Mod.build(cfg)
    opt_cfg = Opt.OptConfig(lr=args.lr, total_steps=args.steps, warmup_steps=max(1, args.steps // 20))
    step_fn = jax.jit(TS.make_train_step(
        model, opt_name=args.opt, opt_cfg=opt_cfg,
        microbatches=args.microbatches, ce_chunk=64,
    ))
    init_fn = TS.make_init(model, args.opt)

    dcfg = Data.DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed,
    )

    start_step = 0
    params, opt_state = init_fn(jax.random.key(args.seed))
    if args.resume and args.ckpt_dir and Ckpt.latest_step(args.ckpt_dir) is not None:
        state, start_step = Ckpt.restore(
            args.ckpt_dir, {"params": params, "opt": opt_state}
        )
        params, opt_state = state["params"], state["opt"]
        print(f"[train] resumed from step {start_step}", flush=True)

    losses = []
    t0 = time.time()
    for step in range(start_step, args.steps):
        if args.fail_at_step is not None and step == args.fail_at_step:
            print(f"[train] INJECTED FAILURE at step {step}", flush=True)
            raise SystemExit(42)
        batch = Data.batch_for_step(dcfg, step)
        batch = {k: jnp.asarray(v) for k, v in batch.items() if not k.startswith("_")}
        if cfg.frontend == "vision":
            rng = np.random.default_rng(step)
            batch["patches"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.frontend_tokens, cfg.d_model)),
                jnp.bfloat16)
        if cfg.n_encoder_layers:
            rng = np.random.default_rng(step)
            batch["frames"] = jnp.asarray(
                rng.standard_normal((args.batch, cfg.encoder_tokens, cfg.d_model)),
                jnp.bfloat16)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {losses[-1]:.4f} "
                  f"lr {float(metrics['lr']):.2e} gnorm {float(metrics['grad_norm']):.2f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            Ckpt.save(args.ckpt_dir, step + 1, {"params": params, "opt": opt_state})

    if args.ckpt_dir:
        Ckpt.save(args.ckpt_dir, args.steps, {"params": params, "opt": opt_state})
    first = np.mean(losses[:5]) if len(losses) >= 5 else losses[0]
    last = np.mean(losses[-5:])
    print(f"[train] loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})", flush=True)
    return losses


if __name__ == "__main__":
    main()
