"""Production meshes.  Functions, not module constants — importing this module
never touches jax device state (the dry-run sets the device-count XLA flag
before any jax import)."""

from __future__ import annotations

import jax


def _make_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; Auto is the old implicit default
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_test_mesh(n_devices: int = 8, model_par: int = 2):
    """Small virtual mesh for CI-grade distributed tests."""
    data = n_devices // model_par
    return _make_mesh((data, model_par), ("data", "model"))


def dp_axes(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def tp_axis(mesh) -> str:
    return "model"


def n_devices(mesh) -> int:
    import numpy as np

    return int(np.prod(mesh.devices.shape))
