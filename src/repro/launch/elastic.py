"""Fault-tolerant training driver: checkpoint/restart + straggler mitigation.

Runs repro.launch.train as a supervised subprocess; injects failures; proves
the run converges to the same loss trajectory as an uninterrupted run
(deterministic data by (host, step) makes this exact).  This is the
orchestration layer a 1000-node fleet needs: the supervisor is per-slice,
restart is from the atomic LATEST checkpoint, and the data pipeline's
deadline-skip (train/data.py StragglerTimeout) bounds the blast radius of a
slow host.

  PYTHONPATH=src python -m repro.launch.elastic --steps 60 --fail-at 25
"""

from __future__ import annotations

import argparse
import subprocess
import sys
import tempfile


def run_supervised(steps: int, fail_at: int | None, ckpt_dir: str,
                   arch: str = "tinyllama-1.1b", max_restarts: int = 3) -> int:
    base = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", arch, "--steps", str(steps),
        "--batch", "4", "--seq", "64",
        "--ckpt-dir", ckpt_dir, "--ckpt-every", "10", "--resume",
    ]
    restarts = 0
    injected = False
    while True:
        cmd = list(base)
        if fail_at is not None and not injected:
            cmd += ["--fail-at-step", str(fail_at)]
        proc = subprocess.run(cmd)
        if proc.returncode == 0:
            return restarts
        injected = True
        restarts += 1
        print(f"[elastic] worker died (rc={proc.returncode}); restart #{restarts}",
              flush=True)
        if restarts > max_restarts:
            raise RuntimeError("too many restarts")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--fail-at", type=int, default=25)
    ap.add_argument("--arch", default="tinyllama-1.1b")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        restarts = run_supervised(args.steps, args.fail_at, ckpt_dir, args.arch)
        print(f"[elastic] completed {args.steps} steps with {restarts} restart(s)",
              flush=True)


if __name__ == "__main__":
    main()
