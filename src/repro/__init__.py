"""repro — VeloANN-JAX: SSD-resident graph ANN reproduced as a multi-pod JAX framework.

Three planes (see DESIGN.md):
  * repro.core   — faithful host-plane reproduction (index, buffer pool, async runtime sim)
  * repro.velo   — TPU-native device plane (batched beam search, Pallas kernels)
  * repro.models — assigned LM architectures + training/serving substrate
"""

__version__ = "0.1.0"
