"""Synthetic LM data pipeline: deterministic, sharded, host-prefetched.

Streams (tokens, labels) batches from a seeded synthetic distribution with
learnable structure (a noisy affine next-token rule over the vocab), so a
real training run shows a falling loss (examples/train_lm.py).  Sharding is
by (host_id, step): every host generates only its slice, and any step can be
regenerated exactly — which is what makes checkpoint/restart and elastic
resharding deterministic (fault-tolerance tests rely on this).
"""

from __future__ import annotations

import dataclasses
import queue
import threading

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    noise: float = 0.15       # fraction of uniform-random tokens
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _gen_batch(cfg: DataConfig, step: int) -> dict:
    """The (host, step)-deterministic batch."""
    assert cfg.global_batch % cfg.n_hosts == 0
    local = cfg.global_batch // cfg.n_hosts
    rng = np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id])
    )
    V = cfg.vocab_size
    start = rng.integers(0, V, size=(local, 1))
    # affine walk: x_{t+1} = (a*x_t + b) % V with per-sequence (a, b)
    a = rng.integers(1, 8, size=(local, 1))
    b = rng.integers(0, V, size=(local, 1))
    toks = np.empty((local, cfg.seq_len + 1), dtype=np.int64)
    toks[:, 0:1] = start
    for t in range(cfg.seq_len):
        toks[:, t + 1] = (a[:, 0] * toks[:, t] + b[:, 0]) % V
    noise_mask = rng.random((local, cfg.seq_len + 1)) < cfg.noise
    noise_vals = rng.integers(0, V, size=(local, cfg.seq_len + 1))
    toks = np.where(noise_mask, noise_vals, toks)
    return {
        "tokens": toks[:, :-1].astype(np.int32),
        "labels": toks[:, 1:].astype(np.int32),
    }


class DataLoader:
    """Background-thread prefetcher with a straggler deadline.

    next_batch(timeout) raises StragglerTimeout if the pipeline can't deliver
    in time — launch/elastic.py's straggler mitigation skips to a freshly
    generated batch id instead of stalling the step (data-echo style skip)."""

    def __init__(self, cfg: DataConfig, prefetch: int = 4, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.step
        while not self._stop.is_set():
            batch = _gen_batch(self.cfg, step)
            batch["_step"] = step
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next_batch(self, timeout: float | None = None) -> dict:
        try:
            return self._q.get(timeout=timeout)
        except queue.Empty:
            raise StragglerTimeout(f"data stall > {timeout}s")

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)


class StragglerTimeout(TimeoutError):
    pass


def batch_for_step(cfg: DataConfig, step: int) -> dict:
    """Direct (non-threaded) deterministic access — restart/replay path."""
    return _gen_batch(cfg, step)
