"""Sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json       — step, flat leaf index, shapes/dtypes, mesh
            arrays.npz          — one entry per flattened leaf path
         <dir>/LATEST           — atomically updated pointer

Restore is *elastic*: arrays are loaded host-side and device_put with the
shardings of the CURRENT mesh, which may differ from the mesh that saved
them (tests/test_checkpoint.py round-trips 1-device -> mesh and mesh ->
smaller mesh).  Writes go to a temp dir + atomic rename so a killed process
never leaves a half-written checkpoint (launch/elastic.py kills mid-run to
prove it).
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path
        )
        out[key] = leaf
    return out, jax.tree.structure(tree)


def save(ckpt_dir: str, step: int, state: dict) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat, _ = _flatten(state)
    arrays = {}
    dtypes = {}
    for k, v in flat.items():
        a = np.asarray(v)
        dtypes[k] = str(a.dtype)
        if a.dtype.name == "bfloat16":  # npz can't round-trip ml_dtypes
            a = a.view(np.uint16)
        arrays[k] = a
    manifest = {
        "step": step,
        "leaves": {
            k: {"shape": list(a.shape), "dtype": dtypes[k]} for k, a in arrays.items()
        },
    }
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, ".LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(f"step_{step:08d}")
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))
    return final


def latest_step(ckpt_dir: str) -> int | None:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name, "manifest.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)["step"]


def restore(ckpt_dir: str, like: dict, shardings=None) -> tuple[dict, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). `shardings`: optional matching pytree of NamedSharding
    for elastic placement on the current mesh."""
    step = latest_step(ckpt_dir)
    assert step is not None, f"no checkpoint under {ckpt_dir}"
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    flat_like, _ = _flatten(like)
    flat_sh, _ = _flatten(shardings) if shardings is not None else ({}, None)
    restored = {}
    for key, ref in flat_like.items():
        arr = data[key]
        if manifest["leaves"].get(key, {}).get("dtype") == "bfloat16":
            import ml_dtypes

            arr = arr.view(ml_dtypes.bfloat16)
        assert tuple(arr.shape) == tuple(ref.shape), f"{key}: shape mismatch"
        if key in flat_sh:
            restored[key] = jax.device_put(arr, flat_sh[key])
        else:
            restored[key] = jax.numpy.asarray(arr)

    # unflatten by rebuilding along the original tree structure
    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(like)
    ordered = []
    for p, _ in leaves_with_path:
        key = "/".join(str(getattr(e, "key", getattr(e, "idx", e))) for e in p)
        ordered.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef, ordered), step
