"""The train step: loss -> grads -> optimizer, with microbatch accumulation.

`make_train_step` returns a pure function suitable for jit/pjit:
    (params, opt_state, batch) -> (params, opt_state, metrics)
Gradient accumulation scans over microbatches (keeps the per-microbatch
activation peak at 1/k of the full batch — required for train_4k to fit),
and an optional int8 gradient compression hook quantizes gradients before
the (XLA-inserted) cross-replica reduction.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models import model as Mod
from repro.train import optimizer as Opt


def _compress_grads_int8(grads):
    """Blockwise-int8 quantize-dequantize of gradients.  Placed between the
    backward pass and the optimizer so the all-reduce operates on values that
    survive int8 transport (1/4 the DCN bytes across pods when combined with
    reduce-scatter-in-int8 at the transport layer; here we model the
    numerics, the dry-run HLO shows the traffic)."""
    def qdq(g):
        q, s = Opt._q8(g.astype(jnp.float32))
        return Opt._dq8(q, s, g.shape).astype(g.dtype)
    return jax.tree.map(qdq, grads)


def make_train_step(
    model: Mod.Model,
    opt_name: str = "adamw",
    opt_cfg: Opt.OptConfig | None = None,
    microbatches: int = 1,
    ce_chunk: int = 512,
    compress_grads: bool = False,
    grad_pspecs=None,   # PartitionSpec tree matching params: keeps the grad
                        # accumulator sharded like the params (without this,
                        # XLA replicates the f32 accumulator and all-reduces
                        # FULL gradients inside the microbatch loop — measured
                        # 554 GiB/device of spurious all-reduce on tinyllama)
    batch_shardings=None,  # NamedSharding for one (microbatch, ...) batch leaf
                           # AFTER the (mb, per_mb, ...) reshape.  Microbatches
                           # are SCANNED over a statically reshaped leading
                           # axis — a dynamic_slice over the sharded batch axis
                           # would land each microbatch on one data shard and
                           # silently replicate the compute (measured 8.3x
                           # FLOPs on tinyllama before this fix).
):
    opt_cfg = opt_cfg or Opt.OptConfig()
    _, opt_update = Opt.OPTIMIZERS[opt_name]

    def loss_fn(params, batch):
        return Mod.forward_train(model, params, batch, ce_chunk=ce_chunk)

    def constrain_like_params(tree):
        if grad_pspecs is None:
            return tree
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, grad_pspecs
        )

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = constrain_like_params(grads)
        else:
            B = batch["tokens"].shape[0]
            assert B % microbatches == 0
            mb = B // microbatches

            def reshape_leaf(x):
                y = x.reshape(microbatches, mb, *x.shape[1:])
                if batch_shardings is not None:
                    y = jax.lax.with_sharding_constraint(
                        y, batch_shardings(y.ndim)
                    )
                return y

            xs = jax.tree.map(reshape_leaf, batch)

            def micro(carry, mb_batch):
                acc_loss, acc_grads = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_batch)
                g = constrain_like_params(g)
                acc_grads = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), acc_grads, g
                )
                acc_grads = constrain_like_params(acc_grads)
                return (acc_loss + l, acc_grads), None

            zero_grads = constrain_like_params(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            )
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0.0), zero_grads), xs
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        if compress_grads:
            grads = _compress_grads_int8(grads)

        params, opt_state, om = opt_update(params, grads, opt_state, opt_cfg)
        metrics = {"loss": loss, **om}
        return params, opt_state, metrics

    return train_step


def make_init(model: Mod.Model, opt_name: str = "adamw"):
    opt_init, _ = Opt.OPTIMIZERS[opt_name]

    def init(key):
        params = Mod.init_params(model, key)
        return params, opt_init(params)

    return init
