"""Optimizers: AdamW (fp32 moments) and AdamW8 (blockwise-int8 moments).

AdamW8 stores both moments as int8 with one fp32 absmax scale per 256-value
block — 2.25 bytes/param of optimizer state instead of 8.  This is what makes
the kimi-k2 (1T-param) train cell fit a 512-chip fleet's HBM (§Dry-run memory
table); quantization error is bounded by absmax scaling and empirically
converges within noise of fp32 Adam on the 20M-param example (examples/
train_lm.py --opt adamw8).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

BLOCK = 256


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    betas: tuple[float, float] = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), norm


# ------------------------------------------------------------------- AdamW


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**step)
        vh = v / (1 - b2**step)
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


# ----------------------------------------------------------- blockwise int8


def _q8(x32: jnp.ndarray):
    """fp32 (N,) -> (int8 codes (N,), fp32 scales (ceil(N/B),))."""
    n = x32.size
    pad = (-n) % BLOCK
    xp = jnp.pad(x32.reshape(-1), (0, pad)).reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(xp), axis=1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(xp / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray, shape) -> jnp.ndarray:
    x = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return x[:n].reshape(shape)


# Second moments span many orders of magnitude WITHIN a block (hot vs cold
# rows of an embedding), so absmax-int8 flushes cold entries to zero and the
# Adam denominator 1/(sqrt(0)+eps) explodes (observed: loss 5.9 -> 1000 on
# the reduced LM).  v is therefore quantized in LOG space: 255 levels over
# the block's log-range keeps relative error ~exp(range/254)-1 (~12% at 30
# nats) — harmless for the denominator.


def _q8log(v32: jnp.ndarray):
    n = v32.size
    pad = (-n) % BLOCK
    u = jnp.log(jnp.maximum(v32.reshape(-1), 1e-30))
    up = jnp.pad(u, (0, pad), constant_values=-69.0).reshape(-1, BLOCK)
    mn = up.min(axis=1)
    mx = up.max(axis=1)
    scale = jnp.maximum((mx - mn) / 254.0, 1e-12)
    q = jnp.clip(jnp.round((up - mn[:, None]) / scale[:, None]), 0, 254)
    return (q - 127).astype(jnp.int8), scale.astype(jnp.float32), mn.astype(jnp.float32)


def _dq8log(q: jnp.ndarray, scale: jnp.ndarray, mn: jnp.ndarray, shape) -> jnp.ndarray:
    u = (q.astype(jnp.float32) + 127.0) * scale[:, None] + mn[:, None]
    x = jnp.exp(u).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    out = x[:n].reshape(shape)
    return jnp.where(out <= 2e-30, 0.0, out)


def adamw8_init(params):
    def zeros_m(p):
        blocks = -(-p.size // BLOCK)
        return {
            "q": jnp.zeros((blocks, BLOCK), jnp.int8),
            "s": jnp.zeros((blocks,), jnp.float32),
        }

    def zeros_v(p):
        blocks = -(-p.size // BLOCK)
        return {
            "q": jnp.zeros((blocks, BLOCK), jnp.int8),
            "s": jnp.zeros((blocks,), jnp.float32),
            "mn": jnp.full((blocks,), -69.0, jnp.float32),  # log(~1e-30)
        }

    return {
        "m": jax.tree.map(zeros_m, params),
        "v": jax.tree.map(zeros_v, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw8_update(params, grads, state, cfg: OptConfig):
    step = state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)

    def upd(p, g, mq, vq):
        g = g.astype(jnp.float32)
        m = _dq8(mq["q"], mq["s"], p.shape)
        v = _dq8log(vq["q"], vq["s"], vq["mn"], p.shape)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / (1 - b1**step)
        vh = v / (1 - b2**step)
        delta = mh / (jnp.sqrt(jnp.maximum(vh, 0)) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        q_m, s_m = _q8(m)
        q_v, s_v, mn_v = _q8log(v)
        return new_p, {"q": q_m, "s": s_m}, {"q": q_v, "s": s_v, "mn": mn_v}

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = tdef.unflatten([o[0] for o in outs])
    new_m = tdef.unflatten([o[1] for o in outs])
    new_v = tdef.unflatten([o[2] for o in outs])
    return new_params, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}


OPTIMIZERS = {
    "adamw": (adamw_init, adamw_update),
    "adamw8": (adamw8_init, adamw8_update),
}
