"""Training substrate: optimizers, train step, data, checkpointing, fault tolerance."""
