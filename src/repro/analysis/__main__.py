"""CLI for the protocol verifier.

Lint mode (default):      python -m repro.analysis src/
Schedule-explore smoke:   python -m repro.analysis --explore --seed 1 --schedules 5

Lint mode runs the static AST passes over the given files/directories and
prints one ``file:line: [rule] message`` line per finding (exit 1 when any
fire).  ``--explore`` runs every search algorithm over a small clustered
workload under N permuted schedules with the dynamic protocol checker armed
and verifies the results are bitwise schedule-invariant (exit 1 on any
mismatch or protocol violation); tie counts are printed so a vacuous pass —
schedules that never had a choice to permute — is visible.
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static lint + schedule-exploring protocol verifier",
    )
    ap.add_argument("paths", nargs="*",
                    help="files/directories to lint (default: src)")
    ap.add_argument("--explore", action="store_true",
                    help="run the schedule-permutation smoke instead of lint")
    ap.add_argument("--schedules", type=int, default=5,
                    help="number of permuted schedules per algorithm")
    ap.add_argument("--seed", type=int, default=1,
                    help="first schedule seed (seeds run seed..seed+N-1)")
    ap.add_argument("--algorithms",
                    default="velo,diskann,starling,pipeann,inmemory",
                    help="comma-separated systems for --explore (velo runs "
                         "with the cache-aware pivot off — see explore.smoke)")
    args = ap.parse_args(argv)

    if args.explore:
        from repro.analysis.explore import smoke, smoke_sla

        algorithms = tuple(a for a in args.algorithms.split(",") if a)
        reports = smoke(algorithms=algorithms, n_schedules=args.schedules,
                        base_seed=args.seed)
        # The SLA scheduler leg: a pure-EDF serving plane under burst
        # arrivals, where equal deadlines create the slack ties to permute.
        reports.update(smoke_sla(n_schedules=args.schedules,
                                 base_seed=args.seed))
        failed = False
        for name, reps in reports.items():
            worker_ties = sum(r.ties["worker"] for r in reps)
            event_ties = sum(r.ties["event"] for r in reps)
            slack_ties = sum(r.ties.get("slack", 0) for r in reps)
            bad = [r for r in reps if not r.equal]
            verdict = "schedule-invariant" if not bad else "MISMATCH"
            print(f"{name}: {len(reps) - 1} schedule(s) explored, "
                  f"{worker_ties} worker tie(s), {event_ties} event tie(s), "
                  f"{slack_ties} slack tie(s) permuted -> {verdict}")
            for r in bad:
                failed = True
                print(f"  seed {r.seed}: {r.first_diff}")
        return 1 if failed else 0

    from repro.analysis.lint import run_lint

    paths = args.paths or ["src"]
    findings = run_lint(paths)
    for f in findings:
        print(f.format())
    if findings:
        print(f"{len(findings)} finding(s)", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
