"""Protocol verifier for the coroutine runtime and cache hierarchy.

Two layers (docs/verification.md):

  * static lint (``repro.analysis.lint``): AST passes over the source —
    op-registry/arity checks against ``registry.ENGINE_OPS``, LOCKED-window
    begin/finish/abort pairing, coroutine purity, determinism lints.  Never
    imports the code under check; runs as ``python -m repro.analysis src/``.
  * dynamic checker (``repro.analysis.protocol``): a trace validator armed
    by ``SystemConfig.verify_protocol`` that validates live pool/HBM slot
    transitions against the declarative spec (``repro.analysis.spec``), plus
    the bounded schedule explorer (``repro.analysis.explore``) that permutes
    the engine's scheduling ties and proves results schedule-invariant.
"""

from repro.analysis.lint import Finding, run_lint, run_lint_text  # noqa: F401
