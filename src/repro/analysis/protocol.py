"""Dynamic protocol checker: a trace validator for the cache hierarchy.

``ProtocolChecker`` attaches to live ``RecordBufferPool`` / ``HbmTier``
instances by shadowing their public methods with *instance attributes* that
snapshot the slot arrays around every call and validate the observed
(pre, post) diff against the declarative state machine in
``repro.analysis.spec``.  The wrapping is purely observational — results,
stats, and timing charges are untouched, which is why runs with
``SystemConfig.verify_protocol=True`` are bitwise-identical to unverified
runs (tests pin this).

Detectors:

  bad-transition    a slot moved along an edge the spec does not allow for
                    the event that moved it (e.g. FREE -> OCCUPIED inside
                    ``begin_load``), or an event swapped a slot's vid without
                    authority to reinstall.
  lost-wakeup       an event removed parked waiters without queueing the
                    same number of resumes, or waiters / queued resumes
                    survive the end of the run.
  double-publish    ``on_publish`` fired twice for a vid while it stayed
                    resident (the keep-first duplicate-admit rule says the
                    second install must not happen).
  slot-leak         structural invariants broken at a flush boundary: free
                    list vs slot states, mapping array vs occupancy, the
                    HBM record-map/slot bijection, or staging bookkeeping.
  quota-accounting  per-tenant ownership counters out of sync with actual
                    slot ownership, or a tenant past its cap.

Composite-edge note: one *call* may cover several micro-transitions (an
acquiring event runs the clock, then installs into the slot it just freed),
so acquiring events validate against the composite closure of their base
edges with the clock edges — see ``_pool_edges``.  The checker deliberately
avoids literal attribute access on the pool's protocol methods (everything
routes through ``getattr``/``setattr`` name loops) so that this module never
trips the static lint's pairing or purity rules on itself.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.analysis import spec


@dataclasses.dataclass
class Violation:
    rule: str       # detector name, e.g. "bad-transition"
    event: str      # the observed method / boundary that tripped it
    detail: str

    def format(self) -> str:
        return f"[{self.rule}] {self.event}: {self.detail}"


class ProtocolError(AssertionError):
    """Raised by ``raise_if_violations`` — an AssertionError so existing
    invariant-minded callers and pytest treat it uniformly."""


_MAX_VIOLATIONS = 200


def _pool_edges(name: str) -> frozenset[tuple[int, int]]:
    """Per-call allowed edges for a pool event: the spec's base edges, plus —
    for acquiring events only — the composites one call can legitimately
    produce by running the clock before installing (demote + evict lands
    OCCUPIED -> FREE; evicting the very slot it then installs into lands
    OCCUPIED/MARKED -> <install target>)."""
    base = spec.POOL_EVENTS[name]
    if name not in spec.ACQUIRING_EVENTS:
        return base
    if name == "admit_" + "group":
        # the one multi-acquisition pool event: a slot installed for an early
        # member can be demoted — even evicted — by a later member's sweep in
        # the SAME call, so any pair of non-LOCKED states composes.  LOCKED
        # stays inviolable: a pinned slot may not move, and no net transition
        # may land on LOCKED (the install window is transient).
        unlocked = (spec.FREE, spec.OCCUPIED, spec.MARKED)
        return frozenset(
            (a, b) for a in unlocked for b in unlocked if a != b
        )
    edges = set(base) | set(spec.CLOCK_EDGES)
    edges.add((spec.OCCUPIED, spec.FREE))
    installs = {post for pre, post in base if pre == spec.FREE}
    for src in (spec.OCCUPIED, spec.MARKED):
        for dst in installs:
            edges.add((src, dst))
    return frozenset(edges)


class ProtocolChecker:
    """Validates every observed slot transition against the declarative spec.

    Wire-up order matters when an HBM tier subscribes to the pool's publish
    hook: ``watch_hbm(tier)`` first (so the tier's staging entry points are
    shadowed), re-point the pool's hook at the tier's — now wrapped — method,
    then ``watch_pool(pool)`` (which chains the double-publish probe in
    front of whatever hook is installed).  ``build_system`` and the serving
    plane both follow this order.
    """

    def __init__(self, max_violations: int = _MAX_VIOLATIONS):
        self.violations: list[Violation] = []
        self.calls: dict[str, int] = {}   # event -> observed call count
        self.flushes = 0
        self.max_violations = max_violations
        self._pools: list[object] = []
        self._hbms: list[object] = []

    # ------------------------------------------------------------- reporting

    def ok(self) -> bool:
        return not self.violations

    def _record(self, rule: str, event: str, detail: str) -> None:
        if len(self.violations) < self.max_violations:
            self.violations.append(Violation(rule, event, detail))

    def raise_if_violations(self) -> None:
        if self.violations:
            lines = "\n  ".join(v.format() for v in self.violations)
            raise ProtocolError(
                f"{len(self.violations)} protocol violation(s):\n  {lines}"
            )

    # ------------------------------------------------------------- host pool

    def watch_pool(self, pool) -> None:
        """Shadow every spec'd pool event with a diff-validating wrapper and
        chain the double-publish probe in front of the publish hook."""
        self._pools.append(pool)
        published: set[int] = set()
        hook_name = "on_" + "publish"   # avoid the lint's literal-name rules
        prev = getattr(pool, hook_name)
        record = self._record

        def publish_probe(vid, rec, _prev=prev, _published=published):
            vid = int(vid)
            if vid in _published:
                record("double-publish", hook_name,
                       f"vid {vid} published twice while resident")
            _published.add(vid)
            if _prev is not None:
                _prev(vid, rec)

        setattr(pool, hook_name, publish_probe)
        for name in spec.POOL_EVENTS:
            self._wrap_pool_event(pool, name, published)

    def _wrap_pool_event(self, pool, name: str, published: set[int]) -> None:
        orig = getattr(pool, name)
        edges = _pool_edges(name)
        reinstall_ok = name in spec.ACQUIRING_EVENTS
        checker = self

        def wrapped(*args, **kwargs):
            pre_state = pool.state.copy()
            pre_vid = pool.slot_vid.copy()
            w0 = sum(len(ws) for ws in pool.waiters.values())
            p0 = len(pool.pending_resumes)
            result = orig(*args, **kwargs)
            checker.calls[name] = checker.calls.get(name, 0) + 1
            checker._check_slot_diff(
                name, edges, reinstall_ok,
                pre_state, pre_vid, pool.state, pool.slot_vid, published,
            )
            w1 = sum(len(ws) for ws in pool.waiters.values())
            p1 = len(pool.pending_resumes)
            if w1 < w0 and (p1 - p0) != (w0 - w1):
                checker._record(
                    "lost-wakeup", name,
                    f"{w0 - w1} waiter(s) removed but {max(0, p1 - p0)} "
                    f"resume(s) queued",
                )
            return result

        setattr(pool, name, wrapped)

    # ------------------------------------------------------------- HBM tier

    def watch_hbm(self, tier) -> None:
        """Shadow the tier's staging/lookup/scatter entry points.  Staging
        events must leave device slot state untouched (the double-buffering
        claim); only the dispatch-boundary scatter may install or sweep."""
        self._hbms.append(tier)
        for name in spec.HBM_EVENTS:
            self._wrap_hbm_event(tier, name)

    def _wrap_hbm_event(self, tier, name: str) -> None:
        orig = getattr(tier, name)
        edges = spec.HBM_EVENTS[name]
        reinstall_ok = name in spec.HBM_REINSTALL_EVENTS
        cache = tier.cache
        event = "hbm." + name
        checker = self

        def wrapped(*args, **kwargs):
            pre_state = cache.slot_state.copy()
            pre_vid = cache.slot_vid.copy()
            result = orig(*args, **kwargs)
            checker.calls[event] = checker.calls.get(event, 0) + 1
            checker._check_slot_diff(
                event, edges, reinstall_ok,
                pre_state, pre_vid, cache.slot_state, cache.slot_vid, None,
            )
            return result

        setattr(tier, name, wrapped)

    # ------------------------------------------------------ diff validation

    def _check_slot_diff(self, event, edges, reinstall_ok,
                         pre_state, pre_vid, post_state, post_vid,
                         published) -> None:
        changed = np.nonzero(
            (pre_state != post_state) | (pre_vid != post_vid)
        )[0]
        for s in changed:
            s = int(s)
            pre, post = int(pre_state[s]), int(post_state[s])
            old_vid, new_vid = int(pre_vid[s]), int(post_vid[s])
            if pre != post:
                if (pre, post) not in edges:
                    self._record(
                        "bad-transition", event,
                        f"slot {s}: {spec.STATE_NAMES.get(pre, pre)} -> "
                        f"{spec.STATE_NAMES.get(post, post)} not allowed",
                    )
            elif not reinstall_ok:
                # vid swapped under an unchanged state: only the composite
                # evict+reinstall of an acquiring event / the HBM scatter may
                self._record(
                    "bad-transition", event,
                    f"slot {s}: vid {old_vid} -> {new_vid} changed without "
                    f"a state transition",
                )
            if published is not None and old_vid != new_vid and old_vid >= 0:
                # the old vid left its slot (evicted/aborted): a future
                # re-publish of it is legitimate again
                published.discard(old_vid)

    # -------------------------------------------------- boundary invariants

    def at_flush(self) -> None:
        """Cheap invariant pass at every engine dispatch boundary."""
        self.flushes += 1
        for pool in self._pools:
            self._check_pool_invariants(pool, cheap=True)
        for tier in self._hbms:
            self._check_hbm_invariants(tier)

    def at_end(self) -> None:
        """Full structural pass once the run drains."""
        for pool in self._pools:
            self._check_pool_invariants(pool, cheap=False)
            if pool.waiters:
                n = sum(len(ws) for ws in pool.waiters.values())
                self._record(
                    "lost-wakeup", "at_end",
                    f"{n} waiter(s) still parked after the run drained",
                )
            if pool.pending_resumes:
                self._record(
                    "lost-wakeup", "at_end",
                    f"{len(pool.pending_resumes)} queued resume(s) never "
                    f"drained",
                )
        for tier in self._hbms:
            self._check_hbm_invariants(tier)

    def _check_pool_invariants(self, pool, cheap: bool) -> None:
        fn = getattr(pool, "check_" + "invariants")
        try:
            fn(cheap=cheap)
        except AssertionError as exc:
            msg = str(exc) or "structural invariant failed"
            low = msg.lower()
            if "waiter" in low:
                rule = "lost-wakeup"
            elif "tenant" in low or "quota" in low:
                rule = "quota-accounting"
            else:
                rule = "slot-leak"
            self._record(rule, "check_invariants", msg.splitlines()[0])

    def _check_hbm_invariants(self, tier) -> None:
        cache = tier.cache
        state = np.asarray(cache.slot_state)
        vids = np.asarray(cache.slot_vid)
        nonfree = state != spec.FREE
        if (vids[~nonfree] != -1).any():
            self._record("slot-leak", "hbm",
                         "FREE device slot still carries a vid")
            return
        held = vids[nonfree]
        if (held < 0).any():
            self._record("slot-leak", "hbm",
                         "non-FREE device slot carries no vid")
            return
        slots = np.nonzero(nonfree)[0]
        if (np.asarray(cache.record_map)[held] != slots).any():
            self._record("slot-leak", "hbm",
                         "device record_map does not point back at its slot")
        if int((np.asarray(cache.record_map) >= 0).sum()) != int(nonfree.sum()):
            self._record("slot-leak", "hbm",
                         "device residency count disagrees with slot states")
        staged_vids = [int(entry[0]) for entry in tier._staged]
        if (len(staged_vids) != len(tier._staged_set)
                or set(staged_vids) != tier._staged_set):
            self._record("slot-leak", "hbm-staging",
                         "staging list and dedup set out of sync")
