"""Layer-1 static lint: AST passes over the coroutine runtime and the cache
hierarchy.  NOTHING here imports the checked code — every rule works on the
parse tree alone, so the lint runs in CI even when the runtime's own imports
(jax, numpy) are broken, and a rule can never be fooled by monkeypatching.

Rules (each Finding carries the rule name and fires at ``file:line``):

  op-unknown      a ``yield ("name", ...)`` names an op the registry does not
                  declare (only in modules that speak the protocol — i.e.
                  that yield at least one registered op)
  op-arity        a yielded op tuple carries the wrong operand count
  op-dispatch     a dispatcher (a function comparing one variable against two
                  or more registered op names) misses registered ops, or
                  matches names that are neither ops nor scheduler event kinds
  begin-load-pairing
                  a ``begin_load`` call is not matched by a window closer
                  (``finish_load`` / ``abort_load`` / an admit) on every
                  control-flow path of its function
  publish-in-locked
                  an ``on_publish`` hook fires while the most recent slot
                  state written in the function is LOCKED (or before any
                  published state was established at all)
  blocking-call-in-coroutine
                  a module-level search coroutine (generator function outside
                  any class) calls a blocking pool/cache method directly
                  instead of yielding an engine op / going through an accessor
  wall-clock      ``time.time()``-style calls in ``repro.core`` sim paths
  unseeded-rng    ``np.random.<legacy>`` / zero-arg ``default_rng()`` /
                  stdlib ``random`` calls in ``repro.core`` sim paths
  set-iteration   a ``for`` loop over a set-typed local in ``repro.core``
                  (iteration order is implementation-defined; use a dict or
                  sort first)

Path-sensitivity of ``begin-load-pairing`` is deliberately lenient, with the
leniencies DOCUMENTED as part of the rule:

  1. a nested ``def`` that closes anywhere counts as closing at its def site
     (the completion-callback pattern: the closure runs when the I/O lands);
  2. a loop whose body closes counts as closing (the batch pattern: one
     closer per opened window, e.g. ``for v in missing: ... finish/admit``);
  3. closing is transitive through same-module helpers (a function whose own
     body always calls a closer is itself a closer — fixpoint);
  4. a ``begin_load`` whose enclosing statement is a ``return`` is pure
     delegation (a namespace-translating view), exempt from pairing;
  5. ``raise`` terminates a path acceptably (the window is torn down by the
     failing test/scenario, not leaked by the protocol).
"""

from __future__ import annotations

import ast
import dataclasses
import os

from repro.analysis.registry import (
    BLOCKING_POOL_METHODS,
    ENGINE_OPS,
    EVENT_KINDS,
    WINDOW_CLOSERS,
)

_WALL_CLOCK_CALLS = frozenset({
    "time.time", "time.time_ns", "time.clock",
    "time.perf_counter", "time.perf_counter_ns",
    "time.monotonic", "time.monotonic_ns", "time.process_time",
    "datetime.now", "datetime.utcnow", "datetime.today",
    "datetime.datetime.now", "datetime.datetime.utcnow", "date.today",
})


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


# ------------------------------------------------------------- tree helpers


def _dotted(node: ast.AST) -> str | None:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _terminal_name(node: ast.AST) -> str | None:
    """Last component of a Name/Attribute chain (``SlotState.LOCKED`` ->
    ``LOCKED``)."""
    if isinstance(node, ast.Attribute):
        return node.attr
    if isinstance(node, ast.Name):
        return node.id
    return None


def _own_scope(fn: ast.AST):
    """The nodes of a function's own scope, excluding nested function defs.
    Yields in source (pre)order — the set-iteration rule's rebinding tracking
    depends on seeing assignments in the order they execute."""
    stack = list(ast.iter_child_nodes(fn))[::-1]
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
            stack.extend(list(ast.iter_child_nodes(node))[::-1])


def _is_generator(fn: ast.AST) -> bool:
    return any(isinstance(n, (ast.Yield, ast.YieldFrom))
               for n in _own_scope(fn))


def _functions(tree: ast.AST):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


def _is_core_path(path: str) -> bool:
    """The determinism rules apply to the simulator proper."""
    norm = path.replace(os.sep, "/")
    return "repro/core" in norm


# ------------------------------------------------------------ op registry


def _rule_op_registry(tree: ast.AST, path: str) -> list[Finding]:
    findings: list[Finding] = []
    sites: list[tuple[ast.Tuple, str]] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Yield) or not isinstance(node.value,
                                                             ast.Tuple):
            continue
        elts = node.value.elts
        if elts and isinstance(elts[0], ast.Constant) and isinstance(
            elts[0].value, str
        ):
            sites.append((node.value, elts[0].value))
    speaks_protocol = any(name in ENGINE_OPS for _, name in sites)
    for tup, name in sites:
        spec = ENGINE_OPS.get(name)
        if spec is None:
            if speaks_protocol:
                findings.append(Finding(
                    path, tup.lineno, "op-unknown",
                    f"yielded op {name!r} is not in the engine-op registry "
                    f"(known: {', '.join(sorted(ENGINE_OPS))})",
                ))
            continue
        arity = len(tup.elts) - 1
        if arity != spec.arity:
            findings.append(Finding(
                path, tup.lineno, "op-arity",
                f"op {name!r} yielded with {arity} operand(s), registry "
                f"declares {spec.arity}",
            ))
    return findings


def _rule_op_dispatch(tree: ast.AST, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for fn in _functions(tree):
        compared: dict[str, set[str]] = {}
        first_line: dict[str, int] = {}
        for node in _own_scope(fn):
            if not isinstance(node, ast.Compare) or len(node.ops) != 1:
                continue
            if not isinstance(node.ops[0], (ast.Eq, ast.NotEq)):
                continue
            left, right = node.left, node.comparators[0]
            for var, const in ((left, right), (right, left)):
                if (isinstance(var, ast.Name)
                        and isinstance(const, ast.Constant)
                        and isinstance(const.value, str)):
                    compared.setdefault(var.id, set()).add(const.value)
                    first_line.setdefault(var.id, node.lineno)
        for var, names in compared.items():
            ops_seen = names & set(ENGINE_OPS)
            if len(ops_seen) < 2:
                continue  # not an op dispatcher (e.g. event-kind switches)
            missing = set(ENGINE_OPS) - names
            if missing:
                findings.append(Finding(
                    path, first_line[var], "op-dispatch",
                    f"dispatcher {fn.name!r} (on {var!r}) does not handle "
                    f"registered op(s): {', '.join(sorted(missing))}",
                ))
            extras = names - set(ENGINE_OPS) - EVENT_KINDS
            if extras:
                findings.append(Finding(
                    path, first_line[var], "op-dispatch",
                    f"dispatcher {fn.name!r} (on {var!r}) matches name(s) "
                    f"that are neither registered ops nor event kinds: "
                    f"{', '.join(sorted(extras))}",
                ))
    return findings


# --------------------------------------------------------- window pairing


def _transitive_closers(tree: ast.AST) -> set[str]:
    """Module function names whose body always reaches a window closer —
    fixpoint over same-module calls (leniency 3)."""
    bodies = {fn.name: fn for fn in _functions(tree)}
    closers: set[str] = set()

    def body_closes(fn: ast.AST, known: set[str]) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Call):
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr in (WINDOW_CLOSERS | known)):
                    return True
                if isinstance(node.func, ast.Name) and node.func.id in known:
                    return True
        return False

    changed = True
    while changed:
        changed = False
        for name, fn in bodies.items():
            if name not in closers and body_closes(fn, closers):
                closers.add(name)
                changed = True
    return closers


def _contains_closer(node: ast.AST, closers: set[str]) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            if (isinstance(n.func, ast.Attribute)
                    and n.func.attr in (WINDOW_CLOSERS | closers)):
                return True
            if isinstance(n.func, ast.Name) and n.func.id in closers:
                return True
    return False


def _closes_seq(stmts: list[ast.stmt], closers: set[str]) -> bool:
    """Does every control-flow path through ``stmts`` reach a closer?"""
    for i, st in enumerate(stmts):
        rest = stmts[i + 1:]
        if isinstance(st, ast.Return):
            return st.value is not None and _contains_closer(st.value, closers)
        if isinstance(st, ast.Raise):
            return True  # leniency 5
        if isinstance(st, ast.If):
            return (_closes_seq(st.body + rest, closers)
                    and _closes_seq(st.orelse + rest, closers))
        if isinstance(st, (ast.For, ast.While)):
            if _closes_seq(st.body, closers):
                return True  # leniency 2: the batch-closing loop
            continue  # zero-iteration path: keep scanning
        if isinstance(st, ast.Try):
            return _closes_seq(st.body + st.finalbody + rest, closers)
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if _contains_closer(st, closers):
                return True  # leniency 1: the completion-callback pattern
            continue
        if _contains_closer(st, closers):
            return True
    return False


def _path_closes_after(stmts: list[ast.stmt], tail: list[ast.stmt],
                       call: ast.Call, closers: set[str]) -> bool | None:
    """Locate ``call`` inside ``stmts`` and decide whether every path from
    just after it (continuing into ``tail``) reaches a closer.  None when the
    call is not in this block."""
    for i, st in enumerate(stmts):
        if not any(n is call for n in ast.walk(st)):
            continue
        rest = stmts[i + 1:] + tail
        for block_name in ("body", "orelse", "finalbody"):
            block = getattr(st, block_name, None)
            if block:
                r = _path_closes_after(block, rest, call, closers)
                if r is not None:
                    return r
        return _closes_seq(rest, closers)
    return None


def _rule_begin_load_pairing(tree: ast.AST, path: str) -> list[Finding]:
    findings: list[Finding] = []
    closers = _transitive_closers(tree)
    for fn in _functions(tree):
        for node in _own_scope(fn):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "begin_load"):
                continue
            # leniency 4: `return x.begin_load(...)` is pure delegation
            delegated = any(
                isinstance(st, ast.Return)
                and st.value is not None
                and any(n is node for n in ast.walk(st.value))
                for st in ast.walk(fn) if isinstance(st, ast.Return)
            )
            if delegated:
                continue
            closed = _path_closes_after(fn.body, [], node, closers)
            if closed is not True:
                findings.append(Finding(
                    path, node.lineno, "begin-load-pairing",
                    f"begin_load in {fn.name!r} is not matched by "
                    f"finish_load/abort_load/admit on every control-flow "
                    f"path",
                ))
    return findings


def _rule_publish_in_locked(tree: ast.AST, path: str) -> list[Finding]:
    findings: list[Finding] = []
    for fn in _functions(tree):
        state_writes: list[tuple[int, str | None]] = []
        hook_calls: list[ast.Call] = []
        for node in _own_scope(fn):
            if (isinstance(node, ast.Assign) and len(node.targets) == 1
                    and isinstance(node.targets[0], ast.Subscript)
                    and _terminal_name(node.targets[0].value) == "state"):
                state_writes.append((node.lineno,
                                     _terminal_name(node.value)))
            elif (isinstance(node, ast.Call)
                  and _terminal_name(node.func) == "on_publish"):
                hook_calls.append(node)
        if not hook_calls:
            continue
        state_writes.sort()
        for call in hook_calls:
            prior = [st for line, st in state_writes if line < call.lineno]
            if not prior:
                findings.append(Finding(
                    path, call.lineno, "publish-in-locked",
                    f"on_publish fires in {fn.name!r} before any slot state "
                    f"was established as published",
                ))
            elif prior[-1] == "LOCKED":
                findings.append(Finding(
                    path, call.lineno, "publish-in-locked",
                    f"on_publish fires in {fn.name!r} while the most recent "
                    f"slot state written is LOCKED (open window)",
                ))
    return findings


# ------------------------------------------------------- coroutine purity


def _rule_coroutine_purity(tree: ast.AST, path: str) -> list[Finding]:
    """Module-level search coroutines must talk to the pool/cache through an
    accessor or an engine op — never by calling blocking methods directly.
    Accessor METHODS (functions inside a class) are the allowed layer."""
    if not _is_core_path(path):
        return []
    findings: list[Finding] = []
    class_fns: set[ast.AST] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            for fn in ast.walk(node):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    class_fns.add(fn)
    for fn in _functions(tree):
        if fn in class_fns or not _is_generator(fn):
            continue
        for node in ast.walk(fn):  # whole subtree: nested helpers included
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in BLOCKING_POOL_METHODS):
                findings.append(Finding(
                    path, node.lineno, "blocking-call-in-coroutine",
                    f"coroutine {fn.name!r} calls blocking method "
                    f".{node.func.attr}() directly — yield the engine op or "
                    f"go through an accessor method",
                ))
    return findings


# ----------------------------------------------------------- determinism


def _rule_wall_clock(tree: ast.AST, path: str) -> list[Finding]:
    if not _is_core_path(path):
        return []
    findings: list[Finding] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            dotted = _dotted(node.func)
            if dotted in _WALL_CLOCK_CALLS:
                findings.append(Finding(
                    path, node.lineno, "wall-clock",
                    f"{dotted}() in a sim path — simulated time must come "
                    f"from the engine clock, not the host",
                ))
    return findings


def _rule_unseeded_rng(tree: ast.AST, path: str) -> list[Finding]:
    if not _is_core_path(path):
        return []
    findings: list[Finding] = []
    imports_random = any(
        isinstance(n, ast.Import) and any(a.name == "random" for a in n.names)
        for n in ast.walk(tree)
    )
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        dotted = _dotted(node.func)
        if dotted is None:
            continue
        parts = dotted.split(".")
        if dotted.endswith("default_rng") and not (node.args or node.keywords):
            findings.append(Finding(
                path, node.lineno, "unseeded-rng",
                "default_rng() without a seed — thread an explicit seed",
            ))
        elif (len(parts) >= 2 and parts[-2] == "random"
              and parts[0] in ("np", "numpy") and parts[-1] != "default_rng"):
            findings.append(Finding(
                path, node.lineno, "unseeded-rng",
                f"{dotted}() uses the legacy global RNG — use a seeded "
                f"np.random.default_rng(seed) Generator",
            ))
        elif imports_random and parts[0] == "random" and len(parts) == 2:
            findings.append(Finding(
                path, node.lineno, "unseeded-rng",
                f"stdlib {dotted}() in a sim path — use a seeded "
                f"np.random.default_rng(seed) Generator",
            ))
    return findings


def _is_set_expr(val: ast.AST) -> bool:
    return (
        isinstance(val, (ast.Set, ast.SetComp))
        or (isinstance(val, ast.Call) and isinstance(val.func, ast.Name)
            and val.func.id in ("set", "frozenset"))
    )


def _rule_set_iteration(tree: ast.AST, path: str) -> list[Finding]:
    if not _is_core_path(path):
        return []
    findings: list[Finding] = []

    def scan_scope(scope: ast.AST, inherited: frozenset[str]) -> None:
        """Track set-typed names lexically: a closure iterating a set bound
        in an enclosing function is exactly the hazard this rule exists for
        (the scheduler's pool registry was one before it became a dict)."""
        set_vars = set(inherited)
        nested: list[ast.AST] = []
        for node in _own_scope(scope):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node is not scope:
                nested.append(node)
            elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                    and isinstance(node.targets[0], ast.Name):
                if _is_set_expr(node.value):
                    set_vars.add(node.targets[0].id)
                else:
                    set_vars.discard(node.targets[0].id)  # rebound
            elif isinstance(node, ast.AnnAssign) and isinstance(
                node.target, ast.Name
            ):
                ann = _dotted(node.annotation) or ""
                if ann in ("set", "frozenset") or (
                    node.value is not None and _is_set_expr(node.value)
                ):
                    set_vars.add(node.target.id)
                else:
                    set_vars.discard(node.target.id)
        for node in _own_scope(scope):
            if not isinstance(node, ast.For):
                continue
            it = node.iter
            named_set = isinstance(it, ast.Name) and it.id in set_vars
            if _is_set_expr(it) or named_set:
                what = it.id if named_set else "a set expression"
                findings.append(Finding(
                    path, node.lineno, "set-iteration",
                    f"iterating {what} — set order is implementation-"
                    f"defined; iterate a dict (insertion-ordered) or sort",
                ))
        for fn in nested:
            scan_scope(fn, frozenset(set_vars))

    # module scope first; scan_scope recurses into every nested function
    # (class methods included — _own_scope descends through ClassDef)
    scan_scope(tree, frozenset())
    return findings


# ---------------------------------------------------------------- drivers


_RULES = (
    _rule_op_registry,
    _rule_op_dispatch,
    _rule_begin_load_pairing,
    _rule_publish_in_locked,
    _rule_coroutine_purity,
    _rule_wall_clock,
    _rule_unseeded_rng,
    _rule_set_iteration,
)


def run_lint_text(text: str, filename: str) -> list[Finding]:
    """Lint one source text under an (possibly synthetic) filename — the
    filename decides path-scoped rules (determinism applies to repro/core)."""
    tree = ast.parse(text, filename=filename)
    findings: list[Finding] = []
    for rule in _RULES:
        findings.extend(rule(tree, filename))
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings


def _iter_py_files(paths: list[str]) -> list[str]:
    out: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs.sort()
                for f in sorted(files):
                    if f.endswith(".py"):
                        out.append(os.path.join(root, f))
        elif p.endswith(".py"):
            out.append(p)
    return out


def run_lint(paths: list[str]) -> list[Finding]:
    """Lint every .py file under ``paths`` (files or directories)."""
    findings: list[Finding] = []
    for path in _iter_py_files(paths):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        findings.extend(run_lint_text(text, path))
    return findings
