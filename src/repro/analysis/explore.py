"""Bounded schedule-permutation explorer (DPOR-lite) for the engine.

The simulated engine is deterministic: workers and completion events are
ordered by simulated time, with fixed tie-breaks (submission order for the
event heap, worker id for equal-clock workers).  Those tie-breaks are the
only scheduling freedom a real thread-per-core runtime would have had at the
same instants — actions at *distinct* simulated times are causally ordered
by the cost model and may never be swapped.  ``SchedulePolicy`` therefore
permutes exactly the ties:

  * equal-time events in the completion heap drain in a seeded-rank order
    instead of submission order (``event_rank``);
  * equal-clock runnable workers (and stall-flush initiators) are picked by
    a seeded worker permutation instead of lowest-wid (``worker_rank``).

Seed 0 is the identity policy — bitwise the unscheduled engine — and every
run counts how many genuine ties it hit (``ties``), so a "nothing differed"
verdict over schedules that never had a choice to make is visible as a
vacuous one.  The policy also records the engine's decision ``trace``
(wait_any tie-break resolutions as ``("wait_any", qid, pid)``; HBM scatter
boundaries as ``("scatter", n)``), which regression tests replay across
seeds.

``explore`` runs one workload factory under a set of seeds and compares the
returned per-query ``(ids, dists, hops)`` triples bitwise against the seed-0
baseline.  ``reads`` is deliberately NOT compared: which coroutine issues
the page read that others coalesce on is schedule-dependent even though the
answer is not.
"""

from __future__ import annotations

import dataclasses

import numpy as np


class SchedulePolicy:
    """Seeded permutation of the engine's scheduling ties.

    Engine contract (see ``Engine.run``): ``event_rank(seq)`` is called once
    per pushed completion event, in ``seq`` order, and becomes the heap's
    secondary key; ``worker_rank(wid)`` keys equal-clock worker picks;
    ``ties`` counts the decisions that genuinely had more than one choice;
    ``note(entry)`` appends a decision to the replayable trace.
    """

    def __init__(self, seed: int, n_workers: int = 64):
        self.seed = int(seed)
        self.ties: dict[str, int] = {"worker": 0, "event": 0, "slack": 0}
        self.trace: list[tuple] = []
        self._rng = None
        self._worker_perm = None
        if self.seed:
            rng = np.random.default_rng(self.seed)
            self._worker_perm = rng.permutation(int(n_workers))
            self._rng = rng

    def event_rank(self, seq: int) -> int:
        if self._rng is None:
            return 0  # identity: heap order degenerates to (time, seq)
        return int(self._rng.integers(0, 1 << 30))

    def worker_rank(self, wid: int) -> int:
        if self._worker_perm is None:
            return wid
        return int(self._worker_perm[wid % len(self._worker_perm)])

    def slack_rank(self, qid: int) -> int:
        """Tie-break key for EQUAL-DEADLINE ready entries under the "sla"
        scheduler — at one instant equal deadlines mean equal slack, a
        genuine scheduling race.  Must be a pure function of qid (NOT a
        sequential rng draw): the same query must rank the same wherever the
        tie shows up, so a seed permutes ties consistently instead of
        injecting order-dependence of its own.  Identity (seed 0) preserves
        the engine's submission-order tie-break."""
        if self._rng is None:
            return 0  # identity: engine falls through to submission order
        # splitmix64-style hash of (seed, qid): stateless, well-mixed
        x = (qid + 0x9E3779B97F4A7C15 * (self.seed + 1)) & ((1 << 64) - 1)
        x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & ((1 << 64) - 1)
        x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & ((1 << 64) - 1)
        return int(x ^ (x >> 31))

    def note(self, entry) -> None:
        self.trace.append(tuple(entry))


def normalize_results(results, include_hops: bool = True) -> tuple:
    """Schedule-independent projection of a result list: per-query
    ``(ids, dists, hops)``, hashable for bitwise comparison.

    ``include_hops=False`` drops the hop count — the comparison for
    cache-ADAPTIVE algorithms (velo's cbs pivot consults residency, and
    residency at a tie instant is legitimately schedule-dependent, so the
    path length may vary even when the answer does not)."""
    out = []
    for r in results:
        proj = (
            tuple(int(v) for v in r.ids),
            tuple(float(d) for d in r.dists),
        )
        if include_hops:
            proj = proj + (int(r.hops),)
        out.append(proj)
    return tuple(out)


def trace_by_query(trace, kind: str = "wait_any") -> dict[int, list[tuple]]:
    """Group a policy's decision trace by query id (entries of one kind).
    Per-query sequences are the replay unit: the GLOBAL interleaving of
    queries legitimately differs across schedules, the decisions within one
    query must not."""
    out: dict[int, list[tuple]] = {}
    for entry in trace:
        if entry[0] == kind:
            out.setdefault(int(entry[1]), []).append(entry)
    return out


def scatter_sizes(trace) -> list[int]:
    """The HBM staged-scatter boundary sizes, in boundary order."""
    return [int(entry[1]) for entry in trace if entry[0] == "scatter"]


@dataclasses.dataclass
class ScheduleReport:
    seed: int
    ties: dict[str, int]
    equal: bool                # results bitwise equal to the seed-0 baseline
    first_diff: str | None
    trace: list[tuple]


def explore(run_under, seeds, include_hops: bool = True) -> list[ScheduleReport]:
    """Run ``run_under(policy) -> results`` under seed 0 (the identity
    baseline) and then every seed in ``seeds``; report bitwise equality of
    the normalized results against the baseline.  The factory must build a
    FRESH system per call — pools and caches are stateful across runs."""
    base_policy = SchedulePolicy(0)
    baseline = normalize_results(run_under(base_policy), include_hops)
    reports = [ScheduleReport(0, dict(base_policy.ties), True, None,
                              base_policy.trace)]
    for seed in seeds:
        policy = SchedulePolicy(int(seed))
        res = normalize_results(run_under(policy), include_hops)
        equal = res == baseline
        first_diff = None
        if not equal:
            for qid, (a, b) in enumerate(zip(baseline, res)):
                if a != b:
                    first_diff = (
                        f"query {qid}: {a[:2]}... (seed 0) vs "
                        f"{b[:2]}... (seed {seed})"
                    )
                    break
            if first_diff is None:
                first_diff = "result lists differ in length"
        reports.append(ScheduleReport(int(seed), dict(policy.ties), equal,
                                      first_diff, policy.trace))
    return reports


# --------------------------------------------------------------- smoke rig


def _smoke_fixture(n: int = 600, d: int = 32, n_queries: int = 24,
                   seed: int = 0):
    """One small clustered dataset + graph + quantizer, built once per
    process (graph construction dominates the smoke runtime)."""
    global _FIXTURE
    key = (n, d, n_queries, seed)
    if _FIXTURE is not None and _FIXTURE[0] == key:
        return _FIXTURE[1]
    from repro.core.dataset import make_dataset
    from repro.core.quant import RabitQuantizer
    from repro.core.vamana import build_vamana

    ds = make_dataset(n=n, d=d, n_queries=n_queries, k=5, seed=seed)
    graph = build_vamana(ds.base, R=12, L=24, batch_size=128, seed=seed)
    qb = RabitQuantizer(ds.dim, seed=seed).fit_encode(ds.base)
    _FIXTURE = (key, (ds, graph, qb))
    return ds, graph, qb


_FIXTURE = None


def run_system_under(policy, name: str, *, n_workers: int = 2,
                     batch_size: int = 4, buffer_ratio: float = 0.3,
                     hbm_tier: bool = False, verify: bool = True,
                     fixture=None, **config_kw):
    """Build a FRESH system and run the smoke workload under ``policy``.
    ``verify`` arms the dynamic protocol checker alongside the exploration,
    so every explored interleaving is also transition-checked."""
    import dataclasses as _dc

    from repro.core.baselines import SystemConfig, build_system

    ds, graph, qb = fixture if fixture is not None else _smoke_fixture()
    cfg = SystemConfig(
        n_workers=n_workers, batch_size=batch_size,
        buffer_ratio=buffer_ratio, hbm_tier=hbm_tier,
        verify_protocol=verify,
    )
    if config_kw:
        cfg = _dc.replace(cfg, **config_kw)
    system = build_system(name, ds.base, graph, qb, config=cfg)
    results, _stats = system.run(ds.queries, schedule=policy)
    return results


def run_sla_under(policy, *, n_workers: int = 2, batch_size: int = 4,
                  n_ops: int = 36, qps: float = 2500.0, sla_ms: float = 2.0,
                  fixture=None):
    """Build a FRESH 3-tenant serving plane in "sla" mode (pure EDF:
    feedback controller OFF) and run a bursty arrival mix under ``policy``.

    Burst-clustered arrivals land whole same-tenant runs at one instant, so
    their deadlines tie exactly — the equal-slack races ``slack_rank``
    permutes.  The controller stays off here for the same reason velo's cbs
    pivot does in ``smoke``: its steering is input-adaptive with respect to
    completion timing BY DESIGN (a different interleaving legitimately
    shifts the windowed tail signal and with it beam widths), so the bitwise
    claim covers the deterministic EDF scheduler; the feedback loop is
    exercised by bench_multitenant.py instead."""
    from repro.core.baselines import SystemConfig
    from repro.core.search import SearchParams
    from repro.core.serving import ServingPlane, TenantSpec
    from repro.core.workload import bursty_mix

    ds, graph, qb = fixture if fixture is not None else _smoke_fixture()
    specs = [
        TenantSpec.from_dataset(
            f"t{i}", ds, graph, qb, params=SearchParams(cbs=False)
        )
        for i in range(3)
    ]
    cfg = SystemConfig(
        n_workers=n_workers, batch_size=batch_size, buffer_ratio=0.3,
        scheduler="sla", sla_ms=sla_ms, sla_feedback=False,
        verify_protocol=True,
    )
    plane = ServingPlane(specs, cfg)
    wl = bursty_mix(
        [len(ds.queries)] * 3, n_ops, mean_burst=6, s=1.2, seed=3, qps=qps
    )
    return plane.run(wl, schedule=policy).results


def smoke_sla(n_schedules: int = 5, base_seed: int = 1):
    """The ``--explore`` leg for the SLA scheduler: the pure-EDF serving
    plane under permuted schedules must be bitwise schedule-invariant, WITH
    equal-slack ties genuinely permuted (the slack tie count in the report
    shows the pass was not vacuous)."""
    seeds = [base_seed + i for i in range(n_schedules)]
    return {"sla-edf": explore(run_sla_under, seeds)}


def smoke(algorithms=("velo", "diskann", "starling", "pipeann", "inmemory"),
          n_schedules: int = 5, base_seed: int = 1,
          hbm_for=("velo",), verify: bool = True):
    """The CLI's ``--explore`` entry: every algorithm under ``n_schedules``
    permuted schedules (seeds ``base_seed .. base_seed+n-1``), protocol
    checker armed.  Returns ``{algorithm: [ScheduleReport, ...]}``.

    The velo systems run with the cache-aware pivot DISABLED here: cbs is
    input-adaptive with respect to residency timing (Alg. 2 pivots on
    ``InMemory()``), so its search path — and under enough pressure its
    answer — legitimately varies across interleavings.  That adaptivity is
    exercised by the dynamic checker instead; the bitwise claim covers the
    deterministic access paths of all five algorithms."""
    import dataclasses as _dc

    from repro.core.search import SearchParams

    seeds = [base_seed + i for i in range(n_schedules)]
    out: dict[str, list[ScheduleReport]] = {}
    for name in algorithms:
        kw = {}
        if name in ("velo", "velo-page", "+cbs"):
            kw["params"] = SearchParams(cbs=False)
        hbm = name in hbm_for

        def run_under(policy, _name=name, _hbm=hbm, _kw=kw):
            return run_system_under(policy, _name, hbm_tier=_hbm,
                                    verify=verify, **_kw)

        out[name] = explore(run_under, seeds)
    return out
