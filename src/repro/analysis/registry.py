"""The engine-op registry: the ONE declared source of truth for the coroutine
wire protocol (search.py's docstring table, made machine-checkable).

Every search coroutine communicates with the scheduler exclusively through
``yield ("<op>", ...)`` tuples; the scheduler dispatches on the op name.  The
protocol has grown by hand across PRs 1-6 and nothing mechanical kept the two
sides in sync: a new op added to search.py but not engine.py (or vice versa),
or an operand added to one yield site but not another, would only surface as
a confusing runtime unpack error deep inside a workload.

This module declares the registry; ``repro.analysis.lint`` cross-checks it
against the code WITHOUT importing it (pure AST):

  * every ``yield ("name", ...)`` in checked files must name a registered op
    and carry exactly ``arity`` operands (rule ``op-unknown`` / ``op-arity``);
  * every dispatcher (a function comparing one variable against two or more
    registered op names) must handle EVERY registered op and nothing that is
    neither an op nor an event kind (rule ``op-dispatch``).

Adding a new engine op therefore means touching this table first — the lint
fails on both sides until yield sites and dispatcher agree with it.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class OpSpec:
    """One engine op: its operand count and scheduling behavior."""

    name: str
    arity: int          # operands AFTER the op name in the yielded tuple
    suspends: bool      # the coroutine may be parked (resumed via event)
    resumes_with: str   # what gen.send() delivers back
    doc: str


# The coroutine -> scheduler op vocabulary (search.py protocol table).
ENGINE_OPS: dict[str, OpSpec] = {
    op.name: op
    for op in (
        OpSpec("compute", 1, False, "None",
               "charge simulated CPU seconds to the worker"),
        OpSpec("score", 1, False, "np.ndarray",
               "a ScoreRequest; may park in the rendezvous buffer"),
        OpSpec("beam", 1, False, "BeamResult",
               "a BeamRequest executing one fused on-device beam step "
               "(score + visited mask + top-k merge + frontier selection); "
               "may park in the rendezvous buffer; the reply is the next "
               "frontier, not raw distances"),
        OpSpec("scatter", 1, True, "np.ndarray",
               "a ShardScatter routing a ScoreRequest's rows to their "
               "owning engine shards; may park in per-shard rendezvous "
               "buffers until the shards flush and the slices merge"),
        OpSpec("read", 1, True, "{pid: bytes}",
               "blocking batched page read"),
        OpSpec("load_wait", 2, True, "record | None",
               "park on a vid's LOCKED buffer-pool window"),
        OpSpec("submit_cb", 2, False, "None",
               "fire-and-forget reads with a completion callback"),
        OpSpec("submit", 1, False, "[token, ...]",
               "non-blocking reads returning wait tokens"),
        OpSpec("wait_any", 1, True, "(token, pid, bytes)",
               "await the earliest completion of a token set"),
    )
}

# Scheduler-internal completion-event kinds: these legitimately appear in the
# same dispatch functions as engine ops but are NOT part of the coroutine
# protocol (nothing ever yields them).  "arrival" is the SLA scheduler's
# query-arrival event (an SlaPlan timestamp releasing a query into the
# admission queue); it exists only when a plan with nonzero arrivals is
# attached, so default runs carry none.
EVENT_KINDS: frozenset[str] = frozenset({"callback", "resume", "arrival"})

# Buffer-pool protocol names the pairing / purity lint rules key on.
WINDOW_OPENERS: frozenset[str] = frozenset({"begin_load"})
WINDOW_CLOSERS: frozenset[str] = frozenset(
    {"finish_load", "abort_load", "admit", "admit_group"}
)
# Blocking pool/cache methods a search coroutine must never call directly
# (it must go through an accessor, or yield the corresponding engine op).
BLOCKING_POOL_METHODS: frozenset[str] = frozenset(
    {"lookup", "admit", "admit_group", "run_clock",
     "begin_load", "finish_load", "abort_load"}
)
