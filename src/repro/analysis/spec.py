"""Declarative state-machine spec of the cache hierarchy (Fig. 5 + HBM edges).

The dynamic protocol checker (``repro.analysis.protocol``) validates every
observed slot transition against these tables — the spec is data, the checker
is the interpreter, so extending the protocol means adding an edge HERE and
watching the checker reject anything the implementation does beyond it.

Host pool (``RecordBufferPool``), per public method ("event"): the set of
(pre, post) state pairs the event may apply to the slot(s) it targets.  Any
event that acquires a slot may additionally run the clock, whose side
effects on OTHER slots are the ``CLOCK_EDGES``.

Device tier (``HbmTier`` / ``DeviceRecordCache``): the scatter installs
staged records (FREE -> OCCUPIED, running the device sweep under pressure);
lookups give MARKED slots their second chance.  Staging itself never touches
slot state — that is exactly the double-buffering claim the checker enforces
(records wait host-side until the next dispatch boundary).
"""

from __future__ import annotations

from repro.core.bufferpool import SlotState

FREE = int(SlotState.FREE)
LOCKED = int(SlotState.LOCKED)
OCCUPIED = int(SlotState.OCCUPIED)
MARKED = int(SlotState.MARKED)

STATE_NAMES = {FREE: "FREE", LOCKED: "LOCKED",
               OCCUPIED: "OCCUPIED", MARKED: "MARKED"}

# clock second-chance side effects (demote / evict), legal on any slot while
# an acquiring event sweeps for a free one
CLOCK_EDGES: frozenset[tuple[int, int]] = frozenset(
    {(OCCUPIED, MARKED), (MARKED, FREE)}
)

# event -> allowed (pre, post) transitions for the slot(s) the event targets
POOL_EVENTS: dict[str, frozenset[tuple[int, int]]] = {
    # reserve a LOCKED window before the read is issued (no-op if racing
    # loader won the reservation)
    "begin_load": frozenset({(FREE, LOCKED)}),
    # publish the window; degrades to a plain admit if the window was aborted
    # (FREE -> OCCUPIED through the fallback admit)
    "finish_load": frozenset({(LOCKED, OCCUPIED), (FREE, OCCUPIED)}),
    # tear the window down; waiters resume with None
    "abort_load": frozenset({(LOCKED, FREE)}),
    # synchronous install; publishes an open window on the duplicate race
    "admit": frozenset({(FREE, OCCUPIED), (LOCKED, OCCUPIED)}),
    "admit_group": frozenset({(FREE, OCCUPIED), (LOCKED, OCCUPIED)}),
    # a hit gives a MARKED slot its second chance
    "lookup": frozenset({(MARKED, OCCUPIED)}),
    "peek_record": frozenset(),          # pure observer: no transitions
    "take_resumes": frozenset(),         # drains the resume queue only
    "run_clock": CLOCK_EDGES,
}

# events that may acquire slots and therefore run the clock on OTHER slots
ACQUIRING_EVENTS: frozenset[str] = frozenset(
    {"begin_load", "finish_load", "admit", "admit_group", "run_clock"}
)

# The batched scatter (DeviceRecordCache.admit) applies several micro-steps
# per call — install FREE -> OCCUPIED, sweep demote OCCUPIED -> MARKED,
# sweep evict MARKED -> FREE — so one pre/post diff observes their COMPOSITES
# too: evict + reinstall (MARKED -> OCCUPIED), demote + evict
# (OCCUPIED -> FREE).  A same-state slot whose vid changed is the full
# demote + evict + reinstall chain and is also legal for this event only.
HBM_SCATTER_EDGES: frozenset[tuple[int, int]] = (
    frozenset({(FREE, OCCUPIED), (MARKED, OCCUPIED), (OCCUPIED, FREE)})
    | CLOCK_EDGES
)

# device tier (HbmTier): event -> allowed slot_state transitions
HBM_EVENTS: dict[str, frozenset[tuple[int, int]]] = {
    # staging is host-side only: NO device slot may change state
    "note_publish": frozenset(),
    "note_hit": frozenset(),
    # the dispatch-boundary scatter installs staged rows; the device sweep
    # may demote/evict under pressure (composite edges, see above)
    "scatter_staged": HBM_SCATTER_EDGES,
    # a tier hit gives a MARKED slot its second chance
    "lookup": frozenset({(MARKED, OCCUPIED)}),
    "peek_split": frozenset({(MARKED, OCCUPIED)}),
}

# events allowed to swap a slot's vid without a state change (reinstall)
HBM_REINSTALL_EVENTS: frozenset[str] = frozenset({"scatter_staged"})
