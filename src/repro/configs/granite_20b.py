"""granite-20b [dense] — llama-arch MQA, code model [arXiv:2405.04324; hf].

52L d_model=6144 48H (GQA kv=1 = MQA) d_ff=24576 vocab=49152.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_head=128,
    d_ff=24576,
    vocab_size=49152,
)

REDUCED = ModelConfig(
    name="granite-20b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=192,
    vocab_size=256,
)
