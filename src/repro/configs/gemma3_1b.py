"""gemma3-1b [dense] — 5:1 local:global attention, 128k-class context
[hf:google/gemma-3-1b-pt; unverified].

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144.
Local layers use a 512-token sliding window (gemma3 reference value for the
1b model); every 6th layer is global.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262144,
    window_pattern=(512, 512, 512, 512, 512, 0),  # 5 local : 1 global
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma3-1b-reduced",
    family="dense",
    n_layers=8,  # 6-layer pattern + 2 prefix remainder, like 26 = 4*6+2
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_head=16,
    d_ff=160,
    vocab_size=512,
    window_pattern=(16, 16, 16, 16, 16, 0),
    tie_embeddings=True,
)
