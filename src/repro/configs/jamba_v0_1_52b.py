"""jamba-v0.1-52b [hybrid] — Mamba+attention 1:7 interleave with MoE
[arXiv:2403.19887; hf].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16 experts top-2.
Jamba block structure: period-8 layer groups with attention at slot 4 of 8
(index 3), MoE replacing the MLP on every other layer (period 2).
Attention layers serve long contexts with a 32k sliding window (long_500k
mode; attention is full within the trained 32k at shorter shapes, which the
window reproduces exactly for seq <= 32k... window=32768).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=65536,
    kind_pattern=(
        "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba"
    ),
    window_pattern=(32768,),  # rolling 32k window on the 4 attention layers
    n_experts=16,
    moe_top_k=2,
    moe_period=2,
    ssm_state=16,
    ssm_expand=2,
    ssm_conv=4,
)

REDUCED = ModelConfig(
    name="jamba-v0.1-52b-reduced",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    kind_pattern=(
        "mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba"
    ),
    window_pattern=(64,),
    n_experts=4,
    moe_top_k=2,
    moe_period=2,
    ssm_state=4,
    ssm_expand=2,
    ssm_conv=4,
)
