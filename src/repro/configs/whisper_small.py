"""whisper-small [audio] — enc-dec, conv frontend stub [arXiv:2212.04356].

12L (x2: encoder+decoder) d_model=768 12H (kv=12, i.e. MHA) d_ff=3072
vocab=51865.  The conv frontend is a STUB per the assignment: input_specs()
provides precomputed frame embeddings (1500 frames = 30 s at the post-conv
50 Hz rate) at d_model.
"""

from repro.models.config import ModelConfig

ENCODER_FRAMES = 1500

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_head=64,
    d_ff=3072,
    vocab_size=51865,
    n_encoder_layers=12,
    encoder_tokens=ENCODER_FRAMES,
    cross_attention=True,
    frontend="audio",
)

REDUCED = ModelConfig(
    name="whisper-small-reduced",
    family="encdec",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=128,
    vocab_size=256,
    n_encoder_layers=2,
    encoder_tokens=30,
    cross_attention=True,
    frontend="audio",
)
