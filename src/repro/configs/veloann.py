"""The paper's own config: the VeloANN distributed serve cell.

Corpus sharded over every mesh device; scan-mode two-stage search per shard
(binary MXU sweep -> int4 rerank) + distributed top-k merge.  Sized so one
v5e chip's shard fits comfortably in HBM with the level-1/level-2 artifacts:
  corpus 512M vectors x d=128 -> 1M vectors/chip at 512 chips:
  binary 16 B + ext 64 B + adj 128 B + meta ~= 220 B/vec ~= 220 MB/chip.
"""

import dataclasses


@dataclasses.dataclass(frozen=True)
class VeloServeConfig:
    name: str = "veloann"
    corpus_size: int = 512 * 1024 * 1024   # global vectors
    dim: int = 128
    R: int = 32                             # graph degree
    query_batch: int = 4096                 # global concurrent queries
    k: int = 10
    rerank: int = 64                        # stage-2 candidates per shard
    mode: str = "scan"                      # scan | graph


CONFIG = VeloServeConfig()

REDUCED = VeloServeConfig(
    name="veloann-reduced",
    corpus_size=4096,
    dim=64,
    R=12,
    query_batch=32,
    k=10,
    rerank=32,
)
