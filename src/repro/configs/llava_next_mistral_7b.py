"""llava-next-mistral-7b [vlm] — anyres tiling
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

Backbone: mistral-7b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000, sliding window 4096.  The vision frontend is a STUB per the
assignment: input_specs() provides precomputed anyres patch embeddings —
(2144 image tokens: 576 base + 4 tiles x 392 after pooling ~ the llava-next
token budget) already projected to d_model.
"""

from repro.models.config import ModelConfig

IMAGE_TOKENS = 2144

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=14336,
    vocab_size=32000,
    window_pattern=(4096,),  # mistral sliding window
    frontend="vision",
    frontend_tokens=IMAGE_TOKENS,
)

REDUCED = ModelConfig(
    name="llava-next-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=160,
    vocab_size=256,
    window_pattern=(32,),
    frontend="vision",
    frontend_tokens=16,
)
