"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified].

61L d_model=7168 64H (GQA kv=8 per the assignment table) d_ff=2048
vocab=163840, MoE 384 experts top-8, DeepSeek-V3-style: first layer dense
(d_ff_dense=18432), one shared expert, fine-grained routed experts.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    moe_top_k=8,
    n_shared_experts=1,
    moe_period=1,
    first_dense=1,
    d_ff_dense=18432,
)

REDUCED = ModelConfig(
    name="kimi-k2-reduced",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_head=16,
    d_ff=64,
    vocab_size=256,
    n_experts=8,
    moe_top_k=2,
    n_shared_experts=1,
    moe_period=1,
    first_dense=1,
    d_ff_dense=192,
)
