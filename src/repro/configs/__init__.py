"""Assigned architecture configs (--arch <id>) + the paper's own serve config.

Each module exposes CONFIG (full-scale, dry-run only) and REDUCED (same
family, CPU-smoke-testable).  get(name) resolves by id.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "yi_6b",
    "granite_20b",
    "tinyllama_1_1b",
    "gemma3_1b",
    "jamba_v0_1_52b",
    "kimi_k2_1t_a32b",
    "dbrx_132b",
    "llava_next_mistral_7b",
    "whisper_small",
    "rwkv6_7b",
]

ALIASES = {
    "yi-6b": "yi_6b",
    "granite-20b": "granite_20b",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "gemma3-1b": "gemma3_1b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "dbrx-132b": "dbrx_132b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "whisper-small": "whisper_small",
    "rwkv6-7b": "rwkv6_7b",
    "veloann": "veloann",
}


def get(name: str, reduced: bool = False):
    mod_name = ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_archs() -> list[str]:
    return list(ARCHS)
