"""rwkv6-7b [ssm] — Finch, data-dependent decay, attention-free
[arXiv:2404.05892; hf].

32L d_model=4096 d_ff=14336 vocab=65536.  RWKV-6 heads are d_model/64 = 64.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,          # rwkv head size 64
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab_size=65536,
    kind_pattern=("rwkv",),
)

REDUCED = ModelConfig(
    name="rwkv6-7b-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_head=16,
    d_ff=160,
    vocab_size=256,
    kind_pattern=("rwkv",),
)
