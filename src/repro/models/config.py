"""ModelConfig: one dataclass spanning all assigned architecture families."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int

    # attention pattern: per-layer window sizes, cycled across layers.
    # 0 = full/global attention; w > 0 = sliding window of w.
    window_pattern: tuple[int, ...] = (0,)

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    moe_period: int = 1          # MoE on layers where (i % period) == period-1
    first_dense: int = 0         # leading layers forced dense (kimi-k2 style)
    d_ff_dense: int | None = None  # FFN width of the dense layers when mixed
    capacity_factor: float = 1.25

    # hybrid (jamba): layer kinds cycled, e.g. ("mamba",)*7 + ("attn",)
    kind_pattern: tuple[str, ...] = ("attn",)

    # SSM
    ssm_state: int = 16
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_dt_rank: int = 0         # 0 -> ceil(d_model/16)

    # enc-dec (whisper)
    n_encoder_layers: int = 0
    encoder_tokens: int = 0      # e.g. 1500 audio frames
    cross_attention: bool = False

    # modality frontend stub
    frontend: str | None = None  # "audio" | "vision"
    frontend_tokens: int = 0     # vision: image patch token count

    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ---- derived ----
    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    def layer_kind(self, i: int) -> str:
        return self.kind_pattern[i % len(self.kind_pattern)]

    def layer_window(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    def layer_is_moe(self, i: int) -> bool:
        if self.n_experts == 0 or i < self.first_dense:
            return False
        return (i % self.moe_period) == (self.moe_period - 1)

    def params_count(self) -> int:
        """Total parameter count (for 6ND roofline accounting)."""
        d, dff, V = self.d_model, self.d_ff, self.vocab_size
        qkv = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
        o = self.n_heads * self.d_head * d
        total = 0
        layers = [("enc", i) for i in range(self.n_encoder_layers)] + [
            ("dec", i) for i in range(self.n_layers)
        ]
        for side, i in layers:
            kind = self.layer_kind(i) if side == "dec" else "attn"
            if kind == "attn":
                total += qkv + o
                if side == "dec" and self.cross_attention:
                    total += qkv + o
            elif kind == "mamba":
                di, N, dtr = self.d_inner, self.ssm_state, self.dt_rank
                total += d * 2 * di + di * self.ssm_conv + di * (dtr + 2 * N)
                total += dtr * di + di * N + di * d  # dt proj, A? (A is di*N), out
            elif kind == "rwkv":
                total += 4 * d * d + d * d  # r,k,v,o + gate (approx; exact in blocks)
                total += 2 * d * (self.d_ff // 1)  # channel-mix
            if side == "dec" and self.layer_is_moe(i):
                total += self.n_experts * 3 * d * dff
                total += self.n_shared_experts * 3 * d * dff
                total += d * self.n_experts  # router
            elif kind in ("attn", "mamba"):
                dffd = self.d_ff_dense or dff
                total += 3 * d * dffd
        total += V * d * (1 if self.tie_embeddings else 2)
        return total

    def active_params_count(self) -> int:
        """Active parameters per token (MoE: only routed experts count)."""
        if self.n_experts == 0:
            return self.params_count()
        d, dff = self.d_model, self.d_ff
        total = self.params_count()
        n_moe = sum(1 for i in range(self.n_layers) if self.layer_is_moe(i))
        total -= n_moe * (self.n_experts - self.moe_top_k) * 3 * d * dff
        return total
