"""The stacked model: init / train forward / prefill / decode for all families.

Layer stacking: layers are grouped into identical-spec groups of size
lcm(kind-pattern, window-pattern, moe-period); groups are scanned with
`jax.lax.scan` over stacked parameters (compact HLO — essential for lowering
52-61-layer configs for a 512-device mesh), with `jax.checkpoint` (remat)
around each group body for training.  Layers that don't fit the periodic
pattern (gemma3's 26 = 4*6+2, kimi's leading dense layer) run unrolled as a
prefix.
"""

from __future__ import annotations

import dataclasses
import math
from functools import reduce

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models import layers as L
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    prefix_specs: tuple[B.LayerSpec, ...]   # unrolled leading layers
    group_specs: tuple[B.LayerSpec, ...]    # slots of one scanned group
    n_groups: int
    # encoder (whisper): uniform non-causal attention layers, all scanned
    n_enc_groups: int = 0
    enc_group_specs: tuple[B.LayerSpec, ...] = ()


def _lcm(*xs: int) -> int:
    return reduce(math.lcm, [x for x in xs if x > 0], 1)


def build(cfg: ModelConfig) -> Model:
    group = _lcm(len(cfg.kind_pattern), len(cfg.window_pattern), cfg.moe_period)
    body = cfg.n_layers - cfg.first_dense
    group = min(group, max(1, body))
    rem = body % group
    prefix_len = cfg.first_dense + rem
    n_groups = (cfg.n_layers - prefix_len) // group

    prefix_specs = tuple(B.LayerSpec.of(cfg, i) for i in range(prefix_len))
    group_specs = tuple(
        B.LayerSpec.of(cfg, prefix_len + s) for s in range(group)
    )
    enc_specs = ()
    n_enc_groups = 0
    if cfg.n_encoder_layers:
        enc_specs = (
            B.LayerSpec(kind="attn", window=0, is_moe=False, cross=False, causal=False),
        )
        n_enc_groups = cfg.n_encoder_layers
    return Model(
        cfg=cfg,
        prefix_specs=prefix_specs,
        group_specs=group_specs,
        n_groups=n_groups,
        n_enc_groups=n_enc_groups,
        enc_group_specs=enc_specs,
    )


# ----------------------------------------------------------------------- init


def _init_group(key, cfg, specs):
    ks = jax.random.split(key, len(specs))
    return tuple(B.init_layer(k, cfg, s) for k, s in zip(ks, specs))


def init_params(model: Model, key) -> dict:
    cfg = model.cfg
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    k_embed, k_unembed, k_pre, k_groups, k_enc = jax.random.split(key, 5)
    params: dict = {
        "embed": L.init_linear(k_embed, (cfg.vocab_size, cfg.d_model), scale=0.02, dtype=dt),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = L.init_linear(
            k_unembed, (cfg.d_model, cfg.vocab_size), dtype=dt
        )
    if model.prefix_specs:
        ks = jax.random.split(k_pre, len(model.prefix_specs))
        params["prefix"] = tuple(
            B.init_layer(k, cfg, s) for k, s in zip(ks, model.prefix_specs)
        )
    if model.n_groups:
        ks = jax.random.split(k_groups, model.n_groups)
        stacked = [_init_group(k, cfg, model.group_specs) for k in ks]
        params["groups"] = jax.tree.map(lambda *xs: jnp.stack(xs), *stacked)
    if model.n_enc_groups:
        ke1, ke2 = jax.random.split(k_enc)
        ks = jax.random.split(ke1, model.n_enc_groups)
        stacked = [_init_group(k, cfg, model.enc_group_specs) for k in ks]
        params["encoder"] = {
            "groups": jax.tree.map(lambda *xs: jnp.stack(xs), *stacked),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def params_specs(model: Model) -> dict:
    """ShapeDtypeStructs of every parameter (dry-run: no allocation)."""
    return jax.eval_shape(lambda k: init_params(model, k), jax.random.key(0))


# ------------------------------------------------------------------- forward


def _unembed(params, cfg):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def _run_groups_seq(model, gparams, specs, x, positions, enc_states, want_cache, remat):
    cfg = model.cfg

    def body(carry, gp):
        x, aux = carry
        caches = []
        for s, spec in enumerate(specs):
            x, cache, a = B.layer_seq(
                gp[s], x, cfg, spec, positions, enc_states, want_cache
            )
            aux = aux + a
            caches.append(cache if cache is not None else 0)
        return (x, aux), tuple(caches) if want_cache else 0

    if remat:
        body = jax.checkpoint(body)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.float32(0.0)), gparams)
    return x, aux, caches


def _embed_inputs(model: Model, params, batch):
    """Returns (x (B, S, d), positions (B, S), labels-or-None, enc_states)."""
    cfg = model.cfg
    tokens = batch["tokens"]
    x = L.embed(tokens, params["embed"])
    Btok, S = tokens.shape

    enc_states = None
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(x.dtype)       # (B, T_img, d) stub
        x = jnp.concatenate([patches, x], axis=1)
        S = x.shape[1]
    if cfg.n_encoder_layers:
        frames = batch["frames"].astype(x.dtype)         # (B, T_enc, d) stub
        positions_enc = jnp.broadcast_to(
            jnp.arange(frames.shape[1]), frames.shape[:2]
        )
        h, _, _ = _run_groups_seq(
            model, params["encoder"]["groups"], model.enc_group_specs,
            frames, positions_enc, None, want_cache=False, remat=True,
        )
        enc_states = L.rmsnorm(h, params["encoder"]["final_norm"], cfg.norm_eps)

    positions = jnp.broadcast_to(jnp.arange(S), (Btok, S))
    from repro.models import sharding as Sh
    return Sh.constrain_act(x), positions, enc_states


def forward_train(model: Model, params, batch, ce_chunk: int = 512):
    """Returns scalar loss (CE + 0.01 * MoE aux)."""
    cfg = model.cfg
    x, positions, enc_states = _embed_inputs(model, params, batch)
    aux_total = jnp.float32(0.0)

    for i, spec in enumerate(model.prefix_specs):
        x, _, a = B.layer_seq(params["prefix"][i], x, cfg, spec, positions, enc_states)
        aux_total += a
    if model.n_groups:
        x, aux, _ = _run_groups_seq(
            model, params["groups"], model.group_specs, x, positions, enc_states,
            want_cache=False, remat=True,
        )
        aux_total += aux

    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    labels = batch["labels"]
    if cfg.frontend == "vision":
        # image positions carry no next-token loss
        pad = h.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=-100)
    loss = L.chunked_ce_loss(h, labels, _unembed(params, cfg), chunk=ce_chunk)
    return loss + 0.01 * aux_total


def prefill(model: Model, params, batch):
    """Forward over the full prompt; returns (last_logits (B, V), caches)."""
    cfg = model.cfg
    x, positions, enc_states = _embed_inputs(model, params, batch)

    prefix_caches = []
    for i, spec in enumerate(model.prefix_specs):
        x, cache, _ = B.layer_seq(
            params["prefix"][i], x, cfg, spec, positions, enc_states, want_cache=True
        )
        prefix_caches.append(cache)
    group_caches = 0
    if model.n_groups:
        x, _, group_caches = _run_groups_seq(
            model, params["groups"], model.group_specs, x, positions, enc_states,
            want_cache=True, remat=False,
        )
    h = L.rmsnorm(x[:, -1, :], params["final_norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ _unembed(params, cfg).astype(jnp.float32)
    caches = {"prefix": tuple(prefix_caches), "groups": group_caches}
    return logits, caches


def decode_step(model: Model, params, caches, tokens, pos):
    """One decode step. tokens (B,) int32; pos scalar int32 (write index).
    Returns (logits (B, V), new caches)."""
    cfg = model.cfg
    x = L.embed(tokens, params["embed"])

    new_prefix = []
    for i, spec in enumerate(model.prefix_specs):
        x, c, _ = B.layer_decode(params["prefix"][i], x, cfg, spec, caches["prefix"][i], pos)
        new_prefix.append(c)

    new_groups = caches["groups"]
    if model.n_groups:
        specs = model.group_specs

        def body(carry, inp):
            x = carry
            gp, gc = inp
            new_c = []
            for s, spec in enumerate(specs):
                x, c, _ = B.layer_decode(gp[s], x, cfg, spec, gc[s], pos)
                new_c.append(c)
            return x, tuple(new_c)

        x, new_groups = jax.lax.scan(body, x, (params["groups"], caches["groups"]))

    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = h.astype(jnp.float32) @ _unembed(params, cfg).astype(jnp.float32)
    return logits, {"prefix": tuple(new_prefix), "groups": new_groups}


# -------------------------------------------------------------- cache specs


def init_decode_caches(model: Model, batch_size: int, cache_len: int, enc_len: int = 0):
    """Zero-initialized caches for decode-only lowering (dry-run decode shapes)."""
    cfg = model.cfg
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def one(spec: B.LayerSpec):
        if spec.kind == "attn":
            klen = cache_len if spec.window == 0 else min(cache_len, spec.window + 1)
            c = {
                "k": jnp.zeros((batch_size, cfg.n_kv_heads, klen, cfg.d_head), dt),
                "v": jnp.zeros((batch_size, cfg.n_kv_heads, klen, cfg.d_head), dt),
            }
            if spec.cross:
                c["ck"] = jnp.zeros((batch_size, cfg.n_kv_heads, enc_len, cfg.d_head), dt)
                c["cv"] = jnp.zeros((batch_size, cfg.n_kv_heads, enc_len, cfg.d_head), dt)
            return c
        if spec.kind == "mamba":
            return {
                "conv": jnp.zeros((batch_size, cfg.ssm_conv - 1, cfg.d_inner), dt),
                "ssm": jnp.zeros((batch_size, cfg.d_inner, cfg.ssm_state), jnp.float32),
            }
        if spec.kind == "rwkv":
            dh = cfg.d_model // cfg.n_heads
            return {
                "tshift": jnp.zeros((batch_size, cfg.d_model), jnp.float32),
                "wkv": jnp.zeros((batch_size, cfg.n_heads, dh, dh), jnp.float32),
                "cshift": jnp.zeros((batch_size, cfg.d_model), jnp.float32),
            }
        raise ValueError(spec.kind)

    prefix = tuple(one(s) for s in model.prefix_specs)
    groups = 0
    if model.n_groups:
        per_group = tuple(one(s) for s in model.group_specs)
        groups = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (model.n_groups,) + x.shape), per_group
        )
    return {"prefix": prefix, "groups": groups}
