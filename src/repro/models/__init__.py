"""Assigned LM architectures (10) as one composable model framework.

  config.py   — ModelConfig covering dense/GQA, MoE, Mamba-hybrid, RWKV6,
                enc-dec, VLM-stub families
  layers.py   — rmsnorm, rope, swiglu, chunked flash-style attention (pure
                jnp, lax.scan over KV blocks: compact HLO + linear memory),
                decode attention
  moe.py      — capacity-based top-k routing (sort dispatch, real-FLOP experts)
  mamba.py    — Mamba-1 selective SSM block (jamba's recurrent layer)
  rwkv.py     — RWKV-6 "Finch" block (data-dependent decay)
  blocks.py   — per-family layer groups (init + apply)
  model.py    — stacked model: init / train forward / prefill / decode
  sharding.py — parameter & activation partition specs per mesh
"""
