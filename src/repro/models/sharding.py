"""Partition specs: parameters, activations, KV caches, optimizer state.

Strategy (DESIGN.md §5):
  * TP over 'model'  — attention heads / FFN columns / vocab / experts (EP)
  * FSDP over 'data' — the non-TP dimension of every large weight is sharded
    over the data axis (ZeRO-3: XLA all-gathers at use, reduce-scatters grads)
  * DP over 'pod' x 'data' — the batch axis
Params are replicated across 'pod' (cross-pod traffic = gradient all-reduce
only, the DCN-friendly choice); optimizer state mirrors the param specs.

`set_active_mesh` lets model code place with_sharding_constraint hints only
when lowering under a mesh (smoke tests run unconstrained on one device).
"""

from __future__ import annotations

import re

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_ACTIVE = {"mesh": None, "dp": ("data",), "tp": "model"}


def set_active_mesh(mesh, dp_axes=("data",), tp_axis="model"):
    _ACTIVE["mesh"] = mesh
    _ACTIVE["dp"] = tuple(dp_axes)
    _ACTIVE["tp"] = tp_axis


def clear_active_mesh():
    _ACTIVE["mesh"] = None


def constrain(x, *spec):
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_act(x):
    """Pin the residual stream to the Megatron activation layout: batch over
    the DP axes, features replicated.  Without this anchor GSPMD's propagation
    at large model-axis sizes drifts into replicated-batch schedules (measured
    3.6-8.3x FLOPs on 16x16 — see EXPERIMENTS.md §Perf iteration 0)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return x
    if x.ndim == 3:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(_ACTIVE["dp"], None, None))
        )
    if x.ndim == 2:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(_ACTIVE["dp"], None))
        )
    return x


def dp_axes():
    return _ACTIVE["dp"]


def constrain_ep_weight(w):
    """Replicate an expert weight's non-E dims at USE (experts stay on
    'model').  Forces GSPMD to all-gather the FSDP-sharded weight — a
    loop-invariant transfer the scheduler hoists — instead of all-reducing
    the loop-variant (E, C, F) partial sums (measured 525 GiB/device of f32
    all-reduce on dbrx train_4k before this; §Perf iteration 2)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None or w.ndim != 3:
        return w
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    spec_e = "model" if w.shape[0] % sizes.get("model", 1) == 0 else None
    return jax.lax.with_sharding_constraint(
        w, NamedSharding(mesh, P(spec_e, None, None))
    )


def constrain_moe_buf(buf):
    """EP layout for the dispatch buffer (E, C, d): experts over 'model',
    capacity over the DP axes — keeps the expert einsum local per expert
    shard and lets XLA route the scatter as an all-to-all instead of
    all-reducing a replicated buffer (§Perf iteration 2)."""
    mesh = _ACTIVE["mesh"]
    if mesh is None:
        return buf
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = _ACTIVE["dp"]
    dp_size = 1
    for a in dp:
        dp_size *= sizes.get(a, 1)
    spec_c = dp if buf.shape[1] % max(dp_size, 1) == 0 else None
    spec_e = "model" if buf.shape[0] % sizes.get("model", 1) == 0 else None
    return jax.lax.with_sharding_constraint(
        buf, NamedSharding(mesh, P(spec_e, spec_c, None))
    )


# -------------------------------------------------------------- param rules

# matched against the JOINED key path (e.g. "groups/3/attn/wq"); first match
# wins.  Specs are written for the UNSTACKED shape; a leading None is
# prepended automatically for scan-stacked ("groups/...") leaves.
_RULES: list[tuple[str, tuple]] = [
    (r"embed$",            ("model", "data")),   # (V, D)
    (r"unembed$",          ("data", "model")),   # (D, V)
    (r"(attn|cross)/wq$",  ("data", "model")),
    (r"(attn|cross)/wk$",  ("data", "model")),
    (r"(attn|cross)/wv$",  ("data", "model")),
    (r"(attn|cross)/wo$",  ("model", "data")),
    (r"ffn/w_gate$",       ("data", "model")),
    (r"ffn/w_up$",         ("data", "model")),
    (r"ffn/w_down$",       ("model", "data")),
    (r"moe/router$",       ("data", None)),
    (r"moe/w_gate$",       ("model", "data", None)),   # (E, D, F): EP + FSDP
    (r"moe/w_up$",         ("model", "data", None)),
    (r"moe/w_down$",       ("model", None, "data")),
    (r"shared/w_gate$",    ("data", "model")),
    (r"shared/w_up$",      ("data", "model")),
    (r"shared/w_down$",    ("model", "data")),
    (r"mamba/in_proj$",    ("data", "model")),
    (r"mamba/conv_w$",     (None, "model")),
    (r"mamba/conv_b$",     ("model",)),
    (r"mamba/w_dt1$",      ("model", None)),
    (r"mamba/w_dt2$",      (None, "model")),
    (r"mamba/dt_bias$",    ("model",)),
    (r"mamba/w_B$",        ("model", None)),
    (r"mamba/w_C$",        ("model", None)),
    (r"mamba/A_log$",      ("model", None)),
    (r"mamba/D$",          ("model",)),
    (r"mamba/out_proj$",   ("model", "data")),
    (r"rwkv/w_o$",         ("model", "data")),
    (r"rwkv/w_[rkvg]$",    ("data", "model")),
    (r"rwkv/w_decay_a$",   ("data", None)),
    (r"rwkv/w_decay_b$",   (None, "model")),
    (r"rwkv/u_bonus$",     ("model", None)),
    (r"rwkv/cm_r$",        ("data", "model")),
    (r"rwkv/cm_k$",        ("data", "model")),
    (r"rwkv/cm_v$",        ("model", "data")),
    (r"rwkv/(mu_|ln_x|w_decay_base)", (None,)),
    (r"norm",              (None,)),
    (r".*",                (None,)),             # fallback: replicate
]


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def _spec_for(path_str: str, ndim: int) -> P:
    for pat, spec in _RULES:
        if re.search(pat, path_str):
            spec = tuple(spec)
            stacked = path_str.startswith("groups") or "/groups" in path_str
            if stacked:
                spec = (None,) + spec
            # pad/trim to ndim
            spec = spec[:ndim] + (None,) * max(0, ndim - len(spec))
            # divisibility guard happens at lowering; GSPMD requires divisible
            return P(*spec)
    return P()


def param_pspecs(params_shape) -> dict:
    """PartitionSpec pytree matching an eval_shape'd param tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _spec_for(_path_str(path), len(leaf.shape)),
        params_shape,
    )


def check_divisible(params_shape, pspecs, mesh) -> list[str]:
    """Returns a list of leaves whose sharded dims don't divide — these fall
    back to replication (GSPMD would otherwise fail)."""
    bad = []

    def fix(path, leaf, spec):
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        for dim, ax in enumerate(spec):
            if ax is None:
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            total = 1
            for a in axes:
                total *= sizes[a]
            if leaf.shape[dim] % total:
                bad.append(_path_str(path))
                return P()
        return spec

    fixed = jax.tree_util.tree_map_with_path(fix, params_shape, pspecs)
    return fixed, bad


def named(mesh, pspecs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                        is_leaf=lambda x: isinstance(x, P))
