"""RWKV-6 "Finch" block [arXiv:2404.05892]: attention-free time mix with
data-dependent decay (the low-rank 'lora' on w is the Finch signature),
plus the squared-ReLU channel mix.

Sequence path: lax.scan over time with per-head state (B, H, dk, dv).
Decode: one cell step on carried (shift, state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_rwkv(key, d_model: int, d_ff: int, n_heads: int, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 12)
    return {
        # time-mix interpolation factors (token shift)
        "mu_r": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_k": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_v": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_w": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_g": jnp.full((d_model,), 0.5, jnp.float32),
        "w_r": L.init_linear(ks[0], (d_model, d_model), dtype=dtype),
        "w_k": L.init_linear(ks[1], (d_model, d_model), dtype=dtype),
        "w_v": L.init_linear(ks[2], (d_model, d_model), dtype=dtype),
        "w_g": L.init_linear(ks[3], (d_model, d_model), dtype=dtype),
        "w_o": L.init_linear(ks[4], (d_model, d_model), dtype=dtype),
        # data-dependent decay: w = exp(-exp(base + lora(x)))
        "w_decay_base": jnp.full((d_model,), -2.0, jnp.float32),
        "w_decay_a": L.init_linear(ks[5], (d_model, 64), dtype=dtype),
        "w_decay_b": L.init_linear(ks[6], (64, d_model), scale=64**-0.5, dtype=dtype),
        "u_bonus": jnp.zeros((n_heads, dh), jnp.float32),
        "ln_x": jnp.ones((d_model,), jnp.float32),
        # channel mix
        "mu_cr": jnp.full((d_model,), 0.5, jnp.float32),
        "mu_ck": jnp.full((d_model,), 0.5, jnp.float32),
        "cm_r": L.init_linear(ks[7], (d_model, d_model), dtype=dtype),
        "cm_k": L.init_linear(ks[8], (d_model, d_ff), dtype=dtype),
        "cm_v": L.init_linear(ks[9], (d_ff, d_model), scale=d_ff**-0.5, dtype=dtype),
    }


def _shift(x: jnp.ndarray) -> jnp.ndarray:
    """Token shift: x_{t-1} (zeros at t=0). x (B, S, D)."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def _mix(x, prev, mu):
    return x + (prev - x) * mu.astype(x.dtype)


def _heads(x, H):
    B, S, D = x.shape
    return x.reshape(B, S, H, D // H)


def _projections(p, x):
    prev = _shift(x)
    r = jnp.einsum("bsd,de->bse", _mix(x, prev, p["mu_r"]), p["w_r"])
    k = jnp.einsum("bsd,de->bse", _mix(x, prev, p["mu_k"]), p["w_k"])
    v = jnp.einsum("bsd,de->bse", _mix(x, prev, p["mu_v"]), p["w_v"])
    g = jnp.einsum("bsd,de->bse", _mix(x, prev, p["mu_g"]), p["w_g"])
    xw = _mix(x, prev, p["mu_w"])
    decay = p["w_decay_base"] + jnp.einsum(
        "bsd,dr->bsr", xw, p["w_decay_a"]
    ).astype(jnp.float32) @ p["w_decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay))                           # (B, S, D) in (0,1)
    return r, k, v, g, w


def _finish(p, y, g, x_dtype, B, S, D):
    y = L.rmsnorm(y.astype(x_dtype), p["ln_x"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x_dtype)
    return jnp.einsum("bsd,de->bse", y, p["w_o"])


def time_mix_seq(p, x: jnp.ndarray, n_heads: int, chunk: int = 64) -> jnp.ndarray:
    """x (B, S, D) -> (B, S, D).  Dispatches to the chunked form."""
    if chunk and x.shape[1] > 1:
        return time_mix_seq_chunked(p, x, n_heads, chunk=chunk)
    return time_mix_seq_recurrent(p, x, n_heads)


def time_mix_seq_recurrent(p, x: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """Reference per-step recurrence (the tests' oracle for the chunked form).

    Memory behaviour: every step round-trips the (B, H, dh, dh) state through
    HBM and saves per-step residuals for backward — measured 1228 TiB/device
    on rwkv6-7b train_4k (§Perf iteration 3 baseline)."""
    B, S, D = x.shape
    H = n_heads
    dh = D // H
    r, k, v, g, w = _projections(p, x)

    rh = _heads(r, H).astype(jnp.float32)
    kh = _heads(k, H).astype(jnp.float32)
    vh = _heads(v, H).astype(jnp.float32)
    wh = _heads(w.astype(x.dtype), H).astype(jnp.float32)
    u = p["u_bonus"][None]                                  # (1, H, dh)

    def step(state, inp):
        r_t, k_t, v_t, w_t = inp                           # (B, H, dh) each
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, state + u[..., None] * kv)
        state = state * w_t[..., None] + kv
        return state, y

    xs = tuple(a.transpose(1, 0, 2, 3) for a in (rh, kh, vh, wh))
    s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    _, ys = jax.lax.scan(step, s0, xs)                      # (S, B, H, dh)
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, D)
    return _finish(p, y, g, x.dtype, B, S, D)


def time_mix_seq_chunked(p, x: jnp.ndarray, n_heads: int, chunk: int = 64) -> jnp.ndarray:
    """Chunked-parallel WKV6 (§Perf iteration 3): the recurrence is unrolled
    WITHIN chunks of c steps into dense (c x c) matmul form — the standard
    chunked-linear-attention factorization (GLA/RWKV kernels):

        S_{t-1} = diag(a_{t-1}) S_0 + sum_{s<t} diag(a_{t-1}/a_s) k_s^T v_s
        y_t     = r_t S_{t-1} + (r_t . u (x) k_t) v_t
                = rt~ S_0 + [tril_strict(rt~ Kt~^T)] V + diag-term
        with a_t = cumprod(w), rt~ = r_t (.) a_{t-1}, kt~ = k_s (.) a_s^{-1}

    State round-trips HBM once per CHUNK instead of once per step, and
    backward saves per-chunk residuals: c-fold less sequential traffic at the
    cost of the (c x c) intra-chunk matmuls — memory-bound -> MXU-bound.
    Cumulative decays are computed in log space with a +-30 clamp (exact vs
    the recurrence at realistic decay rates; tests/test_models.py asserts
    allclose against the recurrent oracle)."""
    B, S, D = x.shape
    H = n_heads
    dh = D // H
    r, k, v, g, w = _projections(p, x)

    pad = (-S) % chunk
    def pad_heads(a, fill=0.0):
        a = _heads(a, H).astype(jnp.float32)               # (B, S, H, dh)
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=fill)
        return a.transpose(0, 2, 1, 3)                     # (B, H, Sp, dh)

    rh, kh, vh = pad_heads(r), pad_heads(k), pad_heads(v)
    wh = pad_heads(w.astype(x.dtype), fill=1.0)
    nc = (S + pad) // chunk
    c = chunk

    def fold(a):  # (B, H, Sp, dh) -> (nc, B, H, c, dh)
        return a.reshape(B, H, nc, c, dh).transpose(2, 0, 1, 3, 4)

    rc, kc, vc, wc = fold(rh), fold(kh), fold(vh), fold(wh)
    u = p["u_bonus"][None]                                  # (1, H, dh)
    CL = 30.0  # log-space clamp

    def per_chunk(S0, inp):
        rt, kt, vt, wt = inp                               # (B, H, c, dh)
        logw = jnp.log(jnp.maximum(wt, 1e-38))
        Lw = jnp.cumsum(logw, axis=2)                      # inclusive cumsum
        L_excl = Lw - logw                                 # a_{t-1}
        a_excl = jnp.exp(jnp.clip(L_excl, -CL, CL))
        inv_a = jnp.exp(jnp.clip(-Lw, -CL, CL))
        r_t = rt * a_excl
        k_t = kt * inv_a

        scores = jnp.einsum("bhtd,bhsd->bhts", r_t, k_t)   # (B, H, c, c)
        mask = jnp.tril(jnp.ones((c, c), bool), k=-1)
        y_intra = jnp.einsum(
            "bhts,bhsv->bhtv", jnp.where(mask[None, None], scores, 0.0), vt
        )
        y_state = jnp.einsum("bhtd,bhdv->bhtv", r_t, S0)
        y_diag = jnp.sum(rt * u[..., None, :] * kt, axis=-1, keepdims=True) * vt
        y = y_intra + y_state + y_diag                     # (B, H, c, dh)

        a_end = jnp.exp(jnp.clip(Lw[:, :, -1:, :], -CL, CL))  # (B, H, 1, dh)
        decay_to_end = jnp.exp(jnp.clip(Lw[:, :, -1:, :] - Lw, -CL, CL))
        S_new = a_end[:, :, 0, :, None] * S0 + jnp.einsum(
            "bhsd,bhsv->bhdv", kt * decay_to_end, vt
        )
        return S_new, y

    s0 = jnp.zeros((B, H, dh, dh), jnp.float32)
    _, ys = jax.lax.scan(per_chunk, s0, (rc, kc, vc, wc))   # (nc, B, H, c, dh)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(B, H, S + pad, dh)
    y = y[:, :, :S].transpose(0, 2, 1, 3).reshape(B, S, D)
    return _finish(p, y, g, x.dtype, B, S, D)


def channel_mix_seq(p, x: jnp.ndarray) -> jnp.ndarray:
    prev = _shift(x)
    r = jax.nn.sigmoid(
        jnp.einsum("bsd,de->bse", _mix(x, prev, p["mu_cr"]), p["cm_r"]).astype(jnp.float32)
    ).astype(x.dtype)
    k = jnp.einsum("bsd,df->bsf", _mix(x, prev, p["mu_ck"]), p["cm_k"])
    k = jnp.square(jax.nn.relu(k.astype(jnp.float32))).astype(x.dtype)
    return r * jnp.einsum("bsf,fd->bsd", k, p["cm_v"])


# --------------------------------------------------------------------- decode


def init_rwkv_state(batch: int, d_model: int, n_heads: int):
    dh = d_model // n_heads
    return (
        jnp.zeros((batch, d_model), jnp.float32),            # time-mix shift
        jnp.zeros((batch, n_heads, dh, dh), jnp.float32),    # wkv state
        jnp.zeros((batch, d_model), jnp.float32),            # channel-mix shift
    )


def time_mix_decode(p, tshift, wkv, x, n_heads: int):
    """One-token time mix. tshift (B, D) f32, wkv (B, H, dh, dh) f32, x (B, D).
    Returns (new_tshift, new_wkv, out)."""
    B, D = x.shape
    H = n_heads
    dh = D // H
    prev = tshift.astype(x.dtype)

    def mix(mu):
        return x + (prev - x) * mu.astype(x.dtype)

    r = (mix(p["mu_r"]) @ p["w_r"]).reshape(B, H, dh).astype(jnp.float32)
    k = (mix(p["mu_k"]) @ p["w_k"]).reshape(B, H, dh).astype(jnp.float32)
    v = (mix(p["mu_v"]) @ p["w_v"]).reshape(B, H, dh).astype(jnp.float32)
    g = mix(p["mu_g"]) @ p["w_g"]
    decay = p["w_decay_base"] + (
        mix(p["mu_w"]) @ p["w_decay_a"]
    ).astype(jnp.float32) @ p["w_decay_b"].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(decay)).reshape(B, H, dh)
    u = p["u_bonus"][None]

    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, wkv + u[..., None] * kv)
    wkv = wkv * w[..., None] + kv
    y = y.reshape(B, D)
    y = L.rmsnorm(y.astype(x.dtype), p["ln_x"])
    y = y * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    return x.astype(jnp.float32), wkv, y @ p["w_o"]


def channel_mix_decode(p, cshift, x):
    """One-token channel mix. cshift (B, D) f32, x (B, D).
    Returns (new_cshift, out)."""
    prev = cshift.astype(x.dtype)
    rc = jax.nn.sigmoid(
        ((x + (prev - x) * p["mu_cr"].astype(x.dtype)) @ p["cm_r"]).astype(jnp.float32)
    ).astype(x.dtype)
    kc = (x + (prev - x) * p["mu_ck"].astype(x.dtype)) @ p["cm_k"]
    kc = jnp.square(jax.nn.relu(kc.astype(jnp.float32))).astype(x.dtype)
    return x.astype(jnp.float32), rc * (kc @ p["cm_v"])
