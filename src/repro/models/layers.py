"""Shared neural layers: norms, rope, attention (chunked flash-style, pure jnp).

The prefill/train attention streams over KV blocks with `jax.lax.scan` and an
online softmax — the same recurrence as kernels/flash_attention but expressed
in XLA ops, because (a) the dry-run lowers for a CPU-hosted 512-device mesh
where a TPU Pallas kernel cannot compile and interpret mode would unroll the
grid into the HLO, and (b) lax.scan keeps the HLO compact (one body) and the
peak memory linear in block size, which is what makes prefill_32k and
long_500k lowerable at all.  On real TPUs the model flips to the Pallas path
via `use_kernel=True` (tested in interpret mode on small shapes).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def rmsnorm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, Dh); positions: broadcastable to (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, w_down)


# --------------------------------------------------------------- attention


def chunked_attention(
    q: jnp.ndarray,        # (B, H, Sq, Dh)
    k: jnp.ndarray,        # (B, KVH, Skv, Dh)
    v: jnp.ndarray,        # (B, KVH, Skv, Dh)
    causal: bool = True,
    window: int = 0,       # 0 = full
    block: int = 512,
    q_offset: int | None = None,  # key position of query row 0
) -> jnp.ndarray:
    """Flash-style streaming attention in pure jnp (lax.scan over KV blocks)."""
    B, H, Sq, Dh = q.shape
    KVH, Skv = k.shape[1], k.shape[2]
    group = H // KVH
    scale = Dh**-0.5
    q_offset = q_offset if q_offset is not None else (Skv - Sq)
    block = min(block, Skv)
    nb = -(-Skv // block)
    pad = nb * block - Skv

    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    # fold blocks: (nb, B, KVH, block, Dh)
    kb = kp.reshape(B, KVH, nb, block, Dh).transpose(2, 0, 1, 3, 4)
    vb = vp.reshape(B, KVH, nb, block, Dh).transpose(2, 0, 1, 3, 4)

    q32 = (q * scale).astype(jnp.float32)
    q_pos = jnp.arange(Sq) + q_offset

    def body(carry, blk):
        m, l, acc, bi = carry
        kblk, vblk = blk  # (B, KVH, block, Dh)
        kk = jnp.repeat(kblk, group, axis=1).astype(jnp.float32)
        vv = jnp.repeat(vblk, group, axis=1).astype(jnp.float32)
        logits = jnp.einsum("bhqd,bhkd->bhqk", q32, kk)
        k_pos = bi * block + jnp.arange(block)
        mask = (k_pos[None, :] < Skv)
        if causal:
            mask = mask & (k_pos[None, :] <= q_pos[:, None])
        if window > 0:
            mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
        logits = jnp.where(mask[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vv)
        return (m_new, l_new, acc_new, bi + 1), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dh), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,        # (B, H, Dh) one token
    k: jnp.ndarray,        # (B, KVH, S, Dh) cache
    v: jnp.ndarray,
    context_len: jnp.ndarray | int,  # () or (B,) valid tokens
    window: int = 0,
) -> jnp.ndarray:
    """Single-token decode attention over a (possibly sharded) KV cache.

    Expressed as plain einsum/softmax so pjit can shard S (the long_500k path
    shards the cache sequence axis over 'data' and inserts the softmax
    reductions' collectives automatically)."""
    B, H, Dh = q.shape
    KVH, S = k.shape[1], k.shape[2]
    group = H // KVH
    scale = Dh**-0.5
    kk = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vv = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    logits = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32) * scale, kk)
    pos = jnp.arange(S)[None, :]
    ctx = jnp.asarray(context_len).reshape(-1, 1) if jnp.ndim(context_len) else jnp.full((1, 1), context_len)
    mask = pos < ctx
    if window > 0:
        mask = mask & (pos > ctx - 1 - window)
    logits = jnp.where(mask[:, None, :], logits, NEG_INF)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhs,bhsd->bhd", p, vv).astype(q.dtype)


# ----------------------------------------------------------------- embedding


def embed(tokens: jnp.ndarray, table: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(table, tokens, axis=0)


def chunked_ce_loss(
    h: jnp.ndarray,          # (B, S, D) final hidden states
    labels: jnp.ndarray,     # (B, S) int32, -100 = ignore
    unembed: jnp.ndarray,    # (D, V)
    chunk: int = 512,
) -> jnp.ndarray:
    """Cross-entropy without materializing (B, S, V) logits: scan over S chunks.

    Keeps peak activation memory ~ B*chunk*V_shard, which is what makes
    train_4k lowerable for 64k-262k vocabularies."""
    B, S, D = h.shape
    nb = -(-S // chunk)
    pad = nb * chunk - S
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
    lp = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-100)
    hb = hp.reshape(B, nb, chunk, D).transpose(1, 0, 2, 3)
    lb = lp.reshape(B, nb, chunk).transpose(1, 0, 2)

    def body(carry, blk):
        tot, cnt = carry
        hh, ll = blk
        logits = jnp.einsum("bsd,dv->bsv", hh.astype(jnp.float32), unembed.astype(jnp.float32))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(ll, 0)[..., None], axis=-1
        )[..., 0]
        valid = ll >= 0
        tot = tot + jnp.sum(jnp.where(valid, logz - gold, 0.0))
        cnt = cnt + jnp.sum(valid)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.int32(0)), (hb, lb)
    )
    return tot / jnp.maximum(cnt, 1)


def init_linear(key, shape, scale=None, dtype=jnp.bfloat16):
    scale = scale if scale is not None else shape[0] ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)
