"""Per-layer blocks: init + sequence apply (train/prefill) + decode apply.

A layer is described by a LayerSpec (static): kind (attn|mamba|rwkv), sliding
window, MoE-ness, cross-attention.  model.py stacks layers into scan groups.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import mamba as M
from repro.models import moe as MoE
from repro.models import rwkv as R
from repro.models import sharding as Sh
from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str            # attn | mamba | rwkv
    window: int          # 0 = global
    is_moe: bool
    cross: bool = False  # decoder cross-attention (whisper)
    causal: bool = True  # False for encoder self-attention

    @staticmethod
    def of(cfg: ModelConfig, i: int) -> "LayerSpec":
        return LayerSpec(
            kind=cfg.layer_kind(i),
            window=cfg.layer_window(i),
            is_moe=cfg.layer_is_moe(i),
            cross=cfg.cross_attention,
        )


def _dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------- init


def init_attention(key, cfg: ModelConfig):
    dt = _dtype(cfg)
    d, H, KVH, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "wq": L.init_linear(kq, (d, H * dh), dtype=dt),
        "wk": L.init_linear(kk, (d, KVH * dh), dtype=dt),
        "wv": L.init_linear(kv, (d, KVH * dh), dtype=dt),
        "wo": L.init_linear(ko, (H * dh, d), scale=(H * dh) ** -0.5, dtype=dt),
    }


def init_layer(key, cfg: ModelConfig, spec: LayerSpec):
    dt = _dtype(cfg)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p: dict = {"norm1": jnp.ones((d,), jnp.float32)}
    if spec.kind == "attn":
        p["attn"] = init_attention(ks[0], cfg)
    elif spec.kind == "mamba":
        p["mamba"] = M.init_mamba(
            ks[0], d, cfg.d_inner, cfg.ssm_state, cfg.dt_rank, cfg.ssm_conv, dt
        )
    elif spec.kind == "rwkv":
        p["rwkv"] = R.init_rwkv(ks[0], d, cfg.d_ff, cfg.n_heads, dt)
        return p  # rwkv block: time mix + channel mix only
    else:
        raise ValueError(spec.kind)

    if spec.cross:
        p["norm_x"] = jnp.ones((d,), jnp.float32)
        p["cross"] = init_attention(ks[1], cfg)

    p["norm2"] = jnp.ones((d,), jnp.float32)
    if spec.is_moe:
        p["moe"] = MoE.init_moe(
            ks[2], d, cfg.d_ff, cfg.n_experts, cfg.n_shared_experts, dt
        )
    else:
        dff = cfg.d_ff_dense or cfg.d_ff
        kg, ku, kd = jax.random.split(ks[3], 3)
        p["ffn"] = {
            "w_gate": L.init_linear(kg, (d, dff), dtype=dt),
            "w_up": L.init_linear(ku, (d, dff), dtype=dt),
            "w_down": L.init_linear(kd, (dff, d), scale=dff**-0.5, dtype=dt),
        }
    return p


# ------------------------------------------------------------------ seq apply


def _attn_seq(p, x, cfg, window, positions, kv_override=None, causal=True):
    B, S, d = x.shape
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    q = jnp.einsum("bsd,de->bse", x, p["wq"]).reshape(B, S, H, dh).transpose(0, 2, 1, 3)
    if kv_override is None:
        k = jnp.einsum("bsd,de->bse", x, p["wk"]).reshape(B, S, KVH, dh).transpose(0, 2, 1, 3)
        v = jnp.einsum("bsd,de->bse", x, p["wv"]).reshape(B, S, KVH, dh).transpose(0, 2, 1, 3)
        if causal:  # rope only on the decoder path (whisper enc uses none)
            q = L.rope(q, positions[:, None, :], cfg.rope_theta)
            k = L.rope(k, positions[:, None, :], cfg.rope_theta)
    else:
        # cross-attention: kv from the encoder sequence (no rope, bidirectional)
        k, v = kv_override
        causal = False
    out = L.chunked_attention(q, k, v, causal=causal, window=window)
    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * dh)
    return jnp.einsum("bse,ed->bsd", out, p["wo"]), (k, v)


def cross_kv(p_attn, enc_states, cfg):
    """Project encoder states to this layer's cross K/V: (B, KVH, T, dh)."""
    B, T, _ = enc_states.shape
    KVH, dh = cfg.n_kv_heads, cfg.d_head
    k = jnp.einsum("btd,de->bte", enc_states, p_attn["wk"]).reshape(B, T, KVH, dh)
    v = jnp.einsum("btd,de->bte", enc_states, p_attn["wv"]).reshape(B, T, KVH, dh)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def _ffn_or_moe(p, x, cfg, spec):
    B, S, d = x.shape
    if spec.is_moe:
        out, aux = MoE.moe_ffn_auto(
            p["moe"], x.reshape(B * S, d), cfg.moe_top_k, cfg.capacity_factor
        )
        return out.reshape(B, S, d), aux
    return L.swiglu(x, p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"]), jnp.float32(0.0)


def layer_seq(
    p, x, cfg: ModelConfig, spec: LayerSpec, positions, enc_states=None, want_cache=False
):
    """x (B, S, d) -> (x, cache, aux). cache=None unless want_cache."""
    aux = jnp.float32(0.0)
    cache = None
    x = Sh.constrain_act(x)  # anchor the residual-stream layout (Megatron DP)
    if spec.kind == "attn":
        h, (k, v) = _attn_seq(
            p["attn"], L.rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, spec.window,
            positions, causal=spec.causal,
        )
        x = x + h
        if want_cache:
            cache = {"k": k, "v": v}
        if spec.cross:
            assert enc_states is not None
            ck, cv = cross_kv(p["cross"], enc_states, cfg)
            hx, _ = _attn_seq(
                p["cross"], L.rmsnorm(x, p["norm_x"], cfg.norm_eps),
                cfg, 0, positions, kv_override=(ck, cv),
            )
            x = x + hx
            if want_cache:
                cache = dict(cache or {}, ck=ck, cv=cv)
        h, aux = _ffn_or_moe(p, L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg, spec)
        x = Sh.constrain_act(x + h)
    elif spec.kind == "mamba":
        h = M.mamba_seq(p["mamba"], L.rmsnorm(x, p["norm1"], cfg.norm_eps))
        x = x + h
        if want_cache:
            # final recurrent state: recomputed cheaply at decode start; for the
            # dry-run we hand back zeros-shaped state (prefill->decode handoff)
            B = x.shape[0]
            cache = {
                "conv": jnp.zeros((B, cfg.ssm_conv - 1, cfg.d_inner), x.dtype),
                "ssm": jnp.zeros((B, cfg.d_inner, cfg.ssm_state), jnp.float32),
            }
        h, aux = _ffn_or_moe(p, L.rmsnorm(x, p["norm2"], cfg.norm_eps), cfg, spec)
        x = Sh.constrain_act(x + h)
    elif spec.kind == "rwkv":
        x = x + R.time_mix_seq(p["rwkv"], x, cfg.n_heads)
        x = x + R.channel_mix_seq(p["rwkv"], x)
        if want_cache:
            B, D = x.shape[0], cfg.d_model
            dh = D // cfg.n_heads
            cache = {
                "tshift": jnp.zeros((B, D), jnp.float32),
                "wkv": jnp.zeros((B, cfg.n_heads, dh, dh), jnp.float32),
                "cshift": jnp.zeros((B, D), jnp.float32),
            }
    return x, cache, aux


# --------------------------------------------------------------- decode apply


def _attn_decode(p, x, cfg, window, cache, pos, enc_kv=None):
    """x (B, d); cache k/v (B, KVH, S, dh); writes the new token at `pos`."""
    B, d = x.shape
    H, KVH, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    klen = cache["k"].shape[2]
    q = (x @ p["wq"]).reshape(B, H, dh)
    k_new = (x @ p["wk"]).reshape(B, KVH, dh)
    v_new = (x @ p["wv"]).reshape(B, KVH, dh)
    posb = jnp.full((B, 1), pos)
    q = L.rope(q[:, :, None, :], posb[:, None, :], cfg.rope_theta)[:, :, 0, :]
    k_new = L.rope(k_new[:, :, None, :], posb[:, None, :], cfg.rope_theta)[:, :, 0, :]
    # Sliding-window layers use a ROLLING cache of klen <= window+1 slots
    # (gemma3/jamba long-context serving): the write index wraps; once full,
    # every slot is a valid in-window key.  Exact in both regimes: before the
    # wrap, context_len=pos+1 masks unwritten slots; after it, all klen slots
    # are in-window by construction (RoPE carries absolute positions and
    # softmax is order-invariant).
    write_idx = pos % klen if window > 0 else pos
    ctx = jnp.minimum(pos + 1, klen)
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new[:, :, None, :], write_idx, axis=2)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new[:, :, None, :], write_idx, axis=2)
    out = L.decode_attention(q, k, v, context_len=ctx, window=0)
    out = out.reshape(B, H * dh) @ p["wo"]
    return out, {"k": k, "v": v}


def layer_decode(p, x, cfg: ModelConfig, spec: LayerSpec, cache, pos):
    """x (B, d) one token -> (x, new_cache, aux). Cross K/V come from the cache
    (computed once at prefill — the paper's 'decode prefers resident data')."""
    aux = jnp.float32(0.0)
    if spec.kind == "attn":
        new_cache = dict(cache)
        attn_cache = {"k": cache["k"], "v": cache["v"]}
        h, attn_cache = _attn_decode(
            p["attn"], L.rmsnorm(x, p["norm1"], cfg.norm_eps), cfg, spec.window,
            attn_cache, pos,
        )
        new_cache.update(attn_cache)
        cache = new_cache
        x = x + h
        if spec.cross:
            B, d = x.shape
            H, dh = cfg.n_heads, cfg.d_head
            xq = L.rmsnorm(x, p["norm_x"], cfg.norm_eps)
            q = (xq @ p["cross"]["wq"]).reshape(B, H, dh)
            out = L.decode_attention(q, cache["ck"], cache["cv"], context_len=cache["ck"].shape[2])
            x = x + out.reshape(B, H * dh) @ p["cross"]["wo"]
        xf = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.is_moe:
            h, aux = MoE.moe_ffn_auto(p["moe"], xf, cfg.moe_top_k, cfg.capacity_factor)
        else:
            h = L.swiglu(xf[:, None, :], p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])[:, 0]
        x = x + h
    elif spec.kind == "mamba":
        state = (cache["conv"], cache["ssm"])
        state, h = M.mamba_decode(p["mamba"], state, L.rmsnorm(x, p["norm1"], cfg.norm_eps))
        cache = {"conv": state[0], "ssm": state[1]}
        x = x + h
        xf = L.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if spec.is_moe:
            h, aux = MoE.moe_ffn_auto(p["moe"], xf, cfg.moe_top_k, cfg.capacity_factor)
        else:
            h = L.swiglu(xf[:, None, :], p["ffn"]["w_gate"], p["ffn"]["w_up"], p["ffn"]["w_down"])[:, 0]
        x = x + h
    elif spec.kind == "rwkv":
        ts, wkv, out = R.time_mix_decode(p["rwkv"], cache["tshift"], cache["wkv"], x, cfg.n_heads)
        x = x + out
        cs, out2 = R.channel_mix_decode(p["rwkv"], cache["cshift"], x)
        x = x + out2
        cache = {"tshift": ts, "wkv": wkv, "cshift": cs}
    return x, cache, aux
