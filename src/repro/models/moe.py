"""Capacity-based top-k MoE with sort dispatch (GShard/Switch lineage).

Design constraints (dry-run driven):
  * dispatch must be gather/scatter, NOT one-hot matmuls — one-hot dispatch
    would add fake T*E*C*d FLOPs to cost_analysis and wreck the
    MODEL_FLOPS/HLO_FLOPS ratio (§Roofline);
  * expert compute must be a batched einsum (E, C, d) x (E, d, f) so FLOPs =
    topk * capacity_factor * active-FLOPs and EP sharding (experts over the
    'model' axis) partitions it cleanly;
  * static capacity C so shapes stay fixed for pjit.

Overflowed tokens (pos >= C) are dropped, standard for capacity routing.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import sharding as Sh


def init_moe(key, d_model: int, d_ff: int, n_experts: int, n_shared: int, dtype):
    ks = jax.random.split(key, 5)
    p = {
        "router": L.init_linear(ks[0], (d_model, n_experts), dtype=jnp.float32),
        "w_gate": L.init_linear(ks[1], (n_experts, d_model, d_ff), dtype=dtype),
        "w_up": L.init_linear(ks[2], (n_experts, d_model, d_ff), dtype=dtype),
        "w_down": L.init_linear(
            ks[3], (n_experts, d_ff, d_model), scale=d_ff**-0.5, dtype=dtype
        ),
    }
    if n_shared:
        kg, ku, kd = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": L.init_linear(kg, (d_model, n_shared * d_ff), dtype=dtype),
            "w_up": L.init_linear(ku, (d_model, n_shared * d_ff), dtype=dtype),
            "w_down": L.init_linear(
                kd, (n_shared * d_ff, d_model), scale=d_ff**-0.5, dtype=dtype
            ),
        }
    return p


def moe_ffn(
    p: dict,
    x: jnp.ndarray,          # (T, d) flattened tokens
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (T, d), aux_loss ()). Aux = load-balance loss (Switch)."""
    T, d = x.shape
    E = p["router"].shape[1]
    logits = x.astype(jnp.float32) @ p["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)     # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9
    )

    # ---- load-balance aux loss (Switch Transformer eq. 4)
    me = probs.mean(axis=0)                               # (E,)
    ce = jnp.zeros(E).at[gate_idx.reshape(-1)].add(
        jnp.ones(T * top_k) / (T * top_k)
    )
    aux = E * jnp.sum(me * ce)

    # ---- cumsum dispatch (NO global sort).  An argsort over the sharded
    # pair axis lowers to a distributed sort: measured 720 GiB/device of
    # collective-permute + all-reduce on dbrx train_4k (§Perf iteration 2).
    # Position-within-expert comes from an exclusive cumsum over the tiny
    # (T*k, E) one-hot instead.
    se = gate_idx.reshape(-1)                             # (T*k,) expert ids
    sw = gate_vals.reshape(-1).astype(x.dtype)
    st = jnp.repeat(jnp.arange(T), top_k)                 # token of each pair

    onehot = jax.nn.one_hot(se, E, dtype=jnp.int32)       # (T*k, E)
    pos = (jnp.cumsum(onehot, axis=0) - 1)                # position per expert
    pos = jnp.sum(pos * onehot, axis=1)                   # (T*k,)

    C = max(1, int(T * top_k / E * capacity_factor))
    keep = pos < C
    slot = jnp.where(keep, pos, C)                        # overflow -> trash col

    buf = jnp.zeros((E, C + 1, d), x.dtype).at[se, slot].set(x[st])
    buf = buf[:, :C]                                      # (E, C, d)
    # NOTE: constraining buf to P('model', dp, None) was tried and REFUTED:
    # GSPMD lowers the cross-shard scatter to masked u32/f32 all-reduces of
    # the full (T*k, d) update tensor (measured 15 TiB/device on dbrx).
    # Auto propagation + gathered weights is the best GSPMD-era schedule;
    # a shard_map all-to-all dispatch is the documented next step (§Perf).

    # ---- expert FFN (real FLOPs only)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])        # (E, C, d)

    # ---- combine
    yp = jnp.pad(y, ((0, 0), (0, 1), (0, 0)))             # trash col back
    contrib = yp[se, slot] * (sw * keep.astype(sw.dtype))[:, None]
    out = jnp.zeros((T, d), x.dtype).at[st].add(contrib)

    if "shared" in p:
        out = out + L.swiglu(
            x, p["shared"]["w_gate"], p["shared"]["w_up"], p["shared"]["w_down"]
        )
    return out, aux


# ------------------------------------------------------- shard_map EP path


def moe_ffn_ep(
    p: dict,
    x: jnp.ndarray,          # (T, d), T sharded over the DP axes
    top_k: int,
    capacity_factor: float = 1.25,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Expert parallelism with an EXPLICIT schedule (shard_map), used when a
    mesh is active.  GSPMD's auto-partitioning of the scatter/gather dispatch
    was measured at 9.7 TiB/device of collectives on dbrx train_4k, and every
    constraint-based nudge shifted the pathology (masked-all-reduce scatters,
    replicated expert compute — §Perf iteration 2, refuted twice).  The manual
    schedule exploits that expert weights are sharded ONLY over 'model':

      * router + dispatch run replicated within each DP row (token-local),
      * each model column computes only its expert slice for the row's
        local tokens -> NO token movement at dispatch,
      * combine = one bf16 psum over 'model' of the (T_loc, d) partial
        outputs (each column contributes its experts' share).

    Collectives per MoE layer: exactly one all-reduce of T_loc x d bf16 (+
    the FSDP weight gathers XLA hoists) — the napkin minimum for EP without
    token all-to-all.
    """
    mesh = Sh._ACTIVE["mesh"]
    dp = Sh._ACTIVE["dp"]
    E = p["router"].shape[1]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get("model", 1)
    dp_size = 1
    for a in dp:
        dp_size *= sizes.get(a, 1)
    T, d = x.shape
    if E % n_model or T % dp_size:
        # EP ungranular, or too few tokens to split over DP (single-token
        # decode): fall back to the GSPMD path.
        return moe_ffn(p, x, top_k, capacity_factor)
    E_loc = E // n_model

    from jax.sharding import PartitionSpec as P

    def body(router, wg, wu, wd, x_loc):
        # x_loc (T_loc, d); router (d, E) replicated; w* (E_loc, d, F)
        T_loc = x_loc.shape[0]
        logits = x_loc.astype(jnp.float32) @ router           # (T_loc, E)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(axis=-1, keepdims=True), 1e-9
        )

        me = probs.mean(axis=0)
        ce = jnp.zeros(E).at[gate_idx.reshape(-1)].add(
            jnp.ones(T_loc * top_k) / (T_loc * top_k)
        )
        aux = E * jnp.sum(me * ce)
        for ax in dp:
            aux = jax.lax.pmean(aux, ax)

        se = gate_idx.reshape(-1)
        sw = gate_vals.reshape(-1).astype(x_loc.dtype)
        st = jnp.repeat(jnp.arange(T_loc), top_k)
        onehot = jax.nn.one_hot(se, E, dtype=jnp.int32)
        pos = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=1)
        C = max(1, int(T_loc * top_k / E * capacity_factor))
        keep = pos < C
        slot = jnp.where(keep, pos, C)

        # local slice of experts this model column owns
        j = jax.lax.axis_index("model")
        e_lo = j * E_loc
        my = (se >= e_lo) & (se < e_lo + E_loc) & keep
        se_loc = jnp.where(my, se - e_lo, E_loc)              # E_loc = trash row
        buf = jnp.zeros((E_loc + 1, C + 1, d), x_loc.dtype).at[
            se_loc, jnp.where(my, slot, C)
        ].set(x_loc[st])
        buf = buf[:E_loc, :C]                                 # (E_loc, C, d)

        g = jnp.einsum("ecd,edf->ecf", buf, wg)
        u = jnp.einsum("ecd,edf->ecf", buf, wu)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x_loc.dtype) * u
        y = jnp.einsum("ecf,efd->ecd", h, wd)                 # (E_loc, C, d)

        yp = jnp.pad(y, ((0, 1), (0, 1), (0, 0)))
        contrib = yp[se_loc, jnp.where(my, slot, C)] * (
            sw * my.astype(sw.dtype)
        )[:, None]
        out = jnp.zeros((T_loc, d), x_loc.dtype).at[st].add(contrib)
        out = jax.lax.psum(out, "model")                      # combine
        return out, aux

    fn = jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(), P("model", None, None), P("model", None, None),
                  P("model", None, None), P(dp, None)),
        out_specs=(P(dp, None), P()),
        check_vma=False,
    )
    out, aux = fn(
        p["router"], p["w_gate"], p["w_up"], p["w_down"], x
    )
    if "shared" in p:
        out = out + L.swiglu(
            x, p["shared"]["w_gate"], p["shared"]["w_up"], p["shared"]["w_down"]
        )
    return out, aux


def moe_ffn_auto(p, x, top_k, capacity_factor=1.25):
    """Dispatch to the explicit-EP path under a mesh, GSPMD path otherwise."""
    if Sh._ACTIVE["mesh"] is not None:
        return moe_ffn_ep(p, x, top_k, capacity_factor)
    return moe_ffn(p, x, top_k, capacity_factor)
