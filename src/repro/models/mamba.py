"""Mamba-1 selective SSM block (jamba's recurrent layer) [arXiv:2312.00752].

Sequence path uses a sequential lax.scan over time with state (B, d_inner, N):
compact HLO (one body) and exact recurrence semantics.  A fused chunked-scan
Pallas kernel is the production TPU path for this hot spot; the dry-run cost
model of the sequential scan is conservative (noted in DESIGN.md / §Perf).
Decode is the same cell applied once to carried (conv_state, ssm_state).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_mamba(key, d_model: int, d_inner: int, N: int, dt_rank: int, K: int, dtype):
    ks = jax.random.split(key, 8)
    return {
        "in_proj": L.init_linear(ks[0], (d_model, 2 * d_inner), dtype=dtype),
        "conv_w": L.init_linear(ks[1], (K, d_inner), scale=K**-0.5, dtype=dtype),
        "conv_b": jnp.zeros((d_inner,), dtype),
        "w_dt1": L.init_linear(ks[2], (d_inner, dt_rank), dtype=dtype),
        "w_dt2": L.init_linear(ks[3], (dt_rank, d_inner), scale=dt_rank**-0.5, dtype=dtype),
        "dt_bias": jnp.full((d_inner,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "w_B": L.init_linear(ks[4], (d_inner, N), dtype=dtype),
        "w_C": L.init_linear(ks[5], (d_inner, N), dtype=dtype),
        "A_log": jnp.log(
            jnp.tile(jnp.arange(1, N + 1, dtype=jnp.float32)[None, :], (d_inner, 1))
        ),
        "D": jnp.ones((d_inner,), jnp.float32),
        "out_proj": L.init_linear(ks[6], (d_inner, d_model), scale=d_inner**-0.5, dtype=dtype),
    }


def _cell(p, h, x_t, dt_t, B_t, C_t):
    """One recurrence step. h (B, di, N); x_t, dt_t (B, di); B_t, C_t (B, N)."""
    A = -jnp.exp(p["A_log"])                              # (di, N)
    dA = jnp.exp(dt_t[..., None] * A[None])               # (B, di, N)
    dBx = dt_t[..., None] * x_t[..., None] * B_t[:, None, :]
    h = h * dA + dBx
    y = jnp.einsum("bdn,bn->bd", h, C_t)
    return h, y


def _pre(p, x):
    """Shared projections: x (B, S, d_model) -> (xc, z, dt, Bm, Cm)."""
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    x1, z = jnp.split(xz, 2, axis=-1)                     # (B, S, di)
    return x1, z


def _conv_scan_inputs(p, x1):
    B, S, di = x1.shape
    K = p["conv_w"].shape[0]
    xp = jnp.pad(x1, ((0, 0), (K - 1, 0), (0, 0)))
    xc = sum(
        xp[:, i : i + S, :] * p["conv_w"][i][None, None, :] for i in range(K)
    ) + p["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x1.dtype)
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dr->bsr", xc, p["w_dt1"]) @ p["w_dt2"]
        + p["dt_bias"]
    ).astype(jnp.float32)                                  # (B, S, di)
    Bm = jnp.einsum("bsd,dn->bsn", xc, p["w_B"]).astype(jnp.float32)
    Cm = jnp.einsum("bsd,dn->bsn", xc, p["w_C"]).astype(jnp.float32)
    return xc, dt, Bm, Cm


def mamba_seq(p, x: jnp.ndarray, chunk: int = 32) -> jnp.ndarray:
    """Training/prefill path. x (B, S, d_model) -> (B, S, d_model).

    Dispatches to the chunked form (§Perf iteration 5) for S > 1."""
    if chunk and x.shape[1] > 1:
        return mamba_seq_chunked(p, x, chunk=chunk)
    return mamba_seq_recurrent(p, x)


def mamba_seq_recurrent(p, x: jnp.ndarray) -> jnp.ndarray:
    """Reference per-step recurrence (the tests' oracle for the chunked form)."""
    B, S, _ = x.shape
    N = p["w_B"].shape[1]
    di = p["D"].shape[0]
    x1, z = _pre(p, x)
    xc, dt, Bm, Cm = _conv_scan_inputs(p, x1)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        h, y = _cell(p, h, x_t.astype(jnp.float32), dt_t, B_t, C_t)
        return h, y

    h0 = jnp.zeros((B, di, N), jnp.float32)
    xs = (
        xc.transpose(1, 0, 2),
        dt.transpose(1, 0, 2),
        Bm.transpose(1, 0, 2),
        Cm.transpose(1, 0, 2),
    )
    _, ys = jax.lax.scan(step, h0, xs)                     # ys (S, B, di)
    y = ys.transpose(1, 0, 2).astype(x.dtype)
    y = y + p["D"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba_seq_chunked(p, x: jnp.ndarray, chunk: int = 32) -> jnp.ndarray:
    """Chunked selective scan (§Perf iteration 5): the diagonal recurrence
        h_t = a_t (.) h_{t-1} + b_t,   a_t = exp(dt_t A),  b_t = dt_t x_t B_t
    unrolls within a chunk of c steps via log-space cumulative decays:
        h_t = exp(L_t) (.) [h_0 + cumsum_{s<=t} exp(-L_s) (.) b_s],
        y_t = <C_t, h_t>_N
    so the (B, di, N) state round-trips HBM once per CHUNK; the within-chunk
    cumsum runs over a (B, c, di, N) tile (the VMEM-resident working set of a
    fused TPU kernel).  Identical math — allclose vs the recurrence in
    tests/test_models.py."""
    Bsz, S, _ = x.shape
    N = p["w_B"].shape[1]
    di = p["D"].shape[0]
    x1, z = _pre(p, x)
    xc, dt, Bm, Cm = _conv_scan_inputs(p, x1)

    pad = (-S) % chunk
    c = chunk
    nc = (S + pad) // c

    def fold(a, fill=0.0):
        if pad:
            a = jnp.pad(
                a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2),
                constant_values=fill,
            )
        return a.reshape(Bsz, nc, c, *a.shape[2:]).transpose(1, 0, 2, *range(3, a.ndim + 1))

    xcf = fold(xc.astype(jnp.float32))
    dtf = fold(dt)                                          # (nc, B, c, di)
    Bf = fold(Bm)                                           # (nc, B, c, N)
    Cf = fold(Cm)
    A = -jnp.exp(p["A_log"])                                # (di, N)
    CL = 30.0

    def per_chunk(h0, inp):
        xck, dtk, Bk, Ck = inp                              # (B, c, ...)
        # log decays: L_t = sum_{s<=t} dt_s A   (all negative)
        la = dtk[..., None] * A[None, None]                 # (B, c, di, N)
        L = jnp.cumsum(la, axis=1)
        b = dtk[..., None] * xck[..., None] * Bk[:, :, None, :]  # (B, c, di, N)
        inner = jnp.cumsum(jnp.exp(jnp.clip(-L, -CL, CL)) * b, axis=1)
        h = jnp.exp(jnp.clip(L, -CL, CL)) * (h0[:, None] + inner)  # (B, c, di, N)
        y = jnp.einsum("bcdn,bcn->bcd", h, Ck)              # (B, c, di)
        h_end = h[:, -1]
        return h_end, y

    h0 = jnp.zeros((Bsz, di, N), jnp.float32)
    _, ys = jax.lax.scan(per_chunk, h0, (xcf, dtf, Bf, Cf))  # (nc, B, c, di)
    y = ys.transpose(1, 0, 2, 3).reshape(Bsz, S + pad, di)[:, :S]
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", y, p["out_proj"])


def mamba_decode(p, state, x):
    """One-token path. state = (conv_buf (B, K-1, di), h (B, di, N)); x (B, d)."""
    conv_buf, h = state
    K = p["conv_w"].shape[0]
    xz = x @ p["in_proj"]
    x1, z = jnp.split(xz, 2, axis=-1)                      # (B, di)
    window = jnp.concatenate([conv_buf, x1[:, None, :]], axis=1)  # (B, K, di)
    xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc.astype(jnp.float32)).astype(x.dtype)
    dt = jax.nn.softplus(
        (xc @ p["w_dt1"]) @ p["w_dt2"] + p["dt_bias"]
    ).astype(jnp.float32)
    B_t = (xc @ p["w_B"]).astype(jnp.float32)
    C_t = (xc @ p["w_C"]).astype(jnp.float32)
    h, y = _cell(p, h, xc.astype(jnp.float32), dt, B_t, C_t)
    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xc
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    out = y @ p["out_proj"]
    return (window[:, 1:], h), out


def init_mamba_state(batch: int, d_inner: int, N: int, K: int, dtype):
    return (
        jnp.zeros((batch, K - 1, d_inner), dtype),
        jnp.zeros((batch, d_inner, N), jnp.float32),
    )
