"""Continuous batching with cache-aware admission (paper C5 -> serving).

Each decode step assembles a batch of runnable requests.  When the KV pool is
oversubscribed (more requests than resident pages), the scheduler prioritizes
requests whose KV pages are RESIDENT — the serving analogue of Alg. 2's
in-memory pivot — so swap-ins happen off the busy path instead of stalling
every step.  Round-robin aging prevents starvation of swapped-out requests.
"""

from __future__ import annotations

import dataclasses
from collections import deque

from repro.serving.kv_pool import PagedKVPool


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt_len: int
    max_new_tokens: int
    generated: int = 0


class CacheAwareScheduler:
    def __init__(self, pool: PagedKVPool, max_batch: int = 8, age_boost: int = 4,
                 max_running: int | None = None):
        self.pool = pool
        self.max_batch = max_batch
        self.max_running = max_running or 2 * max_batch  # oversubscription: more
        # live requests than decode slots — the regime where cache-aware
        # ordering matters (the KV pool holds more requests than fit a batch)
        self.age_boost = age_boost     # steps after which a starved request
                                       # is scheduled regardless of residency
        self.queue: deque[ServeRequest] = deque()
        self.running: dict[int, ServeRequest] = {}
        self.starved: dict[int, int] = {}
        self.completed: list[int] = []

    def submit(self, req: ServeRequest) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and len(self.running) < self.max_running:
            req = self.queue.popleft()
            self.pool.add_request(req.rid)
            self.running[req.rid] = req
            self.starved[req.rid] = 0

    def next_batch(self) -> list[ServeRequest]:
        """Pick up to max_batch runnable requests, resident-first (C5)."""
        self._admit()
        ranked = sorted(
            self.running.values(),
            key=lambda r: (
                -(self.starved[r.rid] >= self.age_boost),      # aged first
                -self.pool.residency_fraction(r.rid),           # then resident
                r.rid,
            ),
        )
        batch = ranked[: self.max_batch]
        chosen = {r.rid for r in batch}
        for rid in self.running:
            self.starved[rid] = 0 if rid in chosen else self.starved[rid] + 1
        return batch

    def complete_step(self, batch: list[ServeRequest]) -> None:
        for req in batch:
            req.generated += 1
            if req.generated >= req.max_new_tokens:
                self.pool.finish_request(req.rid)
                del self.running[req.rid]
                del self.starved[req.rid]
                self.completed.append(req.rid)

    @property
    def idle(self) -> bool:
        return not self.queue and not self.running
