"""Paged KV block pool — the record-level buffer pool (paper §3.2) for serving.

The mapping (DESIGN.md §Arch-applicability):
  vertex record          -> KV page (page_size tokens of one sequence's K/V)
  record mapping array   -> per-request block table (logical page -> physical)
  slot state machine     -> page states FREE/OCCUPIED/MARKED with a clock hand
  'SSD tier'             -> host swap: evicted pages spill to a host store and
                            reload on access (the larger-than-HBM serving mode)

The pool is the single physical (P, page, KVH, dh) K/V tensor pair that
kernels/paged_attention consumes; block tables index into it — the same
hybrid-pointer indirection the ANN engine uses for records.
"""

from __future__ import annotations

import dataclasses

import numpy as np

FREE, OCCUPIED, MARKED = 0, 2, 3  # matches bufferpool's state ids


@dataclasses.dataclass
class Request:
    rid: int
    block_table: list[int]          # logical page -> physical page (-1 = swapped)
    context_len: int = 0
    done: bool = False


class PagedKVPool:
    """Physical page pool + per-request block tables + clock eviction.

    Evicted pages spill to a host-side store keyed (rid, logical_page) and are
    reloaded (possibly into a different physical page) on access — exactly the
    paper's record load path with the page id swapped for a swap key."""

    def __init__(self, n_pages: int, page_size: int, kv_heads: int, head_dim: int,
                 dtype=np.float32):
        self.page_size = page_size
        self.n_pages = n_pages
        self.k_pages = np.zeros((n_pages, page_size, kv_heads, head_dim), dtype)
        self.v_pages = np.zeros((n_pages, page_size, kv_heads, head_dim), dtype)
        self.state = np.full(n_pages, FREE, np.int8)
        self.owner = np.full((n_pages, 2), -1, np.int64)   # (rid, logical_page)
        self.hand = 0
        self.requests: dict[int, Request] = {}
        self.swap: dict[tuple[int, int], tuple[np.ndarray, np.ndarray]] = {}
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.swap_ins = 0

    # ------------------------------------------------------------- requests

    def add_request(self, rid: int) -> Request:
        req = Request(rid=rid, block_table=[])
        self.requests[rid] = req
        return req

    def finish_request(self, rid: int) -> None:
        req = self.requests.pop(rid)
        req.done = True
        for pp in req.block_table:
            if pp >= 0:
                self._free_page(pp)
        for key in [k for k in self.swap if k[0] == rid]:
            del self.swap[key]

    # ---------------------------------------------------------------- pages

    def _free_page(self, pp: int) -> None:
        self.state[pp] = FREE
        self.owner[pp] = (-1, -1)

    def _alloc_page(self) -> int:
        free = np.nonzero(self.state == FREE)[0]
        if len(free):
            pp = int(free[0])
        else:
            pp = self._clock_evict()
        self.state[pp] = OCCUPIED
        return pp

    def _clock_evict(self) -> int:
        """Clock second-chance over physical pages; victim spills to host."""
        for _ in range(3 * self.n_pages):
            pp = self.hand
            self.hand = (self.hand + 1) % self.n_pages
            st = self.state[pp]
            if st == OCCUPIED:
                self.state[pp] = MARKED
            elif st == MARKED:
                rid, lp = (int(x) for x in self.owner[pp])
                self.swap[(rid, lp)] = (
                    self.k_pages[pp].copy(), self.v_pages[pp].copy()
                )
                if rid in self.requests and lp < len(self.requests[rid].block_table):
                    self.requests[rid].block_table[lp] = -1
                self._free_page(pp)
                self.evictions += 1
                return pp
        raise RuntimeError("clock failed: all pages pinned")

    def _touch(self, pp: int) -> None:
        if self.state[pp] == MARKED:
            self.state[pp] = OCCUPIED  # second chance

    # ----------------------------------------------------------------- write

    def append_token(self, rid: int, k: np.ndarray, v: np.ndarray) -> None:
        """Append one token's K/V (kv_heads, head_dim) to the request."""
        req = self.requests[rid]
        lp = req.context_len // self.page_size
        off = req.context_len % self.page_size
        if lp >= len(req.block_table):
            req.block_table.append(self._alloc_page())
            self.owner[req.block_table[lp]] = (rid, lp)
        pp = self.ensure_resident(rid, lp)
        self.k_pages[pp, off] = k
        self.v_pages[pp, off] = v
        req.context_len += 1

    # ---------------------------------------------------------------- access

    def is_resident(self, rid: int, lp: int) -> bool:
        req = self.requests[rid]
        return lp < len(req.block_table) and req.block_table[lp] >= 0

    def residency_fraction(self, rid: int) -> float:
        req = self.requests[rid]
        if not req.block_table:
            return 1.0
        return sum(p >= 0 for p in req.block_table) / len(req.block_table)

    def ensure_resident(self, rid: int, lp: int) -> int:
        """The load path: hit -> touch; miss -> alloc page + swap-in."""
        req = self.requests[rid]
        pp = req.block_table[lp]
        if pp >= 0:
            self._touch(pp)
            self.hits += 1
            return pp
        self.misses += 1
        pp = self._alloc_page()
        k, v = self.swap.pop((rid, lp))
        self.k_pages[pp] = k
        self.v_pages[pp] = v
        self.owner[pp] = (rid, lp)
        req.block_table[lp] = pp
        self.swap_ins += 1
        return pp

    def block_table_array(self, rid: int, max_pages: int) -> np.ndarray:
        """Materialize a dense block table for the paged_attention kernel,
        swapping in any non-resident page (the demand path)."""
        req = self.requests[rid]
        out = np.zeros(max_pages, np.int32)
        for lp in range(len(req.block_table)):
            out[lp] = self.ensure_resident(rid, lp)
        return out

    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 1.0
