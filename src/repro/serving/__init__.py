"""LM serving substrate: the paper's buffer-pool ideas applied to KV caches.

  kv_pool.py   — paged KV block pool: record_map-style indirection (a block
                 table per request), clock second-chance eviction across
                 requests (paper C2 -> KV pages)
  scheduler.py — continuous batching with cache-aware admission: runnable
                 requests whose KV blocks are resident are scheduled first
                 (paper C5 -> decode scheduling)
"""
