"""HBM record cache with record-map indirection + vectorized clock (§3.2 on device).

For corpora larger than device memory the ext codes + adjacency live on the
host ("SSD" tier); HBM holds a fixed-slot cache of decoded records.  This
module keeps the paper's exact structures as device arrays:

  record_map (n,) int32 — hybrid pointer: >= 0 slot index (resident),
                          < 0 encodes the host page id as -(pid+1)
  slot_state (S,) int8  — FREE/LOCKED/OCCUPIED/MARKED (Fig. 5)
  slot_vid   (S,) int32
  cache_ext  (S, d/2) uint8 / cache_lo/step (S,) / cache_adj (S, R) int32

The clock sweep is a *vectorized* pass (DESIGN.md §2 adaptation 3): instead of
an atomically-advancing hand, one pass demotes OCCUPIED->MARKED and selects
the first `need` MARKED slots past the hand for eviction — identical steady
state, race-free by lockstep construction.

The engine loop (host-driven):
  1. run a search step on device; collect the miss list (ids not resident)
  2. fetch missing records' affinity groups from the host store
  3. scatter them into cache slots (this is the DMA the paper overlaps);
     prefetch for step t+1 issues while step t computes (double buffering)
"""

from __future__ import annotations

import dataclasses

import numpy as np


FREE, LOCKED, OCCUPIED, MARKED = 0, 1, 2, 3


@dataclasses.dataclass
class DeviceRecordCache:
    """Functional cache state; numpy-backed (the host mirror of the device
    arrays — updates produce the scatter indices/values a device step applies)."""

    record_map: np.ndarray     # (n,) int32
    disk_pages: np.ndarray     # (n,) int32 — immutable page ids (host tier)
    slot_state: np.ndarray     # (S,) int8
    slot_vid: np.ndarray       # (S,) int32
    cache_ext: np.ndarray      # (S, d/2) uint8
    cache_lo: np.ndarray       # (S,)
    cache_step: np.ndarray     # (S,)
    cache_adj: np.ndarray      # (S, R) int32
    hand: int = 0
    hits: int = 0
    misses: int = 0
    evictions: int = 0

    @classmethod
    def create(cls, n_slots: int, vid_to_page: np.ndarray, dim: int, R: int,
               code_cols: int | None = None):
        n = len(vid_to_page)
        if code_cols is None:
            code_cols = dim // 2  # 4-bit packed ext codes (8-bit passes dim)
        return cls(
            record_map=-(vid_to_page.astype(np.int32) + 1),
            disk_pages=vid_to_page.astype(np.int32),
            slot_state=np.full(n_slots, FREE, np.int8),
            slot_vid=np.full(n_slots, -1, np.int32),
            cache_ext=np.zeros((n_slots, code_cols), np.uint8),
            cache_lo=np.zeros(n_slots, np.float32),
            cache_step=np.ones(n_slots, np.float32),
            cache_adj=np.full((n_slots, R), -1, np.int32),
        )

    @property
    def n_slots(self) -> int:
        return len(self.slot_state)

    # ------------------------------------------------------------- residency

    def resident_mask(self, vids: np.ndarray) -> np.ndarray:
        return self.record_map[vids] >= 0

    def touch(self, vids: np.ndarray) -> None:
        """Vectorized lookup side effects: hits give MARKED slots a second chance."""
        res = self.resident_mask(vids)
        slots = self.record_map[vids[res]]
        marked = self.slot_state[slots] == MARKED
        self.slot_state[slots[marked]] = OCCUPIED
        self.hits += int(res.sum())
        self.misses += int((~res).sum())

    # ----------------------------------------------------------------- clock

    def sweep(self, need: int) -> np.ndarray:
        """Vectorized clock: returns freed slot indices (len <= need; LOCKED
        slots are never reclaimed, and `need` is capped at the slot count)."""
        need = min(need, self.n_slots)
        freed: list[int] = []
        for _ in range(3):  # at most 3 passes (mirror of the host-plane bound)
            if len(freed) >= need:
                break
            order = (np.arange(self.n_slots) + self.hand) % self.n_slots
            states = self.slot_state[order]
            # first demote-or-evict pass in hand order
            for idx, st in zip(order, states):
                if len(freed) >= need:
                    break
                if st == OCCUPIED:
                    self.slot_state[idx] = MARKED
                elif st == MARKED:
                    vid = int(self.slot_vid[idx])
                    self.record_map[vid] = -(int(self.disk_pages[vid]) + 1)
                    self._evict(idx)
                    freed.append(idx)
                self.hand = (int(idx) + 1) % self.n_slots
        return np.asarray(freed[:need], dtype=np.int64)

    def _evict(self, slot: int) -> None:
        self.slot_state[slot] = FREE
        self.slot_vid[slot] = -1
        self.evictions += 1

    # ----------------------------------------------------------------- admit

    def admit(self, vids, exts, los, steps_, adjs, disk_pages) -> None:
        """Batch-admit fetched records (one affinity group / DMA batch)."""
        todo = [i for i, v in enumerate(vids) if self.record_map[v] < 0]
        if not todo:
            return
        free = np.nonzero(self.slot_state == FREE)[0]
        if len(free) < len(todo):
            extra = self.sweep(len(todo) - len(free))
            free = np.concatenate([free, extra])
        for i, slot in zip(todo, free[: len(todo)]):
            vid = int(vids[i])
            self.slot_state[slot] = LOCKED
            self.cache_ext[slot] = exts[i]
            self.cache_lo[slot] = los[i]
            self.cache_step[slot] = steps_[i]
            adj = adjs[i]
            self.cache_adj[slot, :] = -1
            self.cache_adj[slot, : len(adj)] = adj
            self.slot_vid[slot] = vid
            self.record_map[vid] = slot
            self.slot_state[slot] = OCCUPIED

    def hit_rate(self) -> float:
        t = self.hits + self.misses
        return self.hits / t if t else 0.0
