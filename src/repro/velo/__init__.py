"""Device plane: the TPU-native VeloANN engine (DESIGN.md §2).

  index.py        — DeviceIndex: the compressed index as a pytree of arrays
  batch_search.py — batched lockstep cache-aware beam search (lax.scan)
  scan_search.py  — kernel-powered two-stage scan (binary MXU scan -> int4
                    rerank): the beyond-paper TPU mode for sharded corpora
  device_cache.py — HBM record cache with record_map indirection + vectorized
                    clock second-chance (paper §3.2 on device)
  dist_search.py  — shard_map distributed search with top-k merge
"""
