"""Two-stage compressed scan: binary MXU sweep -> int4 rerank (beyond-paper mode).

On a CPU+SSD, graph traversal wins because it touches ~L of n records.  On a
TPU shard the economics flip: the level-1 codes of a few million vectors fit
in HBM (d/8 bytes each), and the MXU turns the full binary scan into a dense
GEMM running at roofline — no data-dependent gathers, no traversal serialism.
VeloANN's own compression makes this possible: this mode is the paper's
level-1/level-2 hierarchy with the traversal replaced by a scan, and is what
the veloann serve cell lowers for the multi-pod dry-run (each of 512 chips
scans its corpus shard; results merge by distributed top-k).

Stage 1 STREAMS over corpus chunks (lax.scan) keeping a running top-C per
query — materializing the full (B, n) estimate matrix would need
query_batch x shard_size x 4 B = 32 GiB/device at production sizes (measured;
chunking brings the working set to B x chunk ~ 0.5 GiB).
Stage 2 gathers the surviving top-C candidates and refines them with the
int4 codes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.binary_ip.ops import binary_ip
from repro.velo.index import DeviceIndex

DEFAULT_CHUNK = 32768


@functools.partial(
    jax.jit, static_argnames=("k", "rerank", "interpret", "use_kernel", "chunk")
)
def scan_search(
    index: DeviceIndex,
    queries: jnp.ndarray,     # (B, d)
    k: int = 10,
    rerank: int = 64,         # candidates refined in stage 2 (C)
    interpret: bool = True,
    use_kernel: bool = True,  # False: pure-jnp GEMM (dry-run lowering path —
                              # interpret-mode Pallas would unroll the grid
                              # into the HLO; on real TPUs use_kernel=True)
    chunk: int = DEFAULT_CHUNK,
):
    """Returns (ids (B, k) int32, dist2 (B, k) f32)."""
    B, d = queries.shape
    qr = (queries - index.centroid[None, :]) @ index.rotation.T
    qnorm = jnp.linalg.norm(qr, axis=1, keepdims=True)
    qunit = qr / jnp.maximum(qnorm, 1e-12)

    codes = index.binary_codes[:-1]  # drop sentinel row
    n = codes.shape[0]
    C = min(rerank, n)

    def stage1_block(codes_blk, norms_blk, ipb_blk):
        """Level-1 estimates for one corpus block: -> (B, blk) bf16.

        bf16 end-to-end (§Perf iteration 4): the level-1 estimate is a
        STEERING value re-ranked by int4 refinement, so bf16's ~3 decimal
        digits lose nothing (recall checked in tests), while the dominant
        HBM streams — unpacked sign lanes and the (B, chunk) estimate
        tensor — halve."""
        if use_kernel:
            g = binary_ip(qunit.astype(jnp.bfloat16), codes_blk, interpret=interpret)
        else:
            from repro.kernels.binary_ip.ref import binary_ip_ref

            g = binary_ip_ref(qunit.astype(jnp.bfloat16), codes_blk)
        g = (g / jnp.sqrt(jnp.float32(d))).astype(jnp.bfloat16)
        ipb = jnp.maximum(ipb_blk[None, :], 1e-6).astype(jnp.bfloat16)
        est_cos = jnp.clip(g / ipb, -1.0, 1.0)
        nr = norms_blk[None, :].astype(jnp.bfloat16)
        qn = qnorm.astype(jnp.bfloat16)
        return qn**2 + nr**2 - 2.0 * qn * nr * est_cos

    if n <= chunk:
        est = stage1_block(codes, index.norms[:-1], index.ip_bar[:-1])
        neg, cand = jax.lax.top_k(-est, C)
    else:
        nb = n // chunk
        tail = n - nb * chunk
        cb = codes[: nb * chunk].reshape(nb, chunk, -1)
        nrb = index.norms[: nb * chunk].reshape(nb, chunk)
        ipb = index.ip_bar[: nb * chunk].reshape(nb, chunk)

        def body(carry, blk):
            best_d, best_i = carry
            codes_blk, norms_blk, ipb_blk, bi = blk
            est = stage1_block(codes_blk, norms_blk, ipb_blk)     # (B, chunk)
            # top-C of the CHUNK first, then a tiny 2C merge with the carry —
            # sorting concat(C + chunk) repays the C columns every chunk and
            # copies the concat (§Perf iteration 4).  NOTE: the residual sort
            # volume is a CPU-lowering artifact: XLA CPU lowers top_k to a
            # full variadic sort; the TPU backend emits a partial-reduction
            # TopK custom call, and the production path fuses selection into
            # the Pallas stage-1 kernel entirely (running top-C in VMEM).
            negc, selc = jax.lax.top_k(-est, C)
            ids = bi * chunk + selc.astype(jnp.int32)
            all_d = jnp.concatenate([best_d, -negc], axis=1)      # (B, 2C)
            all_i = jnp.concatenate([best_i, ids], axis=1)
            negd, sel = jax.lax.top_k(-all_d, C)
            return (-negd, jnp.take_along_axis(all_i, sel, axis=1)), None

        init = (
            jnp.full((B, C), jnp.bfloat16(3e38)),
            jnp.zeros((B, C), jnp.int32),
        )
        (best_d, best_i), _ = jax.lax.scan(
            body, init,
            (cb, nrb, ipb, jnp.arange(nb, dtype=jnp.int32)),
        )
        if tail:
            est = stage1_block(
                codes[nb * chunk:], index.norms[nb * chunk : n], index.ip_bar[nb * chunk : n]
            )
            ids = nb * chunk + jnp.arange(tail, dtype=jnp.int32)[None, :]
            all_d = jnp.concatenate([best_d, est], axis=1)
            all_i = jnp.concatenate([best_i, jnp.broadcast_to(ids, est.shape)], axis=1)
            negd, sel = jax.lax.top_k(-all_d, C)
            best_d, best_i = -negd, jnp.take_along_axis(all_i, sel, axis=1)
        cand = best_i

    # ---- stage 2: gather top-C, int4 refine
    packed = index.ext_codes[cand].astype(jnp.int32)        # (B, C, d/2)
    lo4 = (packed & 0xF).astype(jnp.float32)
    hi4 = ((packed >> 4) & 0xF).astype(jnp.float32)
    codes4 = jnp.stack([lo4, hi4], axis=-1).reshape(B, C, d)
    x = codes4 * index.ext_step[cand][..., None] + index.ext_lo[cand][..., None]
    diff = qr[:, None, :] - x
    refined = jnp.einsum("bcd,bcd->bc", diff, diff)         # (B, C)

    kk = min(k, C)
    negk, sel = jax.lax.top_k(-refined, kk)
    ids = jnp.take_along_axis(cand, sel, axis=1).astype(jnp.int32)
    return ids, -negk
