"""Distributed vector search over a device mesh (the serving-scale plane).

The corpus is sharded across every mesh device (pod x data x model flattened
into one 'shards' view); queries are replicated; each device searches its
local shard (scan mode or graph mode); per-shard top-k merge via all_gather +
global top-k — one small collective per batch, which is why the veloann serve
cell is compute-bound in the roofline table (§Roofline).

Local ids are translated to global ids with each shard's base offset.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.velo import batch_search as bs
from repro.velo import scan_search as ss
from repro.velo.index import DeviceIndex


def local_search_fn(mode: str, L: int, k: int, max_steps: int, interpret: bool):
    if mode == "scan":
        def run(index, queries):
            ids, d2 = ss.scan_search(index, queries, k=k, rerank=L, interpret=interpret)
            return ids, d2
    elif mode == "scan_ref":
        # pure-jnp stage-1 GEMM: the dry-run lowering path (see scan_search)
        def run(index, queries):
            ids, d2 = ss.scan_search(index, queries, k=k, rerank=L, use_kernel=False)
            return ids, d2
    elif mode == "graph":
        def run(index, queries):
            ids, d2, _ = bs.batch_search(index, queries, L=L, k=k, max_steps=max_steps)
            return ids, d2
    else:
        raise ValueError(mode)
    return run


def mask_local_topk(
    ids: jnp.ndarray, d2: jnp.ndarray, offset: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Translate one shard's local top-k to global ids, masking invalid lanes.

    Under-filled shards pad their local top-k with sentinel ids (< 0).  Adding
    the shard's base offset to a sentinel produces a VALID-LOOKING global id
    (offset - 1 etc.) that can win the merged top-k — so the mask must be
    applied to the LOCAL ids, before translation: invalid lanes keep id -1 and
    get distance +inf, which loses every top-k comparison after the gather.
    """
    valid = ids >= 0
    gids = jnp.where(
        valid, ids.astype(jnp.int32) + offset.astype(jnp.int32), -1
    )
    d2 = jnp.where(valid, d2, jnp.inf)
    return gids, d2


def merge_topk(
    gids_all: jnp.ndarray, d2_all: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Global top-k over the gathered (B, S*k) candidate set."""
    neg, sel = jax.lax.top_k(-d2_all, k)
    out_ids = jnp.take_along_axis(gids_all, sel, axis=1)
    return out_ids, -neg


def make_distributed_search(
    mesh,
    axis_names: tuple[str, ...],
    mode: str = "scan",
    L: int = 64,
    k: int = 10,
    max_steps: int = 96,
    interpret: bool = True,
):
    """Builds a shard_map'd search: (sharded DeviceIndex, shard_offsets,
    replicated queries) -> (global ids (B, k), dist2 (B, k))."""
    local = local_search_fn(mode, L, k, max_steps, interpret)
    all_axes = axis_names

    def searcher(index: DeviceIndex, offset: jnp.ndarray, queries: jnp.ndarray):
        ids, d2 = local(index, queries)                    # local shard results
        # (B, k) global ids, invalid lanes masked BEFORE the gather
        gids_all, d2_all = mask_local_topk(ids, d2, offset)
        # merge: gather every shard's candidates, then global top-k
        for ax in all_axes:
            gids_all = jax.lax.all_gather(gids_all, ax, axis=1, tiled=True)
            d2_all = jax.lax.all_gather(d2_all, ax, axis=1, tiled=True)
        return merge_topk(gids_all, d2_all, k)

    index_specs = DeviceIndex(
        centroid=P(), rotation=P(),
        binary_codes=P(all_axes), norms=P(all_axes), ip_bar=P(all_axes),
        ext_codes=P(all_axes), ext_lo=P(all_axes), ext_step=P(all_axes),
        adjacency=P(all_axes), medoid=P(),
    )
    in_specs = (index_specs, P(all_axes), P())
    out_specs = (P(), P())

    return jax.shard_map(
        searcher, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_vma=False,
    )
