"""DeviceIndex: the compressed VeloANN index as a pytree of device arrays.

Shares the exact artifact format with the host plane (core.quant /
core.vamana): binary codes + norms + ip_bar steer traversal, 4-bit ext codes
refine, padded adjacency drives graph gathers.  A sentinel row is appended so
padding ids (-1 -> n) gather safely and estimate to +inf.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DeviceIndex:
    centroid: jnp.ndarray       # (d,)
    rotation: jnp.ndarray       # (d, d)
    binary_codes: jnp.ndarray   # (n+1, d/8) uint8
    norms: jnp.ndarray          # (n+1,)  — sentinel row: +inf
    ip_bar: jnp.ndarray         # (n+1,)
    ext_codes: jnp.ndarray      # (n+1, d/2) uint8
    ext_lo: jnp.ndarray         # (n+1,)
    ext_step: jnp.ndarray       # (n+1,)
    adjacency: jnp.ndarray      # (n+1, R) int32, -1 padding replaced by n
    medoid: jnp.ndarray         # () int32

    @property
    def n(self) -> int:
        return self.binary_codes.shape[0] - 1

    @property
    def dim(self) -> int:
        return self.rotation.shape[0]

    @property
    def R(self) -> int:
        return self.adjacency.shape[1]


def from_host(qb, graph) -> DeviceIndex:
    """Build the device pytree from host-plane artifacts (QuantizedBase + VamanaGraph)."""
    n = qb.norms.shape[0]
    adj = graph.adjacency.copy()
    adj[adj < 0] = n  # sentinel
    sent_adj = np.full((1, adj.shape[1]), n, dtype=np.int32)
    big = np.float32(1e30)
    return DeviceIndex(
        centroid=jnp.asarray(qb.centroid),
        rotation=jnp.asarray(qb.rotation),
        binary_codes=jnp.asarray(
            np.concatenate([qb.binary_codes, np.zeros((1, qb.binary_codes.shape[1]), np.uint8)])
        ),
        norms=jnp.asarray(np.concatenate([qb.norms, [big]])),
        ip_bar=jnp.asarray(np.concatenate([qb.ip_bar, [1.0]]).astype(np.float32)),
        ext_codes=jnp.asarray(
            np.concatenate([qb.ext_codes, np.zeros((1, qb.ext_codes.shape[1]), np.uint8)])
        ),
        ext_lo=jnp.asarray(np.concatenate([qb.ext_lo, [0.0]]).astype(np.float32)),
        ext_step=jnp.asarray(np.concatenate([qb.ext_step, [1.0]]).astype(np.float32)),
        adjacency=jnp.asarray(np.concatenate([adj, sent_adj])),
        medoid=jnp.asarray(graph.medoid, dtype=jnp.int32),
    )


def synthetic_specs(n: int, d: int, R: int):
    """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
    f32, u8, i32 = jnp.float32, jnp.uint8, jnp.int32
    S = jax.ShapeDtypeStruct
    return DeviceIndex(
        centroid=S((d,), f32),
        rotation=S((d, d), f32),
        binary_codes=S((n + 1, d // 8), u8),
        norms=S((n + 1,), f32),
        ip_bar=S((n + 1,), f32),
        ext_codes=S((n + 1, d // 2), u8),
        ext_lo=S((n + 1,), f32),
        ext_step=S((n + 1,), f32),
        adjacency=S((n + 1, R), i32),
        medoid=S((), i32),
    )
