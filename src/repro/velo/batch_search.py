"""Batched lockstep cache-aware beam search — the coroutine model on a TPU.

The paper runs B query coroutines per core and switches on I/O.  A TPU cannot
suspend lanes, so the B-way concurrency becomes a B-row *vectorized* beam
search advanced in lockstep by `jax.lax.scan` (DESIGN.md §2 adaptation 2):

  * one scan step = every query expands its best unvisited candidate;
  * neighbor gathers for the whole batch coalesce into one HBM gather —
    the io_uring batched-submission analogue;
  * level-1 (binary) estimates steer the beam; level-2 (int4) refinement is
    applied once at the end to the surviving beam (TPU-natural: one batched
    rerank instead of per-step scalar refinement; recall parity with the host
    plane is asserted in tests/test_velo_device.py).

Everything here is jit/pjit-compatible: static shapes, no host sync inside
the scan.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.velo.index import DeviceIndex

INF = jnp.float32(3e38)


def _prepare_queries(index: DeviceIndex, q: jnp.ndarray):
    qr = (q - index.centroid[None, :]) @ index.rotation.T
    qnorm = jnp.linalg.norm(qr, axis=1, keepdims=True)
    qunit = qr / jnp.maximum(qnorm, 1e-12)
    return qr, qnorm, qunit


def _estimate(index: DeviceIndex, ids: jnp.ndarray, qunit: jnp.ndarray, qnorm: jnp.ndarray):
    """Level-1 estimates for gathered ids: ids (B, M), qunit (B, d) -> (B, M)."""
    d = index.dim
    codes = index.binary_codes[ids]                      # (B, M, d/8)
    c = codes.astype(jnp.int32)
    shifts = jnp.arange(8, dtype=jnp.int32)
    bits = (c[..., None] >> shifts) & 1                  # (B, M, d/8, 8)
    signs = (2 * bits - 1).reshape(*ids.shape, d).astype(jnp.float32)
    g = jnp.einsum("bmd,bd->bm", signs, qunit) / jnp.sqrt(jnp.float32(d))
    ipb = jnp.maximum(index.ip_bar[ids], 1e-6)
    est_cos = jnp.clip(g / ipb, -1.0, 1.0)
    nr = index.norms[ids]
    return qnorm**2 + nr**2 - 2.0 * qnorm * nr * est_cos


def _refine(index: DeviceIndex, ids: jnp.ndarray, qr: jnp.ndarray):
    """Level-2 int4 refinement for gathered ids: (B, M) -> (B, M) dist^2."""
    d = index.dim
    packed = index.ext_codes[ids].astype(jnp.int32)      # (B, M, d/2)
    lo4 = (packed & 0xF).astype(jnp.float32)
    hi4 = ((packed >> 4) & 0xF).astype(jnp.float32)
    codes = jnp.stack([lo4, hi4], axis=-1).reshape(*ids.shape, d)
    x = codes * index.ext_step[ids][..., None] + index.ext_lo[ids][..., None]
    diff = qr[:, None, :] - x
    return jnp.einsum("bmd,bmd->bm", diff, diff)


def _merge_and_trim(ids, dist, visited, new_ids, new_dist, L, sentinel):
    """Concat beams with expansions, dedupe by id, keep top-L by distance."""
    all_ids = jnp.concatenate([ids, new_ids], axis=1)
    all_dist = jnp.concatenate([dist, new_dist], axis=1)
    all_vis = jnp.concatenate([visited, jnp.zeros_like(new_ids, dtype=bool)], axis=1)

    # dedupe: sort by id; runs of equal REAL ids have length <= 2 here (beam
    # rows are unique post-trim, adjacency rows are unique), so one
    # neighbor-pair aggregation suffices: the first copy takes min(dist) and
    # OR(visited), the second copy is killed.
    order = jnp.argsort(all_ids, axis=1)
    sid = jnp.take_along_axis(all_ids, order, axis=1)
    sdist = jnp.take_along_axis(all_dist, order, axis=1)
    svis = jnp.take_along_axis(all_vis, order, axis=1)
    eq = sid[:, 1:] == sid[:, :-1]
    zeros = jnp.zeros_like(sid[:, :1], dtype=bool)
    nxt_same = jnp.concatenate([eq, zeros], axis=1)   # next element is my dup
    prv_same = jnp.concatenate([zeros, eq], axis=1)   # I am the dup copy
    sdist_nxt = jnp.roll(sdist, -1, axis=1)
    svis_nxt = jnp.roll(svis, -1, axis=1)
    sdist = jnp.where(nxt_same, jnp.minimum(sdist, sdist_nxt), sdist)
    svis = jnp.where(nxt_same, svis | svis_nxt, svis)
    # a killed copy must ALSO forfeit its id: on an underfull beam the
    # (INF, visited) tail survives the trim, and a ghost that kept a real id
    # would pair with that id's live copy in a LATER merge — the OR(visited)
    # aggregation would then falsely mark the live candidate visited (and a
    # 3-long run would break the pairwise-dedupe assumption above)
    sid = jnp.where(prv_same, sentinel, sid)
    sdist = jnp.where(prv_same, INF, sdist)
    svis = jnp.where(prv_same, True, svis)

    order2 = jnp.argsort(sdist, axis=1)[:, :L]
    ids = jnp.take_along_axis(sid, order2, axis=1)
    dist = jnp.take_along_axis(sdist, order2, axis=1)
    visited = jnp.take_along_axis(svis, order2, axis=1)
    visited = visited | (dist >= INF)
    return ids, dist, visited


@functools.partial(jax.jit, static_argnames=("L", "k", "max_steps"))
def batch_search(
    index: DeviceIndex,
    queries: jnp.ndarray,    # (B, d)
    L: int = 64,
    k: int = 10,
    max_steps: int = 96,
):
    """Returns (ids (B, k) int32, dist2 (B, k) f32, steps_executed (B,))."""
    B, d = queries.shape
    qr, qnorm, qunit = _prepare_queries(index, queries)
    n = index.n

    ids = jnp.full((B, L), n, dtype=jnp.int32)           # sentinel-filled
    dist = jnp.full((B, L), INF, dtype=jnp.float32)
    visited = jnp.ones((B, L), dtype=bool)

    medoid = jnp.full((B, 1), index.medoid, dtype=jnp.int32)
    med_est = _estimate(index, medoid, qunit, qnorm)
    ids = ids.at[:, 0].set(medoid[:, 0])
    dist = dist.at[:, 0].set(med_est[:, 0])
    visited = visited.at[:, 0].set(False)

    # global seen-set: one bit per vertex per query (the lockstep analogue of
    # the host's per-coroutine `seen`); sentinel row pre-marked.
    seen = jnp.zeros((B, n + 1), dtype=bool).at[:, -1].set(True)
    seen = seen.at[jnp.arange(B), medoid[:, 0]].set(True)

    def step(carry, _):
        ids, dist, visited, seen, steps = carry
        masked = jnp.where(visited, INF, dist)
        bi = jnp.argmin(masked, axis=1)                   # (B,)
        best = jnp.take_along_axis(masked, bi[:, None], axis=1)[:, 0]
        active = best < INF
        cur = jnp.take_along_axis(ids, bi[:, None], axis=1)[:, 0]
        cur = jnp.where(active, cur, n)
        visited = jnp.where(
            active[:, None],
            visited.at[jnp.arange(ids.shape[0]), bi].set(True),
            visited,
        )

        neigh = index.adjacency[cur]                      # (B, R)
        fresh = ~jnp.take_along_axis(seen, neigh, axis=1)  # (B, R)
        est = _estimate(index, neigh, qunit, qnorm)
        est = jnp.where(fresh & active[:, None], est, INF)
        seen = seen.at[jnp.arange(ids.shape[0])[:, None], neigh].set(True)

        ids, dist, visited = _merge_and_trim(
            ids, dist, visited, neigh, est, ids.shape[1], n
        )
        return (ids, dist, visited, seen, steps + active.astype(jnp.int32)), None

    (ids, dist, visited, seen, steps), _ = jax.lax.scan(
        step, (ids, dist, visited, seen, jnp.zeros(B, jnp.int32)), None,
        length=max_steps,
    )

    # final rerank: int4 refinement of the surviving beam, take top-k
    refined = _refine(index, ids, qr)
    refined = jnp.where(dist >= INF, INF, refined)
    order = jnp.argsort(refined, axis=1)[:, :k]
    top_ids = jnp.take_along_axis(ids, order, axis=1)
    top_d2 = jnp.take_along_axis(refined, order, axis=1)
    return top_ids, top_d2, steps
