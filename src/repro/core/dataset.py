"""Synthetic vector-search workloads with exact ground truth.

The paper evaluates on SIFT1M/GIST1M/Wiki/Image/Text. Those are not available
offline, so we generate clustered Gaussian datasets whose key properties match
what the paper's mechanisms exploit:

  * cluster structure            -> affinity co-placement has signal (§3.4)
  * skewed query distribution    -> record-level cache has signal (§3.2, Fig. 4)
  * configurable dimensionality  -> fragmentation study (Fig. 6) spans d=128..1536
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import flat


@dataclasses.dataclass(frozen=True)
class Dataset:
    """A vector search workload: base set, query set, exact top-k ground truth."""

    name: str
    base: np.ndarray        # (n, d) float32
    queries: np.ndarray     # (q, d) float32
    groundtruth: np.ndarray  # (q, k) int32 — exact top-k ids under L2
    k: int

    @property
    def n(self) -> int:
        return self.base.shape[0]

    @property
    def dim(self) -> int:
        return self.base.shape[1]


def make_dataset(
    n: int = 20_000,
    d: int = 128,
    n_queries: int = 500,
    k: int = 10,
    n_clusters: int | None = None,
    query_skew: float = 1.2,
    noise: float = 0.3,
    seed: int = 0,
    name: str | None = None,
) -> Dataset:
    """Clustered Gaussian base set; queries drawn near cluster centroids.

    ``query_skew`` is the Zipf exponent over clusters: queries concentrate on a
    few clusters, which reproduces the skewed vertex-access pattern the paper
    measures in Fig. 4 (a uniform query mix still shows skew from graph hubs,
    but the workload-level skew makes Table 1 / hit-rate experiments sharper).

    ``n_clusters`` defaults to n/40: ~40 points per cluster keeps the data
    navigable by greedy graph traversal (isolated blobs much larger than the
    search beam trap best-first search — measured 0.52 in-memory recall at
    64 clusters x 78 points vs 0.98 at this default).
    """
    rng = np.random.default_rng(seed)
    if n_clusters is None:
        n_clusters = max(32, n // 40)
    centers = rng.standard_normal((n_clusters, d)).astype(np.float32)
    # Center spread comparable to intra-cluster noise: near-neighbor distance
    # gaps stay tight relative to the global spread, as in SIFT/GIST — this is
    # what makes quantized refinement genuinely exercised.
    centers *= 2.0 / np.sqrt(d)

    assign = rng.integers(0, n_clusters, size=n)
    base = centers[assign] + noise * rng.standard_normal((n, d)).astype(np.float32)
    base = base.astype(np.float32)

    # Zipf-ish cluster choice for queries.
    ranks = np.arange(1, n_clusters + 1, dtype=np.float64)
    probs = ranks ** (-query_skew)
    probs /= probs.sum()
    q_assign = rng.choice(n_clusters, size=n_queries, p=probs)
    queries = centers[q_assign] + noise * rng.standard_normal(
        (n_queries, d)
    ).astype(np.float32)
    queries = queries.astype(np.float32)

    gt = flat.exact_topk(base, queries, k)
    return Dataset(
        name=name or f"synth-n{n}-d{d}",
        base=base,
        queries=queries,
        groundtruth=gt,
        k=k,
    )


def recall_at_k(result_ids: np.ndarray, groundtruth: np.ndarray, k: int) -> float:
    """Recall@k per the paper's Eq. (2), averaged over queries."""
    assert result_ids.shape[0] == groundtruth.shape[0]
    hits = 0
    for res, gt in zip(result_ids[:, :k], groundtruth[:, :k]):
        hits += len(set(int(x) for x in res) & set(int(x) for x in gt))
    return hits / (groundtruth.shape[0] * k)
