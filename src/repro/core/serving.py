"""Multi-tenant serving plane: N independent indexes on ONE engine.

The single-system stack (PR 1-4) keeps one index saturating the hardware; a
production deployment hosts MANY indexes — tenants — on the same machine.
``ServingPlane`` composes the existing pieces into that shape without forking
any of them:

  * one ``Engine`` runs every tenant's query coroutines on the same simulated
    workers (one scheduler, one SSD, one completion queue), over ONE combined
    ``PageStore`` whose page-id space concatenates the tenants' index images;
  * one ``RecordBufferPool`` is shared by every record-pool tenant: the vid
    namespace is globalized (``vid + vid_base``) through a ``TenantPoolView``,
    so tenants compete for — and coalesce on — the same slots, LOCKED windows
    and clock hand.  Per-tenant *soft quotas* (``SystemConfig.tenant_quota``)
    cap any tenant's slot share: an over-quota tenant recycles its own slots
    via a tenant-scoped second-chance sweep; quota off is the pure global
    clock.  ``shared_pool=False`` statically partitions instead (each tenant
    keeps its isolated-system pool size) — the baseline the shared pool is
    benchmarked against, and the mode whose behavior is bit-identical to N
    isolated systems (the isolation contract, tests/test_serving.py);
  * one ``DistanceEngine`` serves every tenant's score requests.  When all
    tenants share a dimensionality, their quantized tables are concatenated
    into ONE combined table registered once (``combined_table``): requests
    carry global row ids into it, so a single rendezvous flush fuses the
    frontiers of queries from DIFFERENT tenants into one kernel dispatch —
    cross-tenant fusion as pure routing, no new wire format.  Tenants with
    mismatched shapes keep their own registered tables; ``execute_requests``
    then routes each (kind, table) group to its own fused call.

Per-tenant accounting: each tenant's accessor counts its own hits/misses
(``TenantPoolView`` mirrors the pool's hit/miss rules), per-query latencies
are split by the engine's ``latency_qids``, and ``PlaneRun.tenants`` carries
one ``WorkloadStats`` + recall per tenant — the serving-side axes (recall /
QPS / p99 / hit rate) sliced the way an operator would dashboard them.

Workloads come from ``repro.core.workload`` (uniform / zipfian hot-tenant /
bursty arrival mixes); ``benchmarks/bench_multitenant.py`` compares the
shared pool against the static partition under skew.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import baselines as baselines_mod
from repro.core import distance as distance_mod
from repro.core.bufferpool import RecordBufferPool
from repro.core.dataset import recall_at_k
from repro.core.engine import Engine, EngineConfig
from repro.core.hbm import HbmTier, HbmView
from repro.core.pagecache import PageCache
from repro.core.quant import QuantizedBase
from repro.core.scheduling import SlaController, SlaPlan, sla_seconds
from repro.core.search import PageAccessor, RecordAccessor, SearchParams
from repro.core.sim import SSD, SSDConfig, WorkloadStats
from repro.core.store import PageStore
from repro.core.workload import MixedWorkload


# ------------------------------------------------------------ combined table


def combined_table(qbs: list[QuantizedBase]) -> QuantizedBase | None:
    """Concatenate tenants' quantized tables into one registerable table.

    Row i of tenant t lives at global row ``vid_base[t] + i``; each row keeps
    the codes built under ITS tenant's rotation, and each query's
    ``PreparedQuery`` is prepared under that same rotation, so per-row scoring
    is unchanged — the batch primitives only consume per-row data plus the
    shared dimensionality.  Returns None when the tenants' shapes are not
    combinable (different dim or ext width); callers then fall back to
    per-tenant registered tables.

    The combined object's ``centroid``/``rotation`` are copied from the first
    tenant purely to satisfy the dataclass shape — scoring never reads them
    (queries are prepared against each tenant's OWN qb)."""
    if not qbs:
        return None
    d0, e0 = qbs[0].dim, qbs[0].ext_bits
    if any(q.dim != d0 or q.ext_bits != e0 for q in qbs):
        return None
    return QuantizedBase(
        centroid=qbs[0].centroid,
        rotation=qbs[0].rotation,
        binary_codes=np.concatenate([q.binary_codes for q in qbs]),
        norms=np.concatenate([q.norms for q in qbs]),
        ip_bar=np.concatenate([q.ip_bar for q in qbs]),
        ext_codes=np.concatenate([q.ext_codes for q in qbs]),
        ext_lo=np.concatenate([q.ext_lo for q in qbs]),
        ext_step=np.concatenate([q.ext_step for q in qbs]),
        dim=d0,
        ext_bits=e0,
    )


# ------------------------------------------------------------- tenant views


class _TenantIndexView:
    """A tenant's index seen through the plane's global page-id space: reads
    issued by this tenant's coroutines address the combined store.  Record
    decoding, co-residency and payloads stay local — only page ids shift."""

    def __init__(self, index, page_base: int):
        self._index = index
        self._page_base = page_base

    def page_of(self, vid: int) -> int:
        return self._index.page_of(vid) + self._page_base

    def page_record_ids(self, pid: int) -> list[int]:
        return self._index.page_record_ids(pid - self._page_base)

    def __getattr__(self, name):
        return getattr(self._index, name)


class TenantPoolView:
    """A tenant's handle on the shared ``RecordBufferPool``: translates the
    tenant's local vid namespace into the plane's global one and keeps the
    tenant's own hit/miss counters (mirroring the pool's counting rules), so
    ``RecordAccessor.stats()`` reports per-tenant hit rates while the pool's
    totals stay system-wide.  The engine's ``load_wait`` protocol works
    through the view unchanged — waiter parking and resume draining hit the
    one shared pool, so coalescing spans tenants."""

    def __init__(self, pool: RecordBufferPool, vid_base: int):
        self.shared = pool
        self.vid_base = vid_base
        self.hits = 0
        self.misses = 0

    # ---- lookups (tenant-attributed stats) --------------------------------
    def lookup(self, vid: int):
        rec = self.shared.lookup(vid + self.vid_base)
        if rec is None:
            self.misses += 1
        else:
            self.hits += 1
        return rec

    # ---- namespace-translating delegates ----------------------------------
    def admit(self, vid: int, record) -> int:
        return self.shared.admit(vid + self.vid_base, record)

    def admit_group(self, vids, records) -> int:
        return self.shared.admit_group(
            [int(v) + self.vid_base for v in vids], records
        )

    def begin_load(self, vid: int) -> int:
        return self.shared.begin_load(vid + self.vid_base)

    def finish_load(self, vid: int, record) -> int:
        return self.shared.finish_load(vid + self.vid_base, record)

    def abort_load(self, vid: int) -> None:
        self.shared.abort_load(vid + self.vid_base)

    def is_loading(self, vid: int) -> bool:
        return self.shared.is_loading(vid + self.vid_base)

    def peek_resident(self, vid: int) -> bool:
        return self.shared.peek_resident(vid + self.vid_base)

    def peek_present(self, vid: int) -> bool:
        return self.shared.peek_present(vid + self.vid_base)

    def peek_record(self, vid: int):
        return self.shared.peek_record(vid + self.vid_base)

    def status(self, vid: int) -> str:
        return self.shared.status(vid + self.vid_base)

    def add_waiter(self, vid: int, waiter) -> None:
        self.shared.add_waiter(vid + self.vid_base, waiter)

    # ---- engine resume-drain protocol (shared, not translated) ------------
    @property
    def pending_resumes(self):
        return self.shared.pending_resumes

    def take_resumes(self):
        return self.shared.take_resumes()

    def pressure_stats(self) -> dict[str, int]:
        return self.shared.pressure_stats()

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0


# ------------------------------------------------------------------ tenants


@dataclasses.dataclass
class TenantSpec:
    """One tenant: an index image plus its query workload."""

    name: str
    base: np.ndarray
    graph: object                  # VamanaGraph
    qb: QuantizedBase
    queries: np.ndarray
    groundtruth: np.ndarray | None = None
    system: str = "velo"           # any baselines.build_system name
    params: SearchParams | None = None

    @classmethod
    def from_dataset(cls, name, ds, graph, qb, system="velo", params=None):
        return cls(
            name=name, base=ds.base, graph=graph, qb=qb, queries=ds.queries,
            groundtruth=ds.groundtruth, system=system, params=params,
        )


@dataclasses.dataclass
class Tenant:
    """A hosted tenant: the built single-system pieces rewired to the plane."""

    tid: int
    spec: TenantSpec
    system: object                 # the baselines.System it was built from
    ctx: object                    # SearchContext (plane-wired)
    accessor: object               # RecordAccessor | PageAccessor
    algorithm: object
    params: SearchParams
    vid_base: int
    page_base: int

    @property
    def name(self) -> str:
        return self.spec.name


@dataclasses.dataclass
class TenantRun:
    """One tenant's slice of a plane run."""

    name: str
    tid: int
    results: list                  # QueryResult per arrival, arrival order
    stats: WorkloadStats
    recall: float | None           # None when the spec has no groundtruth

    @property
    def hit_rate(self) -> float:
        return self.stats.hit_rate


@dataclasses.dataclass
class PlaneRun:
    results: list                  # all queries, arrival order
    stats: WorkloadStats           # system-wide
    tenants: list[TenantRun]


def _vid_to_page(index) -> np.ndarray:
    """Index-format-agnostic vid -> local page id array."""
    if hasattr(index, "layout"):
        return np.asarray(index.layout.vid_to_page, dtype=np.int64)
    return np.asarray(index.vid_to_page, dtype=np.int64)


# ------------------------------------------------------------ serving plane


class ServingPlane:
    """N tenants, one engine, one (optionally shared) buffer pool."""

    def __init__(
        self,
        specs: list[TenantSpec],
        config: baselines_mod.SystemConfig | None = None,
        cost=None,
        shared_pool: bool = True,
    ):
        assert specs, "a serving plane needs at least one tenant"
        self.config = config or baselines_mod.SystemConfig()
        self.shared_pool_mode = shared_pool

        # ---- per-tenant builds (index image, algorithm, resolved config) --
        built = []
        for spec in specs:
            cfg_t = dataclasses.replace(
                self.config,
                params=spec.params if spec.params is not None else self.config.params,
            )
            built.append(baselines_mod.build_system(
                spec.system, spec.base, spec.graph, spec.qb, cfg_t, cost
            ))
        page_sizes = {b.config.page_size for b in built}
        assert len(page_sizes) == 1, "tenants must share one page size"
        self.page_size = page_sizes.pop()

        # ---- combined page store: one global page-id space ----------------
        page_bases, vid_bases = [], []
        pages: list[bytes] = []
        nv = 0
        for b in built:
            page_bases.append(len(pages))
            vid_bases.append(nv)
            pages.extend(b.index.store.pages)
            nv += b.index.n
        self.store = PageStore(pages, self.page_size)
        self.n_vids = nv

        # ---- one distance engine + (when combinable) one combined table ---
        self.dist = distance_mod.get_engine(
            self.config.distance_backend, resident=self.config.resident_plane
        )
        self.table = combined_table([s.qb for s in specs])

        # ---- the pool plane: shared-with-quotas or static partition -------
        record_tenants = [
            i for i, b in enumerate(built)
            if isinstance(b.ctx.accessor, RecordAccessor)
        ]
        self.pool: RecordBufferPool | None = None
        if shared_pool and record_tenants:
            tenant_of = np.concatenate([
                np.full(b.index.n, i, dtype=np.int64)
                for i, b in enumerate(built)
            ])
            global_vtp = np.concatenate([
                _vid_to_page(b.index) + page_bases[i]
                for i, b in enumerate(built)
            ])
            n_slots = min(
                sum(built[i].ctx.accessor.pool.n_slots for i in record_tenants),
                sum(built[i].index.n for i in record_tenants),
            )
            self.pool = RecordBufferPool(
                n_slots, global_vtp,
                group_demote=self.config.group_demote,
                tenant_of=tenant_of,
                tenant_quota=self.config.tenant_quota,
            )

        # ---- HBM record tier above the shared pool ------------------------
        # One device cache for the whole plane, addressed by GLOBAL vids over
        # the combined table (required: slot gathers index the one registered
        # table).  Static-partition mode gets no tier — it is the baseline.
        self.hbm: HbmTier | None = None
        hbm_on = (
            baselines_mod.default_hbm()[0]
            if self.config.hbm_tier is None else self.config.hbm_tier
        )
        if hbm_on and self.pool is not None and self.table is not None:
            slots = (
                self.config.hbm_slots
                or baselines_mod.default_hbm()[1]
                or self.pool.n_slots
            )
            max_r = max(int(s.graph.R) for s in specs)
            self.hbm = HbmTier(
                self.table, global_vtp,
                n_slots=max(8, min(int(slots), self.n_vids)), R=max_r,
            )
            self.pool.on_publish = self.hbm.note_publish

        # ---- rewire each tenant onto the plane ----------------------------
        self.tenants: list[Tenant] = []
        for i, (spec, b) in enumerate(zip(specs, built)):
            view = _TenantIndexView(b.index, page_bases[i])
            old_acc = b.ctx.accessor
            if isinstance(old_acc, RecordAccessor):
                if self.pool is not None:
                    handle = TenantPoolView(self.pool, vid_bases[i])
                else:
                    # static partition: the tenant keeps its isolated-system
                    # pool size, addressed in the global page space
                    handle = RecordBufferPool(
                        old_acc.pool.n_slots,
                        _vid_to_page(b.index) + page_bases[i],
                        group_demote=self.config.group_demote,
                    )
                # track_access is off on the plane: the Fig. 4 counters are
                # sized to one tenant's local page space, not the global one
                acc = RecordAccessor(
                    view, handle, b.cost,
                    co_admit=self.config.co_admit,
                    async_load=self.config.async_load,
                    hbm=(
                        HbmView(self.hbm, vid_bases[i])
                        if self.hbm is not None else None
                    ),
                )
            else:
                acc = PageAccessor(
                    view, PageCache(
                        old_acc.cache.capacity,
                        policy=self.config.page_policy,
                        seed=self.config.seed,
                    ),
                    b.cost,
                )
            ctx = dataclasses.replace(
                b.ctx,
                index=view,
                accessor=acc,
                dist=self.dist,
                table_qb=self.table if self.table is not None else spec.qb,
                vid_base=vid_bases[i] if self.table is not None else 0,
                tenant=i,
            )
            self.tenants.append(Tenant(
                tid=i, spec=spec, system=b, ctx=ctx, accessor=acc,
                algorithm=b.algorithm, params=b.config.params,
                vid_base=vid_bases[i], page_base=page_bases[i],
            ))

        # ---- dynamic protocol checker (SystemConfig.verify_protocol) ------
        # wired AFTER the tenant rewire so static-partition per-tenant pools
        # exist to be watched too; the hbm-first / re-point-hook / pool-last
        # order is the same rule build_system follows
        self.checker = None
        if self.config.verify_protocol:
            from repro.analysis.protocol import ProtocolChecker

            self.checker = ProtocolChecker()
            if self.hbm is not None:
                self.checker.watch_hbm(self.hbm)
                if self.pool is not None:
                    self.pool.on_publish = self.hbm.note_publish
            if self.pool is not None:
                self.checker.watch_pool(self.pool)
            for t in self.tenants:
                p = getattr(t.accessor, "pool", None)
                if isinstance(p, RecordBufferPool) and p is not self.pool:
                    self.checker.watch_pool(p)

        # sync tenants (diskann/starling/pipeann are B=1 systems) clamp the
        # shared engine's per-worker batch: one scheduler serves everyone
        self.batch_size = min(b.config.batch_size for b in built)
        cfg0 = built[0].config
        self.engine_config = EngineConfig(
            n_workers=self.config.n_workers,
            batch_size=self.batch_size,
            page_size=self.page_size,
            fuse=bool(cfg0.fuse),
            fuse_rows=cfg0.fuse_rows,
            shared_rendezvous=bool(cfg0.shared_rendezvous),
            overlap_flush=bool(cfg0.overlap_flush),
            scheduler=cfg0.scheduler,
        )
        # resolve the None->process-default fields run() reads off the
        # plane's own config (build_system resolved them on each tenant)
        self.config = dataclasses.replace(
            self.config, scheduler=cfg0.scheduler, sla_ms=cfg0.sla_ms,
        )
        self.cost = built[0].cost

    # ------------------------------------------------------------------ run

    def run(
        self, workload: MixedWorkload, ssd_config: SSDConfig | None = None,
        schedule=None,
    ) -> PlaneRun:
        """Run a mixed arrival stream through the one engine; split the
        results and the serving metrics by tenant.  Stats are per-run deltas
        (idempotent across repeated runs on one plane).

        Tenant-count honesty: ``workload.n_tenants`` carries the TRUE tenant
        count from the generator — a cold tenant that drew zero arrivals
        still counts (the per-tenant split below reports its empty row
        instead of silently dropping it).  The guard rejects workloads
        generated for more tenants than the plane serves, which used to slip
        through whenever the excess tenants happened to draw no arrivals.
        (Scaling one tenant's INDEX across engine shards is the orthogonal
        axis — see docs/sharding.md.)"""
        tenants = self.tenants
        assert workload.n_tenants <= len(tenants), (
            f"workload generated for {workload.n_tenants} tenants, plane "
            f"serves {len(tenants)}"
        )
        queries = [
            tenants[int(t)].spec.queries[int(j)]
            for t, j in zip(workload.tenant_ids, workload.query_ids)
        ]

        # ---- SLA plan: arrivals + per-tenant deadlines + feedback ---------
        # Built whenever the run has any SLA surface (the "sla" scheduler, a
        # workload with arrival timestamps, or deadlines configured); plain
        # rr batch runs pass plan=None and stay bitwise the pre-SLA plane.
        cfgS = self.config
        sla_plan = None
        controller = None
        if (
            cfgS.scheduler == "sla"
            or workload.arrival_s is not None
            or cfgS.sla_ms is not None
        ):
            if cfgS.sla_ms is not None and cfgS.sla_feedback:
                controller = SlaController(
                    n_tenants=len(tenants),
                    sla_s=sla_seconds(cfgS.sla_ms, len(tenants)),
                    pool=self.pool,
                )
            sla_plan = SlaPlan.build(
                len(queries),
                arrivals=workload.arrival_s,
                sla_ms=cfgS.sla_ms,
                tenant_of=workload.tenant_ids,
                n_tenants=len(tenants),
                controller=controller,
            )

        def make_coroutine(qid: int, q):
            t = tenants[int(workload.tenant_ids[qid])]
            params = t.params
            if controller is not None:
                # the feedback loop's beam steering: the tenant's CURRENT
                # scale decides this query's candidate-list width
                params = controller.params_for(t.tid, params)
            return t.algorithm(t.ctx, q, params)

        # snapshot cumulative counters -> per-run deltas
        acc0 = [t.accessor.stats() for t in tenants]
        reads0 = [t.accessor.reads for t in tenants]
        hbm0 = [
            (t.accessor.hbm.hits, t.accessor.hbm.misses)
            if getattr(t.accessor, "hbm", None) is not None else None
            for t in tenants
        ]
        pools = {id(self.pool): self.pool} if self.pool is not None else {}
        for t in tenants:
            p = getattr(t.accessor, "pool", None)
            if isinstance(p, RecordBufferPool):
                pools[id(p)] = p
        pressure0 = {
            k: dict(p.pressure_stats()) for k, p in pools.items()
        }

        engine = Engine(
            store=self.store,
            ssd=SSD(ssd_config),
            cost=self.cost,
            config=self.engine_config,
            dist=self.dist,
            qb=None,  # every request carries its table (the tenant tag)
            hbm=self.hbm,
            schedule=schedule,
            verify=self.checker,
        )
        results, stats = engine.run(make_coroutine, queries, sla=sla_plan)
        if self.checker is not None:
            self.checker.raise_if_violations()

        # system-wide cache + pool-pressure deltas
        hits = misses = 0
        for t, (h0, m0) in zip(tenants, acc0):
            h1, m1 = t.accessor.stats()
            hits += h1 - h0
            misses += m1 - m0
        stats.cache_hits = hits
        stats.cache_misses = misses
        # the engine counted lock_waits/coalesced_record_loads for the ops it
        # scheduled; REPLACE them with the pools' own per-run deltas (summed
        # across the shared pool or the partition's per-tenant pools) rather
        # than adding on top — the same rule System.run applies
        if pools:
            stats.lock_waits = 0
            stats.coalesced_record_loads = 0
        for k, p in pools.items():
            for key, val in p.pressure_stats().items():
                setattr(stats, key,
                        getattr(stats, key) + val - pressure0[k][key])

        # per-tenant slices.  The split keys on qid (completion order is
        # whatever the scheduler produced — under "sla" qids complete far out
        # of submission order), so ``lat_by_qid`` must be a qid-indexed map,
        # never a positional zip against ``positions()``;
        # tests/test_serving.py pins this against priority reordering.
        lat_by_qid = dict(zip(stats.latency_qids, stats.latencies))
        svc_by_qid = dict(zip(stats.latency_qids, stats.service_times))
        tenant_runs: list[TenantRun] = []
        for t, (h0, m0), r0, hb0 in zip(tenants, acc0, reads0, hbm0):
            pos = workload.positions(t.tid)
            t_results = [results[i] for i in pos]
            ts = WorkloadStats(n_queries=len(pos))
            ts.makespan_s = stats.makespan_s  # shared wall-clock
            ts.latencies = [lat_by_qid[i] for i in pos if i in lat_by_qid]
            ts.latency_qids = [i for i in pos if i in lat_by_qid]
            ts.sum_latency_s = float(sum(ts.latencies))
            ts.service_times = [svc_by_qid[i] for i in pos if i in svc_by_qid]
            ts.sum_service_s = float(sum(ts.service_times))
            ts.queue_wait_s = ts.sum_latency_s - ts.sum_service_s
            if sla_plan is not None and sla_plan.deadlines is not None:
                # a query met its SLA iff its arrival-relative latency fits
                # inside its deadline window (deadline - arrival)
                for i in ts.latency_qids:
                    win = float(
                        sla_plan.deadlines[i] - sla_plan.arrivals[i]
                    )
                    if lat_by_qid[i] <= win:
                        ts.deadline_hits += 1
                    else:
                        ts.deadline_misses += 1
                        ts.lateness_s += lat_by_qid[i] - win
            h1, m1 = t.accessor.stats()
            ts.cache_hits = h1 - h0
            ts.cache_misses = m1 - m0
            ts.io_count = t.accessor.reads - r0
            ts.io_bytes = ts.io_count * self.page_size
            if hb0 is not None:
                # per-tenant tier split from the view's own counters, as a
                # per-run delta (same idempotence rule as cache_hits)
                hv = t.accessor.hbm
                ts.hbm_hits = hv.hits - hb0[0]
                ts.hbm_misses = hv.misses - hb0[1]
            recall = None
            if t.spec.groundtruth is not None and len(pos):
                k = t.spec.groundtruth.shape[1]
                ids = np.full((len(pos), k), -1, dtype=np.int64)
                for row, r in enumerate(t_results):
                    m = min(k, len(r.ids))
                    ids[row, :m] = r.ids[:m]
                gt = t.spec.groundtruth[workload.query_ids[pos]]
                recall = recall_at_k(ids, gt, k)
            tenant_runs.append(TenantRun(
                name=t.name, tid=t.tid, results=t_results, stats=ts,
                recall=recall,
            ))
        return PlaneRun(results=results, stats=stats, tenants=tenant_runs)


def evaluate_plane(
    plane: ServingPlane,
    workload: MixedWorkload,
    ssd_config: SSDConfig | None = None,
) -> dict:
    """Run a mixed workload; return the serving-side metric dict (global
    throughput plus the per-tenant recall/QPS/p99/hit-rate split)."""
    run = plane.run(workload, ssd_config)
    s = run.stats
    served = s.hbm_hits + s.cache_hits
    accesses = served + s.cache_misses
    out = {
        "workload": workload.name,
        "n_ops": len(workload),
        "shared_pool": plane.pool is not None,
        "tenant_quota": plane.config.tenant_quota,
        "distance_backend": plane.dist.name,
        "combined_table": plane.table is not None,
        "scheduler": plane.config.scheduler,
        "sla_ms": plane.config.sla_ms,
        "qps": s.qps,
        "mean_latency_ms": s.mean_latency_ms,
        "p99_latency_ms": s.p99_latency_ms(),
        "mean_service_ms": s.mean_service_ms,
        "queue_wait_s": s.queue_wait_s,
        "deadline_hit_rate": s.deadline_hit_rate,
        "deadline_misses": s.deadline_misses,
        "hit_rate": s.hit_rate,
        "ios_per_query": s.ios_per_query,
        "lock_waits": s.lock_waits,
        "coalesced_record_loads": s.coalesced_record_loads,
        "quota_reclaims": s.quota_reclaims,
        "quota_denials": s.quota_denials,
        "score_flushes": s.score_flushes,
        "cross_tenant_flushes": s.cross_tenant_flushes,
        "overlap_flushes": s.overlap_flushes,
        "hbm_tier": plane.hbm is not None,
        "hbm_hits": s.hbm_hits,
        "hbm_hit_rate": s.hbm_hit_rate,
        "hbm_scatters": s.hbm_scatters,
        "combined_hit_rate": served / accesses if accesses else 0.0,
        "tenants": {},
    }
    for tr in run.tenants:
        out["tenants"][tr.name] = {
            "n_queries": tr.stats.n_queries,
            "recall@k": tr.recall,
            "qps": tr.stats.qps,
            "mean_latency_ms": tr.stats.mean_latency_ms,
            "p99_latency_ms": tr.stats.p99_latency_ms(),
            "mean_service_ms": tr.stats.mean_service_ms,
            "queue_wait_s": tr.stats.queue_wait_s,
            "deadline_hit_rate": tr.stats.deadline_hit_rate,
            "deadline_misses": tr.stats.deadline_misses,
            "hit_rate": tr.stats.hit_rate,
            "reads": tr.stats.io_count,
            "hbm_hits": tr.stats.hbm_hits,
            "hbm_hit_rate": tr.stats.hbm_hit_rate,
        }
    return out
