"""System configurations compared in the paper (§5.2) + breakdown variants (§5.5).

``build_system`` wires an index layout, an access path (record pool vs page
cache), a search algorithm, and an execution mode into one runnable bundle;
``evaluate`` runs a query workload through the engine and reports
recall / QPS / latency / I/O / hit-rate — the axes of Figs. 8-14.

Systems:
  velo       VeloIndex (affinity layout) + record pool + Alg.2 + async
  diskann    FixedIndex (seq)     + page LRU + sync beam search (B=1)
  starling   FixedIndex (shuffle) + page LRU + block search (B=1)
  pipeann    FixedIndex (seq)     + page LRU + pipelined best-first (B=1)
  inmemory   fp32 in-memory Vamana greedy search (no I/O)
Breakdown variants (Fig. 14), all on the VeloANN layout:
  baseline   sync beam search, page cache
  +async     same, B>1
  +record    record pool
  +prefetch  + stride prefetching
  +cbs       + cache-aware pivot  (== velo)
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import distance as distance_mod
from repro.core import search as search_mod
from repro.core.bufferpool import RecordBufferPool
from repro.core.dataset import Dataset, recall_at_k
from repro.core.engine import run_workload
from repro.core.hbm import HbmTier
from repro.core.pagecache import PageCache
from repro.core.quant import QuantizedBase, RabitQuantizer
from repro.core.search import (
    PageAccessor,
    RecordAccessor,
    SearchContext,
    SearchParams,
)
from repro.core.sim import SSD, CostModel, SSDConfig, WorkloadStats
from repro.core.store import FixedIndex, VeloIndex
from repro.core.vamana import VamanaGraph


_DEFAULT_FUSE = False
_DEFAULT_FUSE_ROWS = 256
_DEFAULT_SHARED_RV = False
_DEFAULT_OVERLAP = False
_DEFAULT_CALIBRATION: dict | None = None
_DEFAULT_HBM = False
_DEFAULT_HBM_SLOTS: int | None = None
_DEFAULT_DEVICE_BEAM = False
_DEFAULT_SCHEDULER = "rr"
_DEFAULT_SLA_MS: float | list | None = None


def set_default_fuse(
    on: bool, rows: int | None = None, shared: bool | None = None,
    overlap: bool | None = None,
) -> None:
    """Process-wide default for cross-query fused score dispatch — the hook
    ``benchmarks/run.py --fuse`` threads through (mirrors
    ``distance.set_default_backend``).  ``shared`` flips the rendezvous
    topology every system inherits (one system-wide buffer vs per-worker);
    ``overlap`` lets the shared-rendezvous stall flush overlap another
    worker's in-flight completions instead of draining them first."""
    global _DEFAULT_FUSE, _DEFAULT_FUSE_ROWS, _DEFAULT_SHARED_RV, _DEFAULT_OVERLAP
    _DEFAULT_FUSE = bool(on)
    if rows is not None:
        _DEFAULT_FUSE_ROWS = int(rows)
    if shared is not None:
        _DEFAULT_SHARED_RV = bool(shared)
    if overlap is not None:
        _DEFAULT_OVERLAP = bool(overlap)


def default_fuse() -> tuple[bool, int]:
    return _DEFAULT_FUSE, _DEFAULT_FUSE_ROWS


def default_shared_rendezvous() -> bool:
    return _DEFAULT_SHARED_RV


def default_overlap_flush() -> bool:
    return _DEFAULT_OVERLAP


def set_default_hbm(on: bool, slots: int | None = None) -> None:
    """Process-wide default for the HBM record-cache tier — the hook
    ``benchmarks/run.py --hbm-tier`` threads through.  ``slots`` fixes the
    device slot count (None: match the host pool's slot count)."""
    global _DEFAULT_HBM, _DEFAULT_HBM_SLOTS
    _DEFAULT_HBM = bool(on)
    if slots is not None:
        _DEFAULT_HBM_SLOTS = int(slots)


def default_hbm() -> tuple[bool, int | None]:
    return _DEFAULT_HBM, _DEFAULT_HBM_SLOTS


def set_default_device_beam(on: bool) -> None:
    """Process-wide default for the fused on-device beam step — the hook
    ``benchmarks/run.py --device-beam`` threads through.  When on, search
    coroutines keep their beam state engine-resident and yield one
    ``("beam", ...)`` op per hop instead of downloading raw distances
    (core.beam, docs/beam_step.md)."""
    global _DEFAULT_DEVICE_BEAM
    _DEFAULT_DEVICE_BEAM = bool(on)


def default_device_beam() -> bool:
    return _DEFAULT_DEVICE_BEAM


def set_default_scheduler(
    scheduler: str, sla_ms: float | list | None = None
) -> None:
    """Process-wide default for the coroutine scheduling policy — the hook
    ``benchmarks/run.py --scheduler/--sla-ms`` threads through.  "rr" is
    FIFO round-robin (bitwise the pre-SLA engine); "sla" is EDF ordering by
    the per-tenant deadlines ``sla_ms`` induces (docs/scheduling.md)."""
    global _DEFAULT_SCHEDULER, _DEFAULT_SLA_MS
    from repro.core.scheduling import SCHEDULERS

    assert scheduler in SCHEDULERS, f"unknown scheduler {scheduler!r}"
    _DEFAULT_SCHEDULER = scheduler
    if sla_ms is not None:
        _DEFAULT_SLA_MS = sla_ms


def default_scheduler() -> tuple[str, float | list | None]:
    return _DEFAULT_SCHEDULER, _DEFAULT_SLA_MS


def set_default_calibration(calib: dict | None) -> None:
    """Process-wide per-backend CostModel overrides, as emitted by
    ``benchmarks/calibrate.py`` ({backend: {cost_field: seconds}}).  Systems
    built with ``SystemConfig.calibration=None`` inherit it."""
    global _DEFAULT_CALIBRATION
    _DEFAULT_CALIBRATION = calib


def load_calibration(source) -> dict | None:
    """Normalize a calibration source: a dict passes through, a str/Path is
    read as the JSON file calibrate.py writes, None returns None."""
    if source is None or isinstance(source, dict):
        return source
    import json

    with open(source) as f:
        return json.load(f)


def apply_calibration(cost: CostModel, backend: str, calib: dict | None) -> CostModel:
    """A CostModel with ``calib[backend]``'s measured per-backend constants
    (dispatch / table-upload seconds) replacing the defaults.  Unknown keys
    are ignored so calibration files can carry extra diagnostics."""
    overrides = (calib or {}).get(backend)
    if not overrides:
        return cost
    fields = {f.name for f in dataclasses.fields(CostModel)}
    return dataclasses.replace(
        cost, **{k: float(v) for k, v in overrides.items() if k in fields}
    )


@dataclasses.dataclass
class SystemConfig:
    name: str = "velo"
    buffer_ratio: float = 0.2     # memory budget as a fraction of disk index size
    page_size: int = 4096
    n_workers: int = 1
    batch_size: int = 8           # B (1 == synchronous)
    params: SearchParams = dataclasses.field(default_factory=SearchParams)
    tau_scale: float = 1.0        # 0 disables co-placement
    adj_codec: str = "pef"
    page_policy: str = "lru"
    co_admit: bool = True         # colored co-admission (§3.4 fetch rule)
    async_load: bool = True       # LOCKED-window loads + record coalescing
                                  # (False: legacy synchronous per-record admits)
    group_demote: bool = False    # clock demotes co-admitted groups together
    track_access: bool = False    # per-vertex/page counters (Fig. 4)
    seed: int = 0
    distance_backend: str = "default"  # scalar | batch | pallas | auto | default
    fuse: bool | None = None      # cross-query fused dispatch (None -> process default)
    fuse_rows: int | None = None  # rendezvous flush row budget (None -> default)
    shared_rendezvous: bool | None = None  # one system-wide rendezvous buffer
                                  # spanning all workers (None -> process
                                  # default; off = per-worker PR-2 semantics)
    overlap_flush: bool | None = None  # overlap the shared-rendezvous stall
                                  # flush with other workers' in-flight
                                  # completions (None -> process default)
    tenant_quota: float | None = None  # serving plane: per-tenant soft cap on
                                  # shared-pool slots, as a fraction of the
                                  # pool (None/0 = pure global clock)
    resident_plane: bool = True   # register-once resident tables + id-based
                                  # refine requests (False = host-gather PR-2
                                  # semantics: per-call row materialization)
    calibration: dict | str | None = None  # per-backend CostModel overrides
                                  # ({backend: {field: s}} or a path to
                                  # calibrate.py's JSON; None -> process default)
    hbm_tier: bool | None = None  # device-resident record-cache tier above
                                  # the host pool (None -> process default;
                                  # only record-pool systems build one)
    hbm_slots: int | None = None  # HBM tier slot count (None -> process
                                  # default, which falls back to the host
                                  # pool's slot count)
    device_beam: bool | None = None  # fused on-device beam step: one
                                  # ("beam", ...) op per hop — score +
                                  # visited mask + top-k merge + frontier
                                  # selection in a single engine call, reply
                                  # is the FRONTIER (None -> process
                                  # default; off = the host-beam bitwise
                                  # reference path)
    n_shards: int | None = None   # sharded scatter-gather serving plane
                                  # (core.sharding): split the index image
                                  # across this many engine shards, each with
                                  # its own SSD, rendezvous buffer, and
                                  # clock; score work scatters to the owning
                                  # shards and merges per flush.  None/0 =
                                  # unsharded.  n_shards=1 is bitwise
                                  # identical to unsharded (the parity
                                  # contract bench_sharded.py enforces).
    scheduler: str | None = None  # coroutine scheduling policy: "rr" = FIFO
                                  # round-robin, bitwise the pre-SLA engine;
                                  # "sla" = EDF by deadline slack (admission,
                                  # ready picks, stall-flush initiator), fed
                                  # by sla_ms deadlines (None -> process
                                  # default; see docs/scheduling.md)
    sla_ms: float | list | None = None  # per-tenant latency target in ms
                                  # (scalar = every tenant; sequence = one
                                  # per tenant).  Induces per-query deadlines
                                  # arrival + sla; powers deadline hit-rate
                                  # accounting and the SLA feedback loop.
    sla_feedback: bool = True     # in sla mode with sla_ms set: run the
                                  # online feedback controller (beam width /
                                  # tenant quota / fuse_rows steering).  Off
                                  # = pure EDF, the schedule-invariant mode
                                  # the explorer covers.
    verify_protocol: bool = False  # arm the dynamic protocol checker
                                  # (repro.analysis.protocol): validates every
                                  # pool/HBM slot transition against the
                                  # Fig. 5 spec, runs cheap invariants at
                                  # each flush boundary, and raises at the
                                  # end of run() on any violation.  Purely
                                  # observational: results are bitwise
                                  # identical to an unverified run.


@dataclasses.dataclass
class System:
    """A runnable ANN system: index + cache + algorithm + engine config."""

    name: str
    config: SystemConfig
    index: object
    ctx: SearchContext
    algorithm: object
    store: object
    cost: CostModel
    hbm: object | None = None  # HbmTier when the device record tier is on
    checker: object | None = None  # ProtocolChecker when verify_protocol is on
    shard_plan: object | None = None  # sharding.ShardPlan when n_shards is set

    def make_coroutine(self, qid: int, q: np.ndarray):
        return self.algorithm(self.ctx, q, self.config.params)

    def run(
        self, queries: np.ndarray, ssd_config: SSDConfig | None = None,
        schedule=None, sla=None,
    ) -> tuple[list, WorkloadStats]:
        ssd = SSD(ssd_config)
        shards = None
        if self.shard_plan is not None:
            # fresh per run, like the SSD: shard clocks start at zero and
            # every shard's device starts idle
            from repro.core import sharding as sharding_mod

            shards = sharding_mod.ShardRouter(self.shard_plan, ssd_config)
        pool = getattr(self.ctx.accessor, "pool", None)
        pressure0 = (
            dict(pool.pressure_stats())
            if pool is not None and hasattr(pool, "pressure_stats") else None
        )
        # snapshot cumulative accessor counters so repeated run()/evaluate()
        # calls on one system report THIS run's delta, not a double count
        hits0, misses0 = self.ctx.accessor.stats()
        results, stats = run_workload(
            self.make_coroutine,
            queries,
            store=self.store,
            cost=self.cost,
            ssd=ssd,
            n_workers=self.config.n_workers,
            batch_size=self.config.batch_size,
            page_size=self.config.page_size,
            dist=self.ctx.dist,
            qb=self.ctx.qb,
            fuse=self.config.fuse,
            fuse_rows=self.config.fuse_rows,
            shared_rendezvous=bool(self.config.shared_rendezvous),
            overlap_flush=bool(self.config.overlap_flush),
            scheduler=self.config.scheduler or "rr",
            hbm=self.hbm,
            schedule=schedule,
            verify=self.checker,
            shards=shards,
            sla=sla,
        )
        if self.checker is not None:
            self.checker.raise_if_violations()
        hits, misses = self.ctx.accessor.stats()
        stats.cache_hits = hits - hits0
        stats.cache_misses = misses - misses0
        if pressure0 is not None:
            # the ONE pool instance is shared by all n_workers; report this
            # run's delta of its pressure counters (the engine counts
            # lock_waits/coalesced too, but only for ops it scheduled)
            for key, val in pool.pressure_stats().items():
                setattr(stats, key, val - pressure0[key])
        return results, stats

    # ---- memory accounting (Table 3) ----
    def disk_bytes(self) -> int:
        return self.index.disk_bytes()

    def memory_bytes(self) -> int:
        """Resident metadata + buffer budget (paper §5.3 footprint analysis).
        The HBM tier's slot arrays count toward the total so tiered and
        host-only configurations compare at equal memory."""
        total = self.index.resident_bytes() + int(
            self.config.buffer_ratio * self.index.disk_bytes()
        )
        if self.hbm is not None:
            total += self.hbm.nbytes()
        return total


# ----------------------------------------------------------------- builders


def _record_slot_bytes(dim: int, R: int) -> int:
    # decoded record: ext code (d/2) + lo/step (8) + adjacency ids (4R logical)
    return dim // 2 + 8 + 4 * R


_BREAKDOWN = {
    "baseline": dict(algo="diskann", pool="page", batch=1, prefetch=False, cbs=False),
    "+async": dict(algo="diskann", pool="page", batch=None, prefetch=False, cbs=False),
    "+record": dict(algo="diskann", pool="record", batch=None, prefetch=False, cbs=False),
    "+prefetch": dict(algo="velo", pool="record", batch=None, prefetch=True, cbs=False),
    "+cbs": dict(algo="velo", pool="record", batch=None, prefetch=True, cbs=True),
}


def build_system(
    name: str,
    base: np.ndarray,
    graph: VamanaGraph,
    qb: QuantizedBase,
    config: SystemConfig | None = None,
    cost: CostModel | None = None,
) -> System:
    config = config or SystemConfig()
    fuse_on, fuse_rows = default_fuse()
    config = dataclasses.replace(
        config,
        name=name,
        fuse=fuse_on if config.fuse is None else config.fuse,
        fuse_rows=fuse_rows if config.fuse_rows is None else config.fuse_rows,
        shared_rendezvous=(
            default_shared_rendezvous()
            if config.shared_rendezvous is None else config.shared_rendezvous
        ),
        overlap_flush=(
            default_overlap_flush()
            if config.overlap_flush is None else config.overlap_flush
        ),
        hbm_tier=(
            default_hbm()[0] if config.hbm_tier is None else config.hbm_tier
        ),
        hbm_slots=(
            default_hbm()[1] if config.hbm_slots is None else config.hbm_slots
        ),
        device_beam=(
            default_device_beam()
            if config.device_beam is None else config.device_beam
        ),
        scheduler=(
            default_scheduler()[0]
            if config.scheduler is None else config.scheduler
        ),
        sla_ms=(
            default_scheduler()[1]
            if config.sla_ms is None else config.sla_ms
        ),
    )
    cost = cost or CostModel()
    # ONE engine per system (it also answers which backend actually resolved
    # — pallas may degrade to batch — for the calibration lookup)
    dist_engine = distance_mod.get_engine(
        config.distance_backend, resident=config.resident_plane
    )
    calib = load_calibration(
        config.calibration if config.calibration is not None
        else _DEFAULT_CALIBRATION
    )
    if calib:
        cost = apply_calibration(cost, dist_engine.name, calib)
    n, dim = base.shape

    def record_pool_for(index) -> RecordAccessor:
        # ONE pool instance per system: all n_workers' coroutines share it,
        # coalescing on the same LOCKED windows and hot records.
        budget = config.buffer_ratio * index.disk_bytes()
        n_slots = max(8, int(budget // _record_slot_bytes(dim, graph.R)))
        pool = RecordBufferPool(min(n_slots, n), index.layout.vid_to_page,
                                group_demote=config.group_demote)
        return RecordAccessor(index, pool, cost, co_admit=config.co_admit,
                              track_access=config.track_access,
                              async_load=config.async_load)

    def page_cache_for(index) -> PageAccessor:
        budget = config.buffer_ratio * index.disk_bytes()
        pages = max(4, int(budget // config.page_size))
        cache = PageCache(pages, policy=config.page_policy, seed=config.seed)
        return PageAccessor(index, cache, cost, track_access=config.track_access)

    if name == "velo":
        index = VeloIndex(
            base, graph, qb,
            adj_codec=config.adj_codec,
            page_size=config.page_size,
            tau_scale=config.tau_scale,
        )
        acc = record_pool_for(index)
        algo = search_mod.velo_search
        refine = cost.refine_ext(dim)
        batch = config.batch_size
    elif name == "velo-page":
        # VeloANN layout + Alg. 2 but page-granular caching (Fig. 13's VeloANN-Page)
        index = VeloIndex(
            base, graph, qb,
            adj_codec=config.adj_codec,
            page_size=config.page_size,
            tau_scale=config.tau_scale,
        )
        acc = page_cache_for(index)
        algo = search_mod.velo_search
        refine = cost.refine_ext(dim)
        batch = config.batch_size
    elif name == "diskann":
        index = FixedIndex(base, graph, qb, page_size=config.page_size, shuffle=False)
        acc = page_cache_for(index)
        algo = search_mod.diskann_search
        refine = cost.refine_full(dim)
        batch = 1  # synchronous
    elif name == "starling":
        index = FixedIndex(base, graph, qb, page_size=config.page_size, shuffle=True)
        acc = page_cache_for(index)
        algo = search_mod.starling_search
        refine = cost.refine_full(dim)
        batch = 1
    elif name == "pipeann":
        index = FixedIndex(base, graph, qb, page_size=config.page_size, shuffle=False)
        acc = page_cache_for(index)
        algo = search_mod.pipeann_search
        refine = cost.refine_full(dim)
        batch = 1
    elif name == "inmemory":
        index = VeloIndex(base, graph, qb, page_size=config.page_size, tau_scale=0.0)
        acc = record_pool_for(index)  # unused: algorithm never touches disk
        algo = search_mod.inmemory_search
        refine = cost.refine_full(dim)
        batch = config.batch_size
    elif name in _BREAKDOWN:
        spec = _BREAKDOWN[name]
        index = VeloIndex(
            base, graph, qb,
            adj_codec=config.adj_codec,
            page_size=config.page_size,
            tau_scale=config.tau_scale,
        )
        acc = record_pool_for(index) if spec["pool"] == "record" else page_cache_for(index)
        algo = search_mod.ALGORITHMS[spec["algo"]]
        refine = cost.refine_ext(dim)
        batch = spec["batch"] or config.batch_size
        config = dataclasses.replace(
            config,
            params=dataclasses.replace(
                config.params, prefetch=spec["prefetch"], cbs=spec["cbs"]
            ),
        )
    else:
        raise ValueError(f"unknown system {name!r}")

    config = dataclasses.replace(config, batch_size=batch)
    shard_plan = None
    if config.n_shards:
        # the sharded scatter-gather plane: page->shard ownership derived
        # from the layout (pages are the affinity-preserving atomic unit)
        from repro.core import sharding as sharding_mod

        shard_plan = sharding_mod.plan_for_index(index, config.n_shards)
    hbm = None
    if (
        config.hbm_tier
        and not config.n_shards  # tier rides the unsharded dispatch path
        and name != "inmemory"
        and isinstance(acc, RecordAccessor)
        and isinstance(index, VeloIndex)
    ):
        # second cache tier ABOVE the host pool: device slots holding full
        # records; the accessor consults it first and the pool's publish
        # hook drains the miss list into staged scatters
        slots = config.hbm_slots or acc.pool.n_slots
        hbm = HbmTier(qb, index.layout.vid_to_page,
                      n_slots=max(8, min(int(slots), n)), R=graph.R)
        acc.hbm = hbm
        acc.pool.on_publish = hbm.note_publish
    checker = None
    if config.verify_protocol:
        # lazy import: core stays import-independent of the analysis layer
        from repro.analysis.protocol import ProtocolChecker

        checker = ProtocolChecker()
        if hbm is not None:
            # order matters: shadow the tier's entry points FIRST, then
            # re-point the pool's publish hook at the (now wrapped) staging
            # method, then let watch_pool chain its double-publish probe in
            # front of it — otherwise the pool keeps calling the raw bound
            # method captured above and staging goes unobserved
            checker.watch_hbm(hbm)
            acc.pool.on_publish = hbm.note_publish
        pool = getattr(acc, "pool", None)
        if pool is not None:
            checker.watch_pool(pool)
    ctx = SearchContext(
        index=index,
        qb=qb,
        accessor=acc,
        cost=cost,
        medoid=graph.medoid,
        base=base if name == "inmemory" else None,
        refine_cost_s=refine,
        dist=dist_engine,
        resident_ids=config.resident_plane,
        shard_plan=shard_plan,
        device_beam=bool(config.device_beam),
    )
    return System(
        name=name,
        config=config,
        index=index,
        ctx=ctx,
        algorithm=algo,
        store=index.store,
        cost=cost,
        hbm=hbm,
        checker=checker,
        shard_plan=shard_plan,
    )


def evaluate(
    system: System,
    ds: Dataset,
    ssd_config: SSDConfig | None = None,
) -> dict:
    """Run all dataset queries; return the paper's metrics.

    Stats collection is idempotent: the distance engine's cumulative counters
    are snapshotted around the run, so calling ``evaluate`` twice on one
    system reports each run's own dispatches/uploads — not a double count."""
    dist0 = dataclasses.replace(system.ctx.dist.stats)
    results, stats = system.run(ds.queries, ssd_config)
    dist1 = system.ctx.dist.stats
    k = ds.k
    ids = np.full((len(results), k), -1, dtype=np.int64)
    for i, r in enumerate(results):
        m = min(k, len(r.ids))
        ids[i, :m] = r.ids[:m]
    rec = recall_at_k(ids, ds.groundtruth, k)
    # combined two-tier hit rate: an access is a hit if EITHER tier served it
    # (tier misses fall through to the pool, so pool counters already exclude
    # tier hits — the sum is disjoint)
    served = stats.hbm_hits + stats.cache_hits
    accesses = served + stats.cache_misses
    combined = served / accesses if accesses else 0.0
    return {
        "system": system.name,
        "distance_backend": system.ctx.dist.name,
        "fuse": bool(system.config.fuse),
        "shared_rendezvous": bool(system.config.shared_rendezvous),
        "overlap_flush": bool(system.config.overlap_flush),
        "resident_plane": bool(system.config.resident_plane),
        "scheduler": system.config.scheduler or "rr",
        "recall@k": rec,
        "qps": stats.qps,
        "mean_latency_ms": stats.mean_latency_ms,
        "p99_latency_ms": stats.p99_latency_ms(),
        "mean_service_ms": stats.mean_service_ms,
        "queue_wait_s": stats.queue_wait_s,
        "deadline_hit_rate": stats.deadline_hit_rate,
        "ios_per_query": stats.ios_per_query,
        "coalesced_reads": stats.coalesced_reads,
        "hit_rate": stats.hit_rate,
        "lock_waits": stats.lock_waits,
        "coalesced_record_loads": stats.coalesced_record_loads,
        "group_admits": stats.group_admits,
        "clock_skips": stats.clock_skips,
        "overlap_flushes": stats.overlap_flushes,
        "disk_bytes": system.disk_bytes(),
        "memory_bytes": system.memory_bytes(),
        "mean_hops": float(np.mean([r.hops for r in results])),
        "dist_dispatches": dist1.dispatches() - dist0.dispatches(),
        "dist_uploads": dist1.uploads - dist0.uploads,
        "resident_gathers": dist1.resident_gathers - dist0.resident_gathers,
        "score_requests_per_flush": stats.requests_per_flush,
        "score_rows_per_flush": stats.rows_per_flush,
        "n_shards": system.config.n_shards or 0,
        "scatter_ops": stats.scatter_ops,
        "shard_flushes": stats.shard_flushes,
        "shard_merges": stats.shard_merges,
        "device_beam": bool(system.config.device_beam),
        "beam_ops": stats.beam_ops,
        "beam_flushes": stats.beam_flushes,
        "beam_rows": stats.beam_rows,
        "beam_steps": dist1.beam_steps - dist0.beam_steps,
        "dist_downloads": stats.dist_downloads,
        "downloads_per_query": stats.downloads_per_query,
        "hbm_tier": system.hbm is not None,
        "hbm_hits": stats.hbm_hits,
        "hbm_misses": stats.hbm_misses,
        "hbm_hit_rate": stats.hbm_hit_rate,
        "hbm_scatters": stats.hbm_scatters,
        "hbm_evictions": stats.hbm_evictions,
        "combined_hit_rate": combined,
    }
