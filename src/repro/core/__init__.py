"""Host-plane faithful reproduction of VeloANN (paper §3-§4).

Submodules:
  dataset     — synthetic vector workloads + ground truth
  flat        — brute-force exact search (oracle)
  quant       — RaBitQ-style 1-bit + 4-bit two-level quantization (paper §3.3)
  codec       — delta-varint + partitioned Elias-Fano adjacency compression (§3.3)
  pages       — slotted variable-size-record page layout (§3.3, Fig. 7)
  vamana      — batched Vamana graph construction + affinity coloring (Alg. 1)
  placement   — affinity-based record co-placement (§3.4)
  store       — on-"disk" page store (the simulated SSD-resident index)
  bufferpool  — record-level buffer pool, clock second-chance (§3.2, Fig. 5)
  pagecache   — page-level LRU/FIFO/Random baselines (Table 1)
  search      — search algorithms as schedulable coroutines (Alg. 2 + baselines)
  sim         — discrete-event SSD + CPU cost model
  engine      — coroutine scheduler (paper Fig. 3) sync/async executors
  baselines   — DiskANN-, Starling-, PipeANN-style system configurations
  workload    — multi-tenant arrival mixes (uniform / zipfian / bursty)
  serving     — multi-tenant serving plane: N indexes on one engine, shared
                pool with per-tenant quotas, cross-tenant fused dispatch
"""
