"""The batched distance plane: pluggable DistanceEngine backends.

Every level-1 (binary estimate) and level-2 (extended-code / fp32 refinement)
distance evaluated by the search plane goes through one of these engines:

  * ``scalar`` — per-row NumPy loop.  Deliberately naive: it is the oracle the
    other backends are tested against, and the "before" point of the paper's
    batching argument (one distance per call, no SIMD amortization).
  * ``batch``  — vectorized NumPy over whole code matrices (the default).
    One BLAS/ufunc dispatch per frontier batch instead of per vertex.
  * ``pallas`` — the JAX/Pallas kernels (kernels/binary_ip, kernels/int4_dist)
    in interpret mode on CPU, compiled on real accelerators.  Falls back to
    ``batch`` automatically when JAX is not importable.

Selection:

  get_engine("scalar" | "batch" | "pallas" | "auto" | "default" | None)

``auto`` resolves to ``pallas`` when JAX is available, else ``batch``.
``default`` (and None) resolve to the process-wide default set with
``set_default_backend`` — the hook benchmarks/run.py's ``--backend`` flag
threads through without touching every call site.

All engines consume the same packed artifact formats produced by
``RabitQuantizer.fit_encode`` (bit-packed level-1 codes, nibble-packed level-2
codes), so the host plane, the simulator, and the device kernels share one
index image.  Each engine keeps per-instance counters (``DistanceStats``) so
callers can report how much work the plane absorbed per batch.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.quant import PreparedQuery, QuantizedBase, RabitQuantizer, unpack_bits

BACKENDS = ("scalar", "batch", "pallas")

_DEFAULT_BACKEND = "batch"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (see ``get_engine``)."""
    global _DEFAULT_BACKEND
    if name not in BACKENDS and name != "auto":
        raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")
    _DEFAULT_BACKEND = name


def default_backend() -> str:
    return _DEFAULT_BACKEND


def resolved_backend(name: str | None = None) -> str:
    """The engine name ``get_engine(name)`` would actually serve — resolves
    ``default``/``auto`` and the pallas-without-jax degradation."""
    return get_engine(name).name


def pallas_available() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except Exception:  # pragma: no cover - exercised only without jax
        return False


@dataclasses.dataclass
class DistanceStats:
    """Work counters: calls vs rows show the batching amortization factor."""

    level1_calls: int = 0
    level1_rows: int = 0
    level2_calls: int = 0
    level2_rows: int = 0
    full_calls: int = 0
    full_rows: int = 0
    # cross-query fusion: dispatches that served >1 query's rows at once
    fused_calls: int = 0
    fused_queries: int = 0

    def dispatches(self) -> int:
        """Total kernel/ufunc dispatches issued by this engine instance."""
        return self.level1_calls + self.level2_calls + self.full_calls

    def rows_per_call(self) -> float:
        calls = self.dispatches()
        rows = self.level1_rows + self.level2_rows + self.full_rows
        return rows / calls if calls else 0.0


@dataclasses.dataclass
class ScoreRequest:
    """One coroutine's distance work, yielded to the engine as a ("score", req)
    op.  The engine collects requests from all ready coroutines on a worker
    into a rendezvous buffer and executes them as ONE fused DistanceEngine
    call per kind (see ``execute_requests``), resuming each coroutine with its
    slice of the results.

    kinds:
      "estimate" — level-1 binary estimates; payload = vertex-id array
      "refine"   — level-2 extended-code refinement; payload = (codes, lo, step)
      "full"     — exact fp32 distances; payload = (m, d) vector matrix
    ``flop_s`` is the per-row arithmetic cost in simulated seconds (WITHOUT the
    dispatch overhead — the engine charges one amortized dispatch per flush).
    """

    kind: str
    rows: int
    flop_s: float
    pq: object = None                 # PreparedQuery ("estimate" / "refine")
    payload: object = None
    query: np.ndarray | None = None   # fp32 query vector ("full")


class DistanceEngine:
    """Base class: counters + empty-batch handling; subclasses implement the
    three kernels over packed matrices."""

    name = "abstract"

    def __init__(self):
        self.stats = DistanceStats()

    # ---- level 1: binary estimate ------------------------------------------
    def estimate(
        self, qb: QuantizedBase, pq: PreparedQuery, ids: np.ndarray
    ) -> np.ndarray:
        """Level-1 estimated squared distances for vertex ids (resident codes)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level1_calls += 1
        self.stats.level1_rows += ids.size
        return self._estimate(
            qb, pq, qb.binary_codes[ids], qb.norms[ids], qb.ip_bar[ids]
        )

    # ---- level 2: extended-code refinement ---------------------------------
    def refine(
        self,
        qb: QuantizedBase,
        pq: PreparedQuery,
        codes: np.ndarray,
        lo: np.ndarray,
        step: np.ndarray,
    ) -> np.ndarray:
        """Level-2 refined squared distances from packed extended codes."""
        if codes.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level2_calls += 1
        self.stats.level2_rows += codes.shape[0]
        return self._refine(qb, pq, codes, lo, step)

    # ---- exact fp32 (DiskANN-style records, in-memory oracle) --------------
    def refine_full(self, q: np.ndarray, vectors: np.ndarray) -> np.ndarray:
        """Exact squared distances from full fp32 vectors to query ``q``."""
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.full_calls += 1
        self.stats.full_rows += vectors.shape[0]
        return self._refine_full(np.asarray(q, dtype=np.float32), vectors)

    # ---- fused multi-query dispatch ----------------------------------------
    # The cross-query batching plane: each method serves SEVERAL queries'
    # row groups in ONE dispatch (one stats "call").  Single-group batches
    # delegate to the per-query path, so a rendezvous of one is bitwise
    # identical to unfused execution.

    def estimate_many(
        self, qb: QuantizedBase, groups: list[tuple[PreparedQuery, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused level-1 estimates: ``groups`` is (pq, ids) per query; returns
        the per-query estimate arrays, order preserved."""
        outs: list = [None] * len(groups)
        live: list[tuple[int, PreparedQuery, np.ndarray]] = []
        for i, (pq, ids) in enumerate(groups):
            ids = np.asarray(ids, dtype=np.int64)
            if ids.size == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, pq, ids))
        if not live:
            return outs
        if len(live) == 1:
            i, pq, ids = live[0]
            outs[i] = self.estimate(qb, pq, ids)
            return outs
        sizes = [ids.size for _, _, ids in live]
        all_ids = np.concatenate([ids for _, _, ids in live])
        self.stats.level1_calls += 1
        self.stats.level1_rows += all_ids.size
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._estimate_many(
            qb,
            [pq for _, pq, _ in live],
            sizes,
            qb.binary_codes[all_ids],
            qb.norms[all_ids],
            qb.ip_bar[all_ids],
        )
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    def refine_many(
        self,
        qb: QuantizedBase,
        groups: list[tuple[PreparedQuery, np.ndarray, np.ndarray, np.ndarray]],
    ) -> list[np.ndarray]:
        """Fused level-2 refinement: ``groups`` is (pq, codes, lo, step)."""
        outs: list = [None] * len(groups)
        live = []
        for i, g in enumerate(groups):
            if g[1].shape[0] == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, g))
        if not live:
            return outs
        if len(live) == 1:
            i, (pq, codes, lo, step) = live[0]
            outs[i] = self.refine(qb, pq, codes, lo, step)
            return outs
        sizes = [g[1].shape[0] for _, g in live]
        codes = np.concatenate([g[1] for _, g in live])
        lo = np.concatenate([g[2] for _, g in live])
        step = np.concatenate([g[3] for _, g in live])
        self.stats.level2_calls += 1
        self.stats.level2_rows += codes.shape[0]
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_many(qb, [g[0] for _, g in live], sizes, codes, lo, step)
        off = 0
        for (i, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    def refine_full_many(
        self, groups: list[tuple[np.ndarray, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused exact-fp32 refinement: ``groups`` is (q, vectors)."""
        outs: list = [None] * len(groups)
        live = []
        for i, (q, vectors) in enumerate(groups):
            vectors = np.asarray(vectors, dtype=np.float32)
            if vectors.shape[0] == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, np.asarray(q, dtype=np.float32), vectors))
        if not live:
            return outs
        if len(live) == 1:
            i, q, vectors = live[0]
            outs[i] = self.refine_full(q, vectors)
            return outs
        sizes = [v.shape[0] for _, _, v in live]
        vectors = np.concatenate([v for _, _, v in live])
        self.stats.full_calls += 1
        self.stats.full_rows += vectors.shape[0]
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_full_many([q for _, q, _ in live], sizes, vectors)
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    # ---- subclass hooks ----------------------------------------------------
    def _estimate(self, qb, pq, codes, norms, ip_bar) -> np.ndarray:
        raise NotImplementedError

    def _refine(self, qb, pq, codes, lo, step) -> np.ndarray:
        raise NotImplementedError

    def _refine_full(self, q, vectors) -> np.ndarray:
        raise NotImplementedError

    # Fused-dispatch hooks.  The defaults evaluate per query group over the
    # stacked matrices (correct everywhere, fused only in accounting); the
    # batch/pallas backends override them with genuinely fused evaluations.
    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar) -> np.ndarray:
        out = np.empty(codes.shape[0], dtype=np.float32)
        off = 0
        for pq, m in zip(pqs, sizes):
            out[off : off + m] = self._estimate(
                qb, pq, codes[off : off + m], norms[off : off + m],
                ip_bar[off : off + m],
            )
            off += m
        return out

    def _refine_many(self, qb, pqs, sizes, codes, lo, step) -> np.ndarray:
        out = np.empty(codes.shape[0], dtype=np.float32)
        off = 0
        for pq, m in zip(pqs, sizes):
            out[off : off + m] = self._refine(
                qb, pq, codes[off : off + m], lo[off : off + m],
                step[off : off + m],
            )
            off += m
        return out

    def _refine_full_many(self, qs, sizes, vectors) -> np.ndarray:
        out = np.empty(vectors.shape[0], dtype=np.float32)
        off = 0
        for q, m in zip(qs, sizes):
            out[off : off + m] = self._refine_full(q, vectors[off : off + m])
            off += m
        return out


class ScalarEngine(DistanceEngine):
    """One row at a time — the oracle and the pre-batching cost baseline."""

    name = "scalar"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.estimate_batch(
                qb, pq, codes[i : i + 1], norms[i : i + 1], ip_bar[i : i + 1]
            )[0]
        return out

    def _refine(self, qb, pq, codes, lo, step):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.refine_batch(
                qb, pq, codes[i : i + 1], lo[i : i + 1], step[i : i + 1]
            )[0]
        return out

    def _refine_full(self, q, vectors):
        out = np.empty(vectors.shape[0], dtype=np.float32)
        for i in range(vectors.shape[0]):
            diff = vectors[i] - q
            out[i] = diff @ diff
        return out


class BatchEngine(DistanceEngine):
    """Vectorized NumPy over whole code matrices (default backend)."""

    name = "batch"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        return RabitQuantizer.estimate_batch(qb, pq, codes, norms, ip_bar).astype(
            np.float32, copy=False
        )

    def _refine(self, qb, pq, codes, lo, step):
        return RabitQuantizer.refine_batch(qb, pq, codes, lo, step).astype(
            np.float32, copy=False
        )

    def _refine_full(self, q, vectors):
        diff = vectors - q[None, :]
        return np.einsum("ij,ij->i", diff, diff).astype(np.float32, copy=False)

    # ---- genuinely fused multi-query paths ---------------------------------

    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar):
        # One GEMM over the stacked frontier rows of ALL queries: (M, d) signs
        # times (d, B) stacked unit queries; each row then selects its owner's
        # column — one dispatch serves B queries.
        d = qb.dim
        signs = 2.0 * unpack_bits(codes, d).astype(np.float32) - 1.0  # (M, d)
        Q = np.stack([pq.qunit for pq in pqs])                        # (B, d)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        g = signs @ Q.T                                               # (M, B)
        g = g[np.arange(g.shape[0]), owner] / np.sqrt(d)
        est_cos = np.clip(g / np.maximum(ip_bar, 1e-6), -1.0, 1.0)
        qn = np.asarray([pq.qnorm for pq in pqs], dtype=np.float64)[owner]
        out = qn**2 + norms**2 - 2.0 * qn * norms * est_cos
        return out.astype(np.float32, copy=False)

    def _refine_many(self, qb, pqs, sizes, codes, lo, step):
        rec = qb.decode_ext(codes) * step[:, None] + lo[:, None]      # (M, d)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        qr_rows = np.stack([pq.qr for pq in pqs])[owner]              # (M, d)
        diff = qr_rows - rec
        return (diff * diff).sum(axis=1).astype(np.float32, copy=False)

    def _refine_full_many(self, qs, sizes, vectors):
        owner = np.repeat(np.arange(len(qs)), sizes)
        diff = vectors - np.stack(qs)[owner]
        return np.einsum("ij,ij->i", diff, diff).astype(np.float32, copy=False)


class PallasEngine(BatchEngine):
    """JAX/Pallas kernels for both quantized levels.

    Row counts are padded up to multiples of ``bucket`` so the jitted kernel
    wrappers see a small set of static shapes (bounded recompiles) — the
    frontier size varies every hop.  The exact-fp32 path and the 8-bit
    extended codes (no int4 kernel applies) stay on the NumPy batch path.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, bucket: int = 64):
        super().__init__()
        import jax  # raises if jax missing
        import jax.numpy as jnp  # noqa: F401

        from repro.kernels.binary_ip import estimate_dist2 as _binary_est
        from repro.kernels.int4_dist import int4_dist2 as _int4_dist2

        if interpret is None:
            # interpret mode on CPU (Pallas has no CPU lowering), compiled
            # kernels on real accelerators
            interpret = jax.default_backend() == "cpu"
        self._jnp = jnp
        self._binary_est = _binary_est
        self._int4_dist2 = _int4_dist2
        self.interpret = interpret
        self.bucket = bucket

    def _pad_rows(self, m: int) -> int:
        b = self.bucket
        return max(b, ((m + b - 1) // b) * b)

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            norms = np.concatenate([norms, np.zeros(mp - m, dtype=norms.dtype)])
            ip_bar = np.concatenate([ip_bar, np.ones(mp - m, dtype=ip_bar.dtype)])
        out = self._binary_est(
            pq.qr[None, :], codes, norms, ip_bar, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _refine(self, qb, pq, codes, lo, step):
        if qb.ext_bits != 4:  # the kernel is nibble-packed int4 only
            return super()._refine(qb, pq, codes, lo, step)
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            lo = np.concatenate([lo, np.zeros(mp - m, dtype=lo.dtype)])
            step = np.concatenate([step, np.ones(mp - m, dtype=step.dtype)])
        out = self._int4_dist2(
            pq.qr[None, :], codes, lo, step, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    # ---- fused multi-query paths: the kernels are (B, N)-shaped already ----

    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar):
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            norms = np.concatenate([norms, np.zeros(mp - m, dtype=norms.dtype)])
            ip_bar = np.concatenate([ip_bar, np.ones(mp - m, dtype=ip_bar.dtype)])
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(
            self._binary_est(Q, codes, norms, ip_bar, interpret=self.interpret)
        )  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)

    def _refine_many(self, qb, pqs, sizes, codes, lo, step):
        if qb.ext_bits != 4:  # no int4 kernel: NumPy fused path
            return super()._refine_many(qb, pqs, sizes, codes, lo, step)
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            lo = np.concatenate([lo, np.zeros(mp - m, dtype=lo.dtype)])
            step = np.concatenate([step, np.ones(mp - m, dtype=step.dtype)])
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(
            self._int4_dist2(Q, codes, lo, step, interpret=self.interpret)
        )  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)


def get_engine(name: str | None = None) -> DistanceEngine:
    """Build a fresh engine for ``name`` (see module docstring for the rules)."""
    if name is None or name == "default":
        name = _DEFAULT_BACKEND
    if name == "auto":
        name = "pallas" if pallas_available() else "batch"
    if name == "scalar":
        return ScalarEngine()
    if name == "batch":
        return BatchEngine()
    if name == "pallas":
        try:
            return PallasEngine()
        except ImportError as e:  # no jax: degrade, keep serving
            warnings.warn(
                f"pallas distance backend unavailable ({e}); using batch",
                RuntimeWarning,
                stacklevel=2,
            )
            return BatchEngine()
    raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")


def execute_requests(
    engine: DistanceEngine, qb: QuantizedBase | None, reqs: list[ScoreRequest]
) -> list[np.ndarray]:
    """Execute a rendezvous batch of score requests: ONE fused engine call per
    request kind present, results returned in request order.

    This is the engine scheduler's flush primitive: requests from different
    coroutines (different queries) sharing a kind are stacked and dispatched
    together — the Pallas wrappers are (B, N)-shaped, so one kernel launch
    serves every query in the batch.
    """
    out: list = [None] * len(reqs)
    by_kind: dict[str, list[int]] = {}
    for i, r in enumerate(reqs):
        by_kind.setdefault(r.kind, []).append(i)
    if qb is None and (by_kind.keys() - {"full"}):
        raise ValueError(
            "score requests of kind 'estimate'/'refine' need the QuantizedBase: "
            "pass qb= to the Engine / run_workload executing these coroutines"
        )
    for kind, idxs in by_kind.items():
        if kind == "estimate":
            res = engine.estimate_many(
                qb, [(reqs[i].pq, reqs[i].payload) for i in idxs]
            )
        elif kind == "refine":
            res = engine.refine_many(
                qb, [(reqs[i].pq, *reqs[i].payload) for i in idxs]
            )
        elif kind == "full":
            res = engine.refine_full_many(
                [(reqs[i].query, reqs[i].payload) for i in idxs]
            )
        else:
            raise ValueError(f"unknown score request kind {kind!r}")
        for i, r_ in zip(idxs, res):
            out[i] = r_
    return out
