"""The batched distance plane: pluggable DistanceEngine backends.

Every level-1 (binary estimate) and level-2 (extended-code / fp32 refinement)
distance evaluated by the search plane goes through one of these engines:

  * ``scalar`` — per-row NumPy loop.  Deliberately naive: it is the oracle the
    other backends are tested against, and the "before" point of the paper's
    batching argument (one distance per call, no SIMD amortization).
  * ``batch``  — vectorized NumPy over whole code matrices (the default).
    One BLAS/ufunc dispatch per frontier batch instead of per vertex.
  * ``pallas`` — the JAX/Pallas kernels (kernels/binary_ip, kernels/int4_dist)
    in interpret mode on CPU, compiled on real accelerators.  Falls back to
    ``batch`` automatically when JAX is not importable.

Selection:

  get_engine("scalar" | "batch" | "pallas" | "auto" | "default" | None)

``auto`` resolves to ``pallas`` when JAX is available, else ``batch``.
``default`` (and None) resolve to the process-wide default set with
``set_default_backend`` — the hook benchmarks/run.py's ``--backend`` flag
threads through without touching every call site.

Resident code plane (register-once tables):

Engines no longer consume caller-gathered code matrices on the hot path.
``register_index(qb)`` pins an index's resident tables ONCE per engine —
contiguous host views (``quant.ResidentView``) for the NumPy backends, device
arrays via ``jax.device_put`` for the Pallas backend (the
``velo.index.DeviceIndex`` pattern) — and every id-based request gathers from
the registered table: on-device inside the jitted kernel wrappers for
``pallas``, one fancy-index per table for the host backends.  Registration is
lazy (first id-based call registers) and idempotent; ``DistanceStats.uploads``
counts table uploads so benchmarks can assert they are O(1) per index rather
than O(hops).  The matrix-consuming entry points (``refine`` over payload
rows, the ``*_many`` matrix hooks) remain for the host-gather parity path and
for ext_bits=8 records — on the Pallas backend each such call re-uploads its
gathered rows and is counted as an upload.

All engines consume the same packed artifact formats produced by
``RabitQuantizer.fit_encode`` (bit-packed level-1 codes, nibble-packed level-2
codes), so the host plane, the simulator, and the device kernels share one
index image.  Each engine keeps per-instance counters (``DistanceStats``) so
callers can report how much work the plane absorbed per batch.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core import beam as beam_mod
from repro.core.quant import (
    PreparedQuery,
    QuantizedBase,
    RabitQuantizer,
    ResidentView,
    unpack_bits,
)

BACKENDS = ("scalar", "batch", "pallas")

_DEFAULT_BACKEND = "batch"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (see ``get_engine``)."""
    global _DEFAULT_BACKEND
    if name not in BACKENDS and name != "auto":
        raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")
    _DEFAULT_BACKEND = name


def default_backend() -> str:
    return _DEFAULT_BACKEND


def resolved_backend(name: str | None = None) -> str:
    """The engine name ``get_engine(name)`` would actually serve — resolves
    ``default``/``auto`` and the pallas-without-jax degradation."""
    return get_engine(name).name


def pallas_available() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except Exception:  # pragma: no cover - exercised only without jax
        return False


@dataclasses.dataclass
class DistanceStats:
    """Work counters: calls vs rows show the batching amortization factor."""

    level1_calls: int = 0
    level1_rows: int = 0
    level2_calls: int = 0
    level2_rows: int = 0
    full_calls: int = 0
    full_rows: int = 0
    # cross-query fusion: dispatches that served >1 query's rows at once
    fused_calls: int = 0
    fused_queries: int = 0
    # resident code plane: table uploads (register_index, plus one per
    # gathered-row kernel call on the non-resident pallas path) and rows
    # gathered from registered tables instead of caller-materialized matrices
    uploads: int = 0
    resident_gathers: int = 0
    # HBM record-cache tier: rows refined by slot-indirection gathers from
    # device cache slots (zero per-hop upload, like the resident table path)
    slot_gathers: int = 0
    # fused on-device beam steps: score + visited mask + top-k merge +
    # frontier select executed engine-side (the reply is a frontier, not a
    # per-row distance download)
    beam_steps: int = 0
    beam_rows: int = 0

    def dispatches(self) -> int:
        """Total kernel/ufunc dispatches issued by this engine instance."""
        return self.level1_calls + self.level2_calls + self.full_calls

    def rows_per_call(self) -> float:
        calls = self.dispatches()
        rows = self.level1_rows + self.level2_rows + self.full_rows
        return rows / calls if calls else 0.0


@dataclasses.dataclass
class ScoreRequest:
    """One coroutine's distance work, yielded to the engine as a ("score", req)
    op.  The engine collects requests from all ready coroutines — on one
    worker, or system-wide with the shared rendezvous — into a rendezvous
    buffer and executes them as ONE fused DistanceEngine call per kind (see
    ``execute_requests``), resuming each coroutine with its slice of the
    results.

    kinds:
      "estimate" — level-1 binary estimates; payload = vertex-id array
                   (rows resolved against the engine's registered tables)
      "refine"   — level-2 extended-code refinement; payload = vertex-id
                   array (resident path, the default), or a materialized
                   (codes, lo, step) tuple (host-gather parity path)
      "full"     — exact fp32 distances; payload = (m, d) vector matrix
    ``flop_s`` is the per-row arithmetic cost in simulated seconds (WITHOUT the
    dispatch overhead — the engine charges one amortized dispatch per flush).

    ``qb`` names the quantized table the id payload indexes (the tenant tag of
    the multi-tenant serving plane): requests from different indexes sharing
    one engine each carry their own table, and ``execute_requests`` routes
    each (kind, table) group to its own fused call.  ``qb=None`` falls back to
    the engine-level default — the single-system wire format, bitwise
    unchanged.  ``tenant`` is a purely diagnostic tag (``WorkloadStats.
    cross_tenant_flushes`` counts flushes spanning more than one).
    """

    kind: str
    rows: int
    flop_s: float
    pq: object = None                 # PreparedQuery ("estimate" / "refine")
    payload: object = None
    query: np.ndarray | None = None   # fp32 query vector ("full")
    qb: object = None                 # QuantizedBase the ids resolve against
                                      # (None -> engine default; serving plane
                                      # sets the tenant's registered table)
    tenant: int = 0                   # serving-plane tenant id (diagnostic)


class DistanceEngine:
    """Base class: counters + empty-batch handling + the register-once table
    registry; subclasses implement the kernels over registered tables and
    packed matrices."""

    name = "abstract"

    def __init__(self, resident: bool = True):
        self.stats = DistanceStats()
        # resident=False keeps PR-2 semantics on the pallas path: rows are
        # gathered on the host and re-uploaded per call (the "before" point
        # the uploads counter quantifies).  Host backends gather from the
        # registered views either way — results are bitwise identical.
        self.resident = resident
        self._tables: dict[int, object] = {}

    # ---- register-once resident tables -------------------------------------
    def register_index(self, qb: QuantizedBase):
        """Pin ``qb``'s resident tables on this engine (idempotent).  Returns
        the table handle; the first registration counts one upload."""
        tbl = self._tables.get(id(qb))
        if tbl is None:
            tbl = self._build_table(qb)
            self._tables[id(qb)] = tbl
            self.stats.uploads += 1
        return tbl

    def is_registered(self, qb: QuantizedBase) -> bool:
        return id(qb) in self._tables

    def _build_table(self, qb: QuantizedBase):
        return ResidentView.from_qb(qb)

    # ---- level 1: binary estimate ------------------------------------------
    def estimate(
        self, qb: QuantizedBase, pq: PreparedQuery, ids: np.ndarray
    ) -> np.ndarray:
        """Level-1 estimated squared distances for vertex ids (resident codes)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty(0, dtype=np.float32)
        tbl = self.register_index(qb)
        self.stats.level1_calls += 1
        self.stats.level1_rows += ids.size
        self.stats.resident_gathers += ids.size
        return self._estimate_ids(qb, tbl, pq, ids)

    # ---- level 2: extended-code refinement ---------------------------------
    def refine_ids(
        self, qb: QuantizedBase, pq: PreparedQuery, ids: np.ndarray
    ) -> np.ndarray:
        """Level-2 refined squared distances for vertex ids, served from the
        registered extended-code table (resident path)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty(0, dtype=np.float32)
        tbl = self.register_index(qb)
        self.stats.level2_calls += 1
        self.stats.level2_rows += ids.size
        self.stats.resident_gathers += ids.size
        return self._refine_ids(qb, tbl, pq, ids)

    def refine(
        self,
        qb: QuantizedBase,
        pq: PreparedQuery,
        codes: np.ndarray,
        lo: np.ndarray,
        step: np.ndarray,
    ) -> np.ndarray:
        """Level-2 refined squared distances from packed extended codes
        (host-gather path: the caller materialized the rows)."""
        if codes.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level2_calls += 1
        self.stats.level2_rows += codes.shape[0]
        return self._refine(qb, pq, codes, lo, step)

    def refine_slots(
        self, view, pq: PreparedQuery, slots: np.ndarray
    ) -> np.ndarray:
        """Level-2 refinement by HBM cache SLOT index: rows gather from the
        tier's slot arrays (``cache_ext``/``cache_lo``/``cache_step``) rather
        than the per-vid registered table — the slot-indirection sibling of
        ``refine_ids``.  ``view`` is the tier handle (``core.hbm.HbmTier`` or
        any object with ``qb``, ``gather(slots)`` and, for the device
        backends, ``device_arrays()``)."""
        slots = np.asarray(slots, dtype=np.int64)
        if slots.size == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level2_calls += 1
        self.stats.level2_rows += slots.size
        self.stats.slot_gathers += slots.size
        return self._refine_slots(view, pq, slots)

    def refine_slots_many(
        self, view, groups: list[tuple[PreparedQuery, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused slot-based level-2 refinement: ``groups`` is (pq, slots)."""
        outs: list = [None] * len(groups)
        live: list[tuple[int, PreparedQuery, np.ndarray]] = []
        for i, (pq, slots) in enumerate(groups):
            slots = np.asarray(slots, dtype=np.int64)
            if slots.size == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, pq, slots))
        if not live:
            return outs
        if len(live) == 1:
            i, pq, slots = live[0]
            outs[i] = self.refine_slots(view, pq, slots)
            return outs
        sizes = [slots.size for _, _, slots in live]
        all_slots = np.concatenate([slots for _, _, slots in live])
        self.stats.level2_calls += 1
        self.stats.level2_rows += all_slots.size
        self.stats.slot_gathers += all_slots.size
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_slots_many(
            view, [pq for _, pq, _ in live], sizes, all_slots
        )
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    # ---- exact fp32 (DiskANN-style records, in-memory oracle) --------------
    def refine_full(self, q: np.ndarray, vectors: np.ndarray) -> np.ndarray:
        """Exact squared distances from full fp32 vectors to query ``q``."""
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.full_calls += 1
        self.stats.full_rows += vectors.shape[0]
        return self._refine_full(np.asarray(q, dtype=np.float32), vectors)

    # ---- fused multi-query dispatch ----------------------------------------
    # The cross-query batching plane: each method serves SEVERAL queries'
    # row groups in ONE dispatch (one stats "call").  Single-group batches
    # delegate to the per-query path, so a rendezvous of one is bitwise
    # identical to unfused execution.

    def estimate_many(
        self, qb: QuantizedBase, groups: list[tuple[PreparedQuery, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused level-1 estimates: ``groups`` is (pq, ids) per query; returns
        the per-query estimate arrays, order preserved."""
        outs: list = [None] * len(groups)
        live: list[tuple[int, PreparedQuery, np.ndarray]] = []
        for i, (pq, ids) in enumerate(groups):
            ids = np.asarray(ids, dtype=np.int64)
            if ids.size == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, pq, ids))
        if not live:
            return outs
        if len(live) == 1:
            i, pq, ids = live[0]
            outs[i] = self.estimate(qb, pq, ids)
            return outs
        tbl = self.register_index(qb)
        sizes = [ids.size for _, _, ids in live]
        all_ids = np.concatenate([ids for _, _, ids in live])
        self.stats.level1_calls += 1
        self.stats.level1_rows += all_ids.size
        self.stats.resident_gathers += all_ids.size
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._estimate_ids_many(
            qb, tbl, [pq for _, pq, _ in live], sizes, all_ids
        )
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    def refine_ids_many(
        self, qb: QuantizedBase, groups: list[tuple[PreparedQuery, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused id-based level-2 refinement: ``groups`` is (pq, ids)."""
        outs: list = [None] * len(groups)
        live: list[tuple[int, PreparedQuery, np.ndarray]] = []
        for i, (pq, ids) in enumerate(groups):
            ids = np.asarray(ids, dtype=np.int64)
            if ids.size == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, pq, ids))
        if not live:
            return outs
        if len(live) == 1:
            i, pq, ids = live[0]
            outs[i] = self.refine_ids(qb, pq, ids)
            return outs
        tbl = self.register_index(qb)
        sizes = [ids.size for _, _, ids in live]
        all_ids = np.concatenate([ids for _, _, ids in live])
        self.stats.level2_calls += 1
        self.stats.level2_rows += all_ids.size
        self.stats.resident_gathers += all_ids.size
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_ids_many(
            qb, tbl, [pq for _, pq, _ in live], sizes, all_ids
        )
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    def refine_many(
        self,
        qb: QuantizedBase,
        groups: list[tuple[PreparedQuery, np.ndarray, np.ndarray, np.ndarray]],
    ) -> list[np.ndarray]:
        """Fused level-2 refinement over materialized rows: ``groups`` is
        (pq, codes, lo, step) — the host-gather parity path."""
        outs: list = [None] * len(groups)
        live = []
        for i, g in enumerate(groups):
            if g[1].shape[0] == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, g))
        if not live:
            return outs
        if len(live) == 1:
            i, (pq, codes, lo, step) = live[0]
            outs[i] = self.refine(qb, pq, codes, lo, step)
            return outs
        sizes = [g[1].shape[0] for _, g in live]
        codes = np.concatenate([g[1] for _, g in live])
        lo = np.concatenate([g[2] for _, g in live])
        step = np.concatenate([g[3] for _, g in live])
        self.stats.level2_calls += 1
        self.stats.level2_rows += codes.shape[0]
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_many(qb, [g[0] for _, g in live], sizes, codes, lo, step)
        off = 0
        for (i, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    def refine_full_many(
        self, groups: list[tuple[np.ndarray, np.ndarray]]
    ) -> list[np.ndarray]:
        """Fused exact-fp32 refinement: ``groups`` is (q, vectors)."""
        outs: list = [None] * len(groups)
        live = []
        for i, (q, vectors) in enumerate(groups):
            vectors = np.asarray(vectors, dtype=np.float32)
            if vectors.shape[0] == 0:
                outs[i] = np.empty(0, dtype=np.float32)
            else:
                live.append((i, np.asarray(q, dtype=np.float32), vectors))
        if not live:
            return outs
        if len(live) == 1:
            i, q, vectors = live[0]
            outs[i] = self.refine_full(q, vectors)
            return outs
        sizes = [v.shape[0] for _, _, v in live]
        vectors = np.concatenate([v for _, _, v in live])
        self.stats.full_calls += 1
        self.stats.full_rows += vectors.shape[0]
        self.stats.fused_calls += 1
        self.stats.fused_queries += len(live)
        res = self._refine_full_many([q for _, q, _ in live], sizes, vectors)
        off = 0
        for (i, _, _), m in zip(live, sizes):
            outs[i] = np.asarray(res[off : off + m], dtype=np.float32)
            off += m
        return outs

    # ---- fused beam step: score -> visited mask -> top-k -> frontier -------
    # The reply to a beam op is the next FRONTIER, not a distance download:
    # the per-query candidate heap and visited/explored masks stay engine-
    # resident across hops (device arrays on the pallas backend).  Scoring
    # routes through the same estimate/full machinery as the host path, so
    # distances are bitwise identical to a ("score", ...) op; the merge and
    # frontier selection follow the (d, v)-tuple order of the host _Beam.

    def beam_new(self, L: int, n: int) -> beam_mod.BeamState:
        """Fresh engine-resident beam state for one query (L-slot candidate
        heap over an n-vertex id space)."""
        return beam_mod.BeamState.new(L, n)

    def beam_step(self, qb, req: beam_mod.BeamRequest) -> beam_mod.BeamResult:
        """One fused beam step (see ``beam_step_many``)."""
        return self.beam_step_many(qb, [req])[0]

    def beam_step_many(
        self, qb, reqs: list[beam_mod.BeamRequest]
    ) -> list[beam_mod.BeamResult]:
        """Fused beam steps for a rendezvous group of queries: score each
        request's fresh ids, drop visited, merge into its candidate heap,
        mark explored, and select its next frontier — one launch for the
        whole group on the device backend."""
        self.stats.beam_steps += len(reqs)
        self.stats.beam_rows += sum(int(r.rows) for r in reqs)
        return self._beam_step_many(qb, reqs)

    def _beam_step_many(self, qb, reqs):
        scores = self._beam_scores(qb, reqs)
        return [self._beam_apply(r, s) for r, s in zip(reqs, scores)]

    def _beam_scores(self, qb, reqs) -> list[np.ndarray]:
        """Fresh-id distances per request, via the engine's own fused score
        paths (bitwise the values a ("score", ...) op would have returned)."""
        scores: list = [None] * len(reqs)

        def ids_of(r):  # BeamRequest carries .fresh, BeamShardPart .ids
            return r.fresh if isinstance(r, beam_mod.BeamRequest) else r.ids

        subgroups: dict[tuple, list[int]] = {}
        for i, r in enumerate(reqs):
            gqb = r.qb if r.qb is not None else qb
            subgroups.setdefault((r.kind, id(gqb)), []).append(i)
        for (kind, _), idxs in subgroups.items():
            if kind == "estimate":
                gqb = reqs[idxs[0]].qb if reqs[idxs[0]].qb is not None else qb
                res = self.estimate_many(gqb, [
                    (reqs[i].pq,
                     np.asarray(ids_of(reqs[i]), np.int64) + reqs[i].vid_base)
                    for i in idxs
                ])
            elif kind == "full":
                res = self.refine_full_many([
                    (reqs[i].query, reqs[i].vectors) for i in idxs
                ])
            else:
                raise ValueError(f"unknown beam request kind {kind!r}")
            for i, s in zip(idxs, res):
                scores[i] = s
        return scores

    def _beam_apply(
        self, req: beam_mod.BeamRequest, fresh_d: np.ndarray
    ) -> beam_mod.BeamResult:
        """Reference (vectorized NumPy) mask/merge/select over one state."""
        st = req.state
        cand_d, cand_v, visited, explored = self._beam_host_view(st)
        cv = np.concatenate([
            np.asarray(req.fresh, np.int64),
            np.asarray(req.insert_ids, np.int64),
        ])
        cd = np.concatenate([
            np.asarray(fresh_d, np.float32),
            np.asarray(req.insert_ds, np.float32),
        ])
        # first-wins within the step, then the visited bitmask — the host
        # _Beam.insert early-return semantics
        keep = beam_mod.dedupe_first(cv) & ~beam_mod.mask_ids(visited, cv)
        cv, cd = cv[keep], cd[keep]
        beam_mod.set_ids(visited, cv)
        cand_d, cand_v = beam_mod.merge_topk(cand_d, cand_v, cd, cv, st.L)
        expl = np.asarray(req.explored, np.int64)
        if expl.size:
            beam_mod.set_ids(explored, expl)
        self._beam_store(st, cand_d, cand_v, visited, explored)
        frontier, wlen, tail = beam_mod.select_frontier(cand_d, cand_v, explored)
        res = beam_mod.BeamResult(frontier=frontier, window_len=wlen, tail=tail)
        if req.topk:
            k = min(int(req.topk), st.L)
            real = cand_v[:k] != beam_mod.PAD_VID
            res.topk_ids = cand_v[:k][real]
            res.topk_ds = cand_d[:k][real]
        return res

    def _beam_host_view(self, st: beam_mod.BeamState):
        return st.cand_d, st.cand_v, st.visited, st.explored

    def _beam_store(self, st, cand_d, cand_v, visited, explored):
        st.cand_d, st.cand_v = cand_d, cand_v
        st.visited, st.explored = visited, explored

    # ---- sharded beam: local top-k per shard, global merge at the join -----

    def beam_score_local(self, qb, part: beam_mod.BeamShardPart):
        return self.beam_score_local_many(qb, [part])[0]

    def beam_score_local_many(
        self, qb, parts: list[beam_mod.BeamShardPart]
    ) -> list[tuple[np.ndarray, np.ndarray]]:
        """Score each shard part's LOCAL ids and return its local top-L
        (ids, dists) — the ``dist_search`` mask-local-topk idiom: ranking
        happens on local ids (mask BEFORE translation); ``vid_base`` is
        applied only for the table gather.  The engine merges the per-shard
        slices at the scatter join (``beam_finalize``); the union of local
        top-Ls contains the global top-L, so the result is bitwise the
        single-shard step."""
        scores = self._beam_scores(qb, parts)
        outs = []
        for p, ds in zip(parts, scores):
            ids = np.asarray(p.ids, np.int64)
            ds = np.asarray(ds, np.float32)
            order = np.lexsort((ids, ds))[: p.L]
            outs.append((ids[order], ds[order]))
        return outs

    def beam_finalize(
        self, qb, req: beam_mod.BeamRequest,
        ids: np.ndarray, ds: np.ndarray,
    ) -> beam_mod.BeamResult:
        """Fold the globally merged candidates of a multi-shard beam scatter
        into the request's state (no scoring — the shards already did it) and
        select the frontier, applying the request's pending inserts and
        explored marks exactly once."""
        self.stats.beam_steps += 1
        self.stats.beam_rows += int(np.asarray(ids).size)
        sub = dataclasses.replace(req, fresh=np.asarray(ids, np.int64))
        return self._beam_apply(sub, np.asarray(ds, np.float32))

    # ---- id-based hooks over registered tables -----------------------------
    # Defaults gather the rows from the registered host view and delegate to
    # the matrix hooks — bitwise identical to a caller-side gather.  The
    # pallas backend overrides them to gather on-device instead.

    def _estimate_ids(self, qb, tbl: ResidentView, pq, ids) -> np.ndarray:
        codes, norms, ip_bar = tbl.gather_level1(ids)
        return self._estimate(qb, pq, codes, norms, ip_bar)

    def _refine_ids(self, qb, tbl: ResidentView, pq, ids) -> np.ndarray:
        codes, lo, step = tbl.gather_level2(ids)
        return self._refine(qb, pq, codes, lo, step)

    def _estimate_ids_many(self, qb, tbl: ResidentView, pqs, sizes, ids) -> np.ndarray:
        codes, norms, ip_bar = tbl.gather_level1(ids)
        return self._estimate_many(qb, pqs, sizes, codes, norms, ip_bar)

    def _refine_ids_many(self, qb, tbl: ResidentView, pqs, sizes, ids) -> np.ndarray:
        codes, lo, step = tbl.gather_level2(ids)
        return self._refine_many(qb, pqs, sizes, codes, lo, step)

    # ---- slot-based hooks over HBM cache slot arrays -----------------------
    # Defaults gather the slot rows on the host and delegate to the matrix
    # hooks; the pallas backend overrides them to gather from the tier's
    # device mirror instead (zero upload — the slot-gather kernel path).

    def _refine_slots(self, view, pq, slots) -> np.ndarray:
        codes, lo, step = view.gather(slots)
        return self._refine(view.qb, pq, codes, lo, step)

    def _refine_slots_many(self, view, pqs, sizes, slots) -> np.ndarray:
        codes, lo, step = view.gather(slots)
        return self._refine_many(view.qb, pqs, sizes, codes, lo, step)

    # ---- subclass hooks ----------------------------------------------------
    def _estimate(self, qb, pq, codes, norms, ip_bar) -> np.ndarray:
        raise NotImplementedError

    def _refine(self, qb, pq, codes, lo, step) -> np.ndarray:
        raise NotImplementedError

    def _refine_full(self, q, vectors) -> np.ndarray:
        raise NotImplementedError

    # Fused-dispatch hooks.  The defaults evaluate per query group over the
    # stacked matrices (correct everywhere, fused only in accounting); the
    # batch/pallas backends override them with genuinely fused evaluations.
    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar) -> np.ndarray:
        out = np.empty(codes.shape[0], dtype=np.float32)
        off = 0
        for pq, m in zip(pqs, sizes):
            out[off : off + m] = self._estimate(
                qb, pq, codes[off : off + m], norms[off : off + m],
                ip_bar[off : off + m],
            )
            off += m
        return out

    def _refine_many(self, qb, pqs, sizes, codes, lo, step) -> np.ndarray:
        out = np.empty(codes.shape[0], dtype=np.float32)
        off = 0
        for pq, m in zip(pqs, sizes):
            out[off : off + m] = self._refine(
                qb, pq, codes[off : off + m], lo[off : off + m],
                step[off : off + m],
            )
            off += m
        return out

    def _refine_full_many(self, qs, sizes, vectors) -> np.ndarray:
        out = np.empty(vectors.shape[0], dtype=np.float32)
        off = 0
        for q, m in zip(qs, sizes):
            out[off : off + m] = self._refine_full(q, vectors[off : off + m])
            off += m
        return out


class ScalarEngine(DistanceEngine):
    """One row at a time — the oracle and the pre-batching cost baseline."""

    name = "scalar"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.estimate_batch(
                qb, pq, codes[i : i + 1], norms[i : i + 1], ip_bar[i : i + 1]
            )[0]
        return out

    def _refine(self, qb, pq, codes, lo, step):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.refine_batch(
                qb, pq, codes[i : i + 1], lo[i : i + 1], step[i : i + 1]
            )[0]
        return out

    def _refine_full(self, q, vectors):
        out = np.empty(vectors.shape[0], dtype=np.float32)
        for i in range(vectors.shape[0]):
            diff = vectors[i] - q
            out[i] = diff @ diff
        return out

    def _beam_apply(self, req, fresh_d):
        # Literal insort oracle, independently implemented from the
        # vectorized merge — the property-test reference, written the way
        # the host _Beam maintains its list.
        import bisect

        st = req.state
        _, _, visited, explored = self._beam_host_view(st)
        items = [
            (float(d), int(v))
            for d, v in zip(st.cand_d, st.cand_v)
            if v != beam_mod.PAD_VID
        ]
        pairs = list(zip(np.asarray(req.fresh, np.int64),
                         np.asarray(fresh_d, np.float32)))
        pairs += list(zip(np.asarray(req.insert_ids, np.int64),
                          np.asarray(req.insert_ds, np.float32)))
        for v, d in pairs:
            v = int(v)
            if visited[v]:
                continue
            visited[v] = True
            bisect.insort(items, (float(np.float32(d)), v))
        items = items[: st.L]
        cand_d = np.full(st.L, beam_mod.INF, dtype=np.float32)
        cand_v = np.full(st.L, beam_mod.PAD_VID, dtype=np.int64)
        for i, (d, v) in enumerate(items):
            cand_d[i], cand_v[i] = d, v
        for v in np.asarray(req.explored, np.int64):
            explored[int(v)] = True
        self._beam_store(st, cand_d, cand_v, visited, explored)
        frontier = np.asarray(
            [v for _, v in items if not explored[v]], dtype=np.int64
        )
        res = beam_mod.BeamResult(
            frontier=frontier, window_len=len(items), tail=float(cand_d[-1])
        )
        if req.topk:
            head = items[: min(int(req.topk), st.L)]
            res.topk_ids = np.asarray([v for _, v in head], dtype=np.int64)
            res.topk_ds = np.asarray([d for d, _ in head], dtype=np.float32)
        return res


class BatchEngine(DistanceEngine):
    """Vectorized NumPy over whole code matrices (default backend)."""

    name = "batch"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        return RabitQuantizer.estimate_batch(qb, pq, codes, norms, ip_bar).astype(
            np.float32, copy=False
        )

    def _refine(self, qb, pq, codes, lo, step):
        return RabitQuantizer.refine_batch(qb, pq, codes, lo, step).astype(
            np.float32, copy=False
        )

    def _refine_full(self, q, vectors):
        diff = vectors - q[None, :]
        return np.einsum("ij,ij->i", diff, diff).astype(np.float32, copy=False)

    # ---- genuinely fused multi-query paths ---------------------------------

    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar):
        # One GEMM over the stacked frontier rows of ALL queries: (M, d) signs
        # times (d, B) stacked unit queries; each row then selects its owner's
        # column — one dispatch serves B queries.
        d = qb.dim
        signs = 2.0 * unpack_bits(codes, d).astype(np.float32) - 1.0  # (M, d)
        Q = np.stack([pq.qunit for pq in pqs])                        # (B, d)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        g = signs @ Q.T                                               # (M, B)
        g = g[np.arange(g.shape[0]), owner] / np.sqrt(d)
        est_cos = np.clip(g / np.maximum(ip_bar, 1e-6), -1.0, 1.0)
        qn = np.asarray([pq.qnorm for pq in pqs], dtype=np.float64)[owner]
        out = qn**2 + norms**2 - 2.0 * qn * norms * est_cos
        return out.astype(np.float32, copy=False)

    def _refine_many(self, qb, pqs, sizes, codes, lo, step):
        rec = qb.decode_ext(codes) * step[:, None] + lo[:, None]      # (M, d)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        qr_rows = np.stack([pq.qr for pq in pqs])[owner]              # (M, d)
        diff = qr_rows - rec
        return (diff * diff).sum(axis=1).astype(np.float32, copy=False)

    def _refine_full_many(self, qs, sizes, vectors):
        owner = np.repeat(np.arange(len(qs)), sizes)
        diff = vectors - np.stack(qs)[owner]
        return np.einsum("ij,ij->i", diff, diff).astype(np.float32, copy=False)


# Jitted device-gather wrappers for the resident pallas path, built once per
# process (NOT per engine instance — a per-instance closure would defeat the
# jit cache and recompile for every system the benchmarks build).
_PALLAS_RESIDENT_FNS = None


def _pallas_resident_fns():
    global _PALLAS_RESIDENT_FNS
    if _PALLAS_RESIDENT_FNS is None:
        import functools

        import jax

        from repro.kernels.binary_ip import estimate_dist2 as _binary_est
        from repro.kernels.int4_dist import int4_dist2 as _int4_dist2

        @functools.partial(jax.jit, static_argnames=("interpret",))
        def gather_estimate(q, codes, norms, ip_bar, ids, interpret):
            # the gather happens where the table lives: on the device
            return _binary_est(
                q, codes[ids], norms[ids], ip_bar[ids], interpret=interpret
            )

        @functools.partial(jax.jit, static_argnames=("interpret",))
        def gather_refine(q, codes, lo, step, ids, interpret):
            return _int4_dist2(
                q, codes[ids], lo[ids], step[ids], interpret=interpret
            )

        _PALLAS_RESIDENT_FNS = (gather_estimate, gather_refine)
    return _PALLAS_RESIDENT_FNS


# The fused beam step: score -> visited mask -> top-k merge -> frontier
# selection as ONE jitted call over device-resident state.  Module-level
# cache for the same reason as ``_pallas_resident_fns``: one jit cache per
# process, retraced only per static shape bucket (B, Fp, Ip, Ep, L, n).
_PALLAS_BEAM_FN = None


def _pallas_beam_fn():
    global _PALLAS_BEAM_FN
    if _PALLAS_BEAM_FN is None:
        import functools

        import jax
        import jax.numpy as jnp

        from repro.kernels.binary_ip import estimate_dist2 as _binary_est

        @functools.partial(jax.jit, static_argnames=("bucket", "interpret"))
        def beam_step(Q, codes, norms, ip_bar, ids, vid_base, fresh_len,
                      ins_v, ins_d, ins_len, expl, cand_d, cand_v, visited,
                      explored, bucket, interpret):
            B, Fp = ids.shape
            L = cand_d.shape[1]
            sink = visited.shape[1] - 1  # pad-lane write target (slot n)
            PAD = jnp.int32(2**31 - 1)
            INF = jnp.float32(jnp.inf)
            rows_b = jnp.arange(B)[:, None]

            # -- score: gather codes by id where the table lives, one kernel
            # launch for every query's fresh rows (pad lanes gather row 0 and
            # are masked below, exactly like _pad_ids)
            flat = (ids + vid_base[:, None]).reshape(-1)
            pad_rows = -flat.shape[0] % bucket
            if pad_rows:
                flat = jnp.concatenate(
                    [flat, jnp.zeros(pad_rows, dtype=flat.dtype)]
                )
            est = _binary_est(
                Q, codes[flat], norms[flat], ip_bar[flat], interpret=interpret
            )  # (B, Mp)
            owner = jnp.repeat(jnp.arange(B), Fp)
            d_fresh = est[owner, jnp.arange(B * Fp)].reshape(B, Fp)

            # -- visited-bitmask filter + first-wins dedupe over the step's
            # candidates (fresh rows first, then host-provided inserts)
            lane_f = jnp.arange(Fp)[None, :]
            ok_f = lane_f < fresh_len[:, None]
            lane_i = jnp.arange(ins_v.shape[1])[None, :]
            ok_i = lane_i < ins_len[:, None]
            cv = jnp.concatenate([ids, ins_v], axis=1)
            cd = jnp.concatenate([d_fresh, ins_d], axis=1)
            ok = jnp.concatenate([ok_f, ok_i], axis=1)
            ok = ok & ~jnp.take_along_axis(
                visited, jnp.minimum(cv, sink), axis=1
            )
            masked_v = jnp.where(ok, cv, PAD)
            perm = jnp.argsort(masked_v, axis=1)  # stable: lane order on ties
            sv = jnp.take_along_axis(masked_v, perm, axis=1)
            dup_sorted = jnp.concatenate(
                [jnp.zeros((B, 1), bool), sv[:, 1:] == sv[:, :-1]], axis=1
            )
            dup = jnp.zeros_like(dup_sorted).at[rows_b, perm].set(dup_sorted)
            ok = ok & ~dup

            # -- visited update (invalid lanes write the pad sink)
            visited = visited.at[rows_b, jnp.where(ok, cv, sink)].set(True)

            # -- top-k merge against the resident candidate heap: sort by the
            # (distance, vertex id) tuple — np.lexsort((v, d)) lane for lane
            md = jnp.concatenate([cand_d, jnp.where(ok, cd, INF)], axis=1)
            mv = jnp.concatenate([cand_v, jnp.where(ok, cv, PAD)], axis=1)
            sd, svv = jax.lax.sort((md, mv), num_keys=2, is_stable=True)
            cand_d, cand_v = sd[:, :L], svv[:, :L]

            # -- explored marks, then frontier = unexplored heap entries in
            # heap (ascending) order, stable-compacted to the front
            explored = explored.at[rows_b, expl].set(True)
            real = cand_v != PAD
            live = real & ~jnp.take_along_axis(
                explored, jnp.minimum(cand_v, sink), axis=1
            )
            rank = jnp.where(live, jnp.int32(0), jnp.int32(1))
            lanes = jnp.tile(jnp.arange(L, dtype=jnp.int32)[None, :], (B, 1))
            r_s, _, fv = jax.lax.sort((rank, lanes, cand_v), num_keys=2)
            frontier = jnp.where(r_s == 0, fv, jnp.int32(-1))
            window_len = real.sum(axis=1).astype(jnp.int32)
            tail = cand_d[:, L - 1]
            return cand_d, cand_v, visited, explored, frontier, window_len, tail

        _PALLAS_BEAM_FN = beam_step
    return _PALLAS_BEAM_FN


class _DeviceTable:
    """Register-once device residency for one index: the level-1/level-2
    tables as device arrays (uploaded once via ``jax.device_put``), plus the
    host view for the fallback paths (ext_bits=8, non-resident mode)."""

    __slots__ = ("host", "binary_codes", "norms", "ip_bar",
                 "ext_codes", "ext_lo", "ext_step")

    def __init__(self, qb: QuantizedBase):
        import jax

        self.host = ResidentView.from_qb(qb)
        put = jax.device_put
        self.binary_codes = put(self.host.binary_codes)
        self.norms = put(self.host.norms)
        self.ip_bar = put(self.host.ip_bar)
        self.ext_codes = put(self.host.ext_codes)
        self.ext_lo = put(self.host.ext_lo)
        self.ext_step = put(self.host.ext_step)

    def gather_level1(self, ids):
        return self.host.gather_level1(ids)

    def gather_level2(self, ids):
        return self.host.gather_level2(ids)


class PallasEngine(BatchEngine):
    """JAX/Pallas kernels for both quantized levels.

    ``register_index`` pins the code tables as device arrays once per index;
    id-based requests ship only the (padded) id vector and gather on-device
    inside the jitted kernel wrappers — no per-hop row re-upload.  Row counts
    are padded up to multiples of ``bucket`` so the jitted wrappers see a
    small set of static shapes (bounded recompiles) — the frontier size
    varies every hop.  The exact-fp32 path and the 8-bit extended codes (no
    int4 kernel applies) stay on the NumPy batch path.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, bucket: int = 64,
                 resident: bool = True):
        super().__init__(resident=resident)
        import jax  # raises if jax missing
        import jax.numpy as jnp  # noqa: F401

        from repro.kernels.binary_ip import estimate_dist2 as _binary_est
        from repro.kernels.int4_dist import int4_dist2 as _int4_dist2

        if interpret is None:
            # interpret mode on CPU (Pallas has no CPU lowering), compiled
            # kernels on real accelerators
            interpret = jax.default_backend() == "cpu"
        self._jnp = jnp
        self._binary_est = _binary_est
        self._int4_dist2 = _int4_dist2
        self.interpret = interpret
        self.bucket = bucket

    def _build_table(self, qb: QuantizedBase):
        if not self.resident:
            return ResidentView.from_qb(qb)  # host views only, rows re-upload
        return _DeviceTable(qb)

    # ---- shape bucketing ---------------------------------------------------

    def _pad_rows(self, m: int) -> int:
        b = self.bucket
        return max(b, ((m + b - 1) // b) * b)

    def _pad_to_bucket(self, arrays, pad_values):
        """Pad every row-aligned array up to the bucket multiple of its row
        count (at least one bucket, so m=0 still yields a valid kernel
        shape).  Returns ``(m, padded)`` with m the original row count; when
        m already sits on a bucket multiple the arrays pass through
        unchanged.  ``pad_values`` supplies the fill per array (e.g. step
        pads with 1 to keep dequant finite on padding rows)."""
        m = arrays[0].shape[0]
        mp = self._pad_rows(m)
        if mp == m:
            return m, list(arrays)
        padded = []
        for a, v in zip(arrays, pad_values):
            fill = np.full((mp - m,) + a.shape[1:], v, dtype=a.dtype)
            padded.append(np.concatenate([a, fill]))
        return m, padded

    def _pad_ids(self, ids: np.ndarray) -> tuple[int, np.ndarray]:
        """Bucket-pad an id vector (fill id 0: a safe gather, sliced away)."""
        m, (idsp,) = self._pad_to_bucket([np.asarray(ids, dtype=np.int32)], [0])
        return m, idsp

    # ---- resident id-based paths: gather on-device -------------------------

    def _estimate_ids(self, qb, tbl, pq, ids):
        if not self.resident:
            return super()._estimate_ids(qb, tbl, pq, ids)
        gather_est, _ = _pallas_resident_fns()
        m, idsp = self._pad_ids(ids)
        out = gather_est(
            pq.qr[None, :], tbl.binary_codes, tbl.norms, tbl.ip_bar, idsp,
            interpret=self.interpret,
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _refine_ids(self, qb, tbl, pq, ids):
        if not self.resident or qb.ext_bits != 4:
            # no int4 kernel for 8-bit codes: host gather + NumPy batch path
            return super()._refine_ids(qb, tbl, pq, ids)
        _, gather_ref = _pallas_resident_fns()
        m, idsp = self._pad_ids(ids)
        out = gather_ref(
            pq.qr[None, :], tbl.ext_codes, tbl.ext_lo, tbl.ext_step, idsp,
            interpret=self.interpret,
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _estimate_ids_many(self, qb, tbl, pqs, sizes, ids):
        if not self.resident:
            return super()._estimate_ids_many(qb, tbl, pqs, sizes, ids)
        gather_est, _ = _pallas_resident_fns()
        m, idsp = self._pad_ids(ids)
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(gather_est(
            Q, tbl.binary_codes, tbl.norms, tbl.ip_bar, idsp,
            interpret=self.interpret,
        ))  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)

    def _refine_ids_many(self, qb, tbl, pqs, sizes, ids):
        if not self.resident or qb.ext_bits != 4:
            return super()._refine_ids_many(qb, tbl, pqs, sizes, ids)
        _, gather_ref = _pallas_resident_fns()
        m, idsp = self._pad_ids(ids)
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(gather_ref(
            Q, tbl.ext_codes, tbl.ext_lo, tbl.ext_step, idsp,
            interpret=self.interpret,
        ))  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)

    # ---- slot-based paths: gather from the tier's device mirror ------------
    # The slot-index vector is the only thing shipped per call; the slot
    # arrays were uploaded once (and are maintained by the tier's scatter),
    # so — like the resident id path — these do NOT count uploads.

    def _refine_slots(self, view, pq, slots):
        if not self.resident or view.qb.ext_bits != 4:
            return super()._refine_slots(view, pq, slots)
        _, gather_ref = _pallas_resident_fns()
        ext, lo, step = view.device_arrays()
        m, slotsp = self._pad_ids(slots)
        out = gather_ref(
            pq.qr[None, :], ext, lo, step, slotsp, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _refine_slots_many(self, view, pqs, sizes, slots):
        if not self.resident or view.qb.ext_bits != 4:
            return super()._refine_slots_many(view, pqs, sizes, slots)
        _, gather_ref = _pallas_resident_fns()
        ext, lo, step = view.device_arrays()
        m, slotsp = self._pad_ids(slots)
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(gather_ref(
            Q, ext, lo, step, slotsp, interpret=self.interpret,
        ))  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)

    # ---- fused beam step: the single-jitted-call device path ---------------
    # The candidate heap and visited/explored masks live as device arrays
    # across hops; one jit executes score -> mask -> merge -> select, and the
    # only download per step is the frontier (plus two scalars).  The fp32
    # "full" kind and the non-resident mode take the generic NumPy path via
    # the host-view round-trip, consistent with the engine's existing policy
    # for paths without a kernel.

    def beam_new(self, L, n):
        st = beam_mod.BeamState.new(L, n)
        if self.resident:
            jnp = self._jnp
            st.cand_d = jnp.asarray(st.cand_d)
            st.cand_v = jnp.asarray(st.cand_v.astype(np.int32))
            st.visited = jnp.asarray(st.visited)
            st.explored = jnp.asarray(st.explored)
            st.backend = "device"
        return st

    def _beam_host_view(self, st):
        if st.backend != "device":
            return super()._beam_host_view(st)
        return (
            np.asarray(st.cand_d),
            np.asarray(st.cand_v, dtype=np.int64),
            # masks are mutated in place by the generic path; device->host
            # views are read-only, so materialize writable copies
            np.array(st.visited),
            np.array(st.explored),
        )

    def _beam_store(self, st, cand_d, cand_v, visited, explored):
        if st.backend != "device":
            return super()._beam_store(st, cand_d, cand_v, visited, explored)
        jnp = self._jnp
        st.cand_d = jnp.asarray(np.asarray(cand_d, dtype=np.float32))
        st.cand_v = jnp.asarray(np.asarray(cand_v).astype(np.int32))
        st.visited = jnp.asarray(np.asarray(visited))
        st.explored = jnp.asarray(np.asarray(explored))

    def _beam_step_many(self, qb, reqs):
        gqb = reqs[0].qb if reqs[0].qb is not None else qb
        fusable = (
            self.resident
            and all(r.kind == "estimate" for r in reqs)
            and all((r.qb if r.qb is not None else qb) is gqb for r in reqs)
            and all(int(r.topk) == 0 for r in reqs)
            and all(r.state.backend == "device" for r in reqs)
            and len({(r.state.L, r.state.n) for r in reqs}) == 1
        )
        if not fusable:
            return super()._beam_step_many(qb, reqs)
        jnp = self._jnp
        tbl = self.register_index(gqb)
        B = len(reqs)
        n = reqs[0].state.n

        def pad8(m: int) -> int:
            return max(8, ((m + 7) // 8) * 8)

        fresh = [np.asarray(r.fresh, dtype=np.int64) for r in reqs]
        insv_l = [np.asarray(r.insert_ids, dtype=np.int64) for r in reqs]
        expl_l = [np.asarray(r.explored, dtype=np.int64) for r in reqs]
        Fp = pad8(max(f.size for f in fresh))
        Ip = pad8(max(v.size for v in insv_l))
        Ep = pad8(max(e.size for e in expl_l))
        ids = np.zeros((B, Fp), dtype=np.int32)
        flen = np.zeros(B, dtype=np.int32)
        insv = np.zeros((B, Ip), dtype=np.int32)
        insd = np.full((B, Ip), np.inf, dtype=np.float32)
        ilen = np.zeros(B, dtype=np.int32)
        expl = np.full((B, Ep), n, dtype=np.int32)  # pad lanes hit the sink
        vbase = np.zeros(B, dtype=np.int32)
        for i, r in enumerate(reqs):
            ids[i, : fresh[i].size] = fresh[i]
            flen[i] = fresh[i].size
            insv[i, : insv_l[i].size] = insv_l[i]
            insd[i, : insv_l[i].size] = np.asarray(r.insert_ds, np.float32)
            ilen[i] = insv_l[i].size
            expl[i, : expl_l[i].size] = expl_l[i]
            vbase[i] = int(r.vid_base)
        Q = np.stack([r.pq.qr for r in reqs]).astype(np.float32, copy=False)
        cand_d = jnp.stack([r.state.cand_d for r in reqs])
        cand_v = jnp.stack([r.state.cand_v for r in reqs])
        visited = jnp.stack([r.state.visited for r in reqs])
        explored = jnp.stack([r.state.explored for r in reqs])
        rows = int(flen.sum())
        if rows:  # merge-only steps (insert/mark flushes) score nothing
            self.stats.level1_calls += 1
            self.stats.level1_rows += rows
            self.stats.resident_gathers += rows
            if B > 1:
                self.stats.fused_calls += 1
                self.stats.fused_queries += B
        fn = _pallas_beam_fn()
        (cand_d, cand_v, visited, explored, frontier, wlen, tail) = fn(
            Q, tbl.binary_codes, tbl.norms, tbl.ip_bar, ids, vbase, flen,
            insv, insd, ilen, expl, cand_d, cand_v, visited, explored,
            bucket=self.bucket, interpret=self.interpret,
        )
        # the ONE host<->device exchange per step: frontiers + two scalars
        frontier_np = np.asarray(frontier)
        wlen_np = np.asarray(wlen)
        tail_np = np.asarray(tail)
        out = []
        for i, r in enumerate(reqs):
            r.state.cand_d = cand_d[i]
            r.state.cand_v = cand_v[i]
            r.state.visited = visited[i]
            r.state.explored = explored[i]
            fr = frontier_np[i]
            out.append(beam_mod.BeamResult(
                frontier=fr[fr >= 0].astype(np.int64),
                window_len=int(wlen_np[i]),
                tail=float(tail_np[i]),
            ))
        return out

    # ---- matrix paths: caller-gathered rows, re-uploaded per call ----------

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        m, (codes, norms, ip_bar) = self._pad_to_bucket(
            [codes, norms, ip_bar], [0, 0, 1]
        )
        self.stats.uploads += 1  # gathered rows ship to the device this call
        out = self._binary_est(
            pq.qr[None, :], codes, norms, ip_bar, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _refine(self, qb, pq, codes, lo, step):
        if qb.ext_bits != 4:  # the kernel is nibble-packed int4 only
            return super()._refine(qb, pq, codes, lo, step)
        m, (codes, lo, step) = self._pad_to_bucket([codes, lo, step], [0, 0, 1])
        self.stats.uploads += 1
        out = self._int4_dist2(
            pq.qr[None, :], codes, lo, step, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    # ---- fused multi-query paths: the kernels are (B, N)-shaped already ----

    def _estimate_many(self, qb, pqs, sizes, codes, norms, ip_bar):
        m, (codes, norms, ip_bar) = self._pad_to_bucket(
            [codes, norms, ip_bar], [0, 0, 1]
        )
        self.stats.uploads += 1
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(
            self._binary_est(Q, codes, norms, ip_bar, interpret=self.interpret)
        )  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)

    def _refine_many(self, qb, pqs, sizes, codes, lo, step):
        if qb.ext_bits != 4:  # no int4 kernel: NumPy fused path
            return super()._refine_many(qb, pqs, sizes, codes, lo, step)
        m, (codes, lo, step) = self._pad_to_bucket([codes, lo, step], [0, 0, 1])
        self.stats.uploads += 1
        Q = np.stack([pq.qr for pq in pqs])  # (B, d)
        out = np.asarray(
            self._int4_dist2(Q, codes, lo, step, interpret=self.interpret)
        )  # (B, mp)
        owner = np.repeat(np.arange(len(pqs)), sizes)
        return out[owner, np.arange(m)].astype(np.float32, copy=False)


def get_engine(name: str | None = None, resident: bool = True) -> DistanceEngine:
    """Build a fresh engine for ``name`` (see module docstring for the rules).
    ``resident=False`` keeps the PR-2 host-gather semantics on the pallas
    path (per-call row uploads) — the parity/ablation baseline."""
    if name is None or name == "default":
        name = _DEFAULT_BACKEND
    if name == "auto":
        name = "pallas" if pallas_available() else "batch"
    if name == "scalar":
        return ScalarEngine(resident=resident)
    if name == "batch":
        return BatchEngine(resident=resident)
    if name == "pallas":
        try:
            return PallasEngine(resident=resident)
        except ImportError as e:  # no jax: degrade, keep serving
            warnings.warn(
                f"pallas distance backend unavailable ({e}); using batch",
                RuntimeWarning,
                stacklevel=2,
            )
            return BatchEngine(resident=resident)
    raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")


def request_group_key(req: ScoreRequest, default_qb: QuantizedBase | None):
    """The dispatch-group key of one score request: requests sharing a key are
    served by ONE fused engine call.  Quantized kinds group by (kind, table) —
    the serving plane's cross-index routing: ids from different registered
    tables cannot be gathered by one kernel launch, so each table gets its own
    dispatch (tenants sharing a combined table still fuse into one).  ``full``
    requests group by vector dimensionality so a cross-tenant flush never
    concatenates mismatched matrices.  Single-system runs have one table and
    one dim, so the grouping degenerates to the per-kind PR-2 rule, bitwise.
    """
    if isinstance(req, beam_mod.BeamRequest):
        qb = req.qb if req.qb is not None else default_qb
        return ("beam", (req.kind, id(qb)))
    if isinstance(req, beam_mod.BeamShardPart):
        qb = req.qb if req.qb is not None else default_qb
        return ("beam_part", (req.kind, id(qb)))
    kind = req.kind
    if kind == "refine" and isinstance(req.payload, tuple):
        kind = "refine_rows"  # materialized host-gather wire format
    if kind == "full":
        return (kind, int(np.asarray(req.payload).shape[1]))
    qb = req.qb if req.qb is not None else default_qb
    return (kind, id(qb))


def execute_requests(
    engine: DistanceEngine, qb: QuantizedBase | None, reqs: list[ScoreRequest],
    hbm=None, splits: dict[int, tuple] | None = None,
) -> list[np.ndarray]:
    """Execute a rendezvous batch of score requests: ONE fused engine call per
    dispatch group present (``request_group_key``), results returned in
    request order.

    This is the engine scheduler's flush primitive: requests from different
    coroutines (different queries — with the shared rendezvous, on different
    workers; on the serving plane, from different tenants) sharing a group are
    stacked and dispatched together — the Pallas wrappers are (B, N)-shaped,
    so one kernel launch serves every query in the batch.  ``refine`` requests
    carry vertex-id arrays (resident path, resolved against the request's —
    or the engine-default — registered table) or materialized (codes, lo,
    step) tuples (host-gather parity path); the two are never mixed within
    one system but may be mixed within one flush.

    ``hbm``/``splits`` thread the HBM record-cache tier through a flush:
    ``splits`` maps ``id(req)`` of an id-payload refine request to the
    (hit_mask, slot_indices) partition the engine resolved against the tier
    (``HbmTier.peek_split``).  Hit rows gather from cache slots
    (``refine_slots_many``, zero upload), miss rows take the ordinary
    registered-table path, and each request's results are merged back in id
    order.  With ``hbm=None`` (the default) the body below is untouched.
    """
    out: list = [None] * len(reqs)
    groups: dict[tuple, list[int]] = {}
    for i, r in enumerate(reqs):
        groups.setdefault(request_group_key(r, qb), []).append(i)
    for (kind, _), idxs in groups.items():
        gqb = reqs[idxs[0]].qb if reqs[idxs[0]].qb is not None else qb
        needs_qb = kind in ("estimate", "refine", "refine_rows") or (
            kind in ("beam", "beam_part") and reqs[idxs[0]].kind == "estimate"
        )
        if gqb is None and needs_qb:
            raise ValueError(
                "score requests of kind 'estimate'/'refine' need a "
                "QuantizedBase: set ScoreRequest.qb or pass qb= to the "
                "Engine / run_workload executing these coroutines"
            )
        if kind == "beam":
            res = engine.beam_step_many(gqb, [reqs[i] for i in idxs])
        elif kind == "beam_part":
            res = engine.beam_score_local_many(gqb, [reqs[i] for i in idxs])
        elif kind == "estimate":
            res = engine.estimate_many(
                gqb, [(reqs[i].pq, reqs[i].payload) for i in idxs]
            )
        elif kind == "refine":
            if splits and any(id(reqs[i]) in splits for i in idxs):
                res = _execute_refine_split(engine, gqb, hbm, reqs, idxs, splits)
            else:
                res = engine.refine_ids_many(
                    gqb, [(reqs[i].pq, reqs[i].payload) for i in idxs]
                )
        elif kind == "refine_rows":
            res = engine.refine_many(
                gqb, [(reqs[i].pq, *reqs[i].payload) for i in idxs]
            )
        elif kind == "full":
            res = engine.refine_full_many(
                [(reqs[i].query, reqs[i].payload) for i in idxs]
            )
        else:
            raise ValueError(f"unknown score request kind {kind!r}")
        for i, r_ in zip(idxs, res):
            out[i] = r_
    return out


def _execute_refine_split(
    engine: DistanceEngine, gqb, hbm, reqs, idxs, splits
) -> list[np.ndarray]:
    """One refine dispatch group with HBM-tier residency splits: the miss
    rows of every request fuse into one registered-table gather, the hit
    rows into one slot gather, and each request's two result slices merge
    back in its original id order."""
    miss_groups: list[tuple] = []
    hit_groups: list[tuple] = []
    parts: list[tuple] = []  # (ids, mask | None) per request
    for i in idxs:
        r = reqs[i]
        ids = np.asarray(r.payload, dtype=np.int64)
        sp = splits.get(id(r))
        if sp is None:
            miss_groups.append((r.pq, ids))
            hit_groups.append((r.pq, np.empty(0, dtype=np.int64)))
            parts.append((ids, None))
        else:
            mask, slots = sp
            miss_groups.append((r.pq, ids[~mask]))
            hit_groups.append((r.pq, slots))
            parts.append((ids, mask))
    miss_res = engine.refine_ids_many(gqb, miss_groups)
    hit_res = engine.refine_slots_many(hbm, hit_groups)
    res: list[np.ndarray] = []
    for (ids, mask), mr, hr in zip(parts, miss_res, hit_res):
        if mask is None:
            res.append(mr)
            continue
        merged = np.empty(len(ids), dtype=np.float32)
        merged[~mask] = mr
        merged[mask] = hr
        res.append(merged)
    return res
