"""The batched distance plane: pluggable DistanceEngine backends.

Every level-1 (binary estimate) and level-2 (extended-code / fp32 refinement)
distance evaluated by the search plane goes through one of these engines:

  * ``scalar`` — per-row NumPy loop.  Deliberately naive: it is the oracle the
    other backends are tested against, and the "before" point of the paper's
    batching argument (one distance per call, no SIMD amortization).
  * ``batch``  — vectorized NumPy over whole code matrices (the default).
    One BLAS/ufunc dispatch per frontier batch instead of per vertex.
  * ``pallas`` — the JAX/Pallas kernels (kernels/binary_ip, kernels/int4_dist)
    in interpret mode on CPU, compiled on real accelerators.  Falls back to
    ``batch`` automatically when JAX is not importable.

Selection:

  get_engine("scalar" | "batch" | "pallas" | "auto" | "default" | None)

``auto`` resolves to ``pallas`` when JAX is available, else ``batch``.
``default`` (and None) resolve to the process-wide default set with
``set_default_backend`` — the hook benchmarks/run.py's ``--backend`` flag
threads through without touching every call site.

All engines consume the same packed artifact formats produced by
``RabitQuantizer.fit_encode`` (bit-packed level-1 codes, nibble-packed level-2
codes), so the host plane, the simulator, and the device kernels share one
index image.  Each engine keeps per-instance counters (``DistanceStats``) so
callers can report how much work the plane absorbed per batch.
"""

from __future__ import annotations

import dataclasses
import warnings

import numpy as np

from repro.core.quant import PreparedQuery, QuantizedBase, RabitQuantizer

BACKENDS = ("scalar", "batch", "pallas")

_DEFAULT_BACKEND = "batch"


def set_default_backend(name: str) -> None:
    """Set the process-wide default backend (see ``get_engine``)."""
    global _DEFAULT_BACKEND
    if name not in BACKENDS and name != "auto":
        raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")
    _DEFAULT_BACKEND = name


def default_backend() -> str:
    return _DEFAULT_BACKEND


def resolved_backend(name: str | None = None) -> str:
    """The engine name ``get_engine(name)`` would actually serve — resolves
    ``default``/``auto`` and the pallas-without-jax degradation."""
    return get_engine(name).name


def pallas_available() -> bool:
    try:
        import jax  # noqa: F401

        return True
    except Exception:  # pragma: no cover - exercised only without jax
        return False


@dataclasses.dataclass
class DistanceStats:
    """Work counters: calls vs rows show the batching amortization factor."""

    level1_calls: int = 0
    level1_rows: int = 0
    level2_calls: int = 0
    level2_rows: int = 0
    full_calls: int = 0
    full_rows: int = 0

    def rows_per_call(self) -> float:
        calls = self.level1_calls + self.level2_calls + self.full_calls
        rows = self.level1_rows + self.level2_rows + self.full_rows
        return rows / calls if calls else 0.0


class DistanceEngine:
    """Base class: counters + empty-batch handling; subclasses implement the
    three kernels over packed matrices."""

    name = "abstract"

    def __init__(self):
        self.stats = DistanceStats()

    # ---- level 1: binary estimate ------------------------------------------
    def estimate(
        self, qb: QuantizedBase, pq: PreparedQuery, ids: np.ndarray
    ) -> np.ndarray:
        """Level-1 estimated squared distances for vertex ids (resident codes)."""
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level1_calls += 1
        self.stats.level1_rows += ids.size
        return self._estimate(
            qb, pq, qb.binary_codes[ids], qb.norms[ids], qb.ip_bar[ids]
        )

    # ---- level 2: extended-code refinement ---------------------------------
    def refine(
        self,
        qb: QuantizedBase,
        pq: PreparedQuery,
        codes: np.ndarray,
        lo: np.ndarray,
        step: np.ndarray,
    ) -> np.ndarray:
        """Level-2 refined squared distances from packed extended codes."""
        if codes.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.level2_calls += 1
        self.stats.level2_rows += codes.shape[0]
        return self._refine(qb, pq, codes, lo, step)

    # ---- exact fp32 (DiskANN-style records, in-memory oracle) --------------
    def refine_full(self, q: np.ndarray, vectors: np.ndarray) -> np.ndarray:
        """Exact squared distances from full fp32 vectors to query ``q``."""
        vectors = np.asarray(vectors, dtype=np.float32)
        if vectors.shape[0] == 0:
            return np.empty(0, dtype=np.float32)
        self.stats.full_calls += 1
        self.stats.full_rows += vectors.shape[0]
        return self._refine_full(np.asarray(q, dtype=np.float32), vectors)

    # ---- subclass hooks ----------------------------------------------------
    def _estimate(self, qb, pq, codes, norms, ip_bar) -> np.ndarray:
        raise NotImplementedError

    def _refine(self, qb, pq, codes, lo, step) -> np.ndarray:
        raise NotImplementedError

    def _refine_full(self, q, vectors) -> np.ndarray:
        raise NotImplementedError


class ScalarEngine(DistanceEngine):
    """One row at a time — the oracle and the pre-batching cost baseline."""

    name = "scalar"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.estimate_batch(
                qb, pq, codes[i : i + 1], norms[i : i + 1], ip_bar[i : i + 1]
            )[0]
        return out

    def _refine(self, qb, pq, codes, lo, step):
        out = np.empty(codes.shape[0], dtype=np.float32)
        for i in range(codes.shape[0]):
            out[i] = RabitQuantizer.refine_batch(
                qb, pq, codes[i : i + 1], lo[i : i + 1], step[i : i + 1]
            )[0]
        return out

    def _refine_full(self, q, vectors):
        out = np.empty(vectors.shape[0], dtype=np.float32)
        for i in range(vectors.shape[0]):
            diff = vectors[i] - q
            out[i] = diff @ diff
        return out


class BatchEngine(DistanceEngine):
    """Vectorized NumPy over whole code matrices (default backend)."""

    name = "batch"

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        return RabitQuantizer.estimate_batch(qb, pq, codes, norms, ip_bar).astype(
            np.float32, copy=False
        )

    def _refine(self, qb, pq, codes, lo, step):
        return RabitQuantizer.refine_batch(qb, pq, codes, lo, step).astype(
            np.float32, copy=False
        )

    def _refine_full(self, q, vectors):
        diff = vectors - q[None, :]
        return np.einsum("ij,ij->i", diff, diff).astype(np.float32, copy=False)


class PallasEngine(BatchEngine):
    """JAX/Pallas kernels for both quantized levels.

    Row counts are padded up to multiples of ``bucket`` so the jitted kernel
    wrappers see a small set of static shapes (bounded recompiles) — the
    frontier size varies every hop.  The exact-fp32 path and the 8-bit
    extended codes (no int4 kernel applies) stay on the NumPy batch path.
    """

    name = "pallas"

    def __init__(self, interpret: bool | None = None, bucket: int = 64):
        super().__init__()
        import jax  # raises if jax missing
        import jax.numpy as jnp  # noqa: F401

        from repro.kernels.binary_ip import estimate_dist2 as _binary_est
        from repro.kernels.int4_dist import int4_dist2 as _int4_dist2

        if interpret is None:
            # interpret mode on CPU (Pallas has no CPU lowering), compiled
            # kernels on real accelerators
            interpret = jax.default_backend() == "cpu"
        self._jnp = jnp
        self._binary_est = _binary_est
        self._int4_dist2 = _int4_dist2
        self.interpret = interpret
        self.bucket = bucket

    def _pad_rows(self, m: int) -> int:
        b = self.bucket
        return max(b, ((m + b - 1) // b) * b)

    def _estimate(self, qb, pq, codes, norms, ip_bar):
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            norms = np.concatenate([norms, np.zeros(mp - m, dtype=norms.dtype)])
            ip_bar = np.concatenate([ip_bar, np.ones(mp - m, dtype=ip_bar.dtype)])
        out = self._binary_est(
            pq.qr[None, :], codes, norms, ip_bar, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)

    def _refine(self, qb, pq, codes, lo, step):
        if qb.ext_bits != 4:  # the kernel is nibble-packed int4 only
            return super()._refine(qb, pq, codes, lo, step)
        m = codes.shape[0]
        mp = self._pad_rows(m)
        if mp != m:
            codes = np.concatenate(
                [codes, np.zeros((mp - m, codes.shape[1]), dtype=codes.dtype)]
            )
            lo = np.concatenate([lo, np.zeros(mp - m, dtype=lo.dtype)])
            step = np.concatenate([step, np.ones(mp - m, dtype=step.dtype)])
        out = self._int4_dist2(
            pq.qr[None, :], codes, lo, step, interpret=self.interpret
        )
        return np.asarray(out[0, :m], dtype=np.float32)


def get_engine(name: str | None = None) -> DistanceEngine:
    """Build a fresh engine for ``name`` (see module docstring for the rules)."""
    if name is None or name == "default":
        name = _DEFAULT_BACKEND
    if name == "auto":
        name = "pallas" if pallas_available() else "batch"
    if name == "scalar":
        return ScalarEngine()
    if name == "batch":
        return BatchEngine()
    if name == "pallas":
        try:
            return PallasEngine()
        except ImportError as e:  # no jax: degrade, keep serving
            warnings.warn(
                f"pallas distance backend unavailable ({e}); using batch",
                RuntimeWarning,
                stacklevel=2,
            )
            return BatchEngine()
    raise ValueError(f"unknown distance backend {name!r}; expected {BACKENDS}")
