"""Brute-force exact nearest neighbor search — the correctness oracle."""

from __future__ import annotations

import numpy as np


def pairwise_l2sq(base: np.ndarray, queries: np.ndarray) -> np.ndarray:
    """(q, n) matrix of squared L2 distances, computed blockwise."""
    # ||q - x||^2 = ||q||^2 - 2 q.x + ||x||^2
    bn = (base.astype(np.float32) ** 2).sum(axis=1)
    qn = (queries.astype(np.float32) ** 2).sum(axis=1)
    dots = queries.astype(np.float32) @ base.astype(np.float32).T
    return qn[:, None] - 2.0 * dots + bn[None, :]


def exact_topk(base: np.ndarray, queries: np.ndarray, k: int, block: int = 256) -> np.ndarray:
    """Exact top-k ids for each query (ties broken by id for determinism)."""
    n = base.shape[0]
    out = np.empty((queries.shape[0], k), dtype=np.int32)
    for s in range(0, queries.shape[0], block):
        q = queries[s : s + block]
        d2 = pairwise_l2sq(base, q)
        # stable top-k: argpartition then argsort by (dist, id)
        part = np.argpartition(d2, min(k, n - 1), axis=1)[:, :k]
        pd = np.take_along_axis(d2, part, axis=1)
        order = np.lexsort((part, pd), axis=1)
        out[s : s + block] = np.take_along_axis(part, order, axis=1)
    return out
