"""Search algorithms as schedulable coroutines (paper §3.1, §4, Alg. 2).

Every algorithm is a Python generator — the host-plane analogue of a stackless
coroutine.  It yields engine ops and is resumed with their results:

    ("compute", seconds)                      -> None
    ("score", ScoreRequest)                   -> np.ndarray of distances
                                                 (may suspend: the engine can
                                                 park the request in its
                                                 cross-query rendezvous buffer)
    ("read", [pid, ...])                      -> {pid: page_bytes}   (suspends)
    ("load_wait", vid, pool)                  -> decoded record  (suspends:
                                                 parks on the record's LOCKED
                                                 buffer-pool slot until the
                                                 in-flight load publishes it;
                                                 None if the load was aborted)
    ("submit_cb", [pid, ...], callback)       -> None  (fire-and-forget prefetch;
                                                 callback(pid, bytes) runs at
                                                 completion time)
    ("submit", [pid, ...])                    -> [token, ...]  (non-blocking)
    ("wait_any", {token, ...})                -> (token, pid, page_bytes)

The same generator therefore runs unchanged under the synchronous executor
(B=1) and the asynchronous scheduler (B>1) — which is exactly the paper's
claim that the *algorithm* is orthogonal to the execution model, and is what
tests/test_engine.py asserts (async results == sync results).

Search coroutines never compute a distance themselves: every fresh-neighbor
frontier and every fetched record group is yielded to the engine as a
``("score", ScoreRequest)`` op carrying the prepared query and the rows to
evaluate — as VERTEX IDS on the quantized index (the engine owns the
register-once resident code tables and gathers the rows itself, on-device
for the pallas backend; ``SearchContext.resident_ids=False`` materializes
the code matrices from the fetched payload bytes instead, the host-gather
parity path).  The engine executes the request through the pluggable
DistanceEngine (core.distance) — immediately when fusion is off (per-query
dispatch, PR-1 semantics), or fused with the frontiers of the OTHER
coroutines in flight when fusion is on (one kernel dispatch serving many
queries; with the shared rendezvous, the coroutines of ALL workers).
tests/test_distance.py asserts exact id/hop/read parity across backends;
tests/test_fusion.py asserts parity between fused and per-query dispatch;
tests/test_resident.py asserts resident==host-gather and shared==per-worker
parity.
"""

from __future__ import annotations

import dataclasses
import math
from bisect import insort

import numpy as np

from repro.core import beam as beam_mod
from repro.core import distance as distance_mod
from repro.core import sharding as sharding_mod
from repro.core.quant import RabitQuantizer
from repro.core.sim import CostModel


@dataclasses.dataclass
class SearchParams:
    k: int = 10
    L: int = 64          # candidate list size
    W: int = 4           # beam width / look-ahead set size
    cbs: bool = True     # cache-aware beam search (Alg. 2 pivot)
    prefetch: bool = True
    prefetch_depth: int = 4
    pipe_depth: int = 4  # PipeANN in-flight reads


@dataclasses.dataclass
class SearchContext:
    index: object               # VeloIndex | FixedIndex
    qb: object                  # QuantizedBase
    accessor: object            # RecordAccessor | PageAccessor
    cost: CostModel
    medoid: int
    base: np.ndarray | None = None  # only for the in-memory oracle engine
    # CPU charge for one record refinement: 4-bit dequant distance on the
    # compressed index, full fp32 distance on the DiskANN-style index.
    refine_cost_s: float = 0.0
    dist: object | None = None      # DistanceEngine; None -> process default
    # resident wire format: refine ScoreRequests carry vertex ids, resolved
    # against the engine's registered tables (False = PR-2 semantics, the
    # coroutine materializes code matrices from the fetched payload bytes)
    resident_ids: bool = True
    # multi-tenant serving plane (core.serving): score requests are tagged
    # with the registered table their ids index and with the tenant id, and
    # id payloads are shifted into the plane's global vid namespace.  The
    # single-system defaults (own table, offset 0, tenant 0) leave the wire
    # format bitwise unchanged.
    table_qb: object | None = None  # table requests index (None -> qb)
    vid_base: int = 0               # offset into the combined-table rows
    tenant: int = 0                 # tenant tag on every score op
    # sharded scatter-gather plane (core.sharding): when set, score work is
    # yielded as ("scatter", ShardScatter) ops routing each row to the engine
    # shard that owns its record — the algorithm itself stays unchanged (the
    # default, None, keeps the single-engine ("score", ...) wire format)
    shard_plan: object | None = None
    # fused on-device beam step (core.beam): level-1 frontier maintenance
    # moves into ("beam", BeamRequest) ops whose reply is the next FRONTIER —
    # candidate heap and visited masks stay engine-resident across hops.  The
    # default (False) keeps the host _Beam path, which stays the bitwise
    # reference; True matches it result-bitwise (ids/dists/hops) per
    # tests/test_beam.py.
    device_beam: bool = False

    def __post_init__(self):
        if self.dist is None:
            self.dist = distance_mod.get_engine()
        if self.table_qb is None:
            self.table_qb = self.qb


@dataclasses.dataclass
class QueryResult:
    ids: np.ndarray
    dists: np.ndarray
    hops: int
    reads: int


# ------------------------------------------------------------------ accessors


class RecordAccessor:
    """Record-level buffer pool access path (paper §3.2): on miss, read the
    page, decode ONLY the needed record (plus same-Color co-residents, §3.4),
    admit them, discard the rest of the page.

    With ``async_load=True`` (the default) misses open a real LOCKED window:
    the slot is reserved via ``pool.begin_load`` BEFORE the page read is
    issued and published via ``pool.finish_load`` when it completes, so every
    concurrent searcher of the same record — on any worker — parks on the
    slot (engine ``load_wait`` op) instead of re-reading the page; co-resident
    records are installed as one ``admit_group``.  ``async_load=False``
    reproduces the legacy per-record synchronous admits (kept for the
    determinism/parity tests and as the pre-shared-pool baseline).

    ``hbm`` (``core.hbm.HbmTier`` / ``HbmView``, default None == off) inserts
    the HBM record-cache tier ABOVE the pool: lookups consult the tier first
    (a tier hit touches neither the pool nor the SSD), tier misses fall
    through to the pool unchanged, and a pool hit on a record the tier does
    not hold promotes it (``note_hit``) for the next dispatch-boundary
    scatter.  The pool's miss path is untouched — its ``on_publish`` hook,
    not the accessor, stages freshly loaded records."""

    def __init__(self, index, pool, cost: CostModel, co_admit: bool = True,
                 track_access: bool = False, async_load: bool = True,
                 hbm=None):
        self.index = index
        self.pool = pool
        self.cost = cost
        self.co_admit = co_admit
        self.async_load = async_load
        self.hbm = hbm
        self.reads = 0
        # per-vertex / per-page access counters (Fig. 4 skew study)
        self.track_access = track_access
        if track_access:
            import numpy as _np
            self.vertex_counts = _np.zeros(index.n, dtype=_np.int64)
            self.page_counts = _np.zeros(index.store.n_pages, dtype=_np.int64)

    def _track(self, vid: int) -> None:
        if self.track_access:
            self.vertex_counts[vid] += 1
            self.page_counts[self.index.page_of(vid)] += 1

    def resident(self, vid: int) -> bool:
        # Alg. 2's InMemory(): a LOCKED slot is NOT in memory — pivoting to
        # it would block on the in-flight load instead of avoiding an I/O.
        # A record installed in an HBM cache slot is as in-memory as it gets.
        if self.hbm is not None and self.hbm.ready(vid):
            return True
        return self.pool.peek_present(vid)

    def _admit_from_page(self, vid: int, page: bytes):
        rec = self.index.decode_record(vid, page)
        self.pool.admit(vid, rec)
        if self.co_admit:
            for extra in self.index.co_resident_records(vid, page):
                self.pool.admit(extra.vid, extra)
        return rec

    def _publish_from_page(self, vid: int, page: bytes):
        """Close vid's LOCKED window with the decoded record and install its
        co-resident group under one clock interaction."""
        rec = self.index.decode_record(vid, page)
        self.pool.finish_load(vid, rec)
        if self.co_admit:
            extras = self.index.co_resident_records(vid, page)
            if extras:
                self.pool.admit_group([e.vid for e in extras], extras)
        return rec

    def _demand_load(self, vid: int):
        """Demand-read vid's page and publish (or sync-admit) its record.
        The access was already counted/tracked by the caller."""
        slot = self.pool.begin_load(vid) if self.async_load else -1
        pid = self.index.page_of(vid)
        pages = yield ("read", [pid])
        self.reads += 1
        yield ("compute", self.cost.page_parse_s + self.cost.record_decode_s)
        if slot >= 0:
            return self._publish_from_page(vid, pages[pid])
        # legacy path, or pool exhausted (every slot LOCKED): sync admit
        return self._admit_from_page(vid, pages[pid])

    def get(self, vid: int):
        self._track(vid)
        if self.hbm is not None:
            rec = self.hbm.lookup(vid)
            if rec is not None:
                return rec  # tier hit: pool and SSD untouched
        rec = self.pool.lookup(vid)
        if rec is not None:
            if self.hbm is not None:
                self.hbm.note_hit(vid, rec)  # proven hot: promote to the tier
            return rec
        if self.async_load:
            while self.pool.is_loading(vid):
                # coalesce on the in-flight load instead of re-reading
                rec = yield ("load_wait", vid, self.pool)
                if rec is not None:
                    return rec
                # load aborted: fall through and issue our own
        return (yield from self._demand_load(vid))

    def get_many(self, vids: list[int]):
        out: dict[int, object] = {}
        missing: list[int] = []
        loading: list[int] = []
        for v in vids:
            self._track(v)
            if self.hbm is not None:
                rec = self.hbm.lookup(v)
                if rec is not None:
                    out[v] = rec  # tier hit: pool and SSD untouched
                    continue
            rec = self.pool.lookup(v)
            if rec is not None:
                out[v] = rec
                if self.hbm is not None:
                    self.hbm.note_hit(v, rec)
            elif self.async_load and self.pool.is_loading(v):
                loading.append(v)
            else:
                missing.append(v)
        if missing:
            pids = sorted({self.index.page_of(v) for v in missing})
            slots = (
                {v: self.pool.begin_load(v) for v in missing}
                if self.async_load else {}
            )
            pages = yield ("read", pids)
            self.reads += len(pids)
            yield (
                "compute",
                len(pids) * self.cost.page_parse_s
                + len(missing) * self.cost.record_decode_s,
            )
            for v in missing:
                page = pages[self.index.page_of(v)]
                if slots.get(v, -1) >= 0:
                    out[v] = self._publish_from_page(v, page)
                else:
                    out[v] = self._admit_from_page(v, page)
        # park on other coroutines' in-flight loads LAST: our own loads are
        # already published, so the loaders we wait on can never be waiting
        # on us (no cross-coroutine deadlock)
        for v in loading:
            rec = yield ("load_wait", v, self.pool)
            while rec is None:  # window closed empty (abort, or published
                # then evicted before we were scheduled): load it ourselves —
                # WITHOUT re-tracking the access, which was already counted
                if self.pool.is_loading(v):
                    rec = yield ("load_wait", v, self.pool)
                else:
                    rec = yield from self._demand_load(v)
            out[v] = rec
        return out

    def install(self, vid: int, pid: int, page: bytes):
        """Decode vid's record from an already-fetched page and admit it —
        the accessor-owned install path for algorithms that drive their own
        reads (PipeANN's relaxed-ordering completions).  Keeping the pool
        interaction here, not in the coroutine, is the layering the purity
        lint (repro.analysis) enforces: coroutines yield ops and call
        accessors; only accessors touch the pool."""
        rec = self.index.decode_record(vid, page)
        self.pool.admit(vid, rec)
        return rec

    def prefetch_op(self, vid: int):
        """Return a fire-and-forget op loading vid's record, or None if the
        record is already present or its load is already in flight."""
        if self.hbm is not None and self.hbm.ready(vid):
            return None  # already served from an HBM slot: nothing to load
        if self.pool.peek_resident(vid):
            return None
        pid = self.index.page_of(vid)

        if self.async_load:
            slot = self.pool.begin_load(vid)
            if slot >= 0:
                def on_publish(_pid: int, page: bytes) -> None:
                    self._publish_from_page(vid, page)

                return ("submit_cb", [pid], on_publish)
            # every slot LOCKED: fall back to the uncached legacy prefetch

        def on_complete(_pid: int, page: bytes) -> None:
            if not self.pool.peek_resident(vid):
                self._admit_from_page(vid, page)

        return ("submit_cb", [pid], on_complete)

    def stats(self) -> tuple[int, int]:
        return self.pool.hits, self.pool.misses


class PageAccessor:
    """Page-level cache access path (DiskANN/Starling/PipeANN baselines and the
    '+Record'-ablated VeloANN variant): whole pages are cached; records are
    re-parsed out of the cached page on every access."""

    def __init__(self, index, cache, cost: CostModel, track_access: bool = False):
        self.index = index
        self.cache = cache
        self.cost = cost
        self.reads = 0
        self.track_access = track_access
        if track_access:
            import numpy as _np
            self.vertex_counts = _np.zeros(index.n, dtype=_np.int64)
            self.page_counts = _np.zeros(index.store.n_pages, dtype=_np.int64)

    def _track(self, vid: int) -> None:
        if self.track_access:
            self.vertex_counts[vid] += 1
            self.page_counts[self.index.page_of(vid)] += 1

    def resident(self, vid: int) -> bool:
        return self.cache.contains(self.index.page_of(vid))

    def get(self, vid: int):
        self._track(vid)
        pid = self.index.page_of(vid)
        page = self.cache.lookup(pid)
        if page is None:
            pages = yield ("read", [pid])
            self.reads += 1
            page = pages[pid]
            self.cache.admit(pid, page)
        yield ("compute", self.cost.page_parse_s + self.cost.record_decode_s)
        return self.index.decode_record(vid, page)

    def get_many(self, vids: list[int]):
        out: dict[int, object] = {}
        have: dict[int, bytes] = {}   # pid -> bytes, pinned locally for this step
        vid_page: dict[int, int] = {}
        for v in vids:
            self._track(v)
            pid = self.index.page_of(v)
            vid_page[v] = pid
            if pid not in have:
                page = self.cache.lookup(pid)
                if page is not None:
                    have[pid] = page
        missing_pids = sorted({p for p in vid_page.values() if p not in have})
        if missing_pids:
            got = yield ("read", missing_pids)
            self.reads += len(missing_pids)
            for pid, page in got.items():
                self.cache.admit(pid, page)
                have[pid] = page
        yield (
            "compute",
            len(vids) * (self.cost.page_parse_s + self.cost.record_decode_s),
        )
        for v in vids:
            out[v] = self.index.decode_record(v, have[vid_page[v]])
        return out

    def install(self, vid: int, pid: int, page: bytes):
        """Admit an already-fetched page and decode vid's record out of it —
        the page-granular twin of ``RecordAccessor.install`` (same contract:
        the coroutine hands the bytes over; the accessor owns the cache)."""
        self.cache.admit(pid, page)
        return self.index.decode_record(vid, page)

    def prefetch_op(self, vid: int):
        pid = self.index.page_of(vid)
        if self.cache.contains(pid):
            return None

        def on_complete(_pid: int, page: bytes) -> None:
            self.cache.admit(pid, page)

        return ("submit_cb", [pid], on_complete)

    def stats(self) -> tuple[int, int]:
        return self.cache.hits, self.cache.misses


# ------------------------------------------------------------------- helpers


class _Beam:
    """Sorted candidate list P with explored/seen tracking (bounded size L)."""

    def __init__(self, L: int):
        self.L = L
        self.items: list[tuple[float, int]] = []  # (est_d2, vid), sorted
        self.seen: set[int] = set()
        self.explored: set[int] = set()

    def insert(self, vid: int, est: float) -> None:
        if vid in self.seen:
            return
        self.seen.add(vid)
        insort(self.items, (est, vid))
        if len(self.items) > 4 * self.L:
            self.items = self.items[: 2 * self.L]

    def window(self) -> list[tuple[float, int]]:
        return self.items[: self.L]

    def unexplored(self, limit: int | None = None) -> list[int]:
        out = []
        for _, v in self.window():
            if v not in self.explored:
                out.append(v)
                if limit is not None and len(out) >= limit:
                    break
        return out

    def mark(self, vid: int) -> None:
        self.explored.add(vid)


def _query_prep_cost(cost: CostModel, d: int) -> float:
    # rotation via fast transform ~ d log d flops
    return d * max(1.0, math.log2(d)) * 1e-9


def _finish(refined: dict[int, float], k: int) -> tuple[np.ndarray, np.ndarray]:
    items = sorted(refined.items(), key=lambda kv: (kv[1], kv[0]))[:k]
    ids = np.asarray([v for v, _ in items], dtype=np.int64)
    ds = np.asarray([dv for _, dv in items], dtype=np.float32)
    return ids, ds


def _fresh_union(beam: "_Beam", recs: list) -> list[int]:
    """Unseen neighbors of a record group, deduped, first-occurrence order."""
    fresh: list[int] = []
    local: set[int] = set()
    for rec in recs:
        for u in rec.adjacency:
            u = int(u)
            if u not in beam.seen and u not in local:
                local.add(u)
                fresh.append(u)
    return fresh


def _dispatch_score(ctx: SearchContext, req, vids):
    """Yield one score op through the active dispatch plane: the single
    engine ("score"), or — when ``ctx.shard_plan`` is set — the sharded
    scatter-gather plane ("scatter"), routing each row to the engine shard
    owning its record.  ``vids`` are the LOCAL vertex ids of the request's
    rows, in row order (routing is computed before any serving-plane
    ``vid_base`` shift, so it is independent of the table namespace)."""
    if ctx.shard_plan is None:
        out = yield ("score", req)
        return out
    scatter = sharding_mod.ShardScatter(
        req=req, shard_rows=ctx.shard_plan.shards_of(vids)
    )
    out = yield ("scatter", scatter)
    return out


def _estimate_scores(ctx: SearchContext, pq, ids: list[int]):
    """Yield one level-1 score op for ``ids``; returns the estimate array.
    The engine charges the batch's flops plus an amortized dispatch — shared
    with other queries' frontiers when cross-query fusion is on."""
    payload = np.asarray(ids, dtype=np.int64)
    if ctx.vid_base:
        payload = payload + ctx.vid_base  # rows in the combined serving table
    req = distance_mod.ScoreRequest(
        kind="estimate",
        rows=len(ids),
        flop_s=ctx.cost.estimate(len(ids), ctx.qb.dim),
        pq=pq,
        payload=payload,
        qb=ctx.table_qb,
        tenant=ctx.tenant,
    )
    ests = yield from _dispatch_score(ctx, req, ids)
    return ests


def _refine_records(ctx: SearchContext, pq, recs: list):
    """Yield one level-2/fp32 score op refining a fetched record group;
    returns the refined distance array (one per record, in order).  On the
    quantized index the request carries only vertex ids (the engine owns the
    resident level-2 table) unless ``ctx.resident_ids`` is off."""
    kind, payload = ctx.index.refine_payload(recs, resident=ctx.resident_ids)
    if kind == "refine" and ctx.vid_base and not isinstance(payload, tuple):
        payload = payload + ctx.vid_base  # rows in the combined serving table
    req = distance_mod.ScoreRequest(
        kind=kind,
        rows=len(recs),
        flop_s=len(recs) * ctx.refine_cost_s,
        pq=pq,
        payload=payload,
        query=pq.q_orig if kind == "full" else None,
        qb=ctx.table_qb if kind != "full" else None,
        tenant=ctx.tenant,
    )
    dists = yield from _dispatch_score(ctx, req, [r.vid for r in recs])
    return dists


def _score_into_beam(ctx: SearchContext, pq, beam: "_Beam", fresh: list[int]):
    """One batched level-1 evaluation of a fresh frontier, inserted into the
    beam.  (Generator: the engine executes — and may fuse — the score op.)"""
    if not fresh:
        return
    ests = yield from _estimate_scores(ctx, pq, fresh)
    for u, e in zip(fresh, ests):
        beam.insert(u, float(e))


# ------------------------------------------------------ device-resident beam


def _dispatch_beam(ctx: SearchContext, req, vids):
    """Yield one fused beam op through the active dispatch plane: the single
    engine ("beam"), or — when ``ctx.shard_plan`` is set — the scatter plane,
    each owning shard scoring its slice of the fresh frontier and the join
    merging the local top-Ls before frontier selection.  ``vids`` are the
    LOCAL fresh ids in row order (like ``_dispatch_score``)."""
    if ctx.shard_plan is None:
        out = yield ("beam", req)
        return out
    scatter = sharding_mod.ShardScatter(
        req=req, shard_rows=ctx.shard_plan.shards_of(vids)
    )
    out = yield ("scatter", scatter)
    return out


class _DeviceBeam:
    """Host-side mirror of one query's engine-resident beam state.

    The heap and visited/explored masks live with the DistanceEngine
    (``ctx.dist.beam_new``, device arrays on pallas); the coroutine keeps
    only what it needs between hops without a download: the ``seen`` /
    ``explored`` sets (cheap host bookkeeping, also used by
    ``_fresh_union``), the last reply's frontier / window stats, and the
    pending explored-marks and known-distance inserts that ride along with
    the next ``("beam", ...)`` op.  ``step`` is the one generator that talks
    to the engine — one op per hop, whose reply is the next frontier."""

    def __init__(self, ctx: SearchContext, pq, L: int,
                 kind: str = "estimate", query=None):
        self.ctx = ctx
        self.pq = pq
        self.L = L
        self.kind = kind
        self.query = query
        self.state = ctx.dist.beam_new(L, ctx.index.n)
        self.seen: set[int] = set()
        self.explored: set[int] = set()
        self.window_len = 0
        self.tail = float("inf")
        self.topk: tuple[np.ndarray, np.ndarray] | None = None
        self._frontier: list[int] = []
        self._marks: list[int] = []
        self._ins_v: list[int] = []
        self._ins_d: list[float] = []

    def insert(self, vid: int, dist: float) -> bool:
        """Queue a known-distance insert for the next step (first-wins, the
        host ``_Beam.insert`` early-return on seen ids)."""
        if vid in self.seen:
            return False
        self.seen.add(vid)
        self._ins_v.append(int(vid))
        self._ins_d.append(float(dist))
        return True

    def mark(self, vid: int) -> None:
        """Mark explored: applied to the cached frontier immediately, to the
        device mask with the next step's op."""
        self.explored.add(vid)
        self._marks.append(int(vid))
        try:
            self._frontier.remove(vid)
        except ValueError:
            pass

    def unexplored(self, limit: int | None = None) -> list[int]:
        if limit is not None:
            return self._frontier[:limit]
        return list(self._frontier)

    def pending(self) -> bool:
        """True when queued inserts could change the window/frontier (marks
        alone keep the cached frontier exact and can wait for the next op)."""
        return bool(self._ins_v)

    def step(self, fresh: list[int], topk: int = 0):
        """One fused beam step: score ``fresh``, fold in pending inserts and
        marks, merge, and refresh the cached frontier/window from the reply
        — the ONE exchange of this hop."""
        ctx = self.ctx
        for u in fresh:
            self.seen.add(int(u))
        fresh_arr = np.asarray(fresh, dtype=np.int64)
        if self.kind == "full":
            vectors = ctx.base[fresh_arr]
            flop_s = fresh_arr.size * ctx.cost.refine_full(ctx.base.shape[1])
            qb = None
            query = np.asarray(self.query, dtype=np.float32)
        else:
            vectors = None
            flop_s = ctx.cost.estimate(int(fresh_arr.size), ctx.qb.dim)
            qb = ctx.table_qb
            query = None
        req = beam_mod.BeamRequest(
            kind=self.kind,
            state=self.state,
            fresh=fresh_arr,
            explored=np.asarray(self._marks, dtype=np.int64),
            insert_ids=np.asarray(self._ins_v, dtype=np.int64),
            insert_ds=np.asarray(self._ins_d, dtype=np.float32),
            rows=int(fresh_arr.size),
            flop_s=flop_s,
            pq=self.pq,
            query=query,
            vectors=vectors,
            qb=qb,
            tenant=ctx.tenant,
            topk=int(topk),
            vid_base=ctx.vid_base,
        )
        self._marks, self._ins_v, self._ins_d = [], [], []
        res = yield from _dispatch_beam(ctx, req, [int(u) for u in fresh])
        self._frontier = [int(u) for u in res.frontier]
        self.window_len = int(res.window_len)
        self.tail = float(res.tail)
        if topk:
            self.topk = (
                np.asarray(res.topk_ids, dtype=np.int64),
                np.asarray(res.topk_ds, dtype=np.float32),
            )
        return res


# ----------------------------------------------------------- VeloANN (Alg. 2)


def velo_search(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Cache-aware beam search with proactive prefetching (paper Alg. 2)."""
    if ctx.device_beam:
        return (yield from _velo_search_device(ctx, q, p))
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    beam = _Beam(p.L)
    est0 = float((yield from _estimate_scores(ctx, pq, [ctx.medoid]))[0])
    beam.insert(ctx.medoid, est0)

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads
    prefetched: set[int] = set()  # avoid re-submitting in-flight prefetches

    while True:
        unexp = beam.unexplored(limit=p.W)
        if not unexp:
            break
        v = unexp[0]  # top-1 nearest unexplored (Alg. 2 line 5)

        if p.cbs and not acc.resident(v):
            # Alg. 2 lines 8-14: pivot to the first in-memory candidate in the
            # look-ahead set C; prefetch on-disk members of C.
            pivot = None
            for c in unexp:
                if pivot is None and acc.resident(c):
                    pivot = c
                elif p.prefetch and c not in prefetched:
                    op = acc.prefetch_op(c)
                    if op is not None:
                        prefetched.add(c)
                        yield ("compute", cost.io_submit_s)
                        yield op
            if pivot is not None:
                v = pivot
        elif p.prefetch:
            # §4.1 stride prefetch of the top-B frontier candidates
            for c in unexp[1 : 1 + p.prefetch_depth]:
                if c in prefetched:
                    continue
                op = acc.prefetch_op(c)
                if op is not None:
                    prefetched.add(c)
                    yield ("compute", cost.io_submit_s)
                    yield op

        rec = yield from acc.get(v)  # suspends on miss (Alg. 2 line 17)
        yield ("compute", cost.visit_overhead_s)
        refined[v] = float((yield from _refine_records(ctx, pq, [rec]))[0])
        beam.mark(v)
        hops += 1

        yield from _score_into_beam(ctx, pq, beam, _fresh_union(beam, [rec]))

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


def _velo_search_device(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Alg. 2 with the beam engine-resident: the pivot/prefetch policy and
    the refine path are the host loop's, but level-1 frontier maintenance is
    one ("beam", ...) op per hop whose reply is the next frontier — no
    estimate download, and only ``beam_visit_s`` of host bookkeeping per
    explored vertex (result-bitwise the host path; op schedule differs)."""
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    bm = _DeviceBeam(ctx, pq, p.L)
    yield from bm.step([ctx.medoid])  # seed: medoid scored inside the step

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads
    prefetched: set[int] = set()

    while True:
        unexp = bm.unexplored(limit=p.W)
        if not unexp:
            break
        v = unexp[0]

        if p.cbs and not acc.resident(v):
            pivot = None
            for c in unexp:
                if pivot is None and acc.resident(c):
                    pivot = c
                elif p.prefetch and c not in prefetched:
                    op = acc.prefetch_op(c)
                    if op is not None:
                        prefetched.add(c)
                        yield ("compute", cost.io_submit_s)
                        yield op
            if pivot is not None:
                v = pivot
        elif p.prefetch:
            for c in unexp[1 : 1 + p.prefetch_depth]:
                if c in prefetched:
                    continue
                op = acc.prefetch_op(c)
                if op is not None:
                    prefetched.add(c)
                    yield ("compute", cost.io_submit_s)
                    yield op

        rec = yield from acc.get(v)
        yield ("compute", cost.beam_visit_s)
        refined[v] = float((yield from _refine_records(ctx, pq, [rec]))[0])
        bm.mark(v)
        hops += 1

        fresh = _fresh_union(bm, [rec])
        if fresh:
            yield from bm.step(fresh)

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


# ------------------------------------------------- DiskANN-style beam search


def diskann_search(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Synchronous beam search [23]: at each step fetch the top-W unexplored
    candidates with one batched read (bottlenecked by the slowest read)."""
    if ctx.device_beam:
        return (yield from _diskann_search_device(ctx, q, p))
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    beam = _Beam(p.L)
    est0 = float((yield from _estimate_scores(ctx, pq, [ctx.medoid]))[0])
    beam.insert(ctx.medoid, est0)

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads

    while True:
        batch = beam.unexplored(limit=max(1, p.W))
        if not batch:
            break
        recs = yield from acc.get_many(batch)
        rec_list = [recs[v] for v in batch]
        # refine the whole fetched record group in one engine call
        yield ("compute", len(batch) * cost.visit_overhead_s)
        dists = yield from _refine_records(ctx, pq, rec_list)
        for v, dv in zip(batch, dists):
            refined[v] = float(dv)
            beam.mark(v)
            hops += 1
        # one batched level-1 scan over the union of fresh neighbors
        yield from _score_into_beam(ctx, pq, beam, _fresh_union(beam, rec_list))

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


def _diskann_search_device(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """DiskANN beam with engine-resident frontier selection: one beam op per
    batch expansion instead of an estimate download per hop group."""
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    bm = _DeviceBeam(ctx, pq, p.L)
    yield from bm.step([ctx.medoid])

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads

    while True:
        batch = bm.unexplored(limit=max(1, p.W))
        if not batch:
            break
        recs = yield from acc.get_many(batch)
        rec_list = [recs[v] for v in batch]
        yield ("compute", len(batch) * cost.beam_visit_s)
        dists = yield from _refine_records(ctx, pq, rec_list)
        for v, dv in zip(batch, dists):
            refined[v] = float(dv)
            bm.mark(v)
            hops += 1
        fresh = _fresh_union(bm, rec_list)
        if fresh:
            yield from bm.step(fresh)

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


# ------------------------------------------------ Starling-style block search


def starling_search(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """DiskANN beam + block search: every fetched page's co-resident records
    are refined and expanded for free (exploits the shuffled layout)."""
    if ctx.device_beam:
        return (yield from _starling_search_device(ctx, q, p))
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    index = ctx.index
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    beam = _Beam(p.L)
    est0 = float((yield from _estimate_scores(ctx, pq, [ctx.medoid]))[0])
    beam.insert(ctx.medoid, est0)

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads

    while True:
        batch = beam.unexplored(limit=max(1, p.W))
        if not batch:
            break
        recs = yield from acc.get_many(batch)
        extra_vids: list[int] = []
        extra_set: set[int] = set()
        for v in batch:
            pid = index.page_of(v)
            for u in index.page_record_ids(pid):
                if u not in beam.explored and u not in batch and u not in extra_set:
                    extra_set.add(u)
                    extra_vids.append(u)
        extra_recs: dict[int, object] = {}
        if extra_vids:
            # co-resident records: their pages are cached by the batch fetch,
            # so this decodes in place — no new I/O
            extra_recs = yield from acc.get_many(extra_vids)
        group = batch + extra_vids
        rec_list = [recs[v] if v in recs else extra_recs[v] for v in group]
        # refine batch members + co-residents in one engine call …
        yield ("compute", len(group) * cost.visit_overhead_s)
        dists = yield from _refine_records(ctx, pq, rec_list)
        # … then apply the block-search admission filter sequentially: whether
        # a co-resident enters depends on the window as of its turn
        for v, rec, dv in zip(group, rec_list, dists):
            if v in beam.explored:
                continue
            dist = float(dv)
            if v in extra_set:
                window = beam.window()
                if window and len(window) >= p.L and dist >= window[-1][0]:
                    continue
            refined[v] = dist
            beam.mark(v)
            beam.insert(v, dist)
            hops += 1
            yield from _score_into_beam(ctx, pq, beam, _fresh_union(beam, [rec]))

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


def _starling_search_device(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Block search with the beam engine-resident.  The sequential admission
    filter needs the window AS OF each co-resident's turn, so every admitted
    record's step ships immediately (pending insert forces it even when the
    record expands no fresh neighbors) and the cached ``window_len``/``tail``
    mirror the host's ``beam.window()`` check exactly."""
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    index = ctx.index
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    bm = _DeviceBeam(ctx, pq, p.L)
    yield from bm.step([ctx.medoid])

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads

    while True:
        batch = bm.unexplored(limit=max(1, p.W))
        if not batch:
            break
        recs = yield from acc.get_many(batch)
        extra_vids: list[int] = []
        extra_set: set[int] = set()
        for v in batch:
            pid = index.page_of(v)
            for u in index.page_record_ids(pid):
                if u not in bm.explored and u not in batch and u not in extra_set:
                    extra_set.add(u)
                    extra_vids.append(u)
        extra_recs: dict[int, object] = {}
        if extra_vids:
            extra_recs = yield from acc.get_many(extra_vids)
        group = batch + extra_vids
        rec_list = [recs[v] if v in recs else extra_recs[v] for v in group]
        yield ("compute", len(group) * cost.beam_visit_s)
        dists = yield from _refine_records(ctx, pq, rec_list)
        for v, rec, dv in zip(group, rec_list, dists):
            if v in bm.explored:
                continue
            dist = float(dv)
            if v in extra_set and bm.window_len >= p.L and dist >= bm.tail:
                continue
            refined[v] = dist
            bm.mark(v)
            bm.insert(v, dist)
            hops += 1
            fresh = _fresh_union(bm, [rec])
            if fresh or bm.pending():
                yield from bm.step(fresh)

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


# -------------------------------------------------- PipeANN-style pipelining


def pipeann_search(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Pipelined best-first search [15]: keep up to `pipe_depth` reads in
    flight and process completions in arrival order (relaxed ordering) —
    lower latency, some wasted I/O."""
    if ctx.device_beam:
        return (yield from _pipeann_search_device(ctx, q, p))
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    index = ctx.index
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    beam = _Beam(p.L)
    est0 = float((yield from _estimate_scores(ctx, pq, [ctx.medoid]))[0])
    beam.insert(ctx.medoid, est0)

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads
    outstanding: dict[int, int] = {}  # token -> vid
    inflight: set[int] = set()

    def process(v, rec):
        """Refine + expand one arrived record (generator: scores via engine)."""
        nonlocal hops
        refined[v] = float((yield from _refine_records(ctx, pq, [rec]))[0])
        beam.mark(v)
        hops += 1
        yield from _score_into_beam(ctx, pq, beam, _fresh_union(beam, [rec]))

    while True:
        # fill the pipeline with the best unexplored, uninflight candidates
        cands = [v for v in beam.unexplored() if v not in inflight]
        while len(outstanding) < p.pipe_depth and cands:
            v = cands.pop(0)
            if acc.resident(v):
                rec = yield from acc.get(v)
                yield ("compute", cost.visit_overhead_s)
                yield from process(v, rec)
                cands = [x for x in beam.unexplored() if x not in inflight]
                continue
            pid = index.page_of(v)
            yield ("compute", cost.io_submit_s)
            tokens = yield ("submit", [pid])
            outstanding[tokens[0]] = v
            inflight.add(v)

        if not outstanding:
            if not beam.unexplored():
                break
            continue

        token, pid, page = yield ("wait_any", set(outstanding))
        v = outstanding.pop(token)
        inflight.discard(v)
        acc.reads += 1
        yield ("compute", cost.page_parse_s + cost.record_decode_s)
        rec = acc.install(v, pid, page)
        if v in beam.explored:
            continue  # over-fetched: candidate already pruned/processed
        yield ("compute", cost.visit_overhead_s)
        yield from process(v, rec)

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


def _pipeann_search_device(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Pipelined search with engine-resident frontier selection: arrivals
    refine through the normal path, expansion is one beam op per record."""
    cost, qb, acc = ctx.cost, ctx.qb, ctx.accessor
    index = ctx.index
    d = qb.dim
    yield ("compute", _query_prep_cost(cost, d))
    pq = RabitQuantizer.prepare_query(qb, q)

    bm = _DeviceBeam(ctx, pq, p.L)
    yield from bm.step([ctx.medoid])

    refined: dict[int, float] = {}
    hops = 0
    reads0 = acc.reads
    outstanding: dict[int, int] = {}  # token -> vid
    inflight: set[int] = set()

    def process(v, rec):
        nonlocal hops
        refined[v] = float((yield from _refine_records(ctx, pq, [rec]))[0])
        bm.mark(v)
        hops += 1
        fresh = _fresh_union(bm, [rec])
        if fresh:
            yield from bm.step(fresh)

    while True:
        cands = [v for v in bm.unexplored() if v not in inflight]
        while len(outstanding) < p.pipe_depth and cands:
            v = cands.pop(0)
            if acc.resident(v):
                rec = yield from acc.get(v)
                yield ("compute", cost.beam_visit_s)
                yield from process(v, rec)
                cands = [x for x in bm.unexplored() if x not in inflight]
                continue
            pid = index.page_of(v)
            yield ("compute", cost.io_submit_s)
            tokens = yield ("submit", [pid])
            outstanding[tokens[0]] = v
            inflight.add(v)

        if not outstanding:
            if not bm.unexplored():
                break
            continue

        token, pid, page = yield ("wait_any", set(outstanding))
        v = outstanding.pop(token)
        inflight.discard(v)
        acc.reads += 1
        yield ("compute", cost.page_parse_s + cost.record_decode_s)
        rec = acc.install(v, pid, page)
        if v in bm.explored:
            continue  # over-fetched: candidate already pruned/processed
        yield ("compute", cost.beam_visit_s)
        yield from process(v, rec)

    ids, ds = _finish(refined, p.k)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=acc.reads - reads0)


# -------------------------------------------------------- in-memory Vamana


def inmemory_search(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """Fully in-memory Vamana greedy beam search — the paper's Fig. 1/12
    reference point.  Exact fp32 distances, no I/O ever."""
    if ctx.device_beam:
        return (yield from _inmemory_search_device(ctx, q, p))
    assert ctx.base is not None
    cost = ctx.cost
    base = ctx.base
    d = base.shape[1]
    graph = ctx.index.graph

    def full_scores(vids: list[int]):
        vectors = base[np.asarray(vids)]
        req = distance_mod.ScoreRequest(
            kind="full",
            rows=vectors.shape[0],
            flop_s=vectors.shape[0] * cost.refine_full(d),
            payload=vectors,
            query=np.asarray(q, dtype=np.float32),
            tenant=ctx.tenant,
        )
        out = yield from _dispatch_score(ctx, req, vids)
        return out

    beam = _Beam(p.L)
    beam.insert(
        ctx.medoid, float((yield from full_scores([ctx.medoid]))[0])
    )
    hops = 0
    while True:
        unexp = beam.unexplored(limit=1)
        if not unexp:
            break
        v = unexp[0]
        beam.mark(v)
        hops += 1
        nbrs = [int(u) for u in graph.neighbors(v) if int(u) not in beam.seen]
        if nbrs:
            yield ("compute", cost.visit_overhead_s)
            d2 = yield from full_scores(nbrs)
            for u, e in zip(nbrs, d2):
                beam.insert(u, float(e))

    # every beam entry carries an exact distance here
    topk = beam.items[: p.k]
    ids = np.asarray([v for _, v in topk], dtype=np.int64)
    ds = np.asarray([e for e, _ in topk], dtype=np.float32)
    return QueryResult(ids=ids, dists=ds, hops=hops, reads=0)


def _inmemory_search_device(ctx: SearchContext, q: np.ndarray, p: SearchParams):
    """In-memory greedy search with the fp32 (kind="full") beam step: every
    hop ships the expanded neighbors' raw vectors once and reads back only
    the frontier; ``topk=p.k`` keeps the heap head downloaded so the final
    answer needs no extra exchange (marks never change the heap, so the last
    step's readout is already final)."""
    assert ctx.base is not None
    cost = ctx.cost
    graph = ctx.index.graph

    bm = _DeviceBeam(ctx, None, p.L, kind="full", query=q)
    yield from bm.step([ctx.medoid], topk=p.k)
    hops = 0
    while True:
        unexp = bm.unexplored(limit=1)
        if not unexp:
            break
        v = unexp[0]
        bm.mark(v)
        hops += 1
        nbrs = [int(u) for u in graph.neighbors(v) if int(u) not in bm.seen]
        if nbrs:
            yield ("compute", cost.beam_visit_s)
            yield from bm.step(nbrs, topk=p.k)

    ids, ds = bm.topk
    return QueryResult(ids=ids[: p.k], dists=ds[: p.k], hops=hops, reads=0)


ALGORITHMS = {
    "velo": velo_search,
    "diskann": diskann_search,
    "starling": starling_search,
    "pipeann": pipeann_search,
    "inmemory": inmemory_search,
}
