"""The coroutine scheduler + executors (paper §3.1, Fig. 2/3).

Implements the paper's thread-per-core asynchronous execution model as a
discrete-event simulation over real algorithm executions:

  * each worker thread is a simulated timeline with its own scheduler;
  * each query is a coroutine (Python generator, see search.py protocol);
  * a cache miss suspends the coroutine; the scheduler switches to a ready
    one; the I/O driver (the SSD model, stand-in for io_uring) completes
    reads asynchronously; completed coroutines return to the ready queue;
  * if no coroutine is ready, the worker busy-polls the completion queue
    (time jumps to the next completion);
  * the batch size B caps concurrently executing queries per worker
    (paper: B = ceil(alpha * I / T)).

Synchronous execution (DiskANN-style) is the degenerate case B=1.

In-flight page reads are deduplicated (the paper's Locked slot state makes
concurrent loads of one record coalesce; we apply the same rule at page
granularity), so a prefetch racing a demand read costs one I/O, not two.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from collections.abc import Callable

import numpy as np

from repro.core.sim import SSD, CostModel, WorkloadStats


@dataclasses.dataclass
class EngineConfig:
    n_workers: int = 1
    batch_size: int = 8        # B: coroutines in flight per worker
    page_size: int = 4096


class _Worker:
    __slots__ = ("wid", "t", "ready", "active", "deferred_charge", "done_queries")

    def __init__(self, wid: int):
        self.wid = wid
        self.t = 0.0
        self.ready: deque = deque()  # (gen, resume_value, qid)
        self.active = 0
        self.deferred_charge = 0.0
        self.done_queries = 0


class Engine:
    """Runs a workload of query coroutines over the simulated hardware."""

    def __init__(
        self,
        store,                      # PageStore: pid -> bytes (data plane)
        ssd: SSD,
        cost: CostModel,
        config: EngineConfig,
    ):
        self.store = store
        self.ssd = ssd
        self.cost = cost
        self.config = config

    def run(
        self,
        make_coroutine: Callable[[int, np.ndarray], object],
        queries: np.ndarray,
    ) -> tuple[list, WorkloadStats]:
        cfg = self.config
        workers = [_Worker(i) for i in range(cfg.n_workers)]
        query_queue: deque[int] = deque(range(len(queries)))
        start_time: dict[int, float] = {}
        results: list = [None] * len(queries)
        stats = WorkloadStats(n_queries=len(queries))

        # global completion-event heap: (time, seq, kind, payload)
        events: list = []
        seq = 0
        # in-flight page reads: pid -> completion_time (dedup window)
        inflight: dict[int, float] = {}
        token_counter = 0
        token_info: dict[int, tuple[int, float]] = {}  # token -> (pid, completion)

        def issue_read(t: float, pid: int, worker: _Worker) -> float:
            """Submit one page read with in-flight dedup; returns completion time."""
            comp = inflight.get(pid)
            if comp is not None and comp > t:
                return comp
            comp = self.ssd.submit(t, cfg.page_size)
            inflight[pid] = comp
            stats.io_count += 1
            stats.io_bytes += cfg.page_size
            return comp

        def push_event(time: float, kind: str, payload) -> None:
            nonlocal seq
            heapq.heappush(events, (time, seq, kind, payload))
            seq += 1

        def apply_due_events(now: float) -> None:
            """Apply completions (callbacks / worker resumes) due by `now`."""
            while events and events[0][0] <= now:
                time, _, kind, payload = heapq.heappop(events)
                if kind == "callback":
                    cb, pid, issuer = payload
                    cb(pid, self.store.read_page(pid))
                    issuer.deferred_charge += self.cost.record_decode_s
                elif kind == "resume":
                    worker, gen, value, qid = payload
                    worker.t = max(worker.t, time)
                    worker.ready.append((gen, value, qid))

        def run_worker_action(w: _Worker) -> None:
            """One scheduling action on worker w (paper Fig. 3b loop body)."""
            w.t += w.deferred_charge
            w.deferred_charge = 0.0

            if not w.ready:
                if query_queue and w.active < cfg.batch_size:
                    qid = query_queue.popleft()
                    gen = make_coroutine(qid, queries[qid])
                    w.active += 1
                    start_time[qid] = w.t
                    w.ready.append((gen, None, qid))
                else:
                    return

            gen, value, qid = w.ready.popleft()
            w.t += self.cost.coroutine_switch_s

            while True:
                try:
                    op = gen.send(value)
                except StopIteration as fin:
                    results[qid] = fin.value
                    latency = w.t - start_time[qid]
                    stats.sum_latency_s += latency
                    stats.latencies.append(latency)
                    w.active -= 1
                    w.done_queries += 1
                    return

                kind = op[0]
                if kind == "compute":
                    w.t += op[1]
                    value = None
                elif kind == "read":
                    pids = op[1]
                    w.t += self.cost.io_submit_s * max(1, len(pids))
                    comp = max(issue_read(w.t, pid, w) for pid in pids)
                    pages = {pid: self.store.read_page(pid) for pid in pids}
                    push_event(comp, "resume", (w, gen, pages, qid))
                    return  # suspended
                elif kind == "submit_cb":
                    _, pids, cb = op
                    w.t += self.cost.io_submit_s
                    for pid in pids:
                        comp = issue_read(w.t, pid, w)
                        push_event(comp, "callback", (cb, pid, w))
                    value = None
                elif kind == "submit":
                    nonlocal token_counter
                    pids = op[1]
                    w.t += self.cost.io_submit_s
                    tokens = []
                    for pid in pids:
                        comp = issue_read(w.t, pid, w)
                        token_counter += 1
                        token_info[token_counter] = (pid, comp)
                        tokens.append(token_counter)
                    value = tokens
                elif kind == "wait_any":
                    tokens = op[1]
                    tok = min(tokens, key=lambda tk: token_info[tk][1])
                    pid, comp = token_info.pop(tok)
                    push_event(
                        comp, "resume", (w, gen, (tok, pid, self.store.read_page(pid)), qid)
                    )
                    return  # suspended
                else:  # pragma: no cover
                    raise ValueError(f"unknown op {kind}")

        # ------------------------------------------------------- global loop
        def runnable(w: _Worker) -> bool:
            return bool(w.ready) or (bool(query_queue) and w.active < cfg.batch_size)

        while True:
            cand = [w for w in workers if runnable(w)]
            next_event_t = events[0][0] if events else None
            if cand:
                w = min(cand, key=lambda x: x.t)
                if next_event_t is not None and next_event_t <= w.t:
                    apply_due_events(w.t)
                run_worker_action(w)
            elif events:
                t0 = events[0][0]
                apply_due_events(t0)  # busy-poll: jump to next completion
            else:
                break

        stats.makespan_s = max((w.t for w in workers), default=0.0)
        return results, stats


def run_workload(
    make_coroutine: Callable[[int, np.ndarray], object],
    queries: np.ndarray,
    store,
    cost: CostModel | None = None,
    ssd: SSD | None = None,
    n_workers: int = 1,
    batch_size: int = 8,
    page_size: int = 4096,
) -> tuple[list, WorkloadStats]:
    """Convenience wrapper: build an engine, run all queries, return results+stats."""
    engine = Engine(
        store=store,
        ssd=ssd or SSD(),
        cost=cost or CostModel(),
        config=EngineConfig(n_workers=n_workers, batch_size=batch_size, page_size=page_size),
    )
    return engine.run(make_coroutine, queries)
