"""The coroutine scheduler + executors (paper §3.1, Fig. 2/3).

Implements the paper's thread-per-core asynchronous execution model as a
discrete-event simulation over real algorithm executions:

  * each worker thread is a simulated timeline with its own scheduler;
  * each query is a coroutine (Python generator, see search.py protocol);
  * a cache miss suspends the coroutine; the scheduler switches to a ready
    one; the I/O driver (the SSD model, stand-in for io_uring) completes
    reads asynchronously; completed coroutines return to the ready queue;
  * if no coroutine is ready, the worker busy-polls the completion queue
    (time jumps to the next completion);
  * the batch size B caps concurrently executing queries per worker
    (paper: B = ceil(alpha * I / T)).

Synchronous execution (DiskANN-style) is the degenerate case B=1.

In-flight page reads are deduplicated (the paper's Locked slot state makes
concurrent loads of one record coalesce; we apply the same rule at page
granularity), so a prefetch racing a demand read costs one I/O, not two.
Coalesced reads are never charged an SQE submission (no SQE was issued) and
are counted in ``WorkloadStats.coalesced_reads``.

Record-level coalescing rides on top of that: a coroutine that hits a record
whose buffer-pool slot is LOCKED (another coroutine — possibly on another
worker — began its load) yields ``("load_wait", vid, pool)``.  The scheduler
parks it on the pool's waiter list; when the loader publishes the record via
``pool.finish_load`` the pool queues the waiters on ``pending_resumes`` and
the scheduler turns them into resume events (``WorkloadStats.lock_waits`` /
``coalesced_record_loads``).  No duplicate page read, no duplicate decode.

Cross-query fused dispatch (``EngineConfig.fuse``): coroutines yield their
distance work as ``("score", ScoreRequest)`` ops instead of computing it
inline.  The scheduler parks score requests from all ready coroutines on a
worker in a rendezvous buffer and flushes them as ONE fused DistanceEngine
call per request kind — when the buffered row count reaches ``fuse_rows``, or
when the worker has nothing else to run — charging a single amortized kernel
dispatch for the whole batch.  With fusion off, score ops are executed
immediately (per-query dispatch, PR-1 semantics, bitwise-identical results).

Shared rendezvous (``EngineConfig.shared_rendezvous``, requires ``fuse``):
instead of one rendezvous buffer per worker, ALL workers park their score
ops in a single system-wide buffer.  It flushes when the buffered row count
reaches ``fuse_rows`` (the worker that crossed the budget initiates) or when
EVERY worker is stalled — no coroutine ready anywhere and no query left to
admit — in which case the earliest-clock contributing worker initiates.  The
initiator is charged the per-kind fused dispatches; its coroutines rejoin its
ready queue directly (first one switch-free, exactly the per-worker rule) and
the other workers' coroutines are resumed via completion events at the flush
time.  The fused batch B therefore spans the whole system, not one worker's
in-flight queries.  With one worker the flush points and charges coincide
with the per-worker topology, so results are bitwise identical; the engine
also charges a one-time ``CostModel.table_upload_s`` at the first quantized
dispatch of a run — the register-once pin of the index's resident code
tables on the distance engine (see core.distance), once per DISTINCT table
(the multi-tenant serving plane registers one table per tenant, or one
combined table for all of them).

Flush/I-O overlap (``EngineConfig.overlap_flush``, shared rendezvous only):
when every worker is stalled and a completion belonging to ANOTHER worker is
already due, the stall flush is issued immediately — the fused dispatch
overlaps with that worker's I/O drain — instead of first applying the
completion and letting its coroutine run ahead of the flush.  The
initiator's own due completions are always applied first (at one worker
every completion is its own, so the flag cannot change one-worker results —
the existing bitwise-parity contract).  ``WorkloadStats.overlap_flushes``
counts the flushes that engaged the overlap.

Multi-tenant serving (core.serving): score requests carry the registered
table they index (``ScoreRequest.qb``) and a diagnostic tenant tag; the
flush core groups by ``distance.request_group_key`` so one rendezvous flush
routes each (kind, table) group to its own fused call —
``WorkloadStats.cross_tenant_flushes`` counts flushes spanning tenants.

Sharded scatter-gather (``Engine(shards=ShardRouter(...))``, core.sharding):
the index image is split across N engine shards — each shard owns a page
range (and so the records on it), a fresh SSD, a rendezvous buffer, and a
clock.  Coroutines yield ``("scatter", ShardScatter)`` instead of
``("score", ...)``: the router splits the request's rows by owning shard and
each slice executes on ITS shard — inline on the shard clock when fusion is
off, or parked in the shard's rendezvous buffer when fusion is on (flushed
at ``fuse_rows`` per shard, or when every worker stalls — mirroring the
shared-rendezvous stall rule).  A ``ScatterJoin`` reassembles the slices in
row order and resumes the coroutine at the max part completion plus one
``CostModel.shard_merge_s`` collective when more than one shard contributed
(the dist_search all_gather + top_k merge, lifted into the engine).  Page
reads route to the owning shard's SSD.  A scatter whose rows all land on one
shard passes the ORIGINAL request through — with one shard every scatter
does, every flush charge lands at the same time on the same clock, and the
sharded engine is bitwise identical to the unsharded one (the S=1 parity
contract; tests/test_sharding.py, benchmarks/bench_sharded.py).  Resident
code tables upload once per (shard, table): each shard pins its own copy.

Fused on-device beam steps (``SearchContext.device_beam``, core.beam):
coroutines yield ``("beam", BeamRequest)`` ops — score + visited-mask +
top-k merge + frontier selection execute as ONE fused DistanceEngine call
(``beam_step_many``) whose reply is the next FRONTIER, not raw distances.
Beam ops park in the same rendezvous buffers as score ops (per-worker,
shared, or per-shard) and flush under the same rules; each fused beam group
charges ``CostModel.beam_step_s`` once per flush via the ``fused_batch_s``
kind plumbing.  On the sharded plane a multi-shard beam scatter sends each
owning shard a ``BeamShardPart`` (score locally, return the local top-L);
the join merges the slices (``ScatterJoin.merge_beam_candidates``) and the
engine folds them into the resident state exactly once via
``DistanceEngine.beam_finalize``.  ``WorkloadStats.dist_downloads`` counts
the replies that still ship raw distances — beam replies do not, which is
the whole point: downloads/query drops from ~hops x kinds to ~hops.

SLA-aware scheduling (``EngineConfig.scheduler``, core.scheduling): with
``scheduler="sla"`` and an ``SlaPlan`` handed to ``Engine.run``, queries
carry arrival times (withheld from admission until their "arrival" event
fires) and per-tenant deadlines; admission, per-worker ready picks, and
stall-flush initiator selection all order by deadline (EDF), and the plan's
feedback controller may steer the ``fuse_rows`` budget online.  The default
``scheduler="rr"`` keeps every pick FIFO and is bitwise identical to the
pre-SLA engine; a plan with arrivals additionally makes ``latencies``
measure completion-minus-arrival (queue wait included — the old
dispatch-relative number is kept in ``WorkloadStats.service_times``), while
plan=None keeps the old accounting bitwise.  See docs/scheduling.md.
"""

from __future__ import annotations

import dataclasses
import heapq
from collections import deque
from collections.abc import Callable

import numpy as np

from repro.core import beam as beam_mod
from repro.core import distance as distance_mod
from repro.core.scheduling import SCHEDULERS
from repro.core.sim import SSD, CostModel, WorkloadStats


@dataclasses.dataclass
class EngineConfig:
    n_workers: int = 1
    batch_size: int = 8        # B: coroutines in flight per worker
    page_size: int = 4096
    fuse: bool = False         # cross-query fused score dispatch
    fuse_rows: int = 256       # flush the rendezvous buffer at this row budget
    shared_rendezvous: bool = False  # one system-wide rendezvous buffer
                                     # (off = per-worker buffers, PR-2
                                     # semantics; needs fuse)
    overlap_flush: bool = False  # overlap the shared-rendezvous stall flush
                                 # with ANOTHER worker's in-flight completions
                                 # (off = drain the I/O first; at one worker
                                 # every completion is the initiator's own, so
                                 # the flag cannot change results there)
    scheduler: str = "rr"        # ready-queue policy: "rr" = FIFO round-robin
                                 # (bitwise the pre-SLA engine); "sla" = EDF —
                                 # admission, ready picks and stall-flush
                                 # initiator selection order by deadline slack
                                 # from the run's SlaPlan (core.scheduling)


class _Worker:
    __slots__ = ("wid", "t", "ready", "active", "deferred_charge", "done_queries",
                 "pending", "pending_rows", "free_gens")

    def __init__(self, wid: int):
        self.wid = wid
        self.t = 0.0
        self.ready: deque = deque()  # (gen, resume_value, qid, charge_switch)
        self.active = 0
        self.deferred_charge = 0.0
        self.done_queries = 0
        self.pending: list = []      # rendezvous buffer: (gen, qid, ScoreRequest)
        self.pending_rows = 0
        # "sla" mode only: gen ids this worker's LAST flush resumed.  The
        # switch-free credit of a flush belongs to whichever of them the EDF
        # pick runs FIRST — per-entry flags (the rr rule) would let a resume
        # that ran only after an intervening coroutine skip its switch charge.
        self.free_gens: set | None = None


class Engine:
    """Runs a workload of query coroutines over the simulated hardware."""

    def __init__(
        self,
        store,                      # PageStore: pid -> bytes (data plane)
        ssd: SSD,
        cost: CostModel,
        config: EngineConfig,
        dist=None,                  # DistanceEngine executing score ops
        qb=None,                    # QuantizedBase for estimate/refine kinds
        hbm=None,                   # core.hbm.HbmTier: HBM record-cache tier
                                    # (None == off, the bitwise-parity default)
        schedule=None,              # analysis.explore.SchedulePolicy: permutes
                                    # equal-time scheduling ties and records
                                    # the decision trace (None == identity
                                    # order, bitwise the pre-seam engine)
        verify=None,                # analysis.protocol.ProtocolChecker: runs
                                    # cheap pool invariants at flush
                                    # boundaries and end-of-run detectors
        shards=None,                # core.sharding.ShardRouter: the sharded
                                    # scatter-gather plane (None == unsharded;
                                    # fresh per run, like the SSD)
    ):
        self.store = store
        self.ssd = ssd
        self.cost = cost
        self.config = config
        self.dist = dist
        self.qb = qb
        self.hbm = hbm
        self.schedule = schedule
        self.verify = verify
        self.shards = shards

    def run(
        self,
        make_coroutine: Callable[[int, np.ndarray], object],
        queries: np.ndarray,
        sla=None,                   # core.scheduling.SlaPlan: arrival times,
                                    # deadlines and the feedback controller
                                    # (None == every query arrives at t=0 and
                                    # latency == service time, bitwise the
                                    # pre-SLA engine)
    ) -> tuple[list, WorkloadStats]:
        cfg = self.config
        assert cfg.scheduler in SCHEDULERS, f"unknown scheduler {cfg.scheduler!r}"
        if self.dist is None:
            self.dist = distance_mod.get_engine()
        # schedule-exploration / protocol-verification seams (both None in
        # production: the identity schedule and no checker are bitwise the
        # pre-seam engine — tests/test_analysis.py pins that parity)
        sched = self.schedule
        verify = self.verify
        router = self.shards
        plan = sla
        edf = cfg.scheduler == "sla"
        deadlines = plan.deadlines if plan is not None else None
        controller = plan.controller if plan is not None else None
        workers = [_Worker(i) for i in range(cfg.n_workers)]
        query_queue: deque[int] = deque(range(len(queries)))
        start_time: dict[int, float] = {}
        results: list = [None] * len(queries)
        stats = WorkloadStats(n_queries=len(queries))
        # HBM tier counters are cumulative on the tier (it outlives runs, like
        # the pool): snapshot at start, report per-run deltas at the end —
        # the same rule PR 5 established for dist_uploads / pool pressure.
        hbm_c0 = self.hbm.counters() if self.hbm is not None else None

        # global completion-event heap: (time, rank, seq, kind, payload).
        # rank is 0 everywhere without a schedule policy — ordering is then
        # (time, seq), exactly the pre-seam heap; a policy assigns seeded
        # ranks so EQUAL-TIME events drain in a permuted order (actions at
        # distinct times never reorder: the explorer perturbs only ties).
        events: list = []
        seq = 0
        # in-flight page reads: pid -> completion_time (dedup window), with a
        # companion heap so completed entries are pruned instead of growing
        # one-per-page-ever-read over a long run
        inflight: dict[int, float] = {}
        inflight_heap: list[tuple[float, int]] = []
        token_counter = 0
        # token -> (pid, completion); owner tracking so a coroutine finishing
        # with outstanding tokens cannot leak its entries
        token_info: dict[int, tuple[int, float]] = {}
        tokens_by_query: dict[int, set[int]] = {}
        # exposed for tests (leak regression checks inspect them after run)
        self._inflight = inflight
        self._token_info = token_info
        self._tokens_by_query = tokens_by_query

        def issue_read(
            t: float, pid: int, worker: _Worker, charge_submit: bool = False
        ) -> tuple[float, float]:
            """Submit one page read with in-flight dedup.  Returns (completion
            time, new worker time): coalescing with an already in-flight page
            submits no SQE, so no ``io_submit_s`` is charged for it; genuinely
            issued reads pay SQE prep BEFORE the device sees the command (only
            when ``charge_submit`` — the submit/submit_cb ops charge their
            batch up front instead)."""
            # Prune dedup entries whose completion no future read can observe.
            # A worker only matters for the horizon if it can still issue
            # reads: it has active coroutines, or queries remain to admit —
            # including queries that have not ARRIVED yet (an idle drained
            # worker would otherwise pin the horizon at its final time and
            # the dict would grow one entry per page forever).
            if query_queue or n_unarrived:
                horizon = min(w.t for w in workers)
            else:
                horizon = min((w.t for w in workers if w.active > 0),
                              default=float("inf"))
            while inflight_heap and inflight_heap[0][0] <= horizon:
                c, p = heapq.heappop(inflight_heap)
                if inflight.get(p) == c:
                    del inflight[p]
            comp = inflight.get(pid)
            if comp is not None and comp > t:
                stats.coalesced_reads += 1
                return comp, t
            if charge_submit:
                t += self.cost.io_submit_s
            # sharded plane: the read executes on the device of the shard
            # that owns the page (disjoint page ranges, so the global
            # in-flight dedup above stays correct across shards)
            dev = self.ssd if router is None else router.ssd_for_page(pid)
            comp = dev.submit(t, cfg.page_size)
            inflight[pid] = comp
            heapq.heappush(inflight_heap, (comp, pid))
            stats.io_count += 1
            stats.io_bytes += cfg.page_size
            return comp, t

        def drop_query_tokens(qid: int) -> None:
            """Forget any tokens a finished coroutine never waited on."""
            for tok in tokens_by_query.pop(qid, ()):
                token_info.pop(tok, None)

        def push_event(time: float, kind: str, payload) -> None:
            nonlocal seq
            rank = 0 if sched is None else sched.event_rank(seq)
            heapq.heappush(events, (time, rank, seq, kind, payload))
            seq += 1

        # Open-loop arrivals (SlaPlan): a query with arrival > 0 is withheld
        # from the admission queue until its "arrival" event fires — the
        # busy-poll branch of the global loop then jumps time to it exactly
        # like an I/O completion.  All-zero arrivals (and plan=None) seed the
        # full queue up front, the pre-SLA admission order.
        n_unarrived = 0
        if plan is not None:
            arr = plan.arrivals
            assert arr.shape == (len(queries),), (
                f"SlaPlan has {arr.shape[0]} arrivals for {len(queries)} queries"
            )
            if np.any(arr > 0.0):
                query_queue = deque(
                    int(q) for q in np.flatnonzero(arr <= 0.0)
                )
                for q in np.flatnonzero(arr > 0.0):
                    push_event(float(arr[q]), "arrival", int(q))
                    n_unarrived += 1

        def fuse_budget() -> int:
            """The rendezvous flush row budget — static ``cfg.fuse_rows``
            unless the SLA feedback controller is steering it online."""
            if controller is None:
                return cfg.fuse_rows
            return controller.fuse_rows(cfg.fuse_rows)

        def qdeadline(qid: int) -> float:
            return float(deadlines[qid]) if deadlines is not None else float("inf")

        def pick_query(w: _Worker) -> int:
            """Pop the next query to admit: FIFO in rr; earliest deadline in
            sla (EDF starts at admission — a slack-critical query must not
            sit behind the hot tenant's backlog in the arrival queue)."""
            if not edf or deadlines is None or len(query_queue) == 1:
                return query_queue.popleft()
            best = None
            best_key = None
            for q in query_queue:
                key = (qdeadline(q), q)
                if best_key is None or key < best_key:
                    best, best_key = q, key
            if sched is not None:
                tied = [q for q in query_queue if qdeadline(q) == best_key[0]]
                if len(tied) > 1:
                    sched.ties["slack"] += 1
                    best = min(tied, key=lambda q: (sched.slack_rank(q), q))
            query_queue.remove(best)
            return best

        def pop_ready(w: _Worker) -> tuple:
            """Pop the next ready entry: FIFO in rr (bitwise the pre-SLA
            engine, per-entry switch flags untouched); in sla, the entry with
            the earliest deadline (queue position breaks exact ties — or the
            explorer's slack_rank when a schedule policy is attached, since
            equal-slack picks are a genuine scheduling race).  The sla pop
            also resolves the flush switch-free credit: the FIRST pop after a
            flush is free iff it resumes one of that flush's own coroutines
            (see _Worker.free_gens)."""
            if not edf:
                return w.ready.popleft()
            if deadlines is None or len(w.ready) == 1:
                entry = w.ready.popleft()
            else:
                best_i = 0
                best_key = (qdeadline(w.ready[0][2]), 0)
                for i in range(1, len(w.ready)):
                    key = (qdeadline(w.ready[i][2]), i)
                    if key < best_key:
                        best_i, best_key = i, key
                if sched is not None:
                    tied = [
                        i for i in range(len(w.ready))
                        if qdeadline(w.ready[i][2]) == best_key[0]
                    ]
                    if len(tied) > 1:
                        sched.ties["slack"] += 1
                        best_i = min(
                            tied,
                            key=lambda i: (sched.slack_rank(w.ready[i][2]), i),
                        )
                entry = w.ready[best_i]
                del w.ready[best_i]
            gen, value, qid, charge_switch = entry
            if w.free_gens is not None:
                # one credit per flush, consumed by the first pop whatever it
                # is: free only when it IS one of the flush's own resumes
                charge_switch = id(gen) not in w.free_gens
                w.free_gens = None
            return gen, value, qid, charge_switch

        def parked_deadline(w: _Worker) -> float:
            """Earliest deadline among the work a stalled worker has parked
            in the shared/sharded rendezvous — the sla stall-flush initiator
            key (inf in rr / without deadlines: selection degenerates to the
            earliest-clock rule)."""
            if not edf or deadlines is None:
                return float("inf")
            best = float("inf")
            for wk, _, qid, _ in shared_pending:
                if wk is w:
                    best = min(best, qdeadline(qid))
            if router is not None:
                for plist in router.pending:
                    for join, _, _ in plist:
                        if join.worker is w:
                            best = min(best, qdeadline(join.qid))
            return best

        # buffer pools with coroutines parked on LOCKED slots (load_wait op),
        # keyed by id so registration order — not hash order — drives the
        # resume drain; their pending_resumes queues are drained after every
        # action that can publish a record (worker step or prefetch callback)
        wait_pools: dict[int, object] = {}

        def drain_pool_resumes(now: float) -> None:
            """Turn records published by finish_load into resume events for
            the coroutines parked on the LOCKED slot — record-level
            coalescing across all workers.  The pending check keeps the
            common (nothing-published) case allocation-free on the hot
            scheduling path."""
            for pool in wait_pools.values():
                if not pool.pending_resumes:
                    continue
                for (wkr, gen, qid), rec in pool.take_resumes():
                    if rec is not None:
                        stats.coalesced_record_loads += 1
                    push_event(now, "resume", (wkr, gen, rec, qid))

        def apply_due_events(now: float) -> None:
            """Apply completions (callbacks / worker resumes / query
            arrivals) due by `now`."""
            nonlocal n_unarrived
            while events and events[0][0] <= now:
                time, _, _, kind, payload = heapq.heappop(events)
                if sched is not None and events and events[0][0] == time:
                    sched.ties["event"] += 1  # a genuinely permutable tie
                if kind == "callback":
                    cb, pid, issuer = payload
                    cb(pid, self.store.read_page(pid))
                    issuer.deferred_charge += self.cost.record_decode_s
                    # a prefetch callback may finish_load a LOCKED slot:
                    # resume its waiters at the completion time
                    drain_pool_resumes(time)
                elif kind == "resume":
                    worker, gen, value, qid = payload
                    worker.t = max(worker.t, time)
                    worker.ready.append((gen, value, qid, True))
                elif kind == "arrival":
                    # the query is now admissible; a worker clamps its clock
                    # to the arrival time when it actually picks it up
                    query_queue.append(payload)
                    n_unarrived -= 1

        # one-time resident-table pin: the first dispatch of a run that
        # touches a quantized index charges the register-once upload of its
        # code tables to the distance engine (core.distance.register_index).
        # One charge per DISTINCT table — a single-tenant run charges exactly
        # once (the PR-4 rule); the serving plane charges once per registered
        # tenant table (once total when the tenants share a combined table).
        uploaded_tables: set = set()

        def upload_charge_s(reqs, shard: int | None = None) -> float:
            """Seconds of one-time table pins owed by this batch.  On the
            sharded plane each shard keeps its own distance executor, so the
            pin is once per (shard, table) — with one shard that degenerates
            to once per table, the unsharded rule."""
            charge = 0.0
            for r in reqs:
                if r.kind not in ("estimate", "refine"):
                    continue
                qb = r.qb if r.qb is not None else self.qb
                if qb is None:
                    continue
                key = id(qb) if shard is None else (shard, id(qb))
                if key not in uploaded_tables:
                    uploaded_tables.add(key)
                    charge += self.cost.table_upload_s
            return charge

        def charge_upload(w: _Worker, reqs) -> None:
            w.t += upload_charge_s(reqs)

        def hbm_split(reqs) -> tuple[dict, dict]:
            """Resolve each id-payload refine request against the HBM tier:
            ``splits`` maps ``id(req)`` to its (hit_mask, slots) partition;
            ``rebates`` accumulates, per dispatch group, the simulated seconds
            the slot-gather saves over the registered-table refine (hit rows
            are charged ``hbm_refine_ext`` instead of ``refine_ext``)."""
            splits: dict[int, tuple] = {}
            rebates: dict[tuple, float] = {}
            for r in reqs:
                if r.kind != "refine" or isinstance(r.payload, tuple):
                    continue
                rqb = r.qb if r.qb is not None else self.qb
                if rqb is None or not self.hbm.covers(rqb):
                    continue
                sp = self.hbm.peek_split(np.asarray(r.payload, dtype=np.int64))
                if sp is None:
                    continue
                mask, slots = sp
                splits[id(r)] = (mask, slots)
                key = distance_mod.request_group_key(r, self.qb)
                per_row = max(
                    0.0,
                    self.cost.refine_ext(rqb.dim)
                    - self.cost.hbm_refine_ext(rqb.dim),
                )
                rebates[key] = rebates.get(key, 0.0) + per_row * int(mask.sum())
            return splits, rebates

        def dispatch_batch(initiator: _Worker, reqs: list) -> list:
            """The flush core both rendezvous topologies share: one fused
            dispatch per request group present (``distance.request_group_key``
            — per kind, and per registered table across tenants), each charged
            a single amortized dispatch to the initiating worker (plus the
            one-time table uploads), stats updated.  Returns the per-request
            results.  Keeping this in ONE place is what guarantees the
            1-worker bitwise parity between the topologies.

            With the HBM tier on, refine requests are split against the cache
            slots first (hit rows gather on-device at ``hbm_refine_ext`` cost,
            charged as a rebate on the group's flops), and the scatter DMA
            installing the records staged since the LAST boundary overlaps
            this flush's fused dispatch: only ``hbm_scatter_s`` net of the
            dispatch time is charged (double buffering — compute step t hides
            the installs for step t+1)."""
            charge_upload(initiator, reqs)
            splits = rebates = None
            if self.hbm is not None:
                splits, rebates = hbm_split(reqs)
            flop_by_group: dict[tuple, float] = {}
            tenants_by_group: dict[tuple, set] = {}
            for r in reqs:
                key = distance_mod.request_group_key(r, self.qb)
                flop_by_group[key] = flop_by_group.get(key, 0.0) + r.flop_s
                tenants_by_group.setdefault(key, set()).add(r.tenant)
            dispatch_s = 0.0
            for key, flop_s in flop_by_group.items():
                if rebates:
                    flop_s = max(0.0, flop_s - rebates.get(key, 0.0))
                d = self.cost.fused_batch_s(flop_s, kind=key[0])
                initiator.t += d
                dispatch_s += d
            outs = distance_mod.execute_requests(
                self.dist, self.qb, reqs, hbm=self.hbm, splits=splits
            )
            stats.score_flushes += len(flop_by_group)
            stats.score_requests += len(reqs)
            stats.score_rows += sum(r.rows for r in reqs)
            n_beam = sum(
                1 for r in reqs if isinstance(r, beam_mod.BeamRequest)
            )
            stats.beam_ops += n_beam
            stats.beam_rows += sum(
                r.rows for r in reqs if isinstance(r, beam_mod.BeamRequest)
            )
            stats.beam_flushes += sum(
                1 for key in flop_by_group if key[0].startswith("beam")
            )
            # beam replies ship a frontier, not distances — everything else
            # in the flush still downloads its raw per-row result
            stats.dist_downloads += len(reqs) - n_beam
            # cross-tenant FUSION means one dispatch group genuinely spanned
            # tenants — a flush whose per-tenant requests were routed to
            # separate per-table calls does not count
            if any(len(ts) > 1 for ts in tenants_by_group.values()):
                stats.cross_tenant_flushes += 1
            if self.hbm is not None:
                n_scattered = self.hbm.scatter_staged()
                if n_scattered:
                    initiator.t += max(
                        0.0, self.cost.hbm_scatter_s - dispatch_s
                    )
                    if sched is not None:
                        sched.note(("scatter", n_scattered))
            if verify is not None:
                verify.at_flush()
            return outs

        def flush_scores(w: _Worker) -> None:
            """Flush the per-worker rendezvous buffer: every parked coroutine
            returns to the ready queue with its result."""
            pend, w.pending, w.pending_rows = w.pending, [], 0
            outs = dispatch_batch(w, [r for _, _, r in pend])
            for i, ((gen, qid, _), val) in enumerate(zip(pend, outs)):
                # the first resume continues straight out of the fused
                # dispatch — no switch charge, so a rendezvous of one costs
                # exactly what inline execution costs; every later resume is
                # a genuine coroutine switch and pays for it.  In sla mode
                # the EDF pick decides which resume runs first, so the credit
                # moves to pop time (free_gens) instead of entry flags.
                w.ready.append((gen, val, qid, True if edf else i > 0))
            if edf:
                w.free_gens = {id(gen) for gen, _, _ in pend}

        # system-wide shared rendezvous: (worker, gen, qid, req) from ALL
        # workers, flushed at fuse_rows or when every worker is stalled
        shared = cfg.fuse and cfg.shared_rendezvous
        shared_pending: list = []
        shared_rows = 0

        def flush_shared(initiator: _Worker) -> None:
            """Flush the system-wide rendezvous buffer.  The initiator (the
            worker that crossed the row budget, or the earliest-clock
            contributor when every worker stalled) drives the fused dispatch
            and is charged for it; its own coroutines rejoin its ready queue
            directly — the first without a switch charge, exactly the
            per-worker flush rule, so a one-worker system is bitwise
            identical to per-worker fusion — while other workers' coroutines
            are resumed via events at the flush completion time."""
            nonlocal shared_pending, shared_rows
            pend, shared_pending, shared_rows = shared_pending, [], 0
            outs = dispatch_batch(initiator, [r for _, _, _, r in pend])
            first_own = True
            own_gens = set()
            for (wkr, gen, qid, _), val in zip(pend, outs):
                if wkr is initiator:
                    wkr.ready.append(
                        (gen, val, qid, True if edf else not first_own)
                    )
                    first_own = False
                    own_gens.add(id(gen))
                else:
                    push_event(initiator.t, "resume", (wkr, gen, val, qid))
            if edf and own_gens:
                initiator.free_gens = own_gens

        def finish_beam_join(join) -> object:
            """Resolve a completed beam join into its BeamResult: the
            single-owner passthrough already executed the ORIGINAL request
            (the S=1 parity lever — bitwise the unsharded beam step);
            multi-shard joins merge the per-shard local top-Ls and fold them
            into the resident state exactly once (pending inserts/marks
            applied at the finalize, never per part)."""
            if join.direct is not None:
                return join.direct
            req = join.beam_req
            ids, ds = join.merge_beam_candidates()
            rqb = req.qb if req.qb is not None else self.qb
            return self.dist.beam_finalize(rqb, req, ids, ds)

        def flush_sharded(initiator: _Worker, only=None) -> None:
            """Flush the per-shard rendezvous buffers — all of them at a
            stall, or the budget-crossing subset ``only``.  Each shard's
            parked slices dispatch on ITS OWN clock, starting no earlier than
            the initiator's time, so shards execute in parallel with each
            other.  A join whose every part completed resumes its coroutine
            at the max part completion plus one merge collective (multi-shard
            joins only); the initiator's own completed joins rejoin its ready
            queue directly — the first switch-free, exactly the
            ``flush_shared`` rule, which with ONE shard makes the charge
            sequence and resume order bitwise identical to the unsharded
            shared-rendezvous flush (the S=1 parity contract)."""
            t0 = initiator.t
            done: list = []
            shard_ids = range(router.n_shards) if only is None else only
            for s in shard_ids:
                pend = router.pending[s]
                if not pend:
                    continue
                router.pending[s] = []
                router.pending_rows[s] = 0
                reqs = [r for _, r, _ in pend]
                st = max(router.shard_t[s], t0)
                st += upload_charge_s(reqs, shard=s)
                flop_by_group: dict[tuple, float] = {}
                tenants_by_group: dict[tuple, set] = {}
                for r in reqs:
                    key = distance_mod.request_group_key(r, self.qb)
                    flop_by_group[key] = flop_by_group.get(key, 0.0) + r.flop_s
                    tenants_by_group.setdefault(key, set()).add(r.tenant)
                for key, flop_s in flop_by_group.items():
                    st += self.cost.fused_batch_s(flop_s, kind=key[0])
                outs = distance_mod.execute_requests(self.dist, self.qb, reqs)
                router.shard_t[s] = st
                stats.score_flushes += len(flop_by_group)
                stats.score_requests += len(reqs)
                stats.score_rows += sum(r.rows for r in reqs)
                stats.beam_flushes += sum(
                    1 for key in flop_by_group if key[0].startswith("beam")
                )
                stats.shard_flushes += 1
                if any(len(ts) > 1 for ts in tenants_by_group.values()):
                    stats.cross_tenant_flushes += 1
                for (join, _, ridx), val in zip(pend, outs):
                    if join.put(ridx, val, st):
                        done.append(join)
                if verify is not None:
                    verify.at_flush()
            first_own = True
            own_gens = set()
            for join in done:
                t_done = join.t_done
                if join.n_parts > 1:
                    t_done += self.cost.shard_merge_s
                    stats.shard_merges += 1
                if join.beam_req is not None:
                    merged = finish_beam_join(join)
                    stats.beam_ops += 1
                    stats.beam_rows += join.beam_req.rows
                else:
                    merged = join.merge()
                    stats.dist_downloads += 1
                if join.worker is initiator:
                    initiator.t = max(initiator.t, t_done)
                    initiator.ready.append(
                        (join.gen, merged, join.qid,
                         True if edf else not first_own)
                    )
                    first_own = False
                    own_gens.add(id(join.gen))
                else:
                    push_event(
                        t_done, "resume",
                        (join.worker, join.gen, merged, join.qid),
                    )
            if edf and own_gens:
                initiator.free_gens = own_gens

        def run_worker_action(w: _Worker) -> None:
            """One scheduling action on worker w (paper Fig. 3b loop body)."""
            w.t += w.deferred_charge
            w.deferred_charge = 0.0

            if not w.ready:
                if query_queue and w.active < cfg.batch_size:
                    qid = pick_query(w)
                    gen = make_coroutine(qid, queries[qid])
                    w.active += 1
                    if plan is not None:
                        # an idle worker picking up a not-yet-arrived... —
                        # cannot happen (arrival events gate the queue) —
                        # but a worker whose clock is BEHIND the arrival
                        # idles until it: dispatch never precedes arrival
                        w.t = max(w.t, float(plan.arrivals[qid]))
                    start_time[qid] = w.t
                    w.ready.append((gen, None, qid, True))
                elif w.pending:
                    # nothing else can run: flush the rendezvous buffer so the
                    # parked scorers make progress.  (Shared topology: a lone
                    # stalled worker must NOT flush — the global loop flushes
                    # only when EVERY worker is stalled.)
                    flush_scores(w)
                else:
                    return

            gen, value, qid, charge_switch = pop_ready(w)
            if charge_switch:
                w.t += self.cost.coroutine_switch_s
                stats.coroutine_switches += 1

            while True:
                try:
                    op = gen.send(value)
                except StopIteration as fin:
                    drain_pool_resumes(w.t)  # publishes from this final step
                    results[qid] = fin.value
                    service = w.t - start_time[qid]
                    if plan is None:
                        # no arrival schedule: latency == service time, the
                        # pre-SLA numbers, bitwise
                        latency = service
                    else:
                        # latency runs from ARRIVAL: queue wait (the tail's
                        # dominant term under burst) now reaches p99
                        latency = w.t - float(plan.arrivals[qid])
                    stats.sum_latency_s += latency
                    stats.latencies.append(latency)
                    stats.latency_qids.append(qid)
                    stats.sum_service_s += service
                    stats.service_times.append(service)
                    stats.queue_wait_s += latency - service
                    if deadlines is not None:
                        dl = float(deadlines[qid])
                        if w.t <= dl:
                            stats.deadline_hits += 1
                        else:
                            stats.deadline_misses += 1
                            stats.lateness_s += w.t - dl
                    if plan is not None:
                        plan.on_complete(qid, w.t, latency)
                    drop_query_tokens(qid)
                    w.active -= 1
                    w.done_queries += 1
                    return

                # a finish_load in the step that produced this op resumes its
                # waiters AT the publish time, before later ops advance w.t
                if wait_pools:
                    drain_pool_resumes(w.t)

                kind = op[0]
                if kind == "compute":
                    w.t += op[1]
                    value = None
                elif kind == "score":
                    req = op[1]
                    if shared:
                        nonlocal shared_rows
                        shared_pending.append((w, gen, qid, req))
                        shared_rows += req.rows
                        if shared_rows >= fuse_budget():
                            flush_shared(w)
                        return  # parked in the system-wide rendezvous
                    if cfg.fuse:
                        w.pending.append((gen, qid, req))
                        w.pending_rows += req.rows
                        if w.pending_rows >= fuse_budget():
                            flush_scores(w)
                        return  # parked in the rendezvous buffer
                    # fusion off: execute immediately (per-query dispatch)
                    charge_upload(w, (req,))
                    if self.hbm is not None:
                        splits, rebates = hbm_split([req])
                        key = distance_mod.request_group_key(req, self.qb)
                        flop_s = max(
                            0.0, req.flop_s - rebates.get(key, 0.0)
                        ) if rebates else req.flop_s
                        d = self.cost.fused_batch_s(flop_s, kind=key[0])
                        w.t += d
                        value = distance_mod.execute_requests(
                            self.dist, self.qb, [req],
                            hbm=self.hbm, splits=splits,
                        )[0]
                        n_scattered = self.hbm.scatter_staged()
                        if n_scattered:
                            w.t += max(0.0, self.cost.hbm_scatter_s - d)
                            if sched is not None:
                                sched.note(("scatter", n_scattered))
                    else:
                        w.t += self.cost.fused_batch_s(req.flop_s)
                        value = distance_mod.execute_requests(
                            self.dist, self.qb, [req]
                        )[0]
                    stats.dist_downloads += 1
                    if verify is not None:
                        # the per-query dispatch is the degenerate flush
                        # boundary (fusion off): same invariant cadence
                        verify.at_flush()
                elif kind == "beam":
                    req = op[1]
                    if shared:
                        shared_pending.append((w, gen, qid, req))
                        shared_rows += req.rows
                        if shared_rows >= fuse_budget():
                            flush_shared(w)
                        return  # parked in the system-wide rendezvous
                    if cfg.fuse:
                        w.pending.append((gen, qid, req))
                        w.pending_rows += req.rows
                        if w.pending_rows >= fuse_budget():
                            flush_scores(w)
                        return  # parked in the rendezvous buffer
                    # fusion off: one fused beam launch for this query alone
                    # (still a single exchange — the reply is the frontier)
                    charge_upload(w, (req,))
                    key = distance_mod.request_group_key(req, self.qb)
                    w.t += self.cost.fused_batch_s(req.flop_s, kind=key[0])
                    value = distance_mod.execute_requests(
                        self.dist, self.qb, [req]
                    )[0]
                    stats.beam_ops += 1
                    stats.beam_flushes += 1
                    stats.beam_rows += req.rows
                    if verify is not None:
                        verify.at_flush()
                elif kind == "scatter":
                    sc = op[1]
                    parts = router.split(sc)
                    stats.scatter_ops += 1
                    is_beam = isinstance(sc.req, beam_mod.BeamRequest)
                    if cfg.fuse:
                        # park each slice in its owning shard's rendezvous
                        # buffer; flush every shard this scatter pushed over
                        # the row budget (with one shard: exactly the shared
                        # rendezvous budget rule)
                        join = router.make_join(
                            w, gen, qid, sc.req.rows, len(parts),
                            beam_req=sc.req if is_beam else None,
                        )
                        crossed = []
                        for s, sub, ridx in parts:
                            router.pending[s].append((join, sub, ridx))
                            router.pending_rows[s] += sub.rows
                            if router.pending_rows[s] >= fuse_budget():
                                crossed.append(s)
                        if crossed:
                            flush_sharded(w, only=crossed)
                        return  # parked in the per-shard rendezvous buffers
                    # fusion off: each slice dispatches inline on its owning
                    # shard's clock; the worker resumes at the last slice's
                    # completion plus the merge collective (multi-shard only)
                    join = (
                        router.make_join(
                            w, gen, qid, sc.req.rows, len(parts),
                            beam_req=sc.req,
                        ) if is_beam else None
                    )
                    t0 = w.t
                    comp = t0
                    merged = None
                    out_rows = None
                    for s, sub, ridx in parts:
                        st = max(router.shard_t[s], t0)
                        st += upload_charge_s((sub,), shard=s)
                        if is_beam:
                            gkey = distance_mod.request_group_key(sub, self.qb)
                            st += self.cost.fused_batch_s(
                                sub.flop_s, kind=gkey[0]
                            )
                        else:
                            st += self.cost.fused_batch_s(sub.flop_s)
                        val = distance_mod.execute_requests(
                            self.dist, self.qb, [sub]
                        )[0]
                        router.shard_t[s] = st
                        comp = max(comp, st)
                        if join is not None:
                            join.put(ridx, val, st)
                        elif ridx is None:
                            merged = val
                        else:
                            if out_rows is None:
                                out_rows = np.empty(
                                    sc.req.rows, dtype=np.asarray(val).dtype
                                )
                            out_rows[ridx] = val
                    if len(parts) > 1:
                        comp += self.cost.shard_merge_s
                        stats.shard_merges += 1
                    w.t = comp
                    if join is not None:
                        value = finish_beam_join(join)
                        stats.beam_ops += 1
                        stats.beam_flushes += len(parts)
                        stats.beam_rows += sc.req.rows
                    else:
                        value = merged if merged is not None else out_rows
                        stats.dist_downloads += 1
                    if verify is not None:
                        # per-query sharded dispatch: the degenerate flush
                        # boundary, same cadence as the fuse-off score path
                        verify.at_flush()
                elif kind == "load_wait":
                    _, vid, pool = op
                    if pool.is_loading(vid):
                        # park on the LOCKED slot; finish_load resumes us with
                        # the record (one I/O for the whole waiter cohort)
                        wait_pools[id(pool)] = pool
                        pool.add_waiter(vid, (w, gen, qid))
                        stats.lock_waits += 1
                        return  # suspended on the in-flight load
                    # window already closed (published or aborted) before the
                    # scheduler saw the op: resolve inline, stat-free — the
                    # searcher already counted this access as a miss
                    value = pool.peek_record(vid)
                elif kind == "read":
                    pids = op[1]
                    comp = 0.0
                    for pid in pids:
                        c, w.t = issue_read(w.t, pid, w, charge_submit=True)
                        comp = max(comp, c)
                    pages = {pid: self.store.read_page(pid) for pid in pids}
                    push_event(comp, "resume", (w, gen, pages, qid))
                    return  # suspended
                elif kind == "submit_cb":
                    _, pids, cb = op
                    w.t += self.cost.io_submit_s
                    for pid in pids:
                        comp, _ = issue_read(w.t, pid, w)
                        push_event(comp, "callback", (cb, pid, w))
                    value = None
                elif kind == "submit":
                    nonlocal token_counter
                    pids = op[1]
                    w.t += self.cost.io_submit_s
                    tokens = []
                    for pid in pids:
                        comp, _ = issue_read(w.t, pid, w)
                        token_counter += 1
                        token_info[token_counter] = (pid, comp)
                        tokens_by_query.setdefault(qid, set()).add(token_counter)
                        tokens.append(token_counter)
                    value = tokens
                elif kind == "wait_any":
                    tokens = op[1]
                    # ties on completion time break by token id (submission
                    # order), NOT set iteration order — the relative order of
                    # one query's tokens is the same whether its engine is
                    # isolated or shared with other tenants (serving-plane
                    # isolation contract)
                    tok = min(tokens, key=lambda tk: (token_info[tk][1], tk))
                    pid, comp = token_info.pop(tok)
                    if sched is not None:
                        # the tie-break decision, exposed for replay checks
                        sched.note(("wait_any", qid, pid))
                    toks = tokens_by_query.get(qid)
                    if toks is not None:
                        toks.discard(tok)
                    push_event(
                        comp, "resume", (w, gen, (tok, pid, self.store.read_page(pid)), qid)
                    )
                    return  # suspended
                else:  # pragma: no cover
                    raise ValueError(f"unknown op {kind}")

        # ------------------------------------------------------- global loop
        def pick_initiator(contributors) -> _Worker:
            """The worker that drives a stall flush.  rr: the earliest-clock
            contributor (it would otherwise sit idle) — the pre-SLA rule,
            bitwise.  sla: the contributor whose PARKED work has the earliest
            deadline (ties by clock, then wid) — the flush resumes that
            worker's most-slack-critical coroutine first (switch-free), so
            initiator choice is itself an EDF decision."""
            if edf and deadlines is not None:
                if sched is None:
                    initiator = min(
                        contributors,
                        key=lambda x: (parked_deadline(x), x.t, x.wid),
                    )
                else:
                    initiator = min(
                        contributors,
                        key=lambda x: (
                            parked_deadline(x), x.t, sched.worker_rank(x.wid)
                        ),
                    )
                    d0 = parked_deadline(initiator)
                    if sum(1 for x in contributors
                           if parked_deadline(x) == d0
                           and x.t == initiator.t) > 1:
                        sched.ties["slack"] += 1
                return initiator
            if sched is None:
                return min(contributors, key=lambda x: (x.t, x.wid))
            initiator = min(
                contributors, key=lambda x: (x.t, sched.worker_rank(x.wid))
            )
            if sum(1 for x in contributors if x.t == initiator.t) > 1:
                sched.ties["worker"] += 1
            return initiator

        def runnable(w: _Worker) -> bool:
            # a worker whose only work sits in the SHARED rendezvous is
            # stalled — it cannot flush alone; w.pending is per-worker only
            return (
                bool(w.ready)
                or bool(w.pending)
                or (bool(query_queue) and w.active < cfg.batch_size)
            )

        while True:
            cand = [w for w in workers if runnable(w)]
            next_event_t = events[0][0] if events else None
            if cand:
                if sched is None:
                    w = min(cand, key=lambda x: x.t)
                else:
                    # equal-clock candidates are a genuine scheduling race:
                    # permute which one runs (identity when rank == wid)
                    w = min(cand, key=lambda x: (x.t, sched.worker_rank(x.wid)))
                    if sum(1 for x in cand if x.t == w.t) > 1:
                        sched.ties["worker"] += 1
                if next_event_t is not None and next_event_t <= w.t:
                    apply_due_events(w.t)
                run_worker_action(w)
                # the action may have published LOCKED slots (finish_load on a
                # demand path): reschedule the parked waiters now
                drain_pool_resumes(w.t)
            elif shared_pending:
                # every worker is stalled: flush the system-wide rendezvous.
                # The earliest-clock contributing worker initiates (it would
                # otherwise sit idle) — the fused batch spans all workers.
                contributors = {id(wk): wk for wk, _, _, _ in shared_pending}
                initiator = pick_initiator(contributors.values())
                if next_event_t is not None and next_event_t <= initiator.t:
                    def initiator_due() -> bool:
                        # ANY due completion of the initiator's own forces the
                        # apply-first path — the overlap never reorders the
                        # initiator's own completions past its flush
                        for time, _, _, kind, payload in events:
                            if time > initiator.t:
                                continue
                            wkr = payload[2] if kind == "callback" else payload[0]
                            if wkr is initiator:
                                return True
                        return False

                    if not cfg.overlap_flush or initiator_due():
                        # completions already due would have been applied
                        # before a per-worker flush action; apply them and
                        # re-evaluate — a resumed coroutine runs before the
                        # rendezvous flushes.  The overlap path never reorders
                        # the initiator's OWN completions past its flush — at
                        # one worker every completion is the initiator's, so
                        # overlap on/off is bitwise identical there (the
                        # existing 1-worker parity contract).
                        apply_due_events(initiator.t)
                        continue
                    # overlap the flush with the I/O drain: ANOTHER worker's
                    # completion is in flight — issue the fused dispatch now
                    # instead of after applying it; the completion drains
                    # while the dispatch executes and is applied by the next
                    # scheduling round at its own completion time.
                    stats.overlap_flushes += 1
                    flush_shared(initiator)
                    drain_pool_resumes(initiator.t)
                    continue
                # flush, then continue the initiator in the same breath: its
                # first coroutine resumes straight out of the fused dispatch
                # with no event application in between, exactly the
                # per-worker flush action (1 worker => bitwise identical)
                flush_shared(initiator)
                run_worker_action(initiator)
                drain_pool_resumes(initiator.t)
            elif router is not None and router.has_pending():
                # every worker is stalled: flush EVERY shard's rendezvous
                # buffer (the sharded twin of the shared-rendezvous stall
                # rule).  The earliest-clock worker owning a parked join
                # initiates; each shard dispatches on its own clock from the
                # initiator's time, so the flush work itself scales out.
                contributors: dict[int, _Worker] = {}
                for plist in router.pending:
                    for join, _, _ in plist:
                        contributors.setdefault(id(join.worker), join.worker)
                initiator = pick_initiator(contributors.values())
                if next_event_t is not None and next_event_t <= initiator.t:
                    # completions already due run before the stall flush —
                    # the same apply-first rule as the shared branch (the
                    # overlap refinement is a shared-rendezvous feature; the
                    # sharded plane always drains first)
                    apply_due_events(initiator.t)
                    continue
                flush_sharded(initiator)
                run_worker_action(initiator)
                drain_pool_resumes(initiator.t)
            elif events:
                t0 = events[0][0]
                apply_due_events(t0)  # busy-poll: jump to next completion
            else:
                break

        stats.makespan_s = max((w.t for w in workers), default=0.0)
        if router is not None:
            # every shard's final flush feeds a join some worker resumed at
            # or after it, so this max is the worker max already — kept
            # explicit so the invariant cannot silently rot
            stats.makespan_s = max([stats.makespan_s, *router.shard_t])
        if verify is not None:
            verify.at_end()
        if hbm_c0 is not None:
            c1 = self.hbm.counters()
            stats.hbm_hits = c1["hits"] - hbm_c0["hits"]
            stats.hbm_misses = c1["misses"] - hbm_c0["misses"]
            stats.hbm_scatters = c1["scatters"] - hbm_c0["scatters"]
            stats.hbm_evictions = c1["evictions"] - hbm_c0["evictions"]
        return results, stats


def run_workload(
    make_coroutine: Callable[[int, np.ndarray], object],
    queries: np.ndarray,
    store,
    cost: CostModel | None = None,
    ssd: SSD | None = None,
    n_workers: int = 1,
    batch_size: int = 8,
    page_size: int = 4096,
    dist=None,
    qb=None,
    fuse: bool = False,
    fuse_rows: int = 256,
    shared_rendezvous: bool = False,
    overlap_flush: bool = False,
    scheduler: str = "rr",
    hbm=None,
    schedule=None,
    verify=None,
    shards=None,
    sla=None,
) -> tuple[list, WorkloadStats]:
    """Convenience wrapper: build an engine, run all queries, return results+stats."""
    engine = Engine(
        store=store,
        ssd=ssd or SSD(),
        cost=cost or CostModel(),
        config=EngineConfig(
            n_workers=n_workers, batch_size=batch_size, page_size=page_size,
            fuse=fuse, fuse_rows=fuse_rows, shared_rendezvous=shared_rendezvous,
            overlap_flush=overlap_flush, scheduler=scheduler,
        ),
        dist=dist,
        qb=qb,
        hbm=hbm,
        schedule=schedule,
        verify=verify,
        shards=shards,
    )
    return engine.run(make_coroutine, queries, sla=sla)
