"""Mixed-traffic workload generators for the multi-tenant serving plane.

A production serving system never sees one index's queries in isolation: N
tenants share the engine and the buffer pool, and WHICH tenant each arriving
query belongs to is itself a distribution.  Cache policy under mixed/skewed
traffic is where disk-resident systems win or lose (the I/O design-space
literature's recurring result), so the arrival mix is modeled explicitly:

  * ``uniform_mix``  — arrivals spread evenly across tenants (round-robin-ish
    random; the fair-share baseline);
  * ``zipfian_mix``  — tenant popularity follows a Zipf law: one hot tenant
    dominates the stream (the skew regime where a shared pool should beat a
    static partition);
  * ``bursty_mix``   — arrivals come in bursts: a geometric run length keeps
    each tenant's queries temporally clustered (locality a clock cache can
    exploit, and the worst case for a static partition's idle shards).

Every generator returns a ``MixedWorkload`` — parallel arrays of (tenant id,
per-tenant query index) in arrival order.  Query indices are assigned
*sequentially per tenant* (each arrival consumes the tenant's next unused
query, wrapping around its query set): tenant t's queries are processed in
exactly the order an isolated single-tenant run would process them, which is
what makes the serving plane's isolation-contract parity tests possible.

Generators are pure functions of their seed — the same workload replays
bit-identically across runs and processes.
"""

from __future__ import annotations

import dataclasses

import numpy as np


def _zipf_probs(n_tenants: int, s: float) -> np.ndarray:
    """Tenant-popularity law shared by the skewed generators: rank^-s,
    normalized (rank 1 — tenant 0 — is the hot tenant)."""
    ranks = np.arange(1, n_tenants + 1, dtype=np.float64)
    probs = ranks ** (-s)
    return probs / probs.sum()


@dataclasses.dataclass(frozen=True)
class MixedWorkload:
    """A multi-tenant arrival sequence: per-arrival tenant + query index."""

    name: str
    tenant_ids: np.ndarray   # (m,) int64 — tenant of each arriving query
    query_ids: np.ndarray    # (m,) int64 — index into that tenant's query set
    # True tenant count, carried from the generator.  Deriving it from
    # ``tenant_ids.max()+1`` silently drops cold tenants that drew zero
    # arrivals (heavy zipf s, short streams) and skews per-tenant accounting.
    n_tenants: int = 0
    # Absolute arrival time of each query in simulated seconds (None == the
    # open-loop batch regime: everything arrives at t=0, latency == queue
    # wait + service).  Generators attach these when given a ``qps`` rate;
    # the serving plane threads them into per-query deadlines (SlaPlan).
    arrival_s: np.ndarray | None = None

    def __post_init__(self):
        assert self.tenant_ids.shape == self.query_ids.shape
        if self.arrival_s is not None:
            object.__setattr__(
                self, "arrival_s",
                np.asarray(self.arrival_s, dtype=np.float64),
            )
            assert self.arrival_s.shape == self.tenant_ids.shape
        if self.n_tenants == 0 and len(self):
            # Back-compat for hand-built workloads: fall back to the observed
            # maximum (the old, lossy derivation) only when no count is given.
            object.__setattr__(
                self, "n_tenants", int(self.tenant_ids.max()) + 1
            )
        if len(self):
            assert int(self.tenant_ids.max()) < self.n_tenants

    def __len__(self) -> int:
        return int(self.tenant_ids.shape[0])

    def counts(self) -> np.ndarray:
        """Arrivals per tenant."""
        return np.bincount(self.tenant_ids, minlength=self.n_tenants)

    def positions(self, tenant: int) -> np.ndarray:
        """Global arrival positions of one tenant's queries, in order."""
        return np.flatnonzero(self.tenant_ids == tenant)

    def run_lengths(self) -> list[int]:
        """Lengths of the maximal same-tenant runs (burstiness diagnostic)."""
        if not len(self):
            return []
        change = np.flatnonzero(np.diff(self.tenant_ids) != 0)
        edges = np.concatenate([[-1], change, [len(self) - 1]])
        return list(np.diff(edges))


def _poisson_arrivals(rng, n_ops: int, qps: float) -> np.ndarray:
    """Open-arrival Poisson process at rate ``qps``: exponential
    inter-arrival gaps, cumulative absolute times."""
    assert qps > 0
    return np.cumsum(rng.exponential(1.0 / qps, size=n_ops))


def _burst_arrivals(rng, tenants: np.ndarray, qps: float) -> np.ndarray:
    """Burst-clustered arrivals matching the tenant runs: every query of a
    same-tenant run arrives AT the run's start instant (the worst case for
    queue wait — and a source of genuinely equal deadlines the schedule
    explorer can permute); run starts are spaced exponentially so the
    long-run rate is still ``qps``."""
    assert qps > 0
    n = len(tenants)
    out = np.empty(n, dtype=np.float64)
    if n == 0:
        return out
    change = np.flatnonzero(np.diff(tenants) != 0)
    starts = np.concatenate([[0], change + 1])
    lengths = np.diff(np.concatenate([starts, [n]]))
    t = 0.0
    for s0, ln in zip(starts, lengths):
        t += rng.exponential(ln / qps)
        out[s0 : s0 + ln] = t
    return out


def _sequential_query_ids(
    tenant_ids: np.ndarray, queries_per_tenant
) -> np.ndarray:
    """Each arrival consumes its tenant's next query, wrapping at the end of
    the tenant's query set — per-tenant order matches an isolated run."""
    queries_per_tenant = np.asarray(queries_per_tenant, dtype=np.int64)
    next_q = np.zeros(queries_per_tenant.shape[0], dtype=np.int64)
    out = np.empty(len(tenant_ids), dtype=np.int64)
    for i, t in enumerate(tenant_ids):
        out[i] = next_q[t] % queries_per_tenant[t]
        next_q[t] += 1
    return out


def uniform_mix(
    queries_per_tenant, n_ops: int, seed: int = 0, qps: float | None = None
) -> MixedWorkload:
    """Arrivals drawn uniformly across tenants.  ``qps`` attaches Poisson
    arrival times at that rate (drawn AFTER the tenant stream, so the
    tenant/query sequence is bit-identical with or without it)."""
    queries_per_tenant = np.asarray(queries_per_tenant, dtype=np.int64)
    rng = np.random.default_rng(seed)
    tenants = rng.integers(0, queries_per_tenant.shape[0], size=n_ops)
    tenants = tenants.astype(np.int64)
    return MixedWorkload(
        name="uniform",
        tenant_ids=tenants,
        query_ids=_sequential_query_ids(tenants, queries_per_tenant),
        n_tenants=int(queries_per_tenant.shape[0]),
        arrival_s=None if qps is None else _poisson_arrivals(rng, n_ops, qps),
    )


def zipfian_mix(
    queries_per_tenant, n_ops: int, s: float = 1.2, seed: int = 0,
    qps: float | None = None,
) -> MixedWorkload:
    """Tenant popularity ~ rank^-s: tenant 0 is the hot tenant.

    ``s`` is the Zipf exponent; at s=1.2 and 4 tenants the hot tenant takes
    roughly half the traffic — the skew regime the shared-pool-vs-static-
    partition comparison targets."""
    queries_per_tenant = np.asarray(queries_per_tenant, dtype=np.int64)
    n_tenants = queries_per_tenant.shape[0]
    rng = np.random.default_rng(seed)
    probs = _zipf_probs(n_tenants, s)
    tenants = rng.choice(n_tenants, size=n_ops, p=probs).astype(np.int64)
    return MixedWorkload(
        name=f"zipf(s={s:g})",
        tenant_ids=tenants,
        query_ids=_sequential_query_ids(tenants, queries_per_tenant),
        n_tenants=n_tenants,
        arrival_s=None if qps is None else _poisson_arrivals(rng, n_ops, qps),
    )


def bursty_mix(
    queries_per_tenant, n_ops: int, mean_burst: float = 8.0,
    s: float = 0.0, seed: int = 0, qps: float | None = None,
) -> MixedWorkload:
    """Bursty arrivals: pick a tenant (uniform, or Zipf-s when ``s > 0``),
    emit a geometric-length run of its queries, repeat.  Mean run length is
    ``mean_burst``.  ``qps`` attaches burst-clustered arrival times: a whole
    run lands at one instant, runs spaced so the long-run rate is ``qps``."""
    queries_per_tenant = np.asarray(queries_per_tenant, dtype=np.int64)
    n_tenants = queries_per_tenant.shape[0]
    assert mean_burst >= 1.0
    rng = np.random.default_rng(seed)
    if s > 0:
        probs = _zipf_probs(n_tenants, s)
    else:
        probs = np.full(n_tenants, 1.0 / n_tenants)
    tenants = np.empty(n_ops, dtype=np.int64)
    i = 0
    while i < n_ops:
        t = int(rng.choice(n_tenants, p=probs))
        run = min(int(rng.geometric(1.0 / mean_burst)), n_ops - i)
        tenants[i : i + run] = t
        i += run
    return MixedWorkload(
        name=f"bursty(b={mean_burst:g})",
        tenant_ids=tenants,
        query_ids=_sequential_query_ids(tenants, queries_per_tenant),
        n_tenants=n_tenants,
        arrival_s=None if qps is None else _burst_arrivals(rng, tenants, qps),
    )


MIXES = {
    "uniform": uniform_mix,
    "zipfian": zipfian_mix,
    "bursty": bursty_mix,
}
