"""Record-level buffer pool with clock second-chance eviction (paper §3.2, Fig. 5).

Faithful pieces:

  * a slotted pool sized to a fraction of the index ("buffer ratio"), with a
    free list of slots;
  * the *record mapping array*: one hybrid pointer per vertex whose MSB encodes
    residency — MSB=1: remaining bits index a pool slot; MSB=0: remaining bits
    are the page id of the record's disk location.  O(1) vid -> location, no
    hash table, no pointer swizzling (works for graphs, unlike LeanStore).
  * per-slot state machine FREE -> LOCKED -> OCCUPIED <-> MARKED -> FREE driven
    exactly as Fig. 5 (Locked during load; clock hand demotes Occupied to
    Marked; access promotes Marked back; Marked slots under the hand are
    evicted).

Adaptation note (DESIGN.md §2): the paper uses CAS atomics because coroutines
race on slots; our engine is single-threaded per worker and lockstep on device,
so the same state machine is evolved without atomics — transitions and
invariants are identical and are what tests/test_bufferpool.py checks.
"""

from __future__ import annotations

import enum

import numpy as np

RESIDENT_BIT = np.uint64(1) << np.uint64(63)
PTR_MASK = RESIDENT_BIT - np.uint64(1)


class SlotState(enum.IntEnum):
    FREE = 0
    LOCKED = 1
    OCCUPIED = 2
    MARKED = 3


class RecordBufferPool:
    """Caches decoded records at *record* granularity."""

    def __init__(self, n_slots: int, vid_to_page: np.ndarray):
        assert n_slots >= 1
        self.n_slots = n_slots
        self.disk_pages = np.asarray(vid_to_page, dtype=np.int64)  # immutable
        # record mapping array: initially every record is on disk at its page.
        self.record_map = self.disk_pages.astype(np.uint64) & PTR_MASK
        self.state = np.full(n_slots, SlotState.FREE, dtype=np.int8)
        self.slot_vid = np.full(n_slots, -1, dtype=np.int64)
        self.slots: list[object | None] = [None] * n_slots
        self.free_list: list[int] = list(range(n_slots - 1, -1, -1))
        self.hand = 0
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    # ------------------------------------------------------------- residency

    def is_resident(self, vid: int) -> bool:
        return bool(self.record_map[vid] & RESIDENT_BIT)

    def page_of(self, vid: int) -> int:
        """Disk page id from the hybrid pointer (valid when not resident)."""
        assert not self.is_resident(vid)
        return int(self.record_map[vid] & PTR_MASK)

    def _slot_of(self, vid: int) -> int:
        return int(self.record_map[vid] & PTR_MASK)

    # ---------------------------------------------------------------- lookup

    def lookup(self, vid: int) -> object | None:
        """Hit: return record, giving MARKED slots their second chance.
        Miss: return None (caller loads via `admit`)."""
        if self.is_resident(vid):
            slot = self._slot_of(vid)
            if self.state[slot] == SlotState.MARKED:
                self.state[slot] = SlotState.OCCUPIED  # second chance
            self.hits += 1
            return self.slots[slot]
        self.misses += 1
        return None

    def peek_resident(self, vid: int) -> bool:
        """Residency probe without stats side effects (Alg. 2's InMemory()
        test and the prefetcher use this)."""
        return self.is_resident(vid)

    # ----------------------------------------------------------------- admit

    def admit(self, vid: int, record: object) -> int:
        """Load a record into a slot (LOCKED during load, then OCCUPIED).

        Returns the slot index, or -1 when the pool is exhausted — every slot
        LOCKED by an in-flight load (pool smaller than the prefetch window).
        Callers handle -1 by skipping admission: the record is still returned
        to the search, it just isn't cached."""
        if self.is_resident(vid):  # duplicate admit (prefetch + demand): keep first
            return self._slot_of(vid)
        slot = self._acquire_slot()
        if slot < 0:
            return -1
        self.state[slot] = SlotState.LOCKED
        self.slot_vid[slot] = vid
        self.slots[slot] = record
        self.record_map[vid] = RESIDENT_BIT | np.uint64(slot)
        self.state[slot] = SlotState.OCCUPIED
        return slot

    def _acquire_slot(self) -> int:
        if self.free_list:
            return self.free_list.pop()
        if not self.run_clock(target=1):
            return -1  # every slot LOCKED: nothing is evictable right now
        return self.free_list.pop()

    # ----------------------------------------------------------------- clock

    def run_clock(self, target: int = 1) -> int:
        """Clock second-chance sweep (the paper's 'eviction coroutine').

        OCCUPIED -> MARKED and advance; MARKED under the hand -> evict.
        LOCKED is skipped.  Returns the number of slots freed.
        """
        freed = 0
        steps = 0
        # up to three full sweeps: one to demote OCCUPIED to MARKED, one to
        # evict, plus slack for LOCKED slots skipped mid-sweep.  If nothing
        # freed by then, every slot is LOCKED and the caller must cope.
        max_steps = 3 * self.n_slots
        while freed < target and steps < max_steps:
            s = self.hand
            self.hand = (self.hand + 1) % self.n_slots
            steps += 1
            st = self.state[s]
            if st == SlotState.OCCUPIED:
                self.state[s] = SlotState.MARKED
            elif st == SlotState.MARKED:
                self._evict_slot(s)
                freed += 1
        return freed

    def _evict_slot(self, slot: int) -> None:
        vid = int(self.slot_vid[slot])
        assert vid >= 0
        # restore the on-disk pointer (a record's page id never changes)
        self.record_map[vid] = np.uint64(self.disk_pages[vid])
        self.slot_vid[slot] = -1
        self.slots[slot] = None
        self.state[slot] = SlotState.FREE
        self.free_list.append(slot)
        self.evictions += 1

    # ----------------------------------------------------------------- stats

    def occupancy(self) -> int:
        return self.n_slots - len(self.free_list)

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0

    def check_invariants(self) -> None:
        """Structural invariants (exercised by hypothesis tests):
        every resident vid's slot points back at it; free slots hold nothing;
        occupancy + free == n_slots."""
        assert len(self.free_list) == (self.state == SlotState.FREE).sum()
        for s in range(self.n_slots):
            st = self.state[s]
            if st == SlotState.FREE:
                assert self.slots[s] is None and self.slot_vid[s] == -1
            else:
                vid = int(self.slot_vid[s])
                assert vid >= 0
                assert self.record_map[vid] == (RESIDENT_BIT | np.uint64(s))
        resident = (self.record_map & RESIDENT_BIT) != 0
        assert int(resident.sum()) == self.occupancy()
