"""Record-level buffer pool with clock second-chance eviction (paper §3.2, Fig. 5).

Faithful pieces:

  * a slotted pool sized to a fraction of the index ("buffer ratio"), with a
    free list of slots;
  * the *record mapping array*: one hybrid pointer per vertex whose MSB encodes
    residency — MSB=1: remaining bits index a pool slot; MSB=0: remaining bits
    are the page id of the record's disk location.  O(1) vid -> location, no
    hash table, no pointer swizzling (works for graphs, unlike LeanStore).
  * per-slot state machine FREE -> LOCKED -> OCCUPIED <-> MARKED -> FREE driven
    exactly as Fig. 5 (Locked during load; clock hand demotes Occupied to
    Marked; access promotes Marked back; Marked slots under the hand are
    evicted).

The LOCKED state is a real *window*, not a transient flag: ``begin_load(vid)``
reserves a slot as LOCKED before the page read is even issued, and
``finish_load(vid, record)`` publishes it OCCUPIED when the I/O completes.
Any searcher that hits the LOCKED slot in between parks itself on the slot's
waiter list (``add_waiter``) instead of issuing a duplicate read — the paper's
record-level load coalescing, complementing the engine's page-level in-flight
dedup.  ``finish_load`` moves the parked waiters onto ``pending_resumes``;
the engine drains that queue and reschedules the coroutines with the freshly
published record.

Group admits (``admit_group``) install a whole batch-decoded co-resident
record group (the ``store.record_matrix`` unit) under ONE clock interaction:
the sweep runs once for the group's whole slot deficit instead of once per
record.  Slots carry the admitting group's id; with ``group_demote=True`` the
clock demotes all still-OCCUPIED members of a group together, so co-placed
groups age (and free whole pages' worth of slots) as a unit.

One pool instance is shared by every worker of a system (`build_system`
creates it once); coroutines on any worker coalesce on the same LOCKED slots.

Multi-tenant quotas (the serving plane, core.serving): when the pool is
shared by several tenants (``tenant_of`` maps each vid to its tenant), a
*soft clock-based quota* can cap the slots any one tenant holds
(``tenant_quota``: a fraction of the pool, or explicit per-tenant caps).  A
tenant at its cap acquires slots by running the second-chance sweep over its
OWN slots only (``quota_reclaims``) — it recycles itself instead of growing
— and an admission that finds nothing of its own evictable is simply skipped
(``quota_denials``: the record is served uncached, never an error).  Under
its cap a tenant uses the free list and the plain global clock, so an idle
tenant's cold slots are naturally lent to busy ones.  Quota off (the
default) is the pure global clock — bit-identical to the single-tenant pool.

Adaptation note (DESIGN.md §2): the paper uses CAS atomics because coroutines
race on slots; our engine is single-threaded per worker and lockstep on device,
so the same state machine is evolved without atomics — transitions and
invariants are identical and are what tests/test_bufferpool.py and the
stateful suite in tests/test_bufferpool_stateful.py check.
"""

from __future__ import annotations

import enum

import numpy as np

RESIDENT_BIT = np.uint64(1) << np.uint64(63)
PTR_MASK = RESIDENT_BIT - np.uint64(1)


class SlotState(enum.IntEnum):
    FREE = 0
    LOCKED = 1
    OCCUPIED = 2
    MARKED = 3


class RecordBufferPool:
    """Caches decoded records at *record* granularity."""

    def __init__(self, n_slots: int, vid_to_page: np.ndarray,
                 group_demote: bool = False, tenant_of: np.ndarray | None = None,
                 tenant_quota: float | list | tuple | np.ndarray | None = None,
                 on_publish=None):
        assert n_slots >= 1
        self.n_slots = n_slots
        # publication hook: called as on_publish(vid, record) whenever a NEW
        # record is actually installed — finish_load publishes, demand admits,
        # and every member of a group admit.  Duplicate admits (keep-first) do
        # not fire it.  The HBM record-cache tier subscribes here: this is the
        # miss-list handoff that stages freshly loaded records for the next
        # double-buffered scatter into device cache slots.
        self.on_publish = on_publish
        self.disk_pages = np.asarray(vid_to_page, dtype=np.int64)  # immutable
        # record mapping array: initially every record is on disk at its page.
        self.record_map = self.disk_pages.astype(np.uint64) & PTR_MASK
        self.state = np.full(n_slots, SlotState.FREE, dtype=np.int8)
        self.slot_vid = np.full(n_slots, -1, dtype=np.int64)
        self.slots: list[object | None] = [None] * n_slots
        self.free_list: list[int] = list(range(n_slots - 1, -1, -1))
        self.hand = 0
        # multi-tenant bookkeeping: who owns each vid / each non-FREE slot,
        # how many slots each tenant holds, and the per-tenant caps (None ==
        # quota off: the accounting still runs, admission never consults it)
        self.tenant_of = (
            None if tenant_of is None else np.asarray(tenant_of, dtype=np.int64)
        )
        self.n_tenants = (
            1 if self.tenant_of is None else int(self.tenant_of.max()) + 1
        )
        self.slot_tenant = np.full(n_slots, -1, dtype=np.int64)
        self.tenant_owned = np.zeros(self.n_tenants, dtype=np.int64)
        self.tenant_hand = np.zeros(self.n_tenants, dtype=np.int64)
        # incremental per-tenant slot index (kept by _claim/_release) so the
        # quota reclaim sweep touches only the tenant's own slots
        self.tenant_slots: list[set[int]] = [set() for _ in range(self.n_tenants)]
        if tenant_quota is None or (np.isscalar(tenant_quota) and not tenant_quota):
            self.tenant_cap = None
        elif np.isscalar(tenant_quota):
            cap = max(1, int(float(tenant_quota) * n_slots))
            self.tenant_cap = np.full(self.n_tenants, cap, dtype=np.int64)
        else:
            self.tenant_cap = np.asarray(tenant_quota, dtype=np.int64)
            assert self.tenant_cap.shape == (self.n_tenants,)
        # group admits: slot -> admitting group id (0 == admitted alone),
        # plus the reverse index so group demotion is O(group), not O(pool)
        self.group_demote = group_demote
        self.slot_group = np.zeros(n_slots, dtype=np.int64)
        self.group_slots: dict[int, list[int]] = {}
        self._next_group = 1
        # LOCKED windows: vid -> waiters parked on the in-flight load, and the
        # (waiter, record) pairs ready for the engine to resume
        self.waiters: dict[int, list[object]] = {}
        self.pending_resumes: list[tuple[object, object | None]] = []
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.lock_waits = 0              # searchers parked on a LOCKED slot
        self.coalesced_record_loads = 0  # waiters served by someone else's load
        self.group_admits = 0            # admit_group calls that admitted >= 1
        self.clock_skips = 0             # sweep steps that landed on LOCKED
        self.quota_reclaims = 0          # over-quota tenants recycling their own
        self.quota_denials = 0           # slot ACQUISITIONS denied at the cap —
                                         # one uncached demand admission can
                                         # contribute two (its LOCKED-window
                                         # reservation and the fallback admit)

    # ------------------------------------------------------------- residency

    def is_resident(self, vid: int) -> bool:
        """The mapping-array MSB: the vid owns a slot (LOCKED *or* published)."""
        return bool(self.record_map[vid] & RESIDENT_BIT)

    def page_of(self, vid: int) -> int:
        """Disk page id from the hybrid pointer (valid when not resident)."""
        assert not self.is_resident(vid)
        return int(self.record_map[vid] & PTR_MASK)

    def _slot_of(self, vid: int) -> int:
        return int(self.record_map[vid] & PTR_MASK)

    def is_loading(self, vid: int) -> bool:
        """True while vid's slot sits in its LOCKED window (load in flight)."""
        return self.is_resident(vid) and self.state[self._slot_of(vid)] == SlotState.LOCKED

    def status(self, vid: int) -> str:
        """'absent' | 'loading' | 'present' (no stats side effects)."""
        if not self.is_resident(vid):
            return "absent"
        if self.state[self._slot_of(vid)] == SlotState.LOCKED:
            return "loading"
        return "present"

    # ---------------------------------------------------------------- lookup

    def lookup(self, vid: int) -> object | None:
        """Hit: return record, giving MARKED slots their second chance.
        Miss: return None (caller loads via `admit`/`begin_load`).  A LOCKED
        slot is a miss too — the record bytes aren't in memory yet; callers
        that can suspend should park on it via the engine's load_wait op."""
        if self.is_resident(vid):
            slot = self._slot_of(vid)
            if self.state[slot] == SlotState.LOCKED:
                self.misses += 1
                return None
            if self.state[slot] == SlotState.MARKED:
                self.state[slot] = SlotState.OCCUPIED  # second chance
            self.hits += 1
            return self.slots[slot]
        self.misses += 1
        return None

    def peek_resident(self, vid: int) -> bool:
        """Slot-ownership probe without stats side effects.  True for LOCKED
        windows too — the prefetcher uses this to avoid re-submitting a load
        that is already in flight."""
        return self.is_resident(vid)

    def peek_present(self, vid: int) -> bool:
        """Alg. 2's InMemory() test: the record can be read without blocking.
        A LOCKED slot is NOT in memory — pivoting to it would stall on the
        in-flight load rather than avoid an I/O wait."""
        return self.is_resident(vid) and self.state[self._slot_of(vid)] != SlotState.LOCKED

    def peek_record(self, vid: int) -> object | None:
        """Published record or None — NO stats, NO second chance.  The engine
        uses this to resolve a load_wait whose window closed before the op was
        scheduled: that access was already counted as a miss when the searcher
        classified it, exactly like a waiter resumed by finish_load."""
        if self.peek_present(vid):
            return self.slots[self._slot_of(vid)]
        return None

    # --------------------------------------------------------------- tenants

    def _tenant(self, vid: int) -> int:
        return 0 if self.tenant_of is None else int(self.tenant_of[vid])

    def _claim(self, slot: int, vid: int) -> None:
        """Slot-ownership bookkeeping on every FREE -> non-FREE transition."""
        t = self._tenant(vid)
        self.slot_tenant[slot] = t
        self.tenant_owned[t] += 1
        self.tenant_slots[t].add(slot)

    def _release(self, slot: int) -> None:
        t = int(self.slot_tenant[slot])
        if t >= 0:
            self.tenant_owned[t] -= 1
            self.tenant_slots[t].discard(slot)
            self.slot_tenant[slot] = -1

    def _reclaim_from_tenant(self, tenant: int) -> bool:
        """Second-chance sweep restricted to ``tenant``'s own slots — the
        over-quota acquisition path.  The sweep iterates ONLY the slots the
        tenant owns (O(own slots), not O(pool)), resuming from a per-tenant
        hand, with the same OCCUPIED -> MARKED -> evict rules as the global
        clock; LOCKED slots are skipped and counted.  Two passes suffice: the
        first demotes (and evicts anything already MARKED), the second evicts
        what the first demoted.  Returns True when one slot was freed."""
        if not self.tenant_slots[tenant]:
            return False
        own = np.asarray(sorted(self.tenant_slots[tenant]), dtype=np.int64)
        start = int(np.searchsorted(own, int(self.tenant_hand[tenant])))
        order = np.roll(own, -start)
        for _sweep in range(2):
            for s in order:
                s = int(s)
                st = self.state[s]
                if st == SlotState.OCCUPIED:
                    self.state[s] = SlotState.MARKED
                    if self.group_demote and self.slot_group[s]:
                        self._demote_group(int(self.slot_group[s]))
                elif st == SlotState.MARKED:
                    self.tenant_hand[tenant] = (s + 1) % self.n_slots
                    self._evict_slot(s)
                    self.quota_reclaims += 1
                    return True
                elif st == SlotState.LOCKED and _sweep == 0:
                    self.clock_skips += 1
        return False  # every owned slot pinned by an in-flight load

    # ---------------------------------------------------- async LOCKED window

    def begin_load(self, vid: int) -> int:
        """Reserve a slot as LOCKED for an in-flight load of vid.

        Called BEFORE the page read is issued, so concurrent searchers observe
        the LOCKED window and coalesce instead of re-reading.  Returns the
        slot, or -1 when no slot can be reserved (every slot LOCKED); if vid
        already owns a slot (racing loader won), returns that slot."""
        if self.is_resident(vid):
            return self._slot_of(vid)
        slot = self._acquire_slot(vid)
        if slot < 0:
            return -1
        self.state[slot] = SlotState.LOCKED
        self.slot_vid[slot] = vid
        self.slots[slot] = None
        self.record_map[vid] = RESIDENT_BIT | np.uint64(slot)
        self._claim(slot, vid)
        return slot

    def finish_load(self, vid: int, record: object) -> int:
        """Publish a LOCKED slot as OCCUPIED and queue its parked waiters for
        resumption with the record.  Idempotent against the duplicate-admit
        race: if another loader already published vid, the FIRST record is
        kept; if the window was aborted meanwhile, this degrades to a plain
        admit.  Returns the slot (or -1 on an exhausted pool)."""
        if not self.is_resident(vid):
            return self.admit(vid, record)
        slot = self._slot_of(vid)
        if self.state[slot] != SlotState.LOCKED:
            return slot  # racing loader published first: keep its record
        self.slots[slot] = record
        self.state[slot] = SlotState.OCCUPIED
        for waiter in self.waiters.pop(vid, ()):
            self.coalesced_record_loads += 1
            self.pending_resumes.append((waiter, record))
        if self.on_publish is not None:
            self.on_publish(vid, record)
        return slot

    def abort_load(self, vid: int) -> None:
        """Tear down a LOCKED window whose load will never complete; parked
        waiters are queued for resumption with None (they re-issue the load)."""
        if not self.is_loading(vid):
            return
        slot = self._slot_of(vid)
        for waiter in self.waiters.pop(vid, ()):
            self.pending_resumes.append((waiter, None))
        self.record_map[vid] = np.uint64(self.disk_pages[vid])
        self.slot_vid[slot] = -1
        self.slots[slot] = None
        self.slot_group[slot] = 0
        self._release(slot)
        self.state[slot] = SlotState.FREE
        self.free_list.append(slot)

    def add_waiter(self, vid: int, waiter: object) -> None:
        """Park a searcher on vid's LOCKED window (engine load_wait op)."""
        assert self.is_loading(vid), "waiters park only on LOCKED slots"
        self.waiters.setdefault(vid, []).append(waiter)
        self.lock_waits += 1

    def take_resumes(self) -> list[tuple[object, object | None]]:
        """Drain the (waiter, record) pairs made runnable by finish/abort."""
        out, self.pending_resumes = self.pending_resumes, []
        return out

    # ----------------------------------------------------------------- admit

    def admit(self, vid: int, record: object) -> int:
        """Load a record into a slot synchronously (no LOCKED window exposed).

        Returns the slot index, or -1 when the pool is exhausted — every slot
        LOCKED by an in-flight load (pool smaller than the prefetch window).
        Callers handle -1 by skipping admission: the record is still returned
        to the search, it just isn't cached.  A demand admit racing an open
        LOCKED window publishes that window (first record kept, waiters
        resumed) — the record-level duplicate-admit rule."""
        if self.is_resident(vid):
            if self.state[self._slot_of(vid)] == SlotState.LOCKED:
                return self.finish_load(vid, record)
            return self._slot_of(vid)  # duplicate admit: keep first
        slot = self._acquire_slot(vid)
        if slot < 0:
            return -1
        self.state[slot] = SlotState.LOCKED
        self.slot_vid[slot] = vid
        self.slots[slot] = record
        self.record_map[vid] = RESIDENT_BIT | np.uint64(slot)
        self._claim(slot, vid)
        self.state[slot] = SlotState.OCCUPIED
        if self.on_publish is not None:
            self.on_publish(vid, record)
        return slot

    def admit_group(self, vids, records) -> int:
        """Admit a batch-decoded co-resident record group under ONE clock
        interaction (one sweep covers the whole slot deficit).  Already-owned
        vids (published or LOCKED by an in-flight load) are skipped — keep
        first.  Partial admission under pressure is fine: the remainder is
        simply not cached.  Returns the number of records admitted."""
        todo: list[tuple[int, object]] = []
        batch_seen: set[int] = set()
        for v, r in zip(vids, records):
            v = int(v)
            # skip resident vids AND in-batch duplicates (keep first) — a
            # duplicate would otherwise allocate two slots for one vid and
            # corrupt the mapping array when the stale one is evicted
            if v in batch_seen or self.is_resident(v):
                continue
            batch_seen.add(v)
            todo.append((v, r))
        if not todo:
            return 0
        # The hand is persistent, so acquiring the group's slots back to back
        # is ONE continued sweep over the whole deficit (the clock is never
        # re-entered from scratch per record), and slot assignment + demote
        # interleaving are bit-identical to what per-record admits would do —
        # group admission adds the shared group id, group demotion, and the
        # single bookkeeping interaction, without perturbing replacement.
        gid = self._next_group
        self._next_group += 1
        # register the member list up front: under extreme pressure a later
        # acquisition can clock-evict an EARLIER member of this very group,
        # and _evict_slot must find it here to keep the reverse index true
        members: list[int] = []
        self.group_slots[gid] = members
        admitted = 0
        for vid, record in todo:
            slot = self._acquire_slot(vid)
            if slot < 0:
                break  # every slot LOCKED: the rest simply isn't cached
            self.state[slot] = SlotState.OCCUPIED
            self.slot_vid[slot] = vid
            self.slots[slot] = record
            self.slot_group[slot] = gid
            self.record_map[vid] = RESIDENT_BIT | np.uint64(slot)
            self._claim(slot, vid)
            members.append(slot)
            # re-link on every install: if the clock just evicted the LAST
            # earlier member, _evict_slot dropped the (then-empty) index
            # entry, and this slot's tag would otherwise dangle
            self.group_slots[gid] = members
            admitted += 1
            if self.on_publish is not None:
                self.on_publish(vid, record)
        if not members:
            # nothing survived (or nothing admitted); _evict_slot may already
            # have dropped the entry when it removed the last member
            self.group_slots.pop(gid, None)
        if admitted:
            self.group_admits += 1
        return admitted

    def _acquire_slot(self, vid: int = -1) -> int:
        if self.tenant_cap is not None and vid >= 0:
            t = self._tenant(vid)
            if self.tenant_owned[t] >= self.tenant_cap[t]:
                # soft quota: a tenant at its cap recycles its OWN slots
                # (tenant-scoped second-chance sweep) instead of growing;
                # nothing of its own evictable -> the admission is skipped
                if not self._reclaim_from_tenant(t):
                    self.quota_denials += 1
                    return -1
                return self.free_list.pop()
        if self.free_list:
            return self.free_list.pop()
        if not self.run_clock(target=1):
            return -1  # every slot LOCKED: nothing is evictable right now
        return self.free_list.pop()

    # ----------------------------------------------------------------- clock

    def run_clock(self, target: int = 1) -> int:
        """Clock second-chance sweep (the paper's 'eviction coroutine').

        OCCUPIED -> MARKED and advance; MARKED under the hand -> evict.
        LOCKED is skipped — each skip is counted in ``clock_skips``, and a
        full revolution that lands ONLY on LOCKED slots terminates the sweep
        immediately (nothing can become evictable while every slot is pinned
        by an in-flight load), instead of silently burning 3 * n_slots steps.
        Returns the number of slots freed.
        """
        freed = 0
        steps = 0
        locked_run = 0  # consecutive steps that landed on LOCKED slots
        # up to three full sweeps: one to demote OCCUPIED to MARKED, one to
        # evict, plus slack for LOCKED slots skipped mid-sweep.  If nothing
        # freed by then, every slot is LOCKED and the caller must cope.
        max_steps = 3 * self.n_slots
        while freed < target and steps < max_steps:
            s = self.hand
            self.hand = (self.hand + 1) % self.n_slots
            steps += 1
            st = self.state[s]
            if st == SlotState.OCCUPIED:
                locked_run = 0
                self.state[s] = SlotState.MARKED
                if self.group_demote and self.slot_group[s]:
                    self._demote_group(int(self.slot_group[s]))
            elif st == SlotState.MARKED:
                locked_run = 0
                self._evict_slot(s)
                freed += 1
            elif st == SlotState.LOCKED:
                self.clock_skips += 1
                locked_run += 1
                if locked_run >= self.n_slots:
                    break  # whole revolution pinned: sweeping is a live-lock
            else:  # FREE under the hand
                locked_run = 0
        return freed

    def _demote_group(self, gid: int) -> None:
        """Demote every still-OCCUPIED member of a group in the same clock
        step, so co-admitted record groups age out together."""
        for s in self.group_slots.get(gid, ()):
            if self.state[s] == SlotState.OCCUPIED:
                self.state[s] = SlotState.MARKED

    def _evict_slot(self, slot: int) -> None:
        vid = int(self.slot_vid[slot])
        assert vid >= 0
        # restore the on-disk pointer (a record's page id never changes)
        self.record_map[vid] = np.uint64(self.disk_pages[vid])
        self.slot_vid[slot] = -1
        self.slots[slot] = None
        gid = int(self.slot_group[slot])
        if gid:
            members = self.group_slots[gid]
            members.remove(slot)
            if not members:
                del self.group_slots[gid]
        self.slot_group[slot] = 0
        self._release(slot)
        self.state[slot] = SlotState.FREE
        self.free_list.append(slot)
        self.evictions += 1

    # ----------------------------------------------------------------- stats

    def occupancy(self) -> int:
        return self.n_slots - len(self.free_list)

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def pressure_stats(self) -> dict[str, int]:
        """The pool-pressure counters WorkloadStats surfaces per run."""
        return {
            "lock_waits": self.lock_waits,
            "coalesced_record_loads": self.coalesced_record_loads,
            "group_admits": self.group_admits,
            "clock_skips": self.clock_skips,
            "quota_reclaims": self.quota_reclaims,
            "quota_denials": self.quota_denials,
        }

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
        self.lock_waits = self.coalesced_record_loads = 0
        self.group_admits = self.clock_skips = 0
        self.quota_reclaims = self.quota_denials = 0

    def check_invariants(self, cheap: bool = False) -> None:
        """Structural invariants (exercised by hypothesis tests and, with
        ``SystemConfig.verify_protocol``, at every engine flush boundary):
        every resident vid's slot points back at it; free slots hold nothing;
        occupancy + free == n_slots; LOCKED slots carry no record yet and are
        the only ones allowed parked waiters; per-tenant quota accounting
        matches actual slot ownership exactly.

        ``cheap=True`` runs only the vectorized subset (free-list/state
        agreement, mapping-array occupancy, quota totals and caps, and the
        waiters-only-on-LOCKED rule) — O(n_slots) numpy plus O(waiters)
        python, no per-slot python loop; this is what the protocol checker
        calls on the hot flush path."""
        assert len(self.free_list) == (self.state == SlotState.FREE).sum(), (
            "free list out of sync with slot states"
        )
        resident = (self.record_map & RESIDENT_BIT) != 0
        assert int(resident.sum()) == self.occupancy(), (
            "mapping-array residency out of sync with pool occupancy"
        )
        # waiter lists may exist ONLY for vids inside an open LOCKED window —
        # a waiter on a published/FREE/MARKED slot is a lost wakeup in the
        # making (nothing will ever queue its resume)
        for vid, ws in self.waiters.items():
            assert ws, "empty waiter lists must be dropped"
            assert self.is_loading(vid), (
                f"waiters parked on vid {vid} whose slot is not LOCKED"
            )
        assert int(self.tenant_owned.sum()) == self.occupancy(), (
            "tenant quota accounting out of sync with occupancy"
        )
        if self.tenant_cap is not None:
            assert (self.tenant_owned <= self.tenant_cap).all(), (
                "tenant holds more slots than its quota cap"
            )
        if cheap:
            return
        owned_recount = np.zeros(self.n_tenants, dtype=np.int64)
        for s in range(self.n_slots):
            st = self.state[s]
            if st == SlotState.FREE:
                assert self.slots[s] is None and self.slot_vid[s] == -1
                assert self.slot_group[s] == 0
                assert self.slot_tenant[s] == -1
            else:
                vid = int(self.slot_vid[s])
                assert vid >= 0
                assert self.record_map[vid] == (RESIDENT_BIT | np.uint64(s))
                assert self.slot_tenant[s] == self._tenant(vid)
                owned_recount[self.slot_tenant[s]] += 1
                if st == SlotState.LOCKED:
                    assert self.slots[s] is None  # record not published yet
        # quota accounting == slot ownership, after every operation
        assert (owned_recount == self.tenant_owned).all(), (
            f"tenant quota recount {owned_recount.tolist()} disagrees with "
            f"tenant_owned {self.tenant_owned.tolist()}"
        )
        for t in range(self.n_tenants):
            assert self.tenant_slots[t] == {
                s for s in range(self.n_slots) if self.slot_tenant[s] == t
            }, f"tenant {t} slot index out of sync"
        if self.tenant_cap is not None:
            assert (self.tenant_owned <= self.tenant_cap).all()
        # the group reverse index and the per-slot tags agree exactly
        for gid, members in self.group_slots.items():
            assert members, "empty group entries must be dropped"
            for s in members:
                assert self.slot_group[s] == gid
        for s in range(self.n_slots):
            g = int(self.slot_group[s])
            if g:
                assert s in self.group_slots.get(g, ())
