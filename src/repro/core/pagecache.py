"""Page-level caches with classic replacement policies (paper Table 1 baselines).

These are what DiskANN-style systems use; the paper shows they track the
buffer ratio almost linearly because ANN page access has no locality for them
to exploit.  Policies: LRU, FIFO, Random (Table 1), plus CLOCK for parity
with the record pool.
"""

from __future__ import annotations

from collections import OrderedDict, deque

import numpy as np


class PageCache:
    def __init__(self, capacity_pages: int, policy: str = "lru", seed: int = 0):
        assert capacity_pages >= 1
        assert policy in ("lru", "fifo", "random", "clock")
        self.capacity = capacity_pages
        self.policy = policy
        self.rng = np.random.default_rng(seed)
        self.pages: OrderedDict[int, bytes] = OrderedDict()
        self.fifo: deque[int] = deque()
        # clock state
        self.ref_bit: dict[int, bool] = {}
        self.clock_ring: list[int] = []
        self.hand = 0
        # stats
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, pid: int) -> bytes | None:
        page = self.pages.get(pid)
        if page is not None:
            self.hits += 1
            if self.policy == "lru":
                self.pages.move_to_end(pid)
            elif self.policy == "clock":
                self.ref_bit[pid] = True
            return page
        self.misses += 1
        return None

    def contains(self, pid: int) -> bool:
        return pid in self.pages

    def admit(self, pid: int, page: bytes) -> None:
        if pid in self.pages:
            return
        while len(self.pages) >= self.capacity:
            self._evict_one()
        self.pages[pid] = page
        if self.policy == "fifo":
            self.fifo.append(pid)
        elif self.policy == "clock":
            self.ref_bit[pid] = False
            self.clock_ring.append(pid)

    def _evict_one(self) -> None:
        self.evictions += 1
        if self.policy == "lru":
            self.pages.popitem(last=False)
        elif self.policy == "fifo":
            while True:
                pid = self.fifo.popleft()
                if pid in self.pages:
                    del self.pages[pid]
                    return
        elif self.policy == "random":
            keys = list(self.pages.keys())
            pid = keys[int(self.rng.integers(0, len(keys)))]
            del self.pages[pid]
        elif self.policy == "clock":
            while True:
                if not self.clock_ring:
                    # fall back: evict arbitrary
                    pid, _ = self.pages.popitem(last=False)
                    self.ref_bit.pop(pid, None)
                    return
                self.hand %= len(self.clock_ring)
                pid = self.clock_ring[self.hand]
                if pid not in self.pages:
                    self.clock_ring.pop(self.hand)
                    self.ref_bit.pop(pid, None)
                    continue
                if self.ref_bit.get(pid, False):
                    self.ref_bit[pid] = False
                    self.hand += 1
                else:
                    self.clock_ring.pop(self.hand)
                    self.ref_bit.pop(pid, None)
                    del self.pages[pid]
                    return

    def hit_rate(self) -> float:
        tot = self.hits + self.misses
        return self.hits / tot if tot else 0.0

    def reset_stats(self) -> None:
        self.hits = self.misses = self.evictions = 0
