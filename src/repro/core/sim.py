"""Discrete-event hardware model: NVMe SSD + CPU cost accounting.

This container has no SSD and one CPU core, so wall-clock cannot be measured.
Instead the *real* algorithms (real index, real buffer pool, real searches)
run to completion and are charged simulated time from this model.  Recall,
I/O counts, and hit rates are therefore exact; only seconds are modeled.

Constants are calibrated to the paper's testbed class (Solidigm NVMe,
Xeon 8457C):
  * 4 KB random read ~80 us end-to-end at low queue depth, ~3 GB/s streaming,
    queue depth 32 per device as io_uring would drive it;
  * one fp32 distance ~1 ns/dim on one core (AVX-512 FMA at realistic IPC);
  * binary (popcount) distance ~0.05 ns/dim; 4-bit dequant distance ~0.5 ns/dim;
  * stackless coroutine switch 50 ns ("less than a last-level cache miss",
    paper §2.3).
"""

from __future__ import annotations

import dataclasses
import heapq
import math


@dataclasses.dataclass
class SSDConfig:
    read_latency_s: float = 80e-6     # fixed cost per random read
    bandwidth_bps: float = 3.0e9      # per-device streaming bandwidth
    queue_depth: int = 32             # concurrent in-flight commands


class SSD:
    """Queue-depth-limited device: a read occupies one of QD channels."""

    def __init__(self, config: SSDConfig | None = None):
        self.config = config or SSDConfig()
        self._channels: list[float] = [0.0] * self.config.queue_depth
        heapq.heapify(self._channels)
        self.reads = 0
        self.bytes_read = 0

    def submit(self, t_now: float, nbytes: int) -> float:
        """Issue one read at time t_now; returns absolute completion time."""
        free_at = heapq.heappop(self._channels)
        start = max(t_now, free_at)
        done = start + self.config.read_latency_s + nbytes / self.config.bandwidth_bps
        heapq.heappush(self._channels, done)
        self.reads += 1
        self.bytes_read += nbytes
        return done

    def reset(self) -> None:
        self._channels = [0.0] * self.config.queue_depth
        heapq.heapify(self._channels)
        self.reads = 0
        self.bytes_read = 0


@dataclasses.dataclass
class CostModel:
    dist_full_per_dim: float = 1.0e-9
    dist_binary_per_dim: float = 0.05e-9
    dist_ext_per_dim: float = 0.5e-9
    visit_overhead_s: float = 2.0e-6     # beam maintenance per explored vertex
    page_parse_s: float = 0.5e-6         # slot binary search / record locate
    record_decode_s: float = 0.4e-6      # adjacency decompress + payload split
    io_submit_s: float = 0.5e-6          # io_uring SQE prep + syscall amortized
    coroutine_switch_s: float = 50e-9
    batch_dispatch_s: float = 0.3e-6     # one kernel/ufunc dispatch per batched
                                         # distance evaluation, amortized over
                                         # all rows of the batch
    table_upload_s: float = 25e-6        # one-time pin of an index's resident
                                         # code tables on the distance engine
                                         # (host->device DMA of ~hundreds of KB
                                         # at PCIe rates), charged per
                                         # registered index, NOT per hop
    full_dispatch_s: float = 0.3e-6      # dispatch of an fp32 refine_full batch
                                         # (BLAS GEMV path) — calibrated apart
                                         # from the int4 refine dispatch; the
                                         # default equals batch_dispatch_s so
                                         # uncalibrated runs are unchanged
    hbm_scatter_s: float = 1e-6          # one double-buffered scatter DMA that
                                         # installs a staged admit group into
                                         # HBM cache slots; overlapped with the
                                         # concurrent fused dispatch, so only
                                         # the non-hidden remainder is charged
    dist_hbm_per_dim: float = 0.05e-9    # 4-bit refinement of a record already
                                         # resident in an HBM cache slot: the
                                         # gather feeds the kernel from device
                                         # memory (no host decode / upload), so
                                         # the per-dim cost drops to near the
                                         # binary-scan rate
    shard_merge_s: float = 2e-6          # one small collective merging the
                                         # per-shard candidate slices of a
                                         # scattered score op into the global
                                         # result (the all_gather + top_k
                                         # idiom of repro.velo.dist_search);
                                         # charged once per multi-shard
                                         # scatter, never when one shard owns
                                         # every row (S=1 parity)
    beam_step_s: float = 0.4e-6          # one fused on-device beam step
                                         # (score + visited mask + top-k merge
                                         # + frontier select in a single
                                         # launch), amortized over every beam
                                         # op in the rendezvous flush group —
                                         # replaces the per-row distance
                                         # download the host path pays
    beam_visit_s: float = 0.5e-6         # residual host bookkeeping per
                                         # explored vertex when the beam lives
                                         # on device (frontier cursor + I/O
                                         # issue only); the insort/merge share
                                         # of visit_overhead_s moved into the
                                         # fused call

    def estimate(self, count: int, dim: int) -> float:
        """Level-1 binary distance estimates for `count` vertices."""
        return count * dim * self.dist_binary_per_dim

    def refine_ext(self, dim: int) -> float:
        """Level-2 4-bit refinement of one record."""
        return dim * self.dist_ext_per_dim

    def refine_full(self, dim: int) -> float:
        """Exact fp32 distance of one record (DiskANN-style refinement)."""
        return dim * self.dist_full_per_dim

    def hbm_refine_ext(self, dim: int) -> float:
        """Level-2 refinement of one record served from an HBM cache slot."""
        return dim * self.dist_hbm_per_dim

    def fused_batch_s(self, total_flop_s: float, kind: str = "quant") -> float:
        """One fused cross-query evaluation: the per-row flops of every
        participating query's rows plus a SINGLE kernel dispatch, amortized
        across the whole rendezvous batch (instead of one dispatch per query).
        ``kind`` selects the dispatch constant: fp32 ``refine_full`` batches
        ("full") launch through a different kernel than the quantized paths,
        and fused beam steps ("beam"/"beam_part") launch the combined
        score+merge+select call (``beam_step_s``)."""
        if kind.startswith("beam"):
            dispatch = self.beam_step_s
        elif kind == "full":
            dispatch = self.full_dispatch_s
        else:
            dispatch = self.batch_dispatch_s
        return dispatch + total_flop_s


@dataclasses.dataclass
class WorkloadStats:
    """Aggregated over a run of the engine."""

    n_queries: int = 0
    makespan_s: float = 0.0
    sum_latency_s: float = 0.0
    latencies: list[float] = dataclasses.field(default_factory=list)
    # query id of each ``latencies`` entry (completion order) — lets a
    # multi-tenant caller split the latency distribution by tenant
    latency_qids: list[int] = dataclasses.field(default_factory=list)
    # latency vs service time: with an SlaPlan attached, ``latencies`` are
    # completion - ARRIVAL (queue wait + service) while ``service_times``
    # keep the old completion - dispatch number; without a plan the two are
    # identical and queue_wait_s stays 0 (bitwise back-compat)
    sum_service_s: float = 0.0
    service_times: list[float] = dataclasses.field(default_factory=list)
    queue_wait_s: float = 0.0        # total seconds queries sat admitted-but-
                                     # undispatched (latency - service)
    # deadline accounting (SlaPlan with deadlines; zeros otherwise)
    deadline_hits: int = 0           # completions at/before their deadline
    deadline_misses: int = 0
    lateness_s: float = 0.0          # total seconds past deadline, misses only
    # charged coroutine switches (dispatches that paid coroutine_switch_s) —
    # the observable the rr/sla switch-accounting parity tests pin: a
    # preempted-then-resumed coroutine is charged exactly one switch under
    # either scheduler, and a flush's switch-free credit is spent exactly once
    coroutine_switches: int = 0
    io_count: int = 0
    io_bytes: int = 0
    coalesced_reads: int = 0   # reads served by an already in-flight page (no SQE)
    cache_hits: int = 0
    cache_misses: int = 0
    # record buffer pool pressure (shared pool, LOCKED-window coalescing)
    lock_waits: int = 0              # coroutines parked on a LOCKED slot
    coalesced_record_loads: int = 0  # parked waiters served by another's load
    group_admits: int = 0            # co-resident groups admitted in one clock
    clock_skips: int = 0             # clock steps that landed on LOCKED slots
    # per-tenant admission quotas (multi-tenant shared pool)
    quota_reclaims: int = 0          # slots an over-quota tenant took from itself
    quota_denials: int = 0           # slot acquisitions denied at the tenant
                                     # cap (nothing of the tenant's own was
                                     # evictable; an uncached demand admission
                                     # can contribute more than one)
    # cross-query fused score dispatch (engine rendezvous buffer)
    score_flushes: int = 0     # fused kernel dispatches issued by the engine
    score_requests: int = 0    # per-coroutine score ops absorbed by those flushes
    score_rows: int = 0        # total distance rows across all flushes
    cross_tenant_flushes: int = 0  # rendezvous flushes whose requests spanned
                                   # more than one tenant (serving plane)
    overlap_flushes: int = 0   # shared-rendezvous flushes issued while another
                               # worker's completions were still in flight
    # sharded scatter-gather serving plane (core.sharding)
    scatter_ops: int = 0       # scatter ops routed to owning shards
    shard_flushes: int = 0     # per-shard rendezvous flushes
    shard_merges: int = 0      # cross-shard top-k merges (multi-shard
                               # scatters only; single-shard scatters pass
                               # the owning shard's results through)
    # fused on-device beam steps (frontier replies instead of raw distances)
    beam_ops: int = 0          # per-coroutine beam ops absorbed by flushes
    beam_flushes: int = 0      # fused beam-step launches (one per beam group
                               # per flush — the ONE exchange per hop)
    beam_rows: int = 0         # fresh vertices scored inside beam steps
    dist_downloads: int = 0    # score/scatter replies that shipped raw
                               # per-row distances back to the host (beam
                               # replies return frontiers and do not count)
    # HBM record-cache tier (device-resident hot records above the host pool)
    hbm_hits: int = 0          # record lookups served from HBM cache slots
    hbm_misses: int = 0        # lookups that fell through to the host pool
    hbm_scatters: int = 0      # double-buffered scatter DMAs installing
                               # staged admit groups into slots
    hbm_evictions: int = 0     # slots reclaimed by the device clock sweep

    @property
    def qps(self) -> float:
        return self.n_queries / self.makespan_s if self.makespan_s > 0 else 0.0

    @property
    def mean_latency_ms(self) -> float:
        return 1e3 * self.sum_latency_s / self.n_queries if self.n_queries else 0.0

    def p99_latency_ms(self) -> float:
        if not self.latencies:
            return 0.0
        xs = sorted(self.latencies)
        # nearest-rank p99: ceil(0.99 n) - 1.  int(0.99 n) is off by one — it
        # returns the maximum (p100) for every run with <= 100 queries.
        rank = min(len(xs) - 1, max(0, math.ceil(0.99 * len(xs)) - 1))
        return 1e3 * xs[rank]

    @property
    def mean_service_ms(self) -> float:
        return 1e3 * self.sum_service_s / self.n_queries if self.n_queries else 0.0

    @property
    def deadline_hit_rate(self) -> float:
        tot = self.deadline_hits + self.deadline_misses
        return self.deadline_hits / tot if tot else 0.0

    @property
    def ios_per_query(self) -> float:
        return self.io_count / self.n_queries if self.n_queries else 0.0

    @property
    def hit_rate(self) -> float:
        tot = self.cache_hits + self.cache_misses
        return self.cache_hits / tot if tot else 0.0

    @property
    def hbm_hit_rate(self) -> float:
        tot = self.hbm_hits + self.hbm_misses
        return self.hbm_hits / tot if tot else 0.0

    @property
    def requests_per_flush(self) -> float:
        """Mean score ops fused per dispatch (1.0 == no cross-query fusion)."""
        return self.score_requests / self.score_flushes if self.score_flushes else 0.0

    @property
    def rows_per_flush(self) -> float:
        return self.score_rows / self.score_flushes if self.score_flushes else 0.0

    @property
    def downloads_per_query(self) -> float:
        """Host<->device exchanges per query that carried raw distances."""
        return self.dist_downloads / self.n_queries if self.n_queries else 0.0
