"""Slotted on-disk page layout for variable-size records (paper §3.3, Fig. 7).

Layout of one PAGE_SIZE-byte page:

    [ header 6B ][ slot array ->  ........  <- data heap ]

  header : Count u16 | HeapStart u16 | HeapUsed u16   (paper says 5B; we use 6
           for alignment — noted as an implementation liberty)
  slot   : VID u32 | Color u8 | Length u16 | StartOffset u16   = 9 bytes,
           sorted by VID for binary-search lookup
  heap   : record payloads, growing backward from the page end

"Two-way growth design achieves dense packing to fully utilize available page
space."  PageBuilder enforces exactly that invariant; fragmentation accounting
feeds benchmarks/bench_fragmentation.py (Fig. 6).
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

PAGE_SIZE = 4096
HEADER_SIZE = 6
SLOT_SIZE = 9

_HDR = struct.Struct("<HHH")
_SLOT = struct.Struct("<IBHH")


@dataclasses.dataclass
class Slot:
    vid: int
    color: int
    length: int
    offset: int


class PageBuilder:
    """Packs variable-size records into one page; slots forward, heap backward."""

    def __init__(self, page_size: int = PAGE_SIZE):
        self.page_size = page_size
        self.entries: list[tuple[int, int, bytes]] = []  # (vid, color, payload)
        self._used = HEADER_SIZE

    def free_bytes(self) -> int:
        return self.page_size - self._used

    def fits(self, payload_len: int) -> bool:
        return self._used + SLOT_SIZE + payload_len <= self.page_size

    def add(self, vid: int, color: int, payload: bytes) -> bool:
        if not self.fits(len(payload)):
            return False
        self.entries.append((vid, color, payload))
        self._used += SLOT_SIZE + len(payload)
        return True

    def count(self) -> int:
        return len(self.entries)

    def finalize(self) -> bytes:
        buf = bytearray(self.page_size)
        entries = sorted(self.entries, key=lambda e: e[0])  # slots sorted by VID
        heap_ptr = self.page_size
        slots: list[Slot] = []
        for vid, color, payload in entries:
            heap_ptr -= len(payload)
            buf[heap_ptr : heap_ptr + len(payload)] = payload
            slots.append(Slot(vid, color, len(payload), heap_ptr))
        _HDR.pack_into(buf, 0, len(slots), heap_ptr, self.page_size - heap_ptr)
        off = HEADER_SIZE
        for s in slots:
            _SLOT.pack_into(buf, off, s.vid, s.color, s.length, s.offset)
            off += SLOT_SIZE
        return bytes(buf)


def page_count(page: bytes) -> int:
    return _HDR.unpack_from(page, 0)[0]


def page_slots(page: bytes) -> list[Slot]:
    count, _, _ = _HDR.unpack_from(page, 0)
    out = []
    off = HEADER_SIZE
    for _ in range(count):
        vid, color, length, offset = _SLOT.unpack_from(page, off)
        out.append(Slot(vid, color, length, offset))
        off += SLOT_SIZE
    return out


def page_lookup(page: bytes, vid: int) -> tuple[Slot, bytes] | None:
    """Binary search on the sorted slot array (paper: 'fast binary-search lookups')."""
    count, _, _ = _HDR.unpack_from(page, 0)
    lo, hi = 0, count - 1
    while lo <= hi:
        mid = (lo + hi) // 2
        off = HEADER_SIZE + mid * SLOT_SIZE
        v, color, length, offset = _SLOT.unpack_from(page, off)
        if v == vid:
            s = Slot(v, color, length, offset)
            return s, page[offset : offset + length]
        if v < vid:
            lo = mid + 1
        else:
            hi = mid - 1
    return None


def page_records(page: bytes) -> list[tuple[Slot, bytes]]:
    return [(s, page[s.offset : s.offset + s.length]) for s in page_slots(page)]


def page_utilization(page: bytes) -> float:
    """Fraction of the page occupied by header+slots+heap (1 - internal frag)."""
    count, heap_start, heap_used = _HDR.unpack_from(page, 0)
    used = HEADER_SIZE + count * SLOT_SIZE + heap_used
    return used / len(page)


def fixed_layout_utilization(record_size: int, page_size: int = PAGE_SIZE) -> float:
    """Utilization of the DiskANN-style fixed-size-record layout (Fig. 6 oracle):
    floor(page/record) records per page, the remainder is internal fragmentation."""
    per_page = page_size // record_size
    if per_page == 0:
        # record spans multiple pages; fragmentation is the tail waste
        pages = (record_size + page_size - 1) // page_size
        return record_size / (pages * page_size)
    return per_page * record_size / page_size
