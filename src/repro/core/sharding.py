"""Sharded scatter-gather serving plane (paper §3.4 at serving scale).

Affinity-based placement co-locates related records so one fetch serves many
hops; at production scale the same principle says the distance work should
execute on the shard that OWNS the data (the near-data argument).  This
module shards one index image across N engine shards and routes each query's
frontier to the owning shards:

  * ``ShardPlan``   — the page->shard / vid->shard assignment (pages are the
    atomic unit: the affinity layout never splits a group across pages, so
    page-granular sharding preserves co-placement — see
    ``placement.shard_pages``);
  * ``ShardScatter`` — the operand of the engine's ``("scatter", ...)`` op: a
    ScoreRequest plus the owning shard of each of its rows.  Coroutines build
    it via ``SearchContext.shard_plan`` (search.py) and never see shards
    otherwise — the algorithm stays orthogonal to the execution model;
  * ``ShardRouter`` — the engine-side runtime: one fresh SSD and one
    rendezvous buffer and one clock PER SHARD.  ``split`` partitions a
    scatter's rows by owning shard (a scatter whose rows all land on one
    shard passes the ORIGINAL request through untouched — the S=1 bitwise
    parity lever); ``ScatterJoin`` reassembles the per-shard result slices in
    row order and completes at ``max`` of the part completions plus one
    ``CostModel.shard_merge_s`` collective when more than one shard
    contributed — the all_gather + top_k merge idiom of
    ``repro.velo.dist_search``, lifted into the coroutine engine (and with
    the same masking discipline: a shard only ever contributes the rows it
    owns, so no sentinel row can win the merge).

The contract that keeps the plane honest (tests/test_sharding.py,
benchmarks/bench_sharded.py): with one shard the sharded engine is BITWISE
identical to the unsharded engine for all five algorithms, and QPS scales
near-linearly in shards at flat recall.  See docs/sharding.md.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import beam as beam_mod
from repro.core import placement as placement_mod
from repro.core.sim import SSD, SSDConfig


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """The static data-placement half of the plane: who owns what."""

    n_shards: int
    page_shard: np.ndarray   # (n_pages,) int32 — owning shard per page
    vid_shard: np.ndarray    # (n,) int32 — owning shard per record

    def shards_of(self, vids) -> np.ndarray:
        """Owning shard of each vid (the scatter's routing vector)."""
        return self.vid_shard[np.asarray(vids, dtype=np.int64)]

    def shard_page_counts(self) -> np.ndarray:
        return np.bincount(
            self.page_shard.astype(np.int64), minlength=self.n_shards
        )


def plan_shards(
    vid_to_page: np.ndarray, n_pages: int, n_shards: int
) -> ShardPlan:
    """Build a plan from a layout's vid->page map: contiguous balanced page
    ranges (``placement.shard_pages``), vid ownership derived per page."""
    page_shard = placement_mod.shard_pages(n_pages, n_shards)
    vid_shard = page_shard[np.asarray(vid_to_page, dtype=np.int64)]
    return ShardPlan(
        n_shards=int(n_shards), page_shard=page_shard, vid_shard=vid_shard
    )


def plan_for_index(index, n_shards: int) -> ShardPlan:
    """Plan for either index family: VeloIndex keeps its map on ``layout``,
    FixedIndex carries ``vid_to_page`` directly."""
    layout = getattr(index, "layout", None)
    v2p = layout.vid_to_page if layout is not None else index.vid_to_page
    return plan_shards(np.asarray(v2p), int(index.store.n_pages), n_shards)


@dataclasses.dataclass
class ShardScatter:
    """Operand of the engine ``("scatter", ...)`` op: one score request plus
    the owning shard of each of its rows (``ShardPlan.shards_of`` of the
    frontier's vids — computed from LOCAL vids, before any serving-plane
    ``vid_base`` shift, so routing is independent of the table namespace)."""

    req: object                # distance.ScoreRequest
    shard_rows: np.ndarray     # (rows,) int32


class ScatterJoin:
    """Gather side of one scatter: collects per-shard result slices and
    reassembles them in row order.  ``remaining`` hits zero when every owning
    shard has dispatched its slice; the join then completes at the max part
    completion time plus one merge collective (multi-shard only)."""

    __slots__ = ("worker", "gen", "qid", "rows", "n_parts", "remaining",
                 "out", "direct", "t_done", "beam_req", "beam_parts")

    def __init__(self, worker, gen, qid, rows: int, n_parts: int,
                 beam_req=None):
        self.worker = worker
        self.gen = gen
        self.qid = qid
        self.rows = rows
        self.n_parts = n_parts
        self.remaining = n_parts
        self.out: np.ndarray | None = None
        self.direct = None       # single-part passthrough result
        self.t_done = 0.0
        # multi-shard beam scatter: the original BeamRequest (state + pending
        # inserts/marks) plus the per-shard local top-L (ids, dists) slices;
        # the engine finalizes via DistanceEngine.beam_finalize at merge time
        self.beam_req = beam_req
        self.beam_parts: list[tuple[np.ndarray, np.ndarray]] = []

    def put(self, ridx, val, t: float) -> bool:
        """Deliver one shard's slice; True when the join completed."""
        if ridx is None:
            self.direct = val    # the untouched original request's results
        elif self.beam_req is not None:
            self.beam_parts.append(val)   # (local ids, dists) of one shard
        else:
            if self.out is None:
                self.out = np.empty(self.rows, dtype=np.asarray(val).dtype)
            self.out[ridx] = val
        self.t_done = max(self.t_done, t)
        self.remaining -= 1
        return self.remaining == 0

    def merge(self):
        return self.direct if self.direct is not None else self.out

    def merge_beam_candidates(self) -> tuple[np.ndarray, np.ndarray]:
        """Global top-L over the union of the per-shard local top-Ls — the
        ``merge_topk`` half of the dist_search idiom.  Exact: every global
        top-L candidate is in its owning shard's local top-L, so the union
        contains the global answer and ranking by the (distance, id) tuple
        reproduces the single-shard step bitwise."""
        L = self.beam_req.state.L
        ids = np.concatenate([i for i, _ in self.beam_parts])
        ds = np.concatenate([d for _, d in self.beam_parts])
        order = np.lexsort((ids, ds))[:L]
        return ids[order], ds[order]


class ShardRouter:
    """Per-run engine-shard runtime: clocks, SSDs, rendezvous buffers.

    Fresh per run (like the engine's SSD): shard clocks start at zero and the
    per-shard devices start idle.  The engine owns all scheduling decisions —
    the router only holds state and the split/join mechanics."""

    def __init__(self, plan: ShardPlan, ssd_config: SSDConfig | None = None):
        self.plan = plan
        n = plan.n_shards
        self.ssds = [SSD(ssd_config) for _ in range(n)]
        self.shard_t = [0.0] * n
        self.pending: list[list] = [[] for _ in range(n)]
        self.pending_rows = [0] * n

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards

    def ssd_for_page(self, pid: int) -> SSD:
        return self.ssds[int(self.plan.page_shard[pid])]

    def has_pending(self) -> bool:
        # test the queues, NOT the row counts: a fused beam step may park
        # with zero fresh rows (pending-inserts-only — e.g. Starling's
        # refined admissions between reads), and the stall flush must still
        # see that join or the scheduler exits with its coroutine parked
        return any(self.pending)

    def split(self, sc: ShardScatter) -> list:
        """Partition a scatter's rows by owning shard: ``[(shard, subrequest,
        row_indices), ...]`` in ascending shard order.  When ONE shard owns
        every row the original request passes through untouched (row_indices
        None) — sub-request results are then bitwise the unsharded results,
        which is what makes the S=1 parity contract hold to the last bit."""
        req = sc.req
        shards = np.asarray(sc.shard_rows)
        if req.rows == 0 or shards.size == 0:
            return [(0, req, None)]
        first = int(shards[0])
        if bool((shards == first).all()):
            return [(first, req, None)]
        if isinstance(req, beam_mod.BeamRequest):
            # multi-shard beam step: each owning shard scores its slice of
            # the fresh frontier on LOCAL ids and returns its local top-L
            # (mask before translation — vid_base applies only at the
            # gather); the join merges and the engine finalizes against the
            # request's resident state
            parts = []
            fresh = np.asarray(req.fresh, dtype=np.int64)
            for s in range(self.plan.n_shards):
                ridx = np.flatnonzero(shards == s)
                if ridx.size == 0:
                    continue
                sub = beam_mod.BeamShardPart(
                    kind=req.kind,
                    pq=req.pq,
                    query=req.query,
                    vectors=(None if req.vectors is None
                             else np.asarray(req.vectors)[ridx]),
                    ids=fresh[ridx],
                    rows=int(ridx.size),
                    flop_s=req.flop_s * (ridx.size / req.rows),
                    L=req.state.L,
                    qb=req.qb,
                    tenant=req.tenant,
                    vid_base=req.vid_base,
                )
                parts.append((s, sub, ridx))
            return parts
        parts = []
        for s in range(self.plan.n_shards):
            ridx = np.flatnonzero(shards == s)
            if ridx.size == 0:
                continue
            payload = req.payload
            if isinstance(payload, tuple):
                # materialized (codes, lo, step) host-gather wire format
                payload = tuple(np.asarray(a)[ridx] for a in payload)
            else:
                payload = np.asarray(payload)[ridx]
            sub = dataclasses.replace(
                req,
                rows=int(ridx.size),
                flop_s=req.flop_s * (ridx.size / req.rows),
                payload=payload,
            )
            parts.append((s, sub, ridx))
        return parts

    def make_join(self, worker, gen, qid, rows: int, n_parts: int,
                  beam_req=None) -> ScatterJoin:
        return ScatterJoin(worker, gen, qid, rows, n_parts, beam_req=beam_req)
