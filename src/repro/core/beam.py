"""Device-resident beam state for the fused beam-step primitive.

PR 1/4/6 moved the distance GEMM on device, but every hop still downloaded
raw per-row distances so the *host* could mask visited vertices, merge the
candidate heap, and pick the next frontier — O(hops x kinds) host<->device
exchanges per query.  This module holds the per-query state and the pure
merge/selection helpers for the fused alternative: one ``("beam", ...)``
engine op per hop whose reply is the *frontier*, not distances.

The actual execution lives in ``repro.core.distance`` (``beam_step_many``
and friends — scalar oracle / vectorized NumPy / single-jitted-Pallas-call
backends); everything here is plain NumPy so coroutines, the sharded merge
path, and the property tests can share one reference implementation.

Ordering contract (mirrors the host ``_Beam``): candidates rank by the
``(distance, vertex_id)`` tuple, ascending.  Internal padding lanes carry
``(+inf, PAD_VID)`` so they sort strictly after every real candidate —
"padding lanes never win" — and are stripped before results reach a
coroutine.  The visited/explored masks are boolean bitmasks over the vertex
id space with one spare slot at index ``n`` that device pad-lanes may write
harmlessly.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# Sorts after every real vertex id at equal distance, and fits int32 so the
# pallas path can keep candidate ids in device-friendly 32-bit lanes.
PAD_VID = np.int64(2**31 - 1)
INF = np.float32(np.inf)


@dataclasses.dataclass
class BeamState:
    """Per-query search state that stays engine-resident across hops.

    ``cand_d``/``cand_v`` are the top-L candidate heap (sorted ascending by
    ``(d, v)``, padded with ``(INF, PAD_VID)``); ``visited``/``explored``
    are boolean masks over ``n + 1`` vertex ids (slot ``n`` is the pad
    sink).  On the pallas backend the same fields hold ``jnp`` device
    arrays; host backends keep NumPy.  ``backend`` records which, so the
    generic fallback paths know when to round-trip.
    """

    L: int
    n: int
    cand_d: np.ndarray
    cand_v: np.ndarray
    visited: np.ndarray
    explored: np.ndarray
    backend: str = "host"

    @classmethod
    def new(cls, L: int, n: int) -> "BeamState":
        return cls(
            L=int(L), n=int(n),
            cand_d=np.full(L, INF, dtype=np.float32),
            cand_v=np.full(L, PAD_VID, dtype=np.int64),
            visited=np.zeros(n + 1, dtype=bool),
            explored=np.zeros(n + 1, dtype=bool),
        )


@dataclasses.dataclass
class BeamRequest:
    """One fused beam step: score ``fresh`` (by id for the quantized level-1
    table, or by raw ``vectors`` for the fp32 in-memory path), drop already
    visited ids, fold in host-provided ``insert_ids``/``insert_ds`` (seed
    vertices, Starling's refined admissions), merge into the candidate heap,
    mark ``explored``, and select the next frontier.  ``rows``/``flop_s``
    feed the cost model exactly like ``ScoreRequest``.
    """

    kind: str                       # "estimate" (level-1 codes) | "full" (fp32)
    state: BeamState
    fresh: np.ndarray               # int64 vertex ids to score this hop
    explored: np.ndarray            # int64 ids to mark explored (pending marks)
    insert_ids: np.ndarray          # int64 ids inserted with known distances
    insert_ds: np.ndarray           # float32 distances for insert_ids
    rows: int
    flop_s: float
    pq: object = None               # QuantizedQuery for kind="estimate"
    query: np.ndarray | None = None  # fp32 query for kind="full"
    vectors: np.ndarray | None = None  # fp32 rows for kind="full"
    qb: object = None               # QuantizedBase (upload-charge accounting)
    tenant: int = 0
    topk: int = 0                   # >0: also read back the heap head
    vid_base: int = 0               # local->table id shift (serving plane)


@dataclasses.dataclass
class BeamResult:
    """Host-visible reply to one beam step — the ONE exchange per hop."""

    frontier: np.ndarray            # int64 unexplored window ids, (d, v) asc
    window_len: int                 # real (non-pad) candidates in the heap
    tail: float                     # heap slot L-1 distance (INF if underfull)
    topk_ids: np.ndarray | None = None
    topk_ds: np.ndarray | None = None


@dataclasses.dataclass
class BeamShardPart:
    """Per-shard slice of a multi-shard BeamRequest: score locally, return
    the local top-``L`` (ids, dists) for the engine's global merge — the
    ``dist_search`` mask-local-topk / merge-topk idiom, mask BEFORE any id
    translation.  ``state`` stays with the original request; parts carry
    only what the owning shard needs to score.
    """

    kind: str
    pq: object
    query: np.ndarray | None
    vectors: np.ndarray | None
    ids: np.ndarray                 # local vertex ids owned by this shard
    rows: int
    flop_s: float
    L: int
    qb: object = None
    tenant: int = 0
    vid_base: int = 0


# ---------------------------------------------------------------------------
# Pure helpers — the reference semantics shared by every backend.
# ---------------------------------------------------------------------------


def dedupe_first(ids: np.ndarray) -> np.ndarray:
    """Boolean keep-mask selecting the first occurrence of each id,
    preserving order — the host beam's first-wins insert semantics."""
    ids = np.asarray(ids)
    keep = np.zeros(ids.shape[0], dtype=bool)
    if ids.shape[0]:
        keep[np.unique(ids, return_index=True)[1]] = True
    return keep


def merge_topk(cand_d: np.ndarray, cand_v: np.ndarray,
               new_d: np.ndarray, new_v: np.ndarray,
               L: int) -> tuple[np.ndarray, np.ndarray]:
    """Merge new (distance, id) pairs into a sorted top-``L`` heap.

    ``np.lexsort((v, d))`` == sort by the ``(d, v)`` tuple ascending — the
    exact order the host ``_Beam`` maintains via ``insort`` — and matches
    ``jax.lax.sort(..., num_keys=2)`` on the pallas path lane for lane.
    """
    d = np.concatenate([np.asarray(cand_d, np.float32),
                        np.asarray(new_d, np.float32)])
    v = np.concatenate([np.asarray(cand_v, np.int64),
                        np.asarray(new_v, np.int64)])
    order = np.lexsort((v, d))[:L]
    out_d = np.full(L, INF, dtype=np.float32)
    out_v = np.full(L, PAD_VID, dtype=np.int64)
    out_d[: order.shape[0]] = d[order]
    out_v[: order.shape[0]] = v[order]
    return out_d, out_v


def select_frontier(cand_d: np.ndarray, cand_v: np.ndarray,
                    explored: np.ndarray) -> tuple[np.ndarray, int, float]:
    """Frontier = unexplored heap entries in heap (ascending) order, plus the
    admission-window stats: real candidate count and the slot L-1 tail."""
    cand_v = np.asarray(cand_v, np.int64)
    cand_d = np.asarray(cand_d, np.float32)
    real = cand_v != PAD_VID
    live = real & ~explored[np.minimum(cand_v, explored.shape[0] - 1)]
    frontier = cand_v[live]
    return frontier, int(real.sum()), float(cand_d[-1])


def mask_ids(mask: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Test the boolean bitmask at ``ids`` (host backends)."""
    return mask[np.asarray(ids, np.int64)]


def set_ids(mask: np.ndarray, ids: np.ndarray) -> None:
    mask[np.asarray(ids, np.int64)] = True
