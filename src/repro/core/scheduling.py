"""SLA-aware scheduling plane: arrival times, deadlines, and the feedback
controller that steers the engine online.

The engine's coroutine runtime (core.engine) is cooperative: workers pick
the next ready coroutine and run it until it yields.  *Which* ready
coroutine runs next is the scheduling policy:

  * ``scheduler="rr"`` (the default) is plain FIFO round-robin — bitwise
    identical to the pre-SLA engine for every algorithm and topology (the
    parity contract every test in this repo leans on);
  * ``scheduler="sla"`` picks by deadline slack, EDF-style: each query
    carries an absolute arrival time and an absolute deadline
    (``arrival + sla``), and both query admission and the per-worker ready
    queue choose the earliest-deadline entry first.  Slack ordering at a
    fixed instant is deadline ordering, so the pick key is simply the
    deadline; equal-deadline ties break by submission order (and are a
    genuine scheduling race the explorer permutes — see
    ``analysis.explore.SchedulePolicy.slack_rank``).

Arrival times additionally fix a latency-accounting defect: the engine used
to measure latency from worker *dispatch* (``start_time[qid]``), so queue
wait — the dominant term of tail latency under burst — never reached
``p99_latency_ms``.  With an ``SlaPlan`` attached, ``latencies`` measure
completion minus ARRIVAL; the old dispatch-relative number is kept as
``WorkloadStats.service_times`` / ``service_time_s``.  Without a plan the
engine behaves exactly as before (latency == service time, queue wait 0).

``SlaController`` is the feedback loop (the PR 5 / ROADMAP follow-on):
completions stream into per-tenant sliding windows, and every steering
output is a PURE FUNCTION of the window *content* —

  * per-tenant beam scale: a tenant whose windowed tail latency drifts past
    its SLA gets its candidate-list width L shrunk (cheaper, slightly less
    accurate queries that drain the backlog); a tenant with slack widens
    back up to ``max_scale`` (recovering — or banking — recall);
  * global fuse budget: under system-wide pressure the rendezvous flush
    budget ``fuse_rows`` shrinks (earlier flushes, lower batching latency),
    and relaxes back when the tail recovers;
  * tenant quota: a deadline-missing tenant's soft slot cap on the shared
    buffer pool is raised (more cache -> shorter service times), tenants
    with slack fall back toward their base cap.

Pure-function steering matters for verification: the explorer permutes
equal-time scheduling ties, and a controller whose state depended on the
ORDER of equal-time completions would make ``sla`` runs schedule-variant.
Windows are multisets pruned by time, decisions are computed from sorted
window content, so any permutation of equal-time updates lands in the same
state.  (The controller is still input-adaptive with respect to timing *by
design* — like velo's cache-aware pivot, exploration covers the pure-EDF
scheduler and the feedback loop is exercised by the benchmarks; see
docs/scheduling.md.)
"""

from __future__ import annotations

import dataclasses

import numpy as np

SCHEDULERS = ("rr", "sla")


def sla_seconds(sla_ms, n_tenants: int) -> np.ndarray:
    """Normalize ``SystemConfig.sla_ms`` (scalar or per-tenant sequence of
    milliseconds) into a per-tenant array of SECONDS."""
    if np.isscalar(sla_ms):
        return np.full(n_tenants, float(sla_ms) / 1e3)
    out = np.asarray(sla_ms, dtype=np.float64) / 1e3
    assert out.shape == (n_tenants,), (
        f"sla_ms has {out.shape[0]} entries for {n_tenants} tenants"
    )
    return out


class SlaController:
    """Online feedback from completion latencies to beam width, fuse budget
    and tenant quota.  Every output is a pure function of the per-tenant
    completion windows, so equal-time updates commute (see module doc).

    ``ratio(t)`` is the steering signal: the ``target_quantile`` of
    latency/SLA over tenant t's window (1.0 == the tail exactly meets the
    deadline).  Beam scale is ``clip(ratio ** -damp)`` — a tenant running
    its tail at 2x the SLA searches with a ~0.6x beam until it recovers.
    """

    def __init__(
        self,
        n_tenants: int,
        sla_s: np.ndarray,
        horizon_factor: float = 8.0,
        min_scale: float = 0.7,
        max_scale: float = 1.25,
        damp: float = 0.5,
        target_quantile: float = 0.9,
        min_samples: int = 4,
        min_fuse_rows: int = 32,
        pool=None,
        quota_boost: float = 2.0,
    ):
        assert n_tenants >= 1
        self.n_tenants = int(n_tenants)
        self.sla_s = np.asarray(sla_s, dtype=np.float64)
        assert self.sla_s.shape == (self.n_tenants,)
        self.horizon_s = float(horizon_factor) * float(self.sla_s.max())
        self.min_scale = float(min_scale)
        self.max_scale = float(max_scale)
        self.damp = float(damp)
        self.q = float(target_quantile)
        self.min_samples = int(min_samples)
        self.min_fuse_rows = int(min_fuse_rows)
        # per-tenant completion windows: lists of (t_done, latency/sla)
        self._window: list[list[tuple[float, float]]] = [
            [] for _ in range(self.n_tenants)
        ]
        self._scale = np.ones(self.n_tenants, dtype=np.float64)
        self._global_ratio = 0.0
        self.completions = 0
        self.adjustments = 0          # steering updates that moved a scale
        # optional shared-pool quota steering (serving plane only)
        self._pool = None
        self._base_cap = None
        self.quota_boost = float(quota_boost)
        if pool is not None and getattr(pool, "tenant_cap", None) is not None:
            self._pool = pool
            self._base_cap = pool.tenant_cap.copy()

    # ------------------------------------------------------------- updates

    def on_complete(self, tenant: int, t_done: float, latency_s: float) -> None:
        """Fold one completion into tenant's window and re-derive every
        steering output from window content (order-insensitive for
        equal-``t_done`` updates)."""
        t = int(tenant)
        sla = self.sla_s[t]
        self._window[t].append((float(t_done), float(latency_s) / sla))
        self.completions += 1
        lo = float(t_done) - self.horizon_s
        for win in self._window:
            while win and win[0][0] < lo:
                win.pop(0)
        self._recompute()

    def _ratio(self, t: int) -> float:
        """Windowed tail signal for tenant t: the target quantile of
        latency/SLA (0.0 until the window has ``min_samples`` entries)."""
        win = self._window[t]
        if len(win) < self.min_samples:
            return 0.0
        vals = sorted(r for _, r in win)
        rank = min(len(vals) - 1, int(self.q * len(vals)))
        return vals[rank]

    def _recompute(self) -> None:
        ratios = np.array([self._ratio(t) for t in range(self.n_tenants)])
        new = np.ones(self.n_tenants, dtype=np.float64)
        active = ratios > 0.0
        new[active] = np.clip(
            ratios[active] ** -self.damp, self.min_scale, self.max_scale
        )
        if not np.array_equal(new, self._scale):
            self.adjustments += 1
        self._scale = new
        self._global_ratio = float(ratios.max()) if len(ratios) else 0.0
        if self._pool is not None:
            self._apply_quota(ratios)

    def _apply_quota(self, ratios: np.ndarray) -> None:
        """Raise a deadline-missing tenant's soft slot cap (up to
        ``quota_boost`` x its base cap, clamped to the pool) and relax
        on-target tenants back to base.  Caps never drop below the tenant's
        CURRENT ownership — the pool's quota invariant
        (``tenant_owned <= tenant_cap``) must hold at every flush check."""
        pool = self._pool
        n = min(self.n_tenants, len(self._base_cap))
        for t in range(n):
            boost = float(np.clip(ratios[t], 1.0, self.quota_boost))
            cap = min(int(round(self._base_cap[t] * boost)), pool.n_slots)
            pool.tenant_cap[t] = max(cap, int(pool.tenant_owned[t]))

    # ------------------------------------------------------------- outputs

    def beam_scale(self, tenant: int) -> float:
        return float(self._scale[int(tenant)])

    def params_for(self, tenant: int, params):
        """``SearchParams`` with the candidate-list width L steered by the
        tenant's current beam scale (never below k)."""
        scale = self.beam_scale(tenant)
        if scale == 1.0:
            return params
        L = max(int(params.k), int(round(params.L * scale)))
        if L == params.L:
            return params
        return dataclasses.replace(params, L=L)

    def fuse_rows(self, base_rows: int) -> int:
        """The rendezvous flush budget under the current global tail
        pressure: shrinks proportionally past the deadline, never below
        ``min_fuse_rows`` (or the base, whichever is smaller)."""
        r = self._global_ratio
        if r <= 1.0:
            return base_rows
        floor = min(self.min_fuse_rows, base_rows)
        return max(floor, int(base_rows / r))


@dataclasses.dataclass
class SlaPlan:
    """Per-run arrival/deadline schedule handed to ``Engine.run``.

    ``arrivals`` are absolute seconds on the simulated clock (a query cannot
    be admitted before it arrives; latency is measured FROM here).
    ``deadlines`` are absolute seconds (``arrival + sla``); None disables
    deadline accounting and EDF ordering degenerates to FIFO.  ``tenant_of``
    maps qid -> tenant for the controller (None == single tenant)."""

    arrivals: np.ndarray
    deadlines: np.ndarray | None = None
    tenant_of: np.ndarray | None = None
    controller: SlaController | None = None

    def __post_init__(self):
        self.arrivals = np.asarray(self.arrivals, dtype=np.float64)
        if self.deadlines is not None:
            self.deadlines = np.asarray(self.deadlines, dtype=np.float64)
            assert self.deadlines.shape == self.arrivals.shape

    @classmethod
    def build(
        cls,
        n_queries: int,
        arrivals=None,
        sla_ms=None,
        tenant_of=None,
        n_tenants=None,
        controller=None,
    ) -> "SlaPlan":
        """Assemble a plan from workload pieces: missing arrivals mean an
        open-loop batch (everything arrives at t=0 and latency == queue
        wait + service); ``sla_ms`` (scalar or per-tenant) sets deadlines.
        ``n_tenants`` carries the TRUE tenant count — deriving it from the
        observed max drops cold tenants, the exact bug workload.n_tenants
        exists to prevent."""
        arr = (
            np.zeros(n_queries, dtype=np.float64)
            if arrivals is None else np.asarray(arrivals, dtype=np.float64)
        )
        assert arr.shape == (n_queries,)
        deadlines = None
        if sla_ms is not None:
            tof = (
                np.zeros(n_queries, dtype=np.int64)
                if tenant_of is None
                else np.asarray(tenant_of, dtype=np.int64)
            )
            if n_tenants is None:
                n_tenants = int(tof.max()) + 1 if n_queries else 1
            deadlines = arr + sla_seconds(sla_ms, n_tenants)[tof]
        return cls(
            arrivals=arr,
            deadlines=deadlines,
            tenant_of=(
                None if tenant_of is None
                else np.asarray(tenant_of, dtype=np.int64)
            ),
            controller=controller,
        )

    def deadline(self, qid: int) -> float:
        if self.deadlines is None:
            return float("inf")
        return float(self.deadlines[qid])

    def on_complete(self, qid: int, t_done: float, latency_s: float) -> None:
        if self.controller is None:
            return
        tenant = 0 if self.tenant_of is None else int(self.tenant_of[qid])
        self.controller.on_complete(tenant, t_done, latency_s)
