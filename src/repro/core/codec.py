"""Adjacency-list compression: delta-varint and partitioned Elias-Fano (paper §3.3).

The paper: "Adjacency lists are sorted and integer-compressed (e.g., delta
encoding or Partitioned Elias-Fano [38]) to reduce space consumption."

Both codecs operate on a sorted list of distinct uint32 vertex ids and are
exact (lossless); hypothesis round-trip tests live in tests/test_codec.py.
"""

from __future__ import annotations

import struct

import numpy as np

# --------------------------------------------------------------------------- varint


def _write_uvarint(out: bytearray, x: int) -> None:
    while True:
        b = x & 0x7F
        x >>= 7
        if x:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def _read_uvarint(buf: bytes, pos: int) -> tuple[int, int]:
    x = 0
    shift = 0
    while True:
        b = buf[pos]
        pos += 1
        x |= (b & 0x7F) << shift
        if not (b & 0x80):
            return x, pos
        shift += 7


def delta_encode(ids: np.ndarray) -> bytes:
    """Sorted distinct uint32 ids -> delta-gap varint bytes."""
    ids = np.asarray(ids, dtype=np.uint64)
    out = bytearray()
    _write_uvarint(out, len(ids))
    prev = -1
    for v in ids.tolist():
        gap = int(v) - prev - 1
        assert gap >= 0, "ids must be sorted and distinct"
        _write_uvarint(out, gap)
        prev = int(v)
    return bytes(out)


def delta_decode(buf: bytes) -> np.ndarray:
    m, pos = _read_uvarint(buf, 0)
    out = np.empty(m, dtype=np.uint32)
    prev = -1
    for i in range(m):
        gap, pos = _read_uvarint(buf, pos)
        prev = prev + 1 + gap
        out[i] = prev
    return out


# --------------------------------------------------------- partitioned Elias-Fano

_BLOCK = 64  # values per partition


def _ef_encode_block(vals: list[int], lo_base: int, universe: int) -> bytes:
    """Classic Elias-Fano over one block, relative to lo_base."""
    m = len(vals)
    assert m > 0
    u = max(universe - lo_base, 1)
    rel = [v - lo_base for v in vals]
    # number of low bits
    l = max(0, int(np.floor(np.log2(u / m))) if u > m else 0)
    low_mask = (1 << l) - 1

    bits = bytearray()
    bit_len = 0

    def push_bits(value: int, width: int) -> None:
        nonlocal bit_len
        for k in range(width):
            if bit_len % 8 == 0:
                bits.append(0)
            if (value >> k) & 1:
                bits[-1] |= 1 << (bit_len % 8)
            bit_len += 1

    # low halves, fixed width l
    for v in rel:
        push_bits(v & low_mask, l)
    # high halves, unary: for i-th value write (high_i - high_{i-1}) zeros then a one
    prev_hi = 0
    for v in rel:
        hi = v >> l
        push_bits(0, hi - prev_hi)
        push_bits(1, 1)
        prev_hi = hi

    header = struct.pack("<BH", l, m)
    return header + bytes(bits)


def _ef_decode_block(buf: bytes, pos: int, lo_base: int) -> tuple[list[int], int]:
    l, m = struct.unpack_from("<BH", buf, pos)
    pos += 3
    bit_pos = 0

    def read_bits(width: int) -> int:
        nonlocal bit_pos
        v = 0
        for k in range(width):
            byte = buf[pos + (bit_pos // 8)]
            if (byte >> (bit_pos % 8)) & 1:
                v |= 1 << k
            bit_pos += 1
        return v

    lows = [read_bits(l) for _ in range(m)]
    highs = []
    hi = 0
    for _ in range(m):
        while True:
            byte = buf[pos + (bit_pos // 8)]
            bit = (byte >> (bit_pos % 8)) & 1
            bit_pos += 1
            if bit:
                break
            hi += 1
        highs.append(hi)
    nbytes = (bit_pos + 7) // 8
    vals = [lo_base + (h << l | lo) for h, lo in zip(highs, lows)]
    return vals, pos + nbytes


def pef_encode(ids: np.ndarray) -> bytes:
    """Partitioned Elias-Fano: split sorted ids into blocks, each EF-coded
    against its own base — adapts to clustered id distributions, which is
    exactly what affinity-aware id assignment produces (paper §3.4 interacts
    with §3.3 here: co-placed records get nearby ids, shrinking gaps)."""
    ids = np.asarray(ids, dtype=np.uint64)
    out = bytearray()
    _write_uvarint(out, len(ids))
    if len(ids) == 0:
        return bytes(out)
    vals = [int(v) for v in ids.tolist()]
    nblocks = (len(vals) + _BLOCK - 1) // _BLOCK
    _write_uvarint(out, nblocks)
    for b in range(nblocks):
        chunk = vals[b * _BLOCK : (b + 1) * _BLOCK]
        lo_base = chunk[0]
        universe = chunk[-1] + 1
        _write_uvarint(out, lo_base)
        _write_uvarint(out, universe - lo_base)
        out += _ef_encode_block(chunk, lo_base, universe)
    return bytes(out)


def pef_decode(buf: bytes) -> np.ndarray:
    m, pos = _read_uvarint(buf, 0)
    if m == 0:
        return np.empty(0, dtype=np.uint32)
    nblocks, pos = _read_uvarint(buf, pos)
    vals: list[int] = []
    for _ in range(nblocks):
        lo_base, pos = _read_uvarint(buf, pos)
        _, pos = _read_uvarint(buf, pos)  # universe span (kept for skippable decode)
        chunk, pos = _ef_decode_block(buf, pos, lo_base)
        vals.extend(chunk)
    assert len(vals) == m
    return np.asarray(vals, dtype=np.uint32)


# ------------------------------------------------------------------- dispatcher

CODECS = {
    "delta": (delta_encode, delta_decode),
    "pef": (pef_encode, pef_decode),
}


def encode_adjacency(ids: np.ndarray, codec: str = "pef") -> bytes:
    ids = np.sort(np.asarray(ids, dtype=np.uint32))
    enc, _ = CODECS[codec]
    return enc(ids)


def decode_adjacency(buf: bytes, codec: str = "pef") -> np.ndarray:
    _, dec = CODECS[codec]
    return dec(buf)
