"""Affinity-based record co-placement + baseline layouts (paper §3.4).

Produces the physical page image of the index:
  * ``layout_affinity``    — VeloANN: affinity groups co-placed with Color tags;
                             pages padded with non-affine records; sets split
                             across page boundaries only as a last resort.
  * ``layout_sequential``  — DiskANN-style: records packed by ascending id.
  * ``layout_block_shuffle`` — Starling-style: BFS-over-graph ordering so that
                             graph-adjacent vertices share pages (the paper
                             argues this pollutes pages vs. affinity grouping).
"""

from __future__ import annotations

import dataclasses
from collections import deque
from collections.abc import Callable

import numpy as np

from repro.core.pages import PAGE_SIZE, PageBuilder


@dataclasses.dataclass
class Layout:
    pages: list[bytes]
    vid_to_page: np.ndarray   # (n,) int32
    colors: np.ndarray        # (n,) uint8 — 0 = non-affine
    page_size: int

    @property
    def n_pages(self) -> int:
        return len(self.pages)

    def disk_bytes(self) -> int:
        return self.n_pages * self.page_size


PayloadFn = Callable[[int], bytes]


def shard_pages(n_pages: int, n_shards: int) -> np.ndarray:
    """Assign pages to engine shards: contiguous balanced ranges.

    Pages are the atomic sharding unit because the affinity layout never
    splits an affinity group across pages except as a last resort (§3.4) — so
    page-granular sharding preserves the co-placement property that one fetch
    serves many hops, now against the shard that owns the data.  Contiguous
    ranges additionally keep affinity-adjacent PAGES (placed back-to-back by
    the greedy fill) on one shard.  ``shard_of[p] = floor(p * S / P)`` gives
    every shard ``P/S`` pages within one of each other, deterministically.
    """
    assert n_shards >= 1
    if n_pages == 0:
        return np.empty(0, dtype=np.int32)
    return (
        (np.arange(n_pages, dtype=np.int64) * n_shards) // n_pages
    ).astype(np.int32)


def _flush(builder: PageBuilder, pages: list[bytes]) -> PageBuilder:
    if builder.count():
        pages.append(builder.finalize())
    return PageBuilder(builder.page_size)


def layout_affinity(
    payload_fn: PayloadFn,
    n: int,
    affinity: dict[int, list[int]],
    page_size: int = PAGE_SIZE,
) -> Layout:
    """Paper §3.4 'Affinity-based Record Co-Placement', faithfully:

    'We co-locate the affine records by iterating over the affinity dictionary
    and placing the sets contiguously on disk. ... each set receives a unique
    nonzero [Color], incremented cyclically; 0 denotes non-affine records.
    Pages are filled greedily. If a set does not fit in the remaining space, we
    first pad the residual space with non-affine records. If none are
    available, we split the set across page boundaries.'
    """
    placed = np.zeros(n, dtype=bool)
    vid_to_page = np.full(n, -1, dtype=np.int32)
    colors = np.zeros(n, dtype=np.uint8)
    pages: list[bytes] = []

    affine_members: set[int] = set()
    for p, group in affinity.items():
        affine_members.add(p)
        affine_members.update(group)
    non_affine = deque(v for v in range(n) if v not in affine_members)

    builder = PageBuilder(page_size)
    color_counter = 0

    def next_color() -> int:
        nonlocal color_counter
        color_counter = color_counter % 255 + 1  # cyclic 1..255
        return color_counter

    def place(vid: int, color: int) -> None:
        nonlocal builder
        payload = payload_fn(vid)
        if not builder.add(vid, color, payload):
            builder = _flush(builder, pages)
            ok = builder.add(vid, color, payload)
            assert ok, f"record {vid} larger than a page"
        placed[vid] = True
        vid_to_page[vid] = len(pages)  # page index once flushed == current count
        colors[vid] = color

    def pad_with_non_affine() -> None:
        """Fill the residual space of the current page with non-affine records."""
        nonlocal builder
        scanned = 0
        while non_affine and scanned < len(non_affine):
            vid = non_affine[0]
            if placed[vid]:
                non_affine.popleft()
                continue
            if builder.fits(len(payload_fn(vid))):
                non_affine.popleft()
                place(vid, 0)
                scanned = 0
            else:
                break

    for p in sorted(affinity.keys()):
        group = [p] + [v for v in affinity[p] if not placed[v] and v != p]
        group = [v for v in group if not placed[v]]
        if not group:
            continue
        group_bytes = sum(len(payload_fn(v)) + 9 for v in group)
        if group_bytes > builder.free_bytes():
            # paper: pad the residual with non-affine records first ...
            pad_with_non_affine()
            # ... and if none are available, SPLIT the set across the page
            # boundary rather than waste the residual (place() below flushes
            # exactly when the next member no longer fits).
        color = next_color() if len(group) > 1 else 0
        for v in group:
            place(v, color)

    # remaining non-affine records (and any affine members never reached)
    for vid in range(n):
        if not placed[vid]:
            place(vid, 0)
    builder = _flush(builder, pages)

    # fix page ids for records placed into the final builder of each flush:
    # place() recorded len(pages) *before* flush, which is correct because
    # flush appends exactly once after the page fills; verify:
    assert vid_to_page.min() >= 0 and vid_to_page.max() < len(pages)
    return Layout(pages=pages, vid_to_page=vid_to_page, colors=colors, page_size=page_size)


def layout_sequential(
    payload_fn: PayloadFn, n: int, page_size: int = PAGE_SIZE
) -> Layout:
    """Pack slotted records by ascending id (no affinity signal)."""
    pages: list[bytes] = []
    vid_to_page = np.full(n, -1, dtype=np.int32)
    colors = np.zeros(n, dtype=np.uint8)
    builder = PageBuilder(page_size)
    for vid in range(n):
        payload = payload_fn(vid)
        if not builder.add(vid, 0, payload):
            builder = _flush(builder, pages)
            assert builder.add(vid, 0, payload)
        vid_to_page[vid] = len(pages)
    builder = _flush(builder, pages)
    return Layout(pages=pages, vid_to_page=vid_to_page, colors=colors, page_size=page_size)


def layout_block_shuffle(
    payload_fn: PayloadFn,
    n: int,
    adjacency: np.ndarray,
    degrees: np.ndarray,
    page_size: int = PAGE_SIZE,
) -> Layout:
    """Starling-style topology-driven ordering: BFS over the proximity graph so
    graph-adjacent vertices land on the same page."""
    order: list[int] = []
    seen = np.zeros(n, dtype=bool)
    for start in range(n):
        if seen[start]:
            continue
        dq = deque([start])
        seen[start] = True
        while dq:
            v = dq.popleft()
            order.append(v)
            for u in adjacency[v, : degrees[v]]:
                u = int(u)
                if u >= 0 and not seen[u]:
                    seen[u] = True
                    dq.append(u)

    pages: list[bytes] = []
    vid_to_page = np.full(n, -1, dtype=np.int32)
    colors = np.zeros(n, dtype=np.uint8)
    builder = PageBuilder(page_size)
    for vid in order:
        payload = payload_fn(vid)
        if not builder.add(vid, 0, payload):
            builder = _flush(builder, pages)
            assert builder.add(vid, 0, payload)
        vid_to_page[vid] = len(pages)
    builder = _flush(builder, pages)
    return Layout(pages=pages, vid_to_page=vid_to_page, colors=colors, page_size=page_size)
